//! Full-precision baselines — the role cuDNN / ARM Compute Library play
//! in the paper's comparison (explicit-GEMM convolution, Section 3.1).
//!
//! Two GEMMs are provided: `gemm_naive` (the textbook triple loop) and
//! `gemm_blocked` (register-tiled, the measured baseline).  The paper
//! notes its own float GEMM is ~2x slower than cuBLAS; `gemm_blocked`
//! plays the same "honest hand-written baseline" role here.

/// Naive (M,D) x (N,D)^T -> (M,N) row-major.
pub fn gemm_naive(a: &[f32], bt: &[f32], m: usize, n: usize, d: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * d);
    assert_eq!(bt.len(), n * d);
    let mut out = vec![0f32; m * n];
    for mi in 0..m {
        for ni in 0..n {
            let mut acc = 0f32;
            for k in 0..d {
                acc += a[mi * d + k] * bt[ni * d + k];
            }
            out[mi * n + ni] = acc;
        }
    }
    out
}

/// Register-tiled GEMM: 4 output columns per inner loop, accumulators in
/// registers, B^T rows streamed (both operands row-major over D).
pub fn gemm_blocked(a: &[f32], bt: &[f32], m: usize, n: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0f32; m * n];
    gemm_blocked_into(a, bt, m, n, d, &mut out);
    out
}

/// Allocation-free blocked GEMM.
///
/// Write coverage: assigns every element of `out` (len M·N) exactly
/// once; prior contents are never read, so a dirty scratch buffer
/// produces the same result as a fresh allocation.
pub fn gemm_blocked_into(
    a: &[f32],
    bt: &[f32],
    m: usize,
    n: usize,
    d: usize,
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * d);
    assert_eq!(bt.len(), n * d);
    assert_eq!(out.len(), m * n);
    let n4 = n / 4 * 4;
    for mi in 0..m {
        let arow = &a[mi * d..(mi + 1) * d];
        let orow = &mut out[mi * n..(mi + 1) * n];
        let mut ni = 0;
        while ni < n4 {
            let b0 = &bt[ni * d..(ni + 1) * d];
            let b1 = &bt[(ni + 1) * d..(ni + 2) * d];
            let b2 = &bt[(ni + 2) * d..(ni + 3) * d];
            let b3 = &bt[(ni + 3) * d..(ni + 4) * d];
            let (mut c0, mut c1, mut c2, mut c3) = (0f32, 0f32, 0f32, 0f32);
            for k in 0..d {
                let av = arow[k];
                c0 += av * b0[k];
                c1 += av * b1[k];
                c2 += av * b2[k];
                c3 += av * b3[k];
            }
            orow[ni] = c0;
            orow[ni + 1] = c1;
            orow[ni + 2] = c2;
            orow[ni + 3] = c3;
            ni += 4;
        }
        while ni < n {
            let brow = &bt[ni * d..(ni + 1) * d];
            let mut acc = 0f32;
            for k in 0..d {
                acc += arow[k] * brow[k];
            }
            orow[ni] = acc;
            ni += 1;
        }
    }
}

/// Full-precision 'same' convolution via explicit im2col + GEMM
/// (the paper's cuDNN algorithm choice).  `x` (H,W,C), `w` (O,K,K,C)
/// flattened row-major -> (H,W,O).
pub fn conv2d_float(
    x: &[f32],
    w: &[f32],
    h: usize,
    wd: usize,
    c: usize,
    o: usize,
    k: usize,
) -> Vec<f32> {
    let cols = super::im2col::im2col_float(x, h, wd, c, k);
    gemm_blocked(&cols, w, h * wd, o, k * k * c)
}

/// In-place ReLU (full-precision network's activation).
pub fn relu(xs: &mut [f32]) {
    for x in xs {
        if *x < 0.0 {
            *x = 0.0;
        }
    }
}

/// Add a per-channel bias to an (HW, O) activation map.
pub fn add_bias(xs: &mut [f32], bias: &[f32]) {
    let o = bias.len();
    for row in xs.chunks_exact_mut(o) {
        for (v, b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, ensure};

    #[test]
    fn blocked_matches_naive() {
        prop::check(48, |g| {
            let m = g.usize_in(1, 24);
            let n = g.usize_in(1, 17); // deliberately exercises the n%4 tail
            let d = g.usize_in(1, 64);
            let a = g.normals(m * d);
            let b = g.normals(n * d);
            let x = gemm_naive(&a, &b, m, n, d);
            let y = gemm_blocked(&a, &b, m, n, d);
            for (u, v) in x.iter().zip(&y) {
                if (u - v).abs() > 1e-3 * (1.0 + u.abs()) {
                    return Err(format!("blocked {v} != naive {u}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn into_matches_alloc_on_dirty_buffer() {
        // gemm_blocked_into's write-coverage contract: a NaN-poisoned
        // reused buffer must come out identical to a fresh allocation
        prop::check(24, |g| {
            let m = g.usize_in(1, 12);
            let n = g.usize_in(1, 9);
            let d = g.usize_in(1, 32);
            let a = g.normals(m * d);
            let b = g.normals(n * d);
            let mut out = vec![f32::NAN; m * n];
            gemm_blocked_into(&a, &b, m, n, d, &mut out);
            let want = gemm_blocked(&a, &b, m, n, d);
            for (i, (u, v)) in want.iter().zip(&out).enumerate() {
                ensure(u == v, format!("into != alloc at {i}: {v} vs {u}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn gemm_identity() {
        // A x I^T = A (I stored row-major as B^T works since I symmetric)
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let i = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(gemm_blocked(&a, &i, 2, 2, 2), a);
    }

    #[test]
    fn conv_matches_direct_convolution() {
        prop::check(24, |g| {
            let h = g.usize_in(1, 7);
            let wd = g.usize_in(1, 7);
            let c = g.usize_in(1, 3);
            let o = g.usize_in(1, 4);
            let k = *g.pick(&[1usize, 3, 5]);
            let r = (k - 1) / 2;
            let x = g.normals(h * wd * c);
            let w = g.normals(o * k * k * c);
            let got = conv2d_float(&x, &w, h, wd, c, o, k);
            // direct sum
            for oy in 0..h {
                for ox in 0..wd {
                    for oc in 0..o {
                        let mut acc = 0f32;
                        for dy in 0..k {
                            for dx in 0..k {
                                let iy = oy as isize + dy as isize - r as isize;
                                let ix = ox as isize + dx as isize - r as isize;
                                if iy < 0 || ix < 0 || iy >= h as isize || ix >= wd as isize {
                                    continue;
                                }
                                for ch in 0..c {
                                    acc += x[(iy as usize * wd + ix as usize) * c + ch]
                                        * w[((oc * k + dy) * k + dx) * c + ch];
                                }
                            }
                        }
                        let v = got[(oy * wd + ox) * o + oc];
                        if (v - acc).abs() > 1e-3 * (1.0 + acc.abs()) {
                            return Err(format!("conv mismatch at ({oy},{ox},{oc}): {v} vs {acc}"));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn relu_clamps() {
        let mut xs = vec![-1.0, 0.0, 2.0];
        relu(&mut xs);
        assert_eq!(xs, vec![0.0, 0.0, 2.0]);
    }

    #[test]
    fn bias_broadcasts_per_channel() {
        let mut xs = vec![1.0, 2.0, 3.0, 4.0]; // 2 rows, O=2
        add_bias(&mut xs, &[10.0, 20.0]);
        assert_eq!(xs, vec![11.0, 22.0, 13.0, 24.0]);
    }

    #[test]
    fn prop_gemm_linearity() {
        // GEMM(a1+a2, b) == GEMM(a1,b) + GEMM(a2,b)
        prop::check(24, |g| {
            let m = g.usize_in(1, 8);
            let n = g.usize_in(1, 8);
            let d = g.usize_in(1, 32);
            let a1 = g.normals(m * d);
            let a2 = g.normals(m * d);
            let b = g.normals(n * d);
            let sum: Vec<f32> = a1.iter().zip(&a2).map(|(x, y)| x + y).collect();
            let lhs = gemm_blocked(&sum, &b, m, n, d);
            let r1 = gemm_blocked(&a1, &b, m, n, d);
            let r2 = gemm_blocked(&a2, &b, m, n, d);
            for i in 0..lhs.len() {
                let want = r1[i] + r2[i];
                ensure(
                    (lhs[i] - want).abs() <= 1e-3 * (1.0 + want.abs()),
                    format!("linearity at {i}"),
                )?;
            }
            Ok(())
        });
    }
}
