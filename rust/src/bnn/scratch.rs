//! `PlanScratch` — the planned per-worker forward arena.
//!
//! The paper's pitch is that binarization "decreases both the
//! computational load and the memory footprint"; the serving translation
//! of that discipline (FINN's reused on-chip buffers, the XNOR-conv GPU
//! work's once-per-stream workspace) is to allocate every intermediate
//! tensor exactly once per worker and reuse it across calls.
//!
//! Up to PR 4 this arena was `ForwardScratch`: **11 hand-named buffer
//! roles** (`xb`, `cols_p`, `counts`, …) sized for exactly the fixed
//! 2-conv/2-fc topology, with the lifetime-disjoint reuse plan audited
//! by hand at every call site.  The layer-graph compiler
//! ([`crate::bnn::graph::plan`]) replaced that: buffer **count** and
//! **assignment** now come from per-edge liveness analysis over the
//! network's own graph, and this type degenerates to what it always
//! really was — three pools of role-less slots, one per storage class
//! (f32 / u32 / i32), indexed by the plan.
//!
//! Correctness contract (unchanged from the hand-named arena, now
//! enforced per planned slot): every kernel either assigns every
//! element of its exact-resized output range or pre-fills the range
//! with its identity before accumulating, so a slot reused across
//! steps, batches of different sizes, or even different *plans* (the
//! backend pool hands arenas to whatever runs next) can never leak
//! state — property-tested in [`crate::bnn::graph::exec`] and below.
//! The slot *assignment* this arena trusts — that no two live edges
//! share a slot and every slot's class matches its edges — is not
//! assumed either: [`crate::bnn::graph::verify_plan`] independently
//! re-proves it from per-step effect signatures before a plan may be
//! published or (in debug builds) bound.
//!
//! By default slot capacity only grows (monotone high-water mark sized
//! by the largest batch seen).  Long-lived serving workers opt into a
//! **decay policy** ([`PlanScratch::with_decay`]): the arena tracks each
//! slot's per-window high-water mark (sampled on every step write, so
//! a slot that peaks at conv1 and shrinks through the tail is never
//! under-read) and every N batches releases capacity the window never
//! touched — a worker that once served a B=64 burst stops pinning that
//! memory once traffic settles back to B=1.  Decay never changes
//! outputs (property-tested).

/// Role-less planned buffers for one in-flight compiled forward.
///
/// Slots are created lazily ([`PlanScratch::ensure`]) so one arena can
/// serve plans of different depths; a plan only ever touches the slot
/// indices its own liveness analysis assigned.
#[derive(Default)]
pub struct PlanScratch {
    f32s: Vec<Vec<f32>>,
    u32s: Vec<Vec<u32>>,
    i32s: Vec<Vec<i32>>,
    /// Decay policy: shrink every `decay_after` batches back to the
    /// window's per-slot high-water marks.  `0` disables decay (the
    /// default — ad-hoc arenas and benches keep pure monotone growth).
    decay_after: usize,
    /// Per-slot peak `len()` observed in the current decay window,
    /// indexed like the slot pools.
    peaks: [Vec<usize>; 3],
    /// Batches completed since the last decay check.
    batches_since_decay: usize,
}

impl PlanScratch {
    /// Decay window used by serving workers
    /// ([`crate::coordinator::backend::EngineBackend`]'s arena pool):
    /// after this many batches, capacity not touched within the window
    /// is released.  Large enough that a transient dip in batch size
    /// doesn't thrash the allocator; small enough that a one-off B=64
    /// burst stops pinning ~megabytes within a second of steady B=1
    /// traffic.
    pub const SERVING_DECAY_BATCHES: usize = 64;

    pub fn new() -> Self {
        Self::default()
    }

    /// An arena with the decay policy enabled: every `decay_after`
    /// batches, each slot's capacity shrinks to the largest size that
    /// slot actually reached within the window.  `0` disables decay.
    pub fn with_decay(decay_after: usize) -> Self {
        Self { decay_after, ..Self::default() }
    }

    /// Grow the slot pools to a plan's `[f32, u32, i32]` counts.  Called
    /// by the executor before every run; a no-op once the arena has seen
    /// the deepest plan it serves.
    pub(crate) fn ensure(&mut self, nbufs: [usize; 3]) {
        if self.f32s.len() < nbufs[0] {
            self.f32s.resize_with(nbufs[0], Vec::new);
        }
        if self.u32s.len() < nbufs[1] {
            self.u32s.resize_with(nbufs[1], Vec::new);
        }
        if self.i32s.len() < nbufs[2] {
            self.i32s.resize_with(nbufs[2], Vec::new);
        }
    }

    // --- slot checkout (the executor's take/put discipline) ------------
    // A step takes its output (and scratch) slot out of the arena, reads
    // its input slot by shared reference, then puts the written slots
    // back.  `put_*` doubles as the decay window's peak sampler: a
    // slot's `len` only changes when a step writes it, so sampling every
    // put observes the true per-batch high-water mark of every slot —
    // including the ones that peak mid-forward and shrink afterwards.

    pub(crate) fn take_f32(&mut self, idx: usize) -> Vec<f32> {
        std::mem::take(&mut self.f32s[idx])
    }

    pub(crate) fn take_u32(&mut self, idx: usize) -> Vec<u32> {
        std::mem::take(&mut self.u32s[idx])
    }

    pub(crate) fn take_i32(&mut self, idx: usize) -> Vec<i32> {
        std::mem::take(&mut self.i32s[idx])
    }

    pub(crate) fn put_f32(&mut self, idx: usize, buf: Vec<f32>) {
        self.note_peak(0, idx, buf.len());
        self.f32s[idx] = buf;
    }

    pub(crate) fn put_u32(&mut self, idx: usize, buf: Vec<u32>) {
        self.note_peak(1, idx, buf.len());
        self.u32s[idx] = buf;
    }

    pub(crate) fn put_i32(&mut self, idx: usize, buf: Vec<i32>) {
        self.note_peak(2, idx, buf.len());
        self.i32s[idx] = buf;
    }

    pub(crate) fn f32_slot(&self, idx: usize) -> &[f32] {
        &self.f32s[idx]
    }

    pub(crate) fn u32_slot(&self, idx: usize) -> &[u32] {
        &self.u32s[idx]
    }

    pub(crate) fn i32_slot(&self, idx: usize) -> &[i32] {
        &self.i32s[idx]
    }

    fn note_peak(&mut self, class: usize, idx: usize, len: usize) {
        if self.decay_after == 0 {
            return;
        }
        let peaks = &mut self.peaks[class];
        if peaks.len() <= idx {
            peaks.resize(idx + 1, 0);
        }
        peaks[idx] = peaks[idx].max(len);
    }

    /// Mark the end of one compiled forward and run the decay policy —
    /// a no-op unless decay is enabled.
    ///
    /// Correctness: decay only ever *releases capacity* — it truncates a
    /// slot to a length every kernel will re-resize and overwrite before
    /// reading, so shrinking can never change results (property-tested
    /// below).  Under steady traffic the window peak equals the held
    /// capacity, so the decay pass is a no-op and the zero-allocation
    /// steady state is preserved; only after load genuinely drops does a
    /// shrink (and one regrow on the next larger batch) happen.
    pub(crate) fn end_batch(&mut self) {
        if self.decay_after == 0 {
            return;
        }
        self.batches_since_decay += 1;
        if self.batches_since_decay < self.decay_after {
            return;
        }
        fn shrink<T>(bufs: &mut [Vec<T>], peaks: &[usize]) {
            for (i, buf) in bufs.iter_mut().enumerate() {
                let peak = peaks.get(i).copied().unwrap_or(0);
                if buf.capacity() > peak {
                    buf.truncate(peak);
                    buf.shrink_to(peak);
                }
            }
        }
        shrink(&mut self.f32s, &self.peaks[0]);
        shrink(&mut self.u32s, &self.peaks[1]);
        shrink(&mut self.i32s, &self.peaks[2]);
        for p in &mut self.peaks {
            p.fill(0);
        }
        self.batches_since_decay = 0;
    }

    /// Total elements currently reserved across all slots — the arena's
    /// high-water mark, for diagnostics and the allocation benches.
    pub fn capacity_elems(&self) -> usize {
        self.f32s.iter().map(Vec::capacity).sum::<usize>()
            + self.u32s.iter().map(Vec::capacity).sum::<usize>()
            + self.i32s.iter().map(Vec::capacity).sum::<usize>()
    }

    /// Slots currently materialized per class, `[f32, u32, i32]`
    /// (diagnostics; grows to the deepest plan served).
    pub fn slot_counts(&self) -> [usize; 3] {
        [self.f32s.len(), self.u32s.len(), self.i32s.len()]
    }

    /// Reserved bytes per slot class, `[f32, u32, i32]` — all three
    /// classes hold 4-byte elements.  Feeds the scratch-pool gauges in
    /// the metrics exposition.
    pub fn class_capacity_bytes(&self) -> [usize; 3] {
        [
            self.f32s.iter().map(Vec::capacity).sum::<usize>() * 4,
            self.u32s.iter().map(Vec::capacity).sum::<usize>() * 4,
            self.i32s.iter().map(Vec::capacity).sum::<usize>() * 4,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::network::tests_support::{
        synth_bcnn_network, synth_float_network, synth_image,
    };
    use crate::bnn::network::{IMG_C, IMG_H, IMG_W};
    use crate::input::binarize::Scheme;
    use crate::util::prop::{self, ensure_eq};

    const IMG: usize = IMG_H * IMG_W * IMG_C;

    fn images(n: usize, seed: u64) -> Vec<f32> {
        let mut xs = Vec::with_capacity(n * IMG);
        for i in 0..n {
            xs.extend(synth_image(seed.wrapping_add(i as u64)));
        }
        xs
    }

    #[test]
    fn reused_arena_is_bit_identical_and_leak_free() {
        // ONE arena reused across every case: random scheme, random
        // batch size (so consecutive calls shrink and grow the slots),
        // compared against (a) a fresh arena and (b) the single-image
        // forward — both must be bit-identical every time.
        let nets: Vec<_> = Scheme::ALL.iter().map(|&s| synth_bcnn_network(s, 77)).collect();
        let mut reused = PlanScratch::new();
        prop::check(12, |g| {
            let net = g.pick(&nets);
            let n = g.usize_in(1, 5);
            let xs = images(n, g.u64());
            let with_reused = net.infer_batch_with(&xs, &mut reused).unwrap();
            let with_fresh = net.infer_batch_with(&xs, &mut PlanScratch::new()).unwrap();
            ensure_eq(with_reused.clone(), with_fresh, "reused arena == fresh arena")?;
            for i in 0..n {
                let (single, _) = net.forward(&xs[i * IMG..(i + 1) * IMG]);
                ensure_eq(with_reused[i], single, "arena batched == single forward")?;
            }
            Ok(())
        });
    }

    #[test]
    fn float_arena_path_bit_identical_and_leak_free() {
        let net = synth_float_network(78);
        let mut reused = PlanScratch::new();
        prop::check(6, |g| {
            let n = g.usize_in(1, 4);
            let xs = images(n, g.u64());
            let with_reused = net.infer_batch_with(&xs, &mut reused).unwrap();
            let with_fresh = net.infer_batch_with(&xs, &mut PlanScratch::new()).unwrap();
            ensure_eq(with_reused.clone(), with_fresh, "float reused == fresh")?;
            for i in 0..n {
                let (single, _) = net.forward(&xs[i * IMG..(i + 1) * IMG]);
                ensure_eq(with_reused[i], single, "float arena batched == single")?;
            }
            Ok(())
        });
    }

    #[test]
    fn shrinking_then_growing_batches_do_not_leak() {
        // explicit worst case for stale-state bugs: big batch warms the
        // high-water mark, then smaller batches run inside dirty slots
        let net = synth_bcnn_network(Scheme::Rgb, 5);
        let mut scratch = PlanScratch::new();
        let mut high_water = 0;
        for (round, &n) in [4usize, 1, 3, 2, 5, 1].iter().enumerate() {
            let xs = images(n, 1000 + round as u64);
            let got = net.infer_batch_with(&xs, &mut scratch).unwrap();
            for i in 0..n {
                let (want, _) = net.forward(&xs[i * IMG..(i + 1) * IMG]);
                assert_eq!(got[i], want, "round {round}, image {i}");
            }
            // capacity is a monotone high-water mark (no realloc churn)
            let cap = scratch.capacity_elems();
            assert!(cap >= high_water, "round {round}: capacity shrank {high_water} -> {cap}");
            high_water = cap;
        }
    }

    #[test]
    fn one_arena_serves_bcnn_and_float_interleaved() {
        // a worker's arena may alternate between plans; nothing may
        // bleed across (different slot assignments, shared pools)
        let bnet = synth_bcnn_network(Scheme::Gray, 9);
        let fnet = synth_float_network(9);
        let mut scratch = PlanScratch::new();
        for round in 0..3u64 {
            let xs = images(2, 2000 + round);
            let b = bnet.infer_batch_with(&xs, &mut scratch).unwrap();
            let f = fnet.infer_batch_with(&xs, &mut scratch).unwrap();
            for i in 0..2 {
                assert_eq!(b[i], bnet.forward(&xs[i * IMG..(i + 1) * IMG]).0);
                assert_eq!(f[i], fnet.forward(&xs[i * IMG..(i + 1) * IMG]).0);
            }
        }
        // the pools grew to the deeper plan's needs, not the union of
        // hand-named roles
        let [nf, nu, ni] = scratch.slot_counts();
        assert!(nf <= 3 && nu <= 2 && ni <= 1, "{:?}", scratch.slot_counts());
    }

    #[test]
    fn decay_never_changes_outputs() {
        // an aggressively-decaying arena (window of 2, so it shrinks
        // constantly while batch sizes jump around) stays bit-identical
        // to a fresh arena, across schemes and the float network
        let nets: Vec<_> = Scheme::ALL.iter().map(|&s| synth_bcnn_network(s, 91)).collect();
        let fnet = synth_float_network(92);
        let mut decaying = PlanScratch::with_decay(2);
        prop::check(16, |g| {
            let n = g.usize_in(1, 6);
            let xs = images(n, g.u64());
            let (with_decay, with_fresh) = if g.usize_in(0, 3) == 0 {
                (
                    fnet.infer_batch_with(&xs, &mut decaying).unwrap(),
                    fnet.infer_batch_with(&xs, &mut PlanScratch::new()).unwrap(),
                )
            } else {
                let net = g.pick(&nets);
                (
                    net.infer_batch_with(&xs, &mut decaying).unwrap(),
                    net.infer_batch_with(&xs, &mut PlanScratch::new()).unwrap(),
                )
            };
            ensure_eq(with_decay, with_fresh, "decaying arena == fresh arena")
        });
    }

    #[test]
    fn decay_releases_capacity_after_burst() {
        // a B=8 burst grows the arena; once a full decay window passes
        // with only B=1 traffic, the burst capacity must be released
        let net = synth_bcnn_network(Scheme::Rgb, 93);
        let mut scratch = PlanScratch::with_decay(4);
        net.infer_batch_with(&images(8, 1), &mut scratch).unwrap();
        let burst_cap = scratch.capacity_elems();
        for round in 0..8u64 {
            net.infer_batch_with(&images(1, 100 + round), &mut scratch).unwrap();
        }
        let settled_cap = scratch.capacity_elems();
        assert!(
            settled_cap < burst_cap,
            "decay never released the burst: {settled_cap} >= {burst_cap}"
        );
        // and the settled arena still answers correctly
        let xs = images(2, 7);
        let got = net.infer_batch_with(&xs, &mut scratch).unwrap();
        for i in 0..2 {
            assert_eq!(got[i], net.forward(&xs[i * IMG..(i + 1) * IMG]).0);
        }
    }

    #[test]
    fn decay_is_noop_under_steady_traffic() {
        // regression (PR 3 code review, re-proved for the planned arena):
        // an end-of-batch-only sample under-reads slots that peak
        // mid-forward (conv1's counts shrink through the tail), making
        // every window reallocate.  Peaks sampled on every slot write
        // must hold capacity exactly steady under constant load.
        let net = synth_bcnn_network(Scheme::Rgb, 95);
        let mut scratch = PlanScratch::with_decay(3);
        for round in 0..7u64 {
            net.infer_batch_with(&images(2, 300 + round), &mut scratch).unwrap();
        }
        let settled = scratch.capacity_elems();
        for round in 0..6u64 {
            net.infer_batch_with(&images(2, 400 + round), &mut scratch).unwrap();
            assert_eq!(
                scratch.capacity_elems(),
                settled,
                "round {round}: decay churned capacity under steady load"
            );
        }
    }

    #[test]
    fn decay_disabled_keeps_monotone_high_water() {
        // PlanScratch::new() keeps the PR 2 contract: capacity never
        // shrinks, no realloc churn for ad-hoc arenas
        let net = synth_bcnn_network(Scheme::Gray, 94);
        let mut scratch = PlanScratch::new();
        net.infer_batch_with(&images(6, 1), &mut scratch).unwrap();
        let high = scratch.capacity_elems();
        for round in 0..6u64 {
            net.infer_batch_with(&images(1, 200 + round), &mut scratch).unwrap();
            assert_eq!(scratch.capacity_elems(), high, "round {round} reallocated");
        }
    }

    #[test]
    fn arena_rejects_ragged_and_accepts_empty() {
        let net = synth_bcnn_network(Scheme::Rgb, 8);
        let mut scratch = PlanScratch::new();
        assert!(net.infer_batch_with(&[0.0; 100], &mut scratch).is_err());
        assert!(net.infer_batch_with(&[], &mut scratch).unwrap().is_empty());
        let fnet = synth_float_network(8);
        assert!(fnet.infer_batch_with(&[0.0; 7], &mut scratch).is_err());
        assert!(fnet.infer_batch_with(&[], &mut scratch).unwrap().is_empty());
    }
}
