//! `ForwardScratch` — the per-worker forward arena.
//!
//! The paper's pitch is that binarization "decreases both the
//! computational load and the memory footprint"; the serving translation
//! of that discipline (FINN's reused on-chip buffers, the XNOR-conv GPU
//! work's once-per-stream workspace) is to allocate every intermediate
//! tensor of `infer_batch` exactly once per worker and reuse it across
//! calls.  `BcnnNetwork::infer_batch_with` / `FloatNetwork::infer_batch_with`
//! thread one of these through the whole pipeline; `EngineBackend` keeps
//! a pool of them (one per concurrent worker) so steady-state inference
//! performs **no intermediate-tensor allocation at all**.
//!
//! Correctness contract: every `_into` kernel either assigns every
//! element of its exact-resized output range (GEMMs, packers, OR-pool,
//! FC) or pre-fills the range with its required identity before
//! accumulating (zero for float/word im2col padding, `NEG_INFINITY` for
//! max-pool) — so a scratch reused across batches of different sizes, or
//! even across different networks and schemes, can never leak state
//! between calls (property-tested below).  Buffer capacity only grows
//! (monotone high-water mark sized by the largest batch seen).

/// Reusable buffers for one in-flight `infer_batch_with` call.
///
/// Buffers are named by role; stages with disjoint lifetimes share one
/// buffer (e.g. `cols_p` carries conv1's packed patch rows, then is
/// overwritten with conv2's word gather once conv1's GEMM has consumed
/// it).  The reuse plan is documented at each use site in `network.rs`.
#[derive(Default)]
pub struct ForwardScratch {
    /// Binarized batch input (packed-conv1 schemes).
    pub(crate) xb: Vec<f32>,
    /// Per-image grayscale scratch (LBP binarization).
    pub(crate) gray: Vec<f32>,
    /// Packed patch rows: conv1 fused im2col+pack, then conv2 word gather.
    pub(crate) cols_p: Vec<u32>,
    /// XNOR-popcount counts: conv1, then conv2, then fc1.
    pub(crate) counts: Vec<i32>,
    /// Threshold-packed activation words: conv1, then conv2.
    pub(crate) words: Vec<u32>,
    /// OR-pooled words: pool1, then pool2.
    pub(crate) pooled: Vec<u32>,
    /// Float patch rows (`Scheme::None` conv1; `FloatNetwork` conv1/conv2).
    pub(crate) cols_f: Vec<f32>,
    /// Float GEMM activations (`Scheme::None` conv1; `FloatNetwork` conv1/conv2).
    pub(crate) act_f: Vec<f32>,
    /// Max-pooled float activations (`FloatNetwork` pool1, then pool2).
    pub(crate) pool_f: Vec<f32>,
    /// FC-tail hidden activations (per image).
    pub(crate) h_a: Vec<f32>,
    pub(crate) h_b: Vec<f32>,
}

impl ForwardScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Total elements currently reserved across all buffers — the arena's
    /// high-water mark, for diagnostics and the allocation bench.
    pub fn capacity_elems(&self) -> usize {
        self.xb.capacity()
            + self.gray.capacity()
            + self.cols_p.capacity()
            + self.counts.capacity()
            + self.words.capacity()
            + self.pooled.capacity()
            + self.cols_f.capacity()
            + self.act_f.capacity()
            + self.pool_f.capacity()
            + self.h_a.capacity()
            + self.h_b.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::network::tests_support::{
        synth_bcnn_network, synth_float_network, synth_image,
    };
    use crate::bnn::network::{IMG_C, IMG_H, IMG_W};
    use crate::input::binarize::Scheme;
    use crate::util::prop::{self, ensure_eq};

    const IMG: usize = IMG_H * IMG_W * IMG_C;

    fn images(n: usize, seed: u64) -> Vec<f32> {
        let mut xs = Vec::with_capacity(n * IMG);
        for i in 0..n {
            xs.extend(synth_image(seed.wrapping_add(i as u64)));
        }
        xs
    }

    #[test]
    fn bcnn_scratch_path_bit_identical_and_leak_free() {
        // ONE scratch reused across every case: random scheme, random
        // batch size (so consecutive calls shrink and grow the buffers),
        // compared against (a) a fresh scratch and (b) the single-image
        // forward — both must be bit-identical every time.
        let nets: Vec<_> = Scheme::ALL.iter().map(|&s| synth_bcnn_network(s, 77)).collect();
        let mut reused = ForwardScratch::new();
        prop::check(12, |g| {
            let net = g.pick(&nets);
            let n = g.usize_in(1, 5);
            let xs = images(n, g.u64());
            let with_reused = net.infer_batch_with(&xs, &mut reused).unwrap();
            let with_fresh = net.infer_batch_with(&xs, &mut ForwardScratch::new()).unwrap();
            ensure_eq(with_reused.clone(), with_fresh, "reused scratch == fresh scratch")?;
            for i in 0..n {
                let (single, _) = net.forward(&xs[i * IMG..(i + 1) * IMG]);
                ensure_eq(with_reused[i], single, "scratch batched == single forward")?;
            }
            Ok(())
        });
    }

    #[test]
    fn float_scratch_path_bit_identical_and_leak_free() {
        let net = synth_float_network(78);
        let mut reused = ForwardScratch::new();
        prop::check(6, |g| {
            let n = g.usize_in(1, 4);
            let xs = images(n, g.u64());
            let with_reused = net.infer_batch_with(&xs, &mut reused).unwrap();
            let with_fresh = net.infer_batch_with(&xs, &mut ForwardScratch::new()).unwrap();
            ensure_eq(with_reused.clone(), with_fresh, "float reused == fresh")?;
            for i in 0..n {
                let (single, _) = net.forward(&xs[i * IMG..(i + 1) * IMG]);
                ensure_eq(with_reused[i], single, "float scratch batched == single")?;
            }
            Ok(())
        });
    }

    #[test]
    fn shrinking_then_growing_batches_do_not_leak() {
        // explicit worst case for stale-state bugs: big batch warms the
        // high-water mark, then smaller batches run inside dirty buffers
        let net = synth_bcnn_network(Scheme::Rgb, 5);
        let mut scratch = ForwardScratch::new();
        let mut high_water = 0;
        for (round, &n) in [4usize, 1, 3, 2, 5, 1].iter().enumerate() {
            let xs = images(n, 1000 + round as u64);
            let got = net.infer_batch_with(&xs, &mut scratch).unwrap();
            for i in 0..n {
                let (want, _) = net.forward(&xs[i * IMG..(i + 1) * IMG]);
                assert_eq!(got[i], want, "round {round}, image {i}");
            }
            // capacity is a monotone high-water mark (no realloc churn)
            let cap = scratch.capacity_elems();
            assert!(cap >= high_water, "round {round}: capacity shrank {high_water} -> {cap}");
            high_water = cap;
        }
    }

    #[test]
    fn one_scratch_serves_bcnn_and_float_interleaved() {
        // a worker's arena may alternate between model kinds; nothing may
        // bleed across (different buffer roles, but shared h_a/h_b etc.)
        let bnet = synth_bcnn_network(Scheme::Gray, 9);
        let fnet = synth_float_network(9);
        let mut scratch = ForwardScratch::new();
        for round in 0..3u64 {
            let xs = images(2, 2000 + round);
            let b = bnet.infer_batch_with(&xs, &mut scratch).unwrap();
            let f = fnet.infer_batch_with(&xs, &mut scratch).unwrap();
            for i in 0..2 {
                assert_eq!(b[i], bnet.forward(&xs[i * IMG..(i + 1) * IMG]).0);
                assert_eq!(f[i], fnet.forward(&xs[i * IMG..(i + 1) * IMG]).0);
            }
        }
    }

    #[test]
    fn scratch_rejects_ragged_and_accepts_empty() {
        let net = synth_bcnn_network(Scheme::Rgb, 8);
        let mut scratch = ForwardScratch::new();
        assert!(net.infer_batch_with(&[0.0; 100], &mut scratch).is_err());
        assert!(net.infer_batch_with(&[], &mut scratch).unwrap().is_empty());
        let fnet = synth_float_network(8);
        assert!(fnet.infer_batch_with(&[0.0; 7], &mut scratch).is_err());
        assert!(fnet.infer_batch_with(&[], &mut scratch).unwrap().is_empty());
    }
}
