//! `ForwardScratch` — the per-worker forward arena.
//!
//! The paper's pitch is that binarization "decreases both the
//! computational load and the memory footprint"; the serving translation
//! of that discipline (FINN's reused on-chip buffers, the XNOR-conv GPU
//! work's once-per-stream workspace) is to allocate every intermediate
//! tensor of `infer_batch` exactly once per worker and reuse it across
//! calls.  `BcnnNetwork::infer_batch_with` / `FloatNetwork::infer_batch_with`
//! thread one of these through the whole pipeline; `EngineBackend` keeps
//! a pool of them (one per concurrent worker) so steady-state inference
//! performs **no intermediate-tensor allocation at all**.
//!
//! Correctness contract: every `_into` kernel either assigns every
//! element of its exact-resized output range (GEMMs, packers, OR-pool,
//! FC) or pre-fills the range with its required identity before
//! accumulating (zero for float/word im2col padding, `NEG_INFINITY` for
//! max-pool) — so a scratch reused across batches of different sizes, or
//! even across different networks and schemes, can never leak state
//! between calls (property-tested below).  By default buffer capacity
//! only grows (monotone high-water mark sized by the largest batch
//! seen); long-lived serving workers opt into a **decay policy**
//! ([`ForwardScratch::with_decay`]) that shrinks the arena back to the
//! high-water mark of the last N batches every N batches, so a worker
//! that once saw B=64 doesn't pin that memory forever once traffic
//! settles back to B=1 (decay never changes outputs — property-tested).

/// Reusable buffers for one in-flight `infer_batch_with` call.
///
/// Buffers are named by role; stages with disjoint lifetimes share one
/// buffer (e.g. `cols_p` carries conv1's packed patch rows, then is
/// overwritten with conv2's word gather once conv1's GEMM has consumed
/// it).  The reuse plan is documented at each use site in `network.rs`.
#[derive(Default)]
pub struct ForwardScratch {
    /// Binarized batch input (packed-conv1 schemes).
    pub(crate) xb: Vec<f32>,
    /// Per-image grayscale scratch (LBP binarization).
    pub(crate) gray: Vec<f32>,
    /// Packed patch rows: conv1 fused im2col+pack, then conv2 word gather.
    pub(crate) cols_p: Vec<u32>,
    /// XNOR-popcount counts: conv1, then conv2, then fc1.
    pub(crate) counts: Vec<i32>,
    /// Threshold-packed activation words: conv1, then conv2.
    pub(crate) words: Vec<u32>,
    /// OR-pooled words: pool1, then pool2.
    pub(crate) pooled: Vec<u32>,
    /// Float patch rows (`Scheme::None` conv1; `FloatNetwork` conv1/conv2).
    pub(crate) cols_f: Vec<f32>,
    /// Float GEMM activations (`Scheme::None` conv1; `FloatNetwork` conv1/conv2).
    pub(crate) act_f: Vec<f32>,
    /// Max-pooled float activations (`FloatNetwork` pool1, then pool2).
    pub(crate) pool_f: Vec<f32>,
    /// FC-tail hidden activations (per image).
    pub(crate) h_a: Vec<f32>,
    pub(crate) h_b: Vec<f32>,
    /// Decay policy: shrink every `decay_after` batches back to the
    /// window's per-buffer high-water marks.  `0` disables decay (the
    /// default — ad-hoc arenas and benches keep the pure monotone
    /// high-water behavior).
    decay_after: usize,
    /// Per-buffer peak `len()` observed in the current decay window,
    /// in field-declaration order.
    window_peaks: [usize; NUM_BUFFERS],
    /// Batches completed since the last decay check.
    batches_since_decay: usize,
}

/// Number of role-named buffers in the arena (the `Vec` fields of
/// [`ForwardScratch`], in declaration order).
const NUM_BUFFERS: usize = 11;

/// The decay bookkeeping views every buffer through one vtable so the
/// field list lives in exactly one place ([`ForwardScratch::buffers_mut`])
/// instead of being hand-synced across peak sampling and shrinking.
trait DecayBuf {
    fn len(&self) -> usize;
    fn shrink_to_peak(&mut self, peak: usize);
}

impl<T> DecayBuf for Vec<T> {
    fn len(&self) -> usize {
        Vec::len(self)
    }
    fn shrink_to_peak(&mut self, peak: usize) {
        // `shrink_to` keeps capacity ≥ max(len, peak): the buffer ends
        // the window able to hold exactly its window high-water mark, so
        // under steady traffic the next batches fit without reallocating
        if self.capacity() > peak {
            self.shrink_to(peak);
        }
    }
}

impl ForwardScratch {
    /// Every role-named buffer, in `window_peaks` index order — THE
    /// single field list the decay machinery iterates.  The
    /// `NUM_BUFFERS` array length makes the compiler reject a buffer
    /// added to the struct and counted, but missing here (and a
    /// too-short `window_peaks` can't silently truncate a `zip`).
    fn buffers_mut(&mut self) -> [&mut dyn DecayBuf; NUM_BUFFERS] {
        [
            &mut self.xb,
            &mut self.gray,
            &mut self.cols_p,
            &mut self.counts,
            &mut self.words,
            &mut self.pooled,
            &mut self.cols_f,
            &mut self.act_f,
            &mut self.pool_f,
            &mut self.h_a,
            &mut self.h_b,
        ]
    }

    /// Decay window used by serving workers ([`crate::coordinator::backend::EngineBackend`]'s
    /// arena pool): after this many batches, capacity not touched within
    /// the window is released.  Large enough that a transient dip in
    /// batch size doesn't thrash the allocator; small enough that a
    /// one-off B=64 burst stops pinning ~megabytes within a second of
    /// steady B=1 traffic.
    pub const SERVING_DECAY_BATCHES: usize = 64;

    pub fn new() -> Self {
        Self::default()
    }

    /// An arena with the decay policy enabled: every `decay_after`
    /// batches, each buffer's capacity shrinks to the largest size that
    /// buffer actually reached within the window.  `0` disables decay.
    pub fn with_decay(decay_after: usize) -> Self {
        Self { decay_after, ..Self::default() }
    }

    /// Fold the buffers' current `len()`s into the window's per-buffer
    /// peaks.  A single end-of-batch sample would under-read: the
    /// forward resizes several buffers *down* as it proceeds (conv1's
    /// spatial extent is 4× conv2's, and the FC tail is smaller still).
    /// So the networks sample twice — once **after pool1** (where the
    /// conv1-peaking buffers — counts, words, pooled, act_f — hold their
    /// largest extent) and once from [`ForwardScratch::end_batch`]
    /// (which catches the buffers whose *last* resize is their largest:
    /// the conv2 patch-row gathers `cols_p`/`cols_f`, and the constant
    /// FC tails).  The max of both samples is the true per-batch
    /// high-water mark for every buffer.
    pub(crate) fn note_batch_peaks(&mut self) {
        if self.decay_after == 0 {
            return;
        }
        let mut peaks = self.window_peaks;
        for (peak, buf) in peaks.iter_mut().zip(self.buffers_mut()) {
            *peak = (*peak).max(buf.len());
        }
        self.window_peaks = peaks;
    }

    /// Mark the end of one `infer_batch_with` call and run the decay
    /// policy.  Called by the networks after every batched forward; a
    /// no-op unless decay is enabled.
    ///
    /// Correctness: decay only ever *releases capacity* — it truncates a
    /// buffer to a length every `_into` kernel will overwrite (each
    /// kernel resizes its output to the exact size it needs and assigns
    /// or identity-fills the whole range before reading), so shrinking
    /// can never change results (property-tested below).  Under steady
    /// traffic the window peak equals the shrunk capacity, so the decay
    /// check is a no-op and the zero-allocation steady state is
    /// preserved; only after the load genuinely drops does a shrink (and
    /// the one regrow on the next larger batch) happen.
    pub(crate) fn end_batch(&mut self) {
        if self.decay_after == 0 {
            return;
        }
        self.note_batch_peaks();
        self.batches_since_decay += 1;
        if self.batches_since_decay < self.decay_after {
            return;
        }
        let peaks = self.window_peaks;
        for (peak, buf) in peaks.into_iter().zip(self.buffers_mut()) {
            buf.shrink_to_peak(peak);
        }
        self.window_peaks = [0; NUM_BUFFERS];
        self.batches_since_decay = 0;
    }

    /// Total elements currently reserved across all buffers — the arena's
    /// high-water mark, for diagnostics and the allocation bench.
    pub fn capacity_elems(&self) -> usize {
        self.xb.capacity()
            + self.gray.capacity()
            + self.cols_p.capacity()
            + self.counts.capacity()
            + self.words.capacity()
            + self.pooled.capacity()
            + self.cols_f.capacity()
            + self.act_f.capacity()
            + self.pool_f.capacity()
            + self.h_a.capacity()
            + self.h_b.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::network::tests_support::{
        synth_bcnn_network, synth_float_network, synth_image,
    };
    use crate::bnn::network::{IMG_C, IMG_H, IMG_W};
    use crate::input::binarize::Scheme;
    use crate::util::prop::{self, ensure_eq};

    const IMG: usize = IMG_H * IMG_W * IMG_C;

    fn images(n: usize, seed: u64) -> Vec<f32> {
        let mut xs = Vec::with_capacity(n * IMG);
        for i in 0..n {
            xs.extend(synth_image(seed.wrapping_add(i as u64)));
        }
        xs
    }

    #[test]
    fn bcnn_scratch_path_bit_identical_and_leak_free() {
        // ONE scratch reused across every case: random scheme, random
        // batch size (so consecutive calls shrink and grow the buffers),
        // compared against (a) a fresh scratch and (b) the single-image
        // forward — both must be bit-identical every time.
        let nets: Vec<_> = Scheme::ALL.iter().map(|&s| synth_bcnn_network(s, 77)).collect();
        let mut reused = ForwardScratch::new();
        prop::check(12, |g| {
            let net = g.pick(&nets);
            let n = g.usize_in(1, 5);
            let xs = images(n, g.u64());
            let with_reused = net.infer_batch_with(&xs, &mut reused).unwrap();
            let with_fresh = net.infer_batch_with(&xs, &mut ForwardScratch::new()).unwrap();
            ensure_eq(with_reused.clone(), with_fresh, "reused scratch == fresh scratch")?;
            for i in 0..n {
                let (single, _) = net.forward(&xs[i * IMG..(i + 1) * IMG]);
                ensure_eq(with_reused[i], single, "scratch batched == single forward")?;
            }
            Ok(())
        });
    }

    #[test]
    fn float_scratch_path_bit_identical_and_leak_free() {
        let net = synth_float_network(78);
        let mut reused = ForwardScratch::new();
        prop::check(6, |g| {
            let n = g.usize_in(1, 4);
            let xs = images(n, g.u64());
            let with_reused = net.infer_batch_with(&xs, &mut reused).unwrap();
            let with_fresh = net.infer_batch_with(&xs, &mut ForwardScratch::new()).unwrap();
            ensure_eq(with_reused.clone(), with_fresh, "float reused == fresh")?;
            for i in 0..n {
                let (single, _) = net.forward(&xs[i * IMG..(i + 1) * IMG]);
                ensure_eq(with_reused[i], single, "float scratch batched == single")?;
            }
            Ok(())
        });
    }

    #[test]
    fn shrinking_then_growing_batches_do_not_leak() {
        // explicit worst case for stale-state bugs: big batch warms the
        // high-water mark, then smaller batches run inside dirty buffers
        let net = synth_bcnn_network(Scheme::Rgb, 5);
        let mut scratch = ForwardScratch::new();
        let mut high_water = 0;
        for (round, &n) in [4usize, 1, 3, 2, 5, 1].iter().enumerate() {
            let xs = images(n, 1000 + round as u64);
            let got = net.infer_batch_with(&xs, &mut scratch).unwrap();
            for i in 0..n {
                let (want, _) = net.forward(&xs[i * IMG..(i + 1) * IMG]);
                assert_eq!(got[i], want, "round {round}, image {i}");
            }
            // capacity is a monotone high-water mark (no realloc churn)
            let cap = scratch.capacity_elems();
            assert!(cap >= high_water, "round {round}: capacity shrank {high_water} -> {cap}");
            high_water = cap;
        }
    }

    #[test]
    fn one_scratch_serves_bcnn_and_float_interleaved() {
        // a worker's arena may alternate between model kinds; nothing may
        // bleed across (different buffer roles, but shared h_a/h_b etc.)
        let bnet = synth_bcnn_network(Scheme::Gray, 9);
        let fnet = synth_float_network(9);
        let mut scratch = ForwardScratch::new();
        for round in 0..3u64 {
            let xs = images(2, 2000 + round);
            let b = bnet.infer_batch_with(&xs, &mut scratch).unwrap();
            let f = fnet.infer_batch_with(&xs, &mut scratch).unwrap();
            for i in 0..2 {
                assert_eq!(b[i], bnet.forward(&xs[i * IMG..(i + 1) * IMG]).0);
                assert_eq!(f[i], fnet.forward(&xs[i * IMG..(i + 1) * IMG]).0);
            }
        }
    }

    #[test]
    fn decay_never_changes_outputs() {
        // the satellite property: an aggressively-decaying arena (window
        // of 2, so it shrinks constantly while batch sizes jump around)
        // stays bit-identical to a fresh arena and to the single-image
        // forward, across schemes and the float network
        let nets: Vec<_> = Scheme::ALL.iter().map(|&s| synth_bcnn_network(s, 91)).collect();
        let fnet = synth_float_network(92);
        let mut decaying = ForwardScratch::with_decay(2);
        prop::check(16, |g| {
            let n = g.usize_in(1, 6);
            let xs = images(n, g.u64());
            let (with_decay, with_fresh) = if g.usize_in(0, 3) == 0 {
                (
                    fnet.infer_batch_with(&xs, &mut decaying).unwrap(),
                    fnet.infer_batch_with(&xs, &mut ForwardScratch::new()).unwrap(),
                )
            } else {
                let net = g.pick(&nets);
                (
                    net.infer_batch_with(&xs, &mut decaying).unwrap(),
                    net.infer_batch_with(&xs, &mut ForwardScratch::new()).unwrap(),
                )
            };
            ensure_eq(with_decay, with_fresh, "decaying arena == fresh arena")
        });
    }

    #[test]
    fn decay_releases_capacity_after_burst() {
        // a B=8 burst grows the arena; once a full decay window passes
        // with only B=1 traffic, the burst capacity must be released
        let net = synth_bcnn_network(Scheme::Rgb, 93);
        let mut scratch = ForwardScratch::with_decay(4);
        net.infer_batch_with(&images(8, 1), &mut scratch).unwrap();
        let burst_cap = scratch.capacity_elems();
        for round in 0..8u64 {
            net.infer_batch_with(&images(1, 100 + round), &mut scratch).unwrap();
        }
        let settled_cap = scratch.capacity_elems();
        assert!(
            settled_cap < burst_cap,
            "decay never released the burst: {settled_cap} >= {burst_cap}"
        );
        // and the settled arena still answers correctly
        let xs = images(2, 7);
        let got = net.infer_batch_with(&xs, &mut scratch).unwrap();
        for i in 0..2 {
            assert_eq!(got[i], net.forward(&xs[i * IMG..(i + 1) * IMG]).0);
        }
    }

    #[test]
    fn decay_is_noop_under_steady_traffic() {
        // regression (code review): sampling only end-of-batch len() under-
        // read the buffers the forward resizes downward (counts, words,
        // pooled peak at conv1), so decay shrank them below their working
        // size and every window reallocated them.  With two-point peak
        // sampling + shrink_to, capacity must settle and then hold exactly
        // steady across further decay windows under constant load.
        let net = synth_bcnn_network(Scheme::Rgb, 95);
        let mut scratch = ForwardScratch::with_decay(3);
        for round in 0..7u64 {
            net.infer_batch_with(&images(2, 300 + round), &mut scratch).unwrap();
        }
        let settled = scratch.capacity_elems();
        for round in 0..6u64 {
            net.infer_batch_with(&images(2, 400 + round), &mut scratch).unwrap();
            assert_eq!(
                scratch.capacity_elems(),
                settled,
                "round {round}: decay churned capacity under steady load"
            );
        }
    }

    #[test]
    fn decay_disabled_keeps_monotone_high_water() {
        // ForwardScratch::new() must keep the PR 2 contract: capacity
        // never shrinks, no realloc churn for ad-hoc arenas
        let net = synth_bcnn_network(Scheme::Gray, 94);
        let mut scratch = ForwardScratch::new();
        net.infer_batch_with(&images(6, 1), &mut scratch).unwrap();
        let high = scratch.capacity_elems();
        for round in 0..6u64 {
            net.infer_batch_with(&images(1, 200 + round), &mut scratch).unwrap();
            assert_eq!(scratch.capacity_elems(), high, "round {round} reallocated");
        }
    }

    #[test]
    fn scratch_rejects_ragged_and_accepts_empty() {
        let net = synth_bcnn_network(Scheme::Rgb, 8);
        let mut scratch = ForwardScratch::new();
        assert!(net.infer_batch_with(&[0.0; 100], &mut scratch).is_err());
        assert!(net.infer_batch_with(&[], &mut scratch).unwrap().is_empty());
        let fnet = synth_float_network(8);
        assert!(fnet.infer_batch_with(&[0.0; 7], &mut scratch).is_err());
        assert!(fnet.infer_batch_with(&[], &mut scratch).unwrap().is_empty());
    }
}
