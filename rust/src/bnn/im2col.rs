//! Patch extraction: float im2col, fused im2col+pack (paper Algorithm 1),
//! and the channel-packed word gather used between binarized layers.
//!
//! All variants produce 'same'-convolution patches in `(dy, dx, c)` order
//! (the row-major shared-memory walk of the CUDA kernel).  Float im2col
//! pads with 0; binarized variants pad with -1 / zero-words (bit 0 == -1),
//! matching the zero-initialized shared memory of the paper.

use super::packing::{pack_pm1, packed_width};

/// Float 'same' im2col.  `x` is (H, W, C) row-major; output is
/// (H*W, K*K*C) row-major, zero padding.
pub fn im2col_float(x: &[f32], h: usize, w: usize, c: usize, k: usize) -> Vec<f32> {
    assert_eq!(x.len(), h * w * c);
    let d = k * k * c;
    let mut out = vec![0f32; h * w * d];
    im2col_float_into(x, h, w, c, k, &mut out);
    out
}

/// Core: patch one image into a zeroed (H*W, K*K*C) slice.
fn im2col_float_into(x: &[f32], h: usize, w: usize, c: usize, k: usize, out: &mut [f32]) {
    let r = (k - 1) / 2;
    let d = k * k * c;
    for oy in 0..h {
        for ox in 0..w {
            let patch = &mut out[(oy * w + ox) * d..(oy * w + ox + 1) * d];
            let mut p = 0;
            for dy in 0..k {
                let iy = oy as isize + dy as isize - r as isize;
                for dx in 0..k {
                    let ix = ox as isize + dx as isize - r as isize;
                    if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                        let src = ((iy as usize) * w + ix as usize) * c;
                        patch[p..p + c].copy_from_slice(&x[src..src + c]);
                    } // else: leave zeros
                    p += c;
                }
            }
        }
    }
}

/// Batched float im2col over `n` contiguous (H, W, C) images; output is
/// (N*H*W, K*K*C) — image i's patch rows occupy rows [i*H*W, (i+1)*H*W).
/// Bit-identical per image to `im2col_float` (pads never cross images).
pub fn im2col_float_batch(
    xs: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
) -> Vec<f32> {
    let mut out = Vec::new();
    im2col_float_batch_into(xs, n, h, w, c, k, &mut out);
    out
}

/// `im2col_float_batch` into a caller-owned buffer.  The buffer is
/// resized and fully re-initialized (capacity grows monotonically across
/// calls), so reusing one buffer across differently-sized batches can
/// never leak state between calls.
///
/// Write coverage: resizes `out` to exactly N·H·W·K·K·C and assigns
/// every element (zeroed, then patch rows copied in); prior contents are
/// never read.
pub fn im2col_float_batch_into(
    xs: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    out: &mut Vec<f32>,
) {
    assert_eq!(xs.len(), n * h * w * c);
    let d = k * k * c;
    let (img_in, img_out) = (h * w * c, h * w * d);
    out.clear();
    out.resize(n * img_out, 0.0);
    for i in 0..n {
        im2col_float_into(
            &xs[i * img_in..(i + 1) * img_in],
            h,
            w,
            c,
            k,
            &mut out[i * img_out..(i + 1) * img_out],
        );
    }
}

/// MSB-first bit writer — the register + counter of Algorithm 1.
/// Bits stream in patch order; words flush every `b` bits; the final
/// partial word is left-aligned (tail bits 0), matching `pack_bits`.
struct BitWriter<'a> {
    out: &'a mut [u32],
    word: u32,
    fill: u32,
    b: u32,
    pos: usize,
}

impl<'a> BitWriter<'a> {
    #[inline]
    fn new(out: &'a mut [u32], b: usize) -> Self {
        Self { out, word: 0, fill: 0, b: b as u32, pos: 0 }
    }

    #[inline]
    fn push(&mut self, bit: u32) {
        self.word = (self.word << 1) | bit;
        self.fill += 1;
        if self.fill == self.b {
            self.out[self.pos] = self.word;
            self.pos += 1;
            self.word = 0;
            self.fill = 0;
        }
    }

    /// Push `n` zero bits (padding region).
    #[inline]
    fn push_zeros(&mut self, mut n: u32) {
        while n > 0 {
            let take = n.min(self.b - self.fill);
            self.word <<= take;
            self.fill += take;
            if self.fill == self.b {
                self.out[self.pos] = self.word;
                self.pos += 1;
                self.word = 0;
                self.fill = 0;
            }
            n -= take;
        }
    }

    #[inline]
    fn finish(mut self) {
        if self.fill > 0 {
            self.out[self.pos] = self.word << (self.b - self.fill);
        }
    }
}

/// Fused im2col + pack (Algorithm 1): ±1 image -> packed patch rows.
///
/// `x` is (H, W, C) of ±1 floats; returns (H*W) rows of
/// `ceil(K*K*C / b)` u32 words each (flattened).  Padding pixels pack as
/// bit 0 (= -1).  Bits go straight from the pixel compare into the
/// packing register — no intermediate patch buffer, no div/mod (this is
/// the paper's fused kernel, and it is also what makes it fast here; the
/// two-pass variant below exists for the E7 ablation).
pub fn im2col_pack(x: &[f32], h: usize, w: usize, c: usize, k: usize, b: usize) -> Vec<u32> {
    assert_eq!(x.len(), h * w * c);
    let nw = packed_width(k * k * c, b);
    let mut out = vec![0u32; h * w * nw];
    im2col_pack_into(x, h, w, c, k, b, &mut out);
    out
}

/// Core: fused im2col+pack of one image into a (H*W, NW) slice.  The
/// `BitWriter` flushes exactly NW words per patch row (`finish` always
/// emits the partial tail word), so every element is assigned and the
/// slice may arrive dirty — the reused-arena path relies on this.
fn im2col_pack_into(
    x: &[f32],
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    b: usize,
    out: &mut [u32],
) {
    let r = (k - 1) / 2;
    let nw = packed_width(k * k * c, b);
    for oy in 0..h {
        for ox in 0..w {
            let row = &mut out[(oy * w + ox) * nw..(oy * w + ox + 1) * nw];
            let mut bw = BitWriter::new(row, b);
            for dy in 0..k {
                let iy = oy as isize + dy as isize - r as isize;
                if iy < 0 || iy as usize >= h {
                    bw.push_zeros((k * c) as u32);
                    continue;
                }
                let base = (iy as usize) * w;
                for dx in 0..k {
                    let ix = ox as isize + dx as isize - r as isize;
                    if ix < 0 || ix as usize >= w {
                        bw.push_zeros(c as u32);
                    } else {
                        let src = (base + ix as usize) * c;
                        for &v in &x[src..src + c] {
                            bw.push(u32::from(v > 0.0));
                        }
                    }
                }
            }
            bw.finish();
        }
    }
}

/// Batched fused im2col+pack over `n` contiguous (H, W, C) ±1 images;
/// output is (N*H*W, NW) packed patch rows, bit-identical per image to
/// `im2col_pack` (the halo never reads across image boundaries).
pub fn im2col_pack_batch(
    xs: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    b: usize,
) -> Vec<u32> {
    let mut out = Vec::new();
    im2col_pack_batch_into(xs, n, h, w, c, k, b, &mut out);
    out
}

/// `im2col_pack_batch` into a caller-owned buffer (capacity grows
/// monotonically; no pre-zeroing — the `BitWriter` flushes exactly
/// `ceil(K*K*C/b)` words per patch row, covering every element).
///
/// Write coverage: resizes `out` to exactly N·H·W·NW and assigns every
/// word via the per-row `BitWriter` flush; a dirty buffer comes out
/// identical to a fresh allocation.
pub fn im2col_pack_batch_into(
    xs: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    b: usize,
    out: &mut Vec<u32>,
) {
    assert_eq!(xs.len(), n * h * w * c);
    let nw = packed_width(k * k * c, b);
    let (img_in, img_out) = (h * w * c, h * w * nw);
    out.resize(n * img_out, 0);
    for i in 0..n {
        im2col_pack_into(
            &xs[i * img_in..(i + 1) * img_in],
            h,
            w,
            c,
            k,
            b,
            &mut out[i * img_out..(i + 1) * img_out],
        );
    }
}

/// Fused binarize + im2col + pack over `n` contiguous RAW (H, W, C_RAW)
/// float images: each gathered pixel's binarized channel bits are
/// computed on the fly by `bin`, so the intermediate ±1 image is never
/// materialized.  `bin` maps one raw pixel (C_RAW floats) to its C_BIN
/// sign bits, channel 0 in the HIGHEST of the low C_BIN bits — the
/// MSB-first channel order of `im2col_pack`.  Padding packs as bit 0
/// and the halo never reads across image boundaries, so the output is
/// bit-identical to binarizing each image and running
/// `im2col_pack_batch` on the result.
///
/// Write coverage: resizes `out` to exactly N·H·W·NW and assigns every
/// word via the per-row `BitWriter` flush; a dirty buffer comes out
/// identical to a fresh allocation.
#[allow(clippy::too_many_arguments)]
pub fn im2col_binarize_pack_batch_into(
    xs: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c_raw: usize,
    c_bin: usize,
    k: usize,
    b: usize,
    bin: impl Fn(&[f32]) -> u32,
    out: &mut Vec<u32>,
) {
    assert_eq!(xs.len(), n * h * w * c_raw);
    let r = (k - 1) / 2;
    let nw = packed_width(k * k * c_bin, b);
    let (img_in, img_out) = (h * w * c_raw, h * w * nw);
    out.resize(n * img_out, 0);
    for i in 0..n {
        let x = &xs[i * img_in..(i + 1) * img_in];
        let o = &mut out[i * img_out..(i + 1) * img_out];
        for oy in 0..h {
            for ox in 0..w {
                let row = &mut o[(oy * w + ox) * nw..(oy * w + ox + 1) * nw];
                let mut bw = BitWriter::new(row, b);
                for dy in 0..k {
                    let iy = oy as isize + dy as isize - r as isize;
                    if iy < 0 || iy as usize >= h {
                        bw.push_zeros((k * c_bin) as u32);
                        continue;
                    }
                    let base = (iy as usize) * w;
                    for dx in 0..k {
                        let ix = ox as isize + dx as isize - r as isize;
                        if ix < 0 || ix as usize >= w {
                            bw.push_zeros(c_bin as u32);
                        } else {
                            let src = (base + ix as usize) * c_raw;
                            let bits = bin(&x[src..src + c_raw]);
                            for j in (0..c_bin).rev() {
                                bw.push((bits >> j) & 1);
                            }
                        }
                    }
                }
                bw.finish();
            }
        }
    }
}

/// Two-pass (unfused) variant for the fusion ablation (E7): materialize
/// float patches, then pack them — the extra K*K*C global traffic the
/// paper's fusion eliminates.
pub fn im2col_then_pack(x: &[f32], h: usize, w: usize, c: usize, k: usize, b: usize) -> Vec<u32> {
    // pass 1: float im2col with -1 padding
    let r = (k - 1) / 2;
    let d = k * k * c;
    let mut cols = vec![-1.0f32; h * w * d];
    for oy in 0..h {
        for ox in 0..w {
            let patch = &mut cols[(oy * w + ox) * d..(oy * w + ox + 1) * d];
            let mut p = 0;
            for dy in 0..k {
                let iy = oy as isize + dy as isize - r as isize;
                for dx in 0..k {
                    let ix = ox as isize + dx as isize - r as isize;
                    if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                        let src = ((iy as usize) * w + ix as usize) * c;
                        patch[p..p + c].copy_from_slice(&x[src..src + c]);
                    }
                    p += c;
                }
            }
        }
    }
    // pass 2: pack
    let nw = packed_width(d, b);
    let mut out = vec![0u32; h * w * nw];
    for row in 0..h * w {
        let words = pack_pm1(&cols[row * d..(row + 1) * d], b);
        out[row * nw..(row + 1) * nw].copy_from_slice(&words);
    }
    out
}

/// Gather K*K channel-packed words per output pixel ('same', pad word 0).
///
/// `words` is (H, W, NW) u32 (NW words of packed channels per pixel);
/// output is (H*W, K*K*NW).  Used between binarized layers where
/// activations are already channel-packed — the gather IS the im2col.
pub fn im2col_words(words: &[u32], h: usize, w: usize, nw: usize, k: usize) -> Vec<u32> {
    assert_eq!(words.len(), h * w * nw);
    let mut out = vec![0u32; h * w * k * k * nw];
    im2col_words_into(words, h, w, nw, k, &mut out);
    out
}

/// Core: gather one image's words into a zeroed (H*W, K*K*NW) slice.
fn im2col_words_into(words: &[u32], h: usize, w: usize, nw: usize, k: usize, out: &mut [u32]) {
    let r = (k - 1) / 2;
    let row_w = k * k * nw;
    for oy in 0..h {
        for ox in 0..w {
            let base = (oy * w + ox) * row_w;
            let mut p = base;
            for dy in 0..k {
                let iy = oy as isize + dy as isize - r as isize;
                for dx in 0..k {
                    let ix = ox as isize + dx as isize - r as isize;
                    if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                        let src = ((iy as usize) * w + ix as usize) * nw;
                        out[p..p + nw].copy_from_slice(&words[src..src + nw]);
                    } // else: zero words (all channels -1)
                    p += nw;
                }
            }
        }
    }
}

/// Batched word gather over `n` contiguous (H, W, NW) packed images;
/// output is (N*H*W, K*K*NW), bit-identical per image to `im2col_words`.
pub fn im2col_words_batch(
    words: &[u32],
    n: usize,
    h: usize,
    w: usize,
    nw: usize,
    k: usize,
) -> Vec<u32> {
    let mut out = Vec::new();
    im2col_words_batch_into(words, n, h, w, nw, k, &mut out);
    out
}

/// `im2col_words_batch` into a caller-owned buffer (resized + fully
/// re-initialized every call; capacity grows monotonically).
///
/// Write coverage: resizes `out` to exactly N·H·W·K·K·NW and assigns
/// every element (zeroed, then in-bounds words copied in); prior
/// contents are never read.
pub fn im2col_words_batch_into(
    words: &[u32],
    n: usize,
    h: usize,
    w: usize,
    nw: usize,
    k: usize,
    out: &mut Vec<u32>,
) {
    assert_eq!(words.len(), n * h * w * nw);
    let (img_in, img_out) = (h * w * nw, h * w * k * k * nw);
    out.clear();
    out.resize(n * img_out, 0);
    for i in 0..n {
        im2col_words_into(
            &words[i * img_in..(i + 1) * img_in],
            h,
            w,
            nw,
            k,
            &mut out[i * img_out..(i + 1) * img_out],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::packing::{pack_bits, unpack_bits};
    use crate::util::prop::{self, ensure_eq};

    #[test]
    fn float_im2col_center_pixel_identity() {
        // K=1: each patch is exactly the pixel
        let x: Vec<f32> = (0..2 * 3 * 2).map(|i| i as f32).collect();
        let cols = im2col_float(&x, 2, 3, 2, 1);
        assert_eq!(cols, x);
    }

    #[test]
    fn float_im2col_zero_pads_borders() {
        // 1x1 image, K=3: only the center entry of the patch is non-zero
        let cols = im2col_float(&[5.0], 1, 1, 1, 3);
        assert_eq!(cols.len(), 9);
        let mut want = vec![0.0; 9];
        want[4] = 5.0; // (dy,dx) = (1,1)
        assert_eq!(cols, want);
    }

    #[test]
    fn fused_matches_two_pass() {
        prop::check(32, |g| {
            let h = g.usize_in(1, 8);
            let w = g.usize_in(1, 8);
            let c = g.usize_in(1, 4);
            let k = *g.pick(&[1usize, 3, 5]);
            let b = *g.pick(&[8usize, 25, 32]);
            let x = g.pm1(h * w * c);
            ensure_eq(
                im2col_pack(&x, h, w, c, k, b),
                im2col_then_pack(&x, h, w, c, k, b),
                "fused == unfused",
            )
        });
    }

    #[test]
    fn pack_layout_matches_ref_convention() {
        // single pixel, K=1, C=3: patch = pixel channels, packed MSB-first
        let x = [1.0f32, -1.0, 1.0];
        let words = im2col_pack(&x, 1, 1, 3, 1, 32);
        assert_eq!(words, vec![0b101u32 << 29]);
    }

    #[test]
    fn border_padding_packs_as_minus_one() {
        // 1x1 ±1 image of +1, K=3, B=9: only the center bit set
        let words = im2col_pack(&[1.0], 1, 1, 1, 3, 9);
        let bits = unpack_bits(&words, 9, 9);
        assert_eq!(bits, vec![0, 0, 0, 0, 1, 0, 0, 0, 0]);
    }

    #[test]
    fn word_gather_matches_bit_level_pack() {
        // For C=32 channel-packed input, gathering words then flattening
        // must equal packing the (dy,dx,c)-ordered ±1 patch directly.
        prop::check(16, |g| {
            let h = g.usize_in(2, 6);
            let w = g.usize_in(2, 6);
            let c = 32usize;
            let k = 3usize;
            let xs = g.pm1(h * w * c);
            // channel-pack each pixel
            let mut words = Vec::with_capacity(h * w);
            for px in 0..h * w {
                let bits: Vec<u32> =
                    xs[px * c..(px + 1) * c].iter().map(|&v| u32::from(v > 0.0)).collect();
                words.extend(pack_bits(&bits, 32));
            }
            let gathered = im2col_words(&words, h, w, 1, k);
            let direct = im2col_pack(&xs, h, w, c, k, 32);
            ensure_eq(gathered, direct, "word gather == direct pack (C=32)")
        });
    }

    #[test]
    fn im2col_words_shapes() {
        let words = vec![7u32; 4 * 4 * 2];
        let out = im2col_words(&words, 4, 4, 2, 5);
        assert_eq!(out.len(), 16 * 25 * 2);
    }

    #[test]
    fn binarize_while_gather_matches_materialize_then_pack() {
        // the fuse-pack axiom at the kernel level: computing sign bits
        // inside the gather == materializing the ±1 image and packing it
        prop::check(24, |g| {
            let n = g.usize_in(1, 3);
            let h = g.usize_in(1, 6);
            let w = g.usize_in(1, 6);
            let c = g.usize_in(1, 3);
            let k = *g.pick(&[1usize, 3, 5]);
            let xs = g.normals(n * h * w * c);
            let t = g.normals(c);
            // per-channel sign(x + t), materialized
            let pm1: Vec<f32> = xs
                .chunks_exact(c)
                .flat_map(|px| {
                    px.iter()
                        .zip(&t)
                        .map(|(&v, &tv)| if v + tv > 0.0 { 1.0 } else { -1.0 })
                        .collect::<Vec<f32>>()
                })
                .collect();
            let want = im2col_pack_batch(&pm1, n, h, w, c, k, 32);
            let mut got = vec![123u32; 7]; // dirty
            im2col_binarize_pack_batch_into(
                &xs,
                n,
                h,
                w,
                c,
                c,
                k,
                32,
                |px| {
                    let mut bits = 0u32;
                    for (j, (&v, &tv)) in px.iter().zip(&t).enumerate() {
                        bits |= u32::from(v + tv > 0.0) << (c - 1 - j);
                    }
                    bits
                },
                &mut got,
            );
            ensure_eq(got, want, "binarize-while-gather == materialize-then-pack")
        });
    }

    #[test]
    fn binarize_while_gather_reduces_channels() {
        // c_raw != c_bin: a luma-style reduction (3 raw channels -> 1 sign
        // bit) must equal materializing the reduced ±1 plane first
        prop::check(16, |g| {
            let h = g.usize_in(1, 6);
            let w = g.usize_in(1, 6);
            let xs = g.normals(h * w * 3);
            let luma = [0.299f32, 0.587, 0.114];
            let t = g.normals(1)[0];
            let red = |px: &[f32]| px[0] * luma[0] + px[1] * luma[1] + px[2] * luma[2] + t;
            let pm1: Vec<f32> =
                xs.chunks_exact(3).map(|px| if red(px) > 0.0 { 1.0 } else { -1.0 }).collect();
            let want = im2col_pack_batch(&pm1, 1, h, w, 1, 3, 32);
            let mut got = Vec::new();
            im2col_binarize_pack_batch_into(
                &xs,
                1,
                h,
                w,
                3,
                1,
                3,
                32,
                |px| u32::from(red(px) > 0.0),
                &mut got,
            );
            ensure_eq(got, want, "channel-reducing binarize-gather")
        });
    }

    #[test]
    fn reused_into_buffers_never_leak_between_calls() {
        // one set of buffers reused across shrinking/growing shapes must
        // give the same bytes as fresh allocations every time
        let mut fbuf = Vec::new();
        let mut pbuf = Vec::new();
        let mut wbuf = Vec::new();
        prop::check(24, |g| {
            let n = g.usize_in(1, 3);
            let h = g.usize_in(1, 6);
            let w = g.usize_in(1, 6);
            let c = g.usize_in(1, 3);
            let k = *g.pick(&[1usize, 3, 5]);
            let xs = g.pm1(n * h * w * c);
            let words = g.words(n * h * w * c);
            // the buffers arrive dirty from the previous case
            im2col_float_batch_into(&xs, n, h, w, c, k, &mut fbuf);
            ensure_eq(fbuf.clone(), im2col_float_batch(&xs, n, h, w, c, k), "float reuse")?;
            im2col_pack_batch_into(&xs, n, h, w, c, k, 32, &mut pbuf);
            ensure_eq(pbuf.clone(), im2col_pack_batch(&xs, n, h, w, c, k, 32), "pack reuse")?;
            im2col_words_batch_into(&words, n, h, w, c, k, &mut wbuf);
            ensure_eq(wbuf.clone(), im2col_words_batch(&words, n, h, w, c, k), "words reuse")?;
            Ok(())
        });
    }

    #[test]
    fn batch_variants_match_per_image() {
        prop::check(24, |g| {
            let n = g.usize_in(1, 4);
            let h = g.usize_in(1, 6);
            let w = g.usize_in(1, 6);
            let c = g.usize_in(1, 3);
            let k = *g.pick(&[1usize, 3, 5]);
            let b = *g.pick(&[25usize, 32]);
            let xs = g.pm1(n * h * w * c);
            let words = g.words(n * h * w * c);

            let fb = im2col_float_batch(&xs, n, h, w, c, k);
            let pb = im2col_pack_batch(&xs, n, h, w, c, k, b);
            let wb = im2col_words_batch(&words, n, h, w, c, k);

            let img = h * w * c;
            let d = k * k * c;
            let nw = packed_width(d, b);
            for i in 0..n {
                let x = &xs[i * img..(i + 1) * img];
                ensure_eq(
                    fb[i * h * w * d..(i + 1) * h * w * d].to_vec(),
                    im2col_float(x, h, w, c, k),
                    "float batch == single",
                )?;
                ensure_eq(
                    pb[i * h * w * nw..(i + 1) * h * w * nw].to_vec(),
                    im2col_pack(x, h, w, c, k, b),
                    "pack batch == single",
                )?;
                let ws = &words[i * img..(i + 1) * img];
                ensure_eq(
                    wb[i * h * w * k * k * c..(i + 1) * h * w * k * k * c].to_vec(),
                    im2col_words(ws, h, w, c, k),
                    "words batch == single",
                )?;
            }
            Ok(())
        });
    }
}
