//! Bit packing (paper Eq. 2) and the xnor-popcount dot product (Eq. 4).
//!
//! Conventions — identical to `python/compile/kernels/ref.py`:
//! * +1 -> bit 1, -1 -> bit 0;
//! * element `i` of a row lands in word `i / B` at bit `B-1-(i % B)`
//!   (MSB-first), tail bits are 0;
//! * `dot(a, b) = D - 2 * popcount(xor)` with `D` the real bit length —
//!   valid because tail bits match (both 0).
//!
//! The hot-path kernels fuse pairs of u32 words into a single u64 so
//! each `count_ones` covers 64 bits (the paper's 32-bit `__popc`
//! doubled — the natural word width on this CPU).  The fuse is a plain
//! shift+or (`fuse64`), not a pointer reinterpret: no alignment
//! cases, no `unsafe` (the crate root carries `#![deny(unsafe_code)]`).

/// Packed words for a `d`-bit row at bitwidth `b`.
#[inline]
pub fn packed_width(d: usize, b: usize) -> usize {
    d.div_ceil(b)
}

/// Pack a row of {0,1} bits into u32 words at bitwidth `b` (<= 32).
pub fn pack_bits(bits: &[u32], b: usize) -> Vec<u32> {
    assert!(b >= 1 && b <= 32);
    let nw = packed_width(bits.len(), b);
    let mut out = vec![0u32; nw];
    for (i, &bit) in bits.iter().enumerate() {
        debug_assert!(bit <= 1);
        out[i / b] |= bit << (b - 1 - (i % b));
    }
    out
}

/// Pack a row of ±1 floats (bit = x > 0).
pub fn pack_pm1(xs: &[f32], b: usize) -> Vec<u32> {
    assert!(b >= 1 && b <= 32);
    let nw = packed_width(xs.len(), b);
    let mut out = vec![0u32; nw];
    for (i, &x) in xs.iter().enumerate() {
        out[i / b] |= u32::from(x > 0.0) << (b - 1 - (i % b));
    }
    out
}

/// Unpack words back to `d` bits.
pub fn unpack_bits(words: &[u32], d: usize, b: usize) -> Vec<u32> {
    let mut out = Vec::with_capacity(d);
    for i in 0..d {
        out.push((words[i / b] >> (b - 1 - (i % b))) & 1);
    }
    out
}

/// Eq. 4: xnor-popcount dot of two packed rows (same layout, equal pads).
#[inline]
pub fn packed_dot(a: &[u32], b: &[u32], d_real: usize) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    d_real as i32 - 2 * xor_popcount(a, b) as i32
}

/// Fuse two u32 words into one u64.  Which word lands in the high half
/// is irrelevant for xor+popcount — the only requirement is that both
/// operands fuse the SAME positions, which the callers' positional
/// pairing (`chunks_exact(2)` over both slices) guarantees by
/// construction, for any slice offset or alignment.
#[inline]
pub(crate) fn fuse64(hi: u32, lo: u32) -> u64 {
    (u64::from(hi) << 32) | u64::from(lo)
}

/// Total popcount of `a ^ b`, 64 bits per `count_ones` via `fuse64`
/// pairing, odd final word handled scalar.
#[inline]
pub fn xor_popcount(a: &[u32], b: &[u32]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let a2 = a.chunks_exact(2);
    let b2 = b.chunks_exact(2);
    let mut acc: u32 = match (a2.remainder(), b2.remainder()) {
        (&[x], &[y]) => (x ^ y).count_ones(),
        _ => 0,
    };
    for (p, q) in a2.zip(b2) {
        acc += (fuse64(p[0], p[1]) ^ fuse64(q[0], q[1])).count_ones();
    }
    acc
}

/// Sign function from the paper (Eq. 1): -1 if x <= 0 else +1.
#[inline]
pub fn sign_pm1(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else {
        -1.0
    }
}

/// Folded BN threshold: bit = (y > theta) xor flip (ref.py convention).
#[inline]
pub fn threshold_bit(y: f32, theta: f32, flip: u32) -> u32 {
    (u32::from(y > theta)) ^ flip
}

/// Channel-pack one pixel: bits for channels 0..C (C <= 32), channel c at
/// bit position 31-c (matches ref.pack_bits over the trailing channel axis
/// with B=32).
#[inline]
pub fn pack_channels32(bits: impl IntoIterator<Item = u32>) -> u32 {
    let mut w = 0u32;
    for (c, bit) in bits.into_iter().enumerate() {
        debug_assert!(c < 32 && bit <= 1);
        w |= bit << (31 - c);
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, ensure, ensure_eq};

    /// Scalar reference dot in the ±1 domain.
    fn naive_dot(a_bits: &[u32], b_bits: &[u32]) -> i32 {
        a_bits
            .iter()
            .zip(b_bits)
            .map(|(&x, &y)| if x == y { 1 } else { -1 })
            .sum()
    }

    #[test]
    fn pack_matches_eq2_example() {
        // bits 1,0,1,1 at B=4 -> 0b1011
        assert_eq!(pack_bits(&[1, 0, 1, 1], 4), vec![0b1011]);
        // element 0 is the MSB
        assert_eq!(pack_bits(&[1, 0, 0, 0], 4), vec![0b1000]);
    }

    #[test]
    fn pack_tail_bits_zero() {
        let w = pack_bits(&[1, 1, 1], 32);
        assert_eq!(w, vec![0b111u32 << 29]);
    }

    #[test]
    fn unpack_inverts_pack_all_bitwidths() {
        prop::check(128, |g| {
            let b = g.usize_in(1, 32);
            let d = g.usize_in(1, 300);
            let bits = g.bits(d);
            let packed = pack_bits(&bits, b);
            ensure_eq(unpack_bits(&packed, d, b), bits, "unpack∘pack = id")
        });
    }

    #[test]
    fn packed_dot_equals_naive_dot() {
        prop::check(256, |g| {
            let b = *g.pick(&[8usize, 16, 25, 32]);
            let d = g.usize_in(1, 2048);
            let xa = g.bits(d);
            let xb = g.bits(d);
            let pa = pack_bits(&xa, b);
            let pb = pack_bits(&xb, b);
            ensure_eq(packed_dot(&pa, &pb, d), naive_dot(&xa, &xb), "Eq.4")
        });
    }

    #[test]
    fn packed_dot_bounds() {
        prop::check(128, |g| {
            let d = g.usize_in(1, 512);
            let pa = pack_bits(&g.bits(d), 32);
            let pb = pack_bits(&g.bits(d), 32);
            let dot = packed_dot(&pa, &pb, d);
            ensure(
                dot.abs() as usize <= d && (dot + d as i32) % 2 == 0,
                format!("dot {dot} within ±{d} and parity"),
            )
        });
    }

    #[test]
    fn pack_pm1_agrees_with_pack_bits() {
        prop::check(64, |g| {
            let d = g.usize_in(1, 256);
            let xs = g.pm1(d);
            let bits: Vec<u32> = xs.iter().map(|&x| u32::from(x > 0.0)).collect();
            ensure_eq(pack_pm1(&xs, 32), pack_bits(&bits, 32), "pm1 packing")
        });
    }

    #[test]
    fn mixed_alignment_slices() {
        // slices offset by one u32 used to hit a pointer-reinterpret
        // fallback whose mismatched wide/narrow splits silently dropped
        // words; the fuse64 pairing is positional by construction, but
        // this stays as the bit-identity regression for offset slices
        prop::check(64, |g| {
            let n = g.usize_in(2, 33);
            let buf = g.words(n + 1);
            let a = &buf[0..n];
            let b = &buf[1..n + 1];
            let scalar: u32 = a.iter().zip(b).map(|(&x, &y)| (x ^ y).count_ones()).sum();
            ensure_eq(xor_popcount(a, b), scalar, "offset slices")
        });
    }

    #[test]
    fn xor_popcount_handles_odd_lengths() {
        prop::check(64, |g| {
            let n = g.usize_in(1, 65);
            let a = g.words(n);
            let b = g.words(n);
            let scalar: u32 = a.iter().zip(&b).map(|(&x, &y)| (x ^ y).count_ones()).sum();
            ensure_eq(xor_popcount(&a, &b), scalar, "u64 fast path == scalar")
        });
    }

    #[test]
    fn sign_of_zero_is_minus_one() {
        assert_eq!(sign_pm1(0.0), -1.0);
        assert_eq!(sign_pm1(-0.5), -1.0);
        assert_eq!(sign_pm1(1e-30), 1.0);
    }

    #[test]
    fn threshold_bit_flip_semantics() {
        assert_eq!(threshold_bit(5.0, 3.0, 0), 1);
        assert_eq!(threshold_bit(5.0, 3.0, 1), 0);
        assert_eq!(threshold_bit(2.0, 3.0, 0), 0);
        assert_eq!(threshold_bit(2.0, 3.0, 1), 1);
        // exact equality: y > theta is false
        assert_eq!(threshold_bit(3.0, 3.0, 0), 0);
    }

    #[test]
    fn pack_channels32_is_msb_first() {
        assert_eq!(pack_channels32([1, 0, 0]), 1 << 31);
        assert_eq!(pack_channels32([0, 1, 1]), (1 << 30) | (1 << 29));
        let all = pack_channels32((0..32).map(|_| 1u32));
        assert_eq!(all, u32::MAX);
    }
}
