//! The fusion optimizer: pure `Plan → Plan` rewrites, proof-carrying.
//!
//! The paper wins its 7.4× at kernel level; SBNN-style intra-layer
//! fusion is the next tier (ROADMAP item 1): fold the learned threshold
//! into the popcount epilogue so counts never round-trip through
//! memory, compute the input binarization inside the im2col gather so
//! the ±1 float image is never materialized, and finally drop the i32
//! counts buffer entirely.  Every fusion so far in this codebase was
//! hand-argued; these are *checked*.  A pass here only ever produces a
//! candidate — the loader refuses to serve it unless
//! [`super::equiv::check_equiv`] proves it computes the same function
//! as the original plan AND [`super::verify_plan`] re-proves the fused
//! plan's resource soundness.  Three passes, applied in
//! [`RewritePass::ALL`] order:
//!
//! 1. **[`RewritePass::FoldThreshold`]** — `threshold ∘ popcount ≡
//!    fused-epilogue compare`: a `ConvBinPacked`/`ConvBinWords` step
//!    followed by the `ThresholdPack` that consumes its counts becomes
//!    one `*Threshold` step (likewise `FcBin` + `ThresholdPm1` →
//!    `FcBinThreshold`).  The conv's counts output edge disappears; in
//!    this staged form the raw counts are still written to the step's
//!    `scratch2` so the fusion is observable and separately priced.
//! 2. **[`RewritePass::FusePack`]** — `binarize ∘ im2col ≡
//!    pack-while-gather`: an rgb/gray `Binarize` step followed by the
//!    packed conv that consumes it becomes one `BinarizeConvBin*` step;
//!    each gathered pixel's sign bit is computed on the fly.  LBP never
//!    fuses (every patch needs the whole grayscale plane first), and
//!    `Scheme::None` plans have no binarize step to fuse.
//! 3. **[`RewritePass::ElideCounts`]** — drop `scratch2`: legal only
//!    when the counts edge has a single (fused) threshold reader, which
//!    the pass re-checks and [`super::equiv`] independently enforces.
//!
//! Every fusion erases the edge between its two steps, so it is legal
//! only when that edge has exactly ONE reader — the fusion partner.
//! Plans are DAGs (`Add`/`Concat` carry second operands, `Split` fans
//! out), so [`merge_pairs`] guards every candidate pair with a
//! fan-out scan and skips multi-consumer sites; the equivalence
//! checker's `MultiConsumerFusion` axiom independently refuses any
//! rewrite that crossed one anyway.
//!
//! After any step-list surgery the per-edge live intervals change, so
//! every pass ends with [`recolor`]: the same interval-graph liveness
//! coloring `plan::compile` runs — an edge stays allocated until its
//! LAST reader over both operand slots — re-assigning arena slots from
//! scratch.  The weight list is untouched — a fused step binds the
//! union of its constituents' tensors, so the rewritten plan loads the
//! exact same container bytes.

use std::collections::BTreeMap;

use super::plan::{BufClass, BufId, Plan, Slots, Src, Step, StepKind};
use crate::input::binarize::Scheme;

/// One rewrite pass of the fusion optimizer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RewritePass {
    /// Fold a threshold step into the preceding popcount epilogue.
    FoldThreshold,
    /// Fuse rgb/gray input binarization into the im2col pack.
    FusePack,
    /// Elide the i32 counts buffer of fused conv+threshold steps.
    ElideCounts,
}

impl RewritePass {
    /// Every pass, in canonical application order (elision only has
    /// sites once folding has run).
    pub const ALL: [RewritePass; 3] =
        [RewritePass::FoldThreshold, RewritePass::FusePack, RewritePass::ElideCounts];

    pub fn name(self) -> &'static str {
        match self {
            RewritePass::FoldThreshold => "fold-threshold",
            RewritePass::FusePack => "fuse-pack",
            RewritePass::ElideCounts => "elide-counts",
        }
    }
}

/// `"fold-threshold+fuse-pack+elide-counts"`-style tag for a pass list
/// (the loader's `list_models` rewrite status).
pub fn pass_names(passes: &[RewritePass]) -> String {
    let names: Vec<&str> = passes.iter().map(|p| p.name()).collect();
    names.join("+")
}

/// Apply `passes` in order.  Pure: the input plan is untouched, and a
/// pass with no applicable site is the identity (a float plan sails
/// through unchanged).  The result is a *candidate* — callers must
/// gauntlet it through `check_equiv` + `verify_plan` before serving.
pub fn rewrite_plan(plan: &Plan, passes: &[RewritePass]) -> Plan {
    let mut out = plan.clone();
    for pass in passes {
        out = match pass {
            RewritePass::FoldThreshold => fold_threshold(&out),
            RewritePass::FusePack => fuse_pack(&out),
            RewritePass::ElideCounts => elide_counts(&out),
        };
    }
    out
}

/// Placeholder slot for a freshly-introduced scratch; [`recolor`]
/// assigns the real index (and `verify_plan` would refuse a leak).
fn placeholder(class: BufClass) -> BufId {
    BufId { class, idx: usize::MAX }
}

/// Pass 1: `threshold ∘ popcount` → fused epilogue compare.
fn fold_threshold(plan: &Plan) -> Plan {
    let mut out = plan.clone();
    out.steps = merge_pairs(&out.steps, try_fold);
    recolor(out)
}

fn try_fold(conv: &Step, thr: &Step) -> Option<Step> {
    // the threshold must consume exactly the conv's output edge
    if thr.input != Src::Buf(conv.output) {
        return None;
    }
    match (&conv.kind, &thr.kind) {
        (
            StepKind::ConvBinPacked { k, c_out, nw, d, w },
            StepKind::ThresholdPack { f32_in: false, theta, flip },
        ) => Some(Step {
            kind: StepKind::ConvBinPackedThreshold {
                k: *k,
                c_out: *c_out,
                nw: *nw,
                d: *d,
                w: w.clone(),
                theta: theta.clone(),
                flip: flip.clone(),
                cmp_bias: 0,
                elide: false,
            },
            input: conv.input,
            input2: None,
            output: thr.output,
            scratch: conv.scratch,
            scratch2: Some(placeholder(BufClass::I32)),
            in_ty: conv.in_ty,
            out_ty: thr.out_ty,
            label_a: conv.label_a.clone(),
            label_b: Some(fused_label(conv.label_b.as_deref(), &conv.label_a, &thr.label_a)),
        }),
        (
            StepKind::ConvBinWords { k, c_out, d, w },
            StepKind::ThresholdPack { f32_in: false, theta, flip },
        ) => Some(Step {
            kind: StepKind::ConvBinWordsThreshold {
                k: *k,
                c_out: *c_out,
                d: *d,
                w: w.clone(),
                theta: theta.clone(),
                flip: flip.clone(),
                cmp_bias: 0,
                elide: false,
            },
            input: conv.input,
            input2: None,
            output: thr.output,
            scratch: conv.scratch,
            scratch2: Some(placeholder(BufClass::I32)),
            in_ty: conv.in_ty,
            out_ty: thr.out_ty,
            label_a: conv.label_a.clone(),
            label_b: Some(fused_label(conv.label_b.as_deref(), &conv.label_a, &thr.label_a)),
        }),
        (StepKind::FcBin { kw, c_out, d, w }, StepKind::ThresholdPm1 { theta, flip }) => {
            Some(Step {
                // the FC's counts are scalars consumed one compare at a
                // time — the register-resident form needs no staging
                // buffer, so there is no `elide` step for it
                kind: StepKind::FcBinThreshold {
                    kw: *kw,
                    c_out: *c_out,
                    d: *d,
                    w: w.clone(),
                    theta: theta.clone(),
                    flip: flip.clone(),
                    cmp_bias: 0,
                },
                input: conv.input,
                input2: None,
                output: thr.output,
                scratch: None,
                scratch2: None,
                in_ty: conv.in_ty,
                out_ty: thr.out_ty,
                label_a: format!("{}+{}", conv.label_a, thr.label_a),
                label_b: None,
            })
        }
        _ => None,
    }
}

/// Pass 2: `binarize ∘ im2col` → pack-while-gather.
fn fuse_pack(plan: &Plan) -> Plan {
    let mut out = plan.clone();
    out.steps = merge_pairs(&out.steps, try_fuse);
    recolor(out)
}

fn try_fuse(bin: &Step, conv: &Step) -> Option<Step> {
    if conv.input != Src::Buf(bin.output) {
        return None;
    }
    // LBP needs the whole grayscale plane before any patch can be
    // gathered; Scheme::None plans have no binarize step at all
    let scheme = match bin.kind {
        StepKind::Binarize { scheme: s @ (Scheme::Rgb | Scheme::Gray) } => s,
        _ => return None,
    };
    let (kind, label_b) = match &conv.kind {
        StepKind::ConvBinPacked { k, c_out, nw, d, w } => (
            StepKind::BinarizeConvBin {
                scheme,
                k: *k,
                c_out: *c_out,
                nw: *nw,
                d: *d,
                w: w.clone(),
            },
            conv.label_b.clone(),
        ),
        StepKind::ConvBinPackedThreshold { k, c_out, nw, d, w, theta, flip, cmp_bias, elide } => {
            (
                StepKind::BinarizeConvBinThreshold {
                    scheme,
                    k: *k,
                    c_out: *c_out,
                    nw: *nw,
                    d: *d,
                    w: w.clone(),
                    theta: theta.clone(),
                    flip: flip.clone(),
                    cmp_bias: *cmp_bias,
                    elide: *elide,
                },
                conv.label_b.clone(),
            )
        }
        _ => return None,
    };
    Some(Step {
        kind,
        input: bin.input,
        input2: None,
        output: conv.output,
        scratch: conv.scratch,
        scratch2: conv.scratch2,
        in_ty: bin.in_ty,
        out_ty: conv.out_ty,
        label_a: format!("binarize+{}", conv.label_a),
        label_b,
    })
}

/// Pass 3: drop the staged counts buffer (`scratch2`) of every fused
/// conv+threshold step whose counts have no reader besides the fused
/// epilogue itself — the single-reader precondition of the elision
/// axiom, re-checked here and independently by [`super::equiv`].
fn elide_counts(plan: &Plan) -> Plan {
    let mut out = plan.clone();
    for i in 0..out.steps.len() {
        let Some(counts) = out.steps[i].scratch2 else { continue };
        let second_reader = out.steps[i + 1..]
            .iter()
            .any(|s| s.input == Src::Buf(counts) || s.input2 == Some(Src::Buf(counts)));
        if second_reader {
            continue;
        }
        match &mut out.steps[i].kind {
            StepKind::ConvBinPackedThreshold { elide, .. }
            | StepKind::ConvBinWordsThreshold { elide, .. }
            | StepKind::BinarizeConvBinThreshold { elide, .. } => {
                *elide = true;
                out.steps[i].scratch2 = None;
            }
            _ => {}
        }
    }
    recolor(out)
}

/// Walk the step list merging adjacent pairs `merge` accepts (a merged
/// step is not re-considered as the left half of another pair — the
/// passes compose across `rewrite_plan` calls instead).  A pair is
/// never offered to `merge` when the left step's output edge has a
/// reader besides its fusion partner: fusing would erase an edge some
/// other step still consumes (the multi-consumer fusion axiom).
fn merge_pairs(steps: &[Step], merge: impl Fn(&Step, &Step) -> Option<Step>) -> Vec<Step> {
    let mut out: Vec<Step> = Vec::with_capacity(steps.len());
    let mut i = 0;
    while i < steps.len() {
        if i + 1 < steps.len() && single_consumer(steps, i) {
            if let Some(fused) = merge(&steps[i], &steps[i + 1]) {
                out.push(fused);
                i += 2;
                continue;
            }
        }
        out.push(steps[i].clone());
        i += 1;
    }
    out
}

/// The fusion guard: does step `i`'s output edge have exactly one
/// reader (step `i + 1`)?  Reads through either operand slot count;
/// the scan stops once the slot is redefined, because past that point
/// the slot carries a different edge.
fn single_consumer(steps: &[Step], i: usize) -> bool {
    let out = Src::Buf(steps[i].output);
    for later in &steps[i + 2..] {
        if later.input == out || later.input2 == Some(out) {
            return false;
        }
        if later.output == steps[i].output
            || later.scratch == Some(steps[i].output)
            || later.scratch2 == Some(steps[i].output)
        {
            break;
        }
    }
    true
}

fn fused_label(b: Option<&str>, a: &str, thr: &str) -> String {
    format!("{}+{thr}", b.unwrap_or(a))
}

/// Re-run the interval-graph liveness coloring over a rewritten step
/// list: the same walk as `plan::compile`.  Operand slots are first
/// resolved back to producing-step edges (a pass's step surgery leaves
/// old slot ids behind), then every edge is held until its LAST reader
/// over both operand slots — allocating scratch/scratch2/output before
/// retiring dying edges keeps in/scratch/out pairwise distinct, and a
/// skip edge stays allocated across the whole trunk between its
/// producer and its second reader.
fn recolor(mut plan: Plan) -> Plan {
    let n = plan.steps.len();
    // resolve operand slots to the step that last (re-)defined them —
    // in the incoming (sound) plan a read always hits the most recent
    // covering write of its slot
    let mut last_def: BTreeMap<(usize, usize), usize> = BTreeMap::new();
    let key = |b: BufId| (b.class as usize, b.idx);
    let mut in_edge: Vec<Option<usize>> = vec![None; n];
    let mut in2_edge: Vec<Option<usize>> = vec![None; n];
    for j in 0..n {
        if let Src::Buf(b) = plan.steps[j].input {
            in_edge[j] = last_def.get(&key(b)).copied();
        }
        if let Some(Src::Buf(b)) = plan.steps[j].input2 {
            in2_edge[j] = last_def.get(&key(b)).copied();
        }
        last_def.insert(key(plan.steps[j].output), j);
    }
    // interval liveness: an edge dies at its last reader; the final
    // edge (the logits) survives past the end
    let mut last_use: Vec<usize> = (0..n).collect();
    for j in 0..n {
        if let Some(e) = in_edge[j] {
            last_use[e] = j;
        }
        if let Some(e) = in2_edge[j] {
            last_use[e] = j;
        }
    }
    let final_edge = n.saturating_sub(1);
    let mut slots = Slots::new();
    let mut buf_of: Vec<BufId> = Vec::with_capacity(n);
    for j in 0..n {
        let scratch = plan.steps[j].scratch.map(|s| slots.alloc(s.class));
        let scratch2 = plan.steps[j].scratch2.map(|s| slots.alloc(s.class));
        let output = slots.alloc(plan.steps[j].out_ty.class());
        buf_of.push(output);
        let mut dying: Vec<usize> = Vec::new();
        for e in [in_edge[j], in2_edge[j]].into_iter().flatten() {
            if last_use[e] == j && e != final_edge && !dying.contains(&e) {
                dying.push(e);
            }
        }
        for e in dying {
            slots.release(buf_of[e]);
        }
        if let Some(s) = scratch {
            slots.release(s);
        }
        if let Some(s) = scratch2 {
            slots.release(s);
        }
        let step = &mut plan.steps[j];
        if let Some(e) = in_edge[j] {
            step.input = Src::Buf(buf_of[e]);
        }
        if let Some(e) = in2_edge[j] {
            step.input2 = Some(Src::Buf(buf_of[e]));
        }
        step.scratch = scratch;
        step.scratch2 = scratch2;
        step.output = output;
    }
    plan.nbufs = slots.next;
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::graph::verify::verify_plan;
    use crate::bnn::graph::{check_equiv, test_specs, Activation, LayerOp, NetworkSpec};
    use crate::bnn::network::NUM_CLASSES;

    fn three_conv_spec() -> NetworkSpec {
        NetworkSpec {
            ops: vec![
                LayerOp::Binarize { scheme: Scheme::Gray },
                LayerOp::ConvBin { k: 5, c_out: 32 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::ConvBin { k: 3, c_out: 32 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::ConvBin { k: 3, c_out: 32 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::FcBin { c_out: 64 },
                LayerOp::Threshold,
                LayerOp::FcFloat { c_out: NUM_CLASSES, bias: true, act: Activation::None },
            ],
        }
    }

    fn all_specs() -> Vec<NetworkSpec> {
        let mut v: Vec<NetworkSpec> =
            Scheme::ALL.iter().map(|&s| NetworkSpec::legacy_bcnn(s)).collect();
        v.push(NetworkSpec::legacy_float());
        v.push(three_conv_spec());
        v.extend(test_specs::all().into_iter().map(|(_, s)| s));
        v
    }

    #[test]
    fn every_pass_combination_verifies_and_proves_equivalent() {
        // the whole point: no rewrite output is trusted — each one must
        // survive the same gauntlet the loader runs
        let combos: Vec<Vec<RewritePass>> = vec![
            vec![RewritePass::FoldThreshold],
            vec![RewritePass::FusePack],
            vec![RewritePass::ElideCounts], // identity without fold
            vec![RewritePass::FoldThreshold, RewritePass::ElideCounts],
            RewritePass::ALL.to_vec(),
        ];
        for spec in all_specs() {
            let plan = spec.plan().unwrap();
            for passes in &combos {
                let rewritten = rewrite_plan(&plan, passes);
                check_equiv(&plan, &rewritten).unwrap_or_else(|e| {
                    panic!("{}: not equivalent: {e}", pass_names(passes))
                });
                verify_plan(&rewritten)
                    .unwrap_or_else(|e| panic!("{}: unsound: {e}", pass_names(passes)));
            }
        }
    }

    #[test]
    fn the_full_rewrite_fuses_the_legacy_rgb_plan_to_seven_steps() {
        // 11 steps -> 7: binarize+conv1+threshold1 fuse, conv2+threshold2
        // fuse, fc1+threshold3 fuse; pools and the float tail remain
        let plan = NetworkSpec::legacy_bcnn(Scheme::Rgb).plan().unwrap();
        let rw = rewrite_plan(&plan, &RewritePass::ALL);
        assert_eq!(plan.steps.len(), 11);
        assert_eq!(rw.steps.len(), 7);
        assert!(matches!(
            rw.steps[0].kind,
            StepKind::BinarizeConvBinThreshold { elide: true, cmp_bias: 0, .. }
        ));
        assert!(matches!(rw.steps[1].kind, StepKind::OrPool));
        assert!(matches!(
            rw.steps[2].kind,
            StepKind::ConvBinWordsThreshold { elide: true, .. }
        ));
        assert!(matches!(rw.steps[4].kind, StepKind::FcBinThreshold { .. }));
        // all counts buffers elided: the i32 pool is gone entirely
        assert_eq!(rw.nbufs[2], 0, "i32 slots survived elision: {:?}", rw.nbufs);
        // the weight list is untouched — same container bytes bind
        assert_eq!(plan.weights, rw.weights);
    }

    #[test]
    fn staged_fold_keeps_the_counts_buffer_until_elision() {
        let plan = NetworkSpec::legacy_bcnn(Scheme::Gray).plan().unwrap();
        let folded = rewrite_plan(&plan, &[RewritePass::FoldThreshold]);
        let fused_conv = folded
            .steps
            .iter()
            .find(|s| matches!(s.kind, StepKind::ConvBinPackedThreshold { .. }))
            .unwrap();
        assert!(
            matches!(fused_conv.kind, StepKind::ConvBinPackedThreshold { elide: false, .. }),
            "fold alone must not elide"
        );
        assert_eq!(fused_conv.scratch2.map(|s| s.class), Some(BufClass::I32));
        let elided = rewrite_plan(&folded, &[RewritePass::ElideCounts]);
        assert!(elided.steps.iter().all(|s| s.scratch2.is_none()));
        assert_eq!(elided.nbufs[2], 0);
    }

    #[test]
    fn lbp_and_none_schemes_never_fuse_the_gather() {
        // LBP needs the whole gray plane; None has no binarize step
        for scheme in [Scheme::Lbp, Scheme::None] {
            let plan = NetworkSpec::legacy_bcnn(scheme).plan().unwrap();
            let rw = rewrite_plan(&plan, &RewritePass::ALL);
            assert!(
                !rw.steps.iter().any(|s| matches!(
                    s.kind,
                    StepKind::BinarizeConvBin { .. } | StepKind::BinarizeConvBinThreshold { .. }
                )),
                "{scheme:?} fused its gather"
            );
        }
    }

    #[test]
    fn rewriting_shrinks_the_proven_arena_envelope() {
        // the optimizer's whole pitch in one number: peak bytes drop
        let plan = NetworkSpec::legacy_bcnn(Scheme::Rgb).plan().unwrap();
        let before = verify_plan(&plan).unwrap();
        let after = verify_plan(&rewrite_plan(&plan, &RewritePass::ALL)).unwrap();
        let total = |p: [usize; 3]| p.iter().sum::<usize>();
        assert!(
            total(after.peak_bytes) < total(before.peak_bytes),
            "no envelope win: {:?} -> {:?}",
            before.peak_bytes,
            after.peak_bytes
        );
        // the i32 counts pool specifically is gone
        assert_eq!(after.peak_bytes[2], 0);
    }

    #[test]
    fn fused_labels_name_both_ops() {
        let plan = NetworkSpec::legacy_bcnn(Scheme::Rgb).plan().unwrap();
        let rw = rewrite_plan(&plan, &RewritePass::ALL);
        let names = rw.step_names();
        for want in ["binarize+im2col1", "gemm1+threshold_pack1", "fc1+threshold3"] {
            assert!(names.iter().any(|n| n == want), "missing {want} in {names:?}");
        }
    }

    #[test]
    fn fusion_stops_at_a_multi_consumer_edge() {
        // residual_binary's first counts edge feeds BOTH its threshold
        // and the later Add — folding conv+threshold there would orphan
        // the skip reader, so the rewriter must leave the pair split
        let plan = test_specs::residual_binary().plan().unwrap();
        let rw = rewrite_plan(&plan, &RewritePass::ALL);
        let names = rw.step_names();
        assert!(
            names.iter().any(|n| n == "threshold_pack1"),
            "the protected threshold was fused away: {names:?}"
        );
        assert!(
            !names.iter().any(|n| n == "gemm1+threshold_pack1"),
            "fusion crossed a multi-consumer edge: {names:?}"
        );
        // and the proof agrees: the honest rewrite passes the axiom
        check_equiv(&plan, &rw).unwrap();
        verify_plan(&rw).unwrap();
    }

    #[test]
    fn recolor_keeps_a_skip_edge_alive_across_the_trunk() {
        // after rewriting, the residual_float Add must still read a
        // buffer nobody clobbered between its def and the join
        let plan = test_specs::residual_float().plan().unwrap();
        let rw = rewrite_plan(&plan, &RewritePass::ALL);
        verify_plan(&rw).unwrap();
        let add = rw
            .steps
            .iter()
            .position(|s| matches!(s.kind, StepKind::Add))
            .expect("residual plan lost its Add");
        let skip = match rw.steps[add].input2 {
            Some(Src::Buf(b)) => b,
            other => panic!("Add second operand is not a buffer: {other:?}"),
        };
        let def = rw.steps[..add]
            .iter()
            .rposition(|s| s.output == skip)
            .expect("no writer for the skip edge");
        for (j, s) in rw.steps.iter().enumerate().take(add).skip(def + 1) {
            assert_ne!(s.output, skip, "step {j} clobbered the live skip edge");
        }
    }

    #[test]
    fn pass_names_tag_is_stable() {
        assert_eq!(pass_names(&RewritePass::ALL), "fold-threshold+fuse-pack+elide-counts");
        assert_eq!(pass_names(&[]), "");
    }
}
