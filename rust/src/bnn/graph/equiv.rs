//! Static equivalence checker: prove a rewritten [`Plan`] computes the
//! *same function* as the original, without executing either.
//!
//! [`super::verify_plan`] proves a single plan resource-sound (aliasing,
//! dataflow, shapes, weights) — but a fusion optimizer needs a stronger
//! property: that the plan it produced is *semantically interchangeable*
//! with the plan it started from.  XNOR-Net-style pipelines make this
//! easy to get silently wrong (a pad bit in the wrong class, a compare
//! moved across the popcount, a counts buffer privatized while a second
//! reader still exists), and several of those bugs are invisible to the
//! slot/shape verifier because the broken plan is still perfectly
//! resource-sound.  This module closes that gap with symbolic value
//! numbering over plan dataflow:
//!
//! * Every edge gets an **abstract value term** — built by interning
//!   `(operand value-number, primitive descriptor)` pairs, where a
//!   descriptor names the op, its resolved parameters (kernel, depth,
//!   packed row width = the pad-bit class), its weight tensor names, and
//!   its output extent/dtype.  Identical terms ⇔ identical computed
//!   values, by construction.
//! * Fused step kinds **unfold** through algebraic axioms into the
//!   canonical primitive composition they claim to implement — exactly
//!   the legal fusions, nothing else:
//!   `threshold ∘ popcount ≡ fused-epilogue compare` (the conv/fc
//!   `*Threshold` kinds), `binarize ∘ im2col ≡ pack-while-gather`
//!   (the `BinarizeConvBin*` kinds), and counts-elision, which adds no
//!   term at all but is legal **only** when the counts edge has a
//!   single threshold reader (checked structurally below).
//! * The two plans' term sequences are compared in emission order; both
//!   must end in the identical final-logit term.  The first divergence
//!   is reported as a structured [`EquivError::Diverged`] naming the
//!   step and term *in both plans*.
//!
//! Four structural axiom preconditions are checked before value
//! numbering, because they are semantic facts the term language
//! deliberately leaves out of descriptors.  The first three are
//! per-plan; the fourth compares the two plans pairwise — a fusion is
//! only meaning-preserving when the edge it hides had no other reader,
//! and that is a fact about the *original* plan's fan-out:
//!
//! | axiom | precondition | violation |
//! |---|---|---|
//! | fold threshold | epilogue compare is exactly `count > theta` (`cmp_bias == 0`) | [`EquivError::EpilogueBias`] |
//! | any packed conv | weight row width is exactly `ceil(d/32)` (the pad-bit class) | [`EquivError::PadClass`] |
//! | elide counts | the fused counts edge has no reader besides the epilogue | [`EquivError::CountsSecondReader`] |
//! | any fusion | a multi-consumer edge's producer keeps its labels — fusion never crosses it | [`EquivError::MultiConsumerFusion`] |
//!
//! `cmp_bias` is the showcase: a rewrite that off-by-ones the folded
//! compare produces a plan `verify_plan` happily accepts (every slot,
//! shape, and weight is fine) but whose logits are wrong on every
//! image.  Only this checker refuses it — which is why the loader's
//! gauntlet runs rewrite → `check_equiv` → `verify_plan` and falls back
//! to the unoptimized plan on any failure.

use std::collections::BTreeMap;

use crate::bnn::packing::packed_width;

use super::plan::{BufId, Plan, Src, Step, StepKind, ValKind, ValTy};
use super::verify::kind_name;

/// A structured equivalence failure.  Every variant names the step(s)
/// at fault so a refused rewrite is diagnosable from the error string.
#[derive(Debug)]
pub enum EquivError {
    /// A fused threshold epilogue compares `count + bias > theta` with a
    /// nonzero bias — semantically a different function, even though the
    /// plan is resource-sound.
    EpilogueBias { step: usize, bias: i32 },
    /// A packed conv's weight row width is not `ceil(d/32)` — its
    /// pad-bit class differs from the canonical primitive's, so the
    /// popcount terms are not interchangeable.
    PadClass { step: usize, op: String, why: String },
    /// A step reads the counts edge a fused conv+threshold claims as
    /// private — counts elision is legal only with a single threshold
    /// reader.
    CountsSecondReader { fused_step: usize, reader_step: usize },
    /// An original step whose output edge has two or more readers was
    /// fused away by the rewrite — the fused kind computes the edge for
    /// its own epilogue only, so every *other* reader now consumes a
    /// value that no longer exists.
    MultiConsumerFusion { step: usize, label: String },
    /// The two plans emit different value terms: the first diverging
    /// term, named in both plans (`<end of plan>` if one ran out).
    Diverged { step_a: usize, step_b: usize, term_a: String, term_b: String },
}

crate::error_enum_impls!(EquivError {
    EquivError::EpilogueBias { step, bias } =>
        ("step {step}: fused threshold epilogue carries cmp_bias={bias}; \
          a sound fold compares the raw popcount (bias 0)"),
    EquivError::PadClass { step, op, why } => ("step {step} ({op}): pad-bit class: {why}"),
    EquivError::CountsSecondReader { fused_step, reader_step } =>
        ("step {reader_step} reads the counts edge step {fused_step} fused away — \
          counts elision requires a single threshold reader"),
    EquivError::MultiConsumerFusion { step, label } =>
        ("step {step} ({label}) produces a multi-consumer edge but was fused away — \
          fusion may not cross an edge with more than one reader"),
    EquivError::Diverged { step_a, step_b, term_a, term_b } =>
        ("plans diverge: original step {step_a} emits [{term_a}], \
          rewritten step {step_b} emits [{term_b}]"),
});

/// Prove `rewritten` computes the same function as `original`.  Checks
/// the structural axiom preconditions on both plans (the rewritten one
/// first — that is where a broken optimizer shows up), then compares
/// their symbolic value-number traces term by term.
pub fn check_equiv(original: &Plan, rewritten: &Plan) -> Result<(), EquivError> {
    for plan in [rewritten, original] {
        epilogue_unbiased(plan)?;
        pad_class_sound(plan)?;
        counts_single_reader(plan)?;
    }
    fusion_single_consumer(original, rewritten)?;

    // one shared interner: identical (operand, descriptor) chains get
    // identical ids across both plans, so term equality is id equality
    let mut vn = Vn::new();
    let ta = symbolic_trace(original, &mut vn);
    let tb = symbolic_trace(rewritten, &mut vn);
    for i in 0..ta.len().max(tb.len()) {
        let (a, b) = (ta.get(i), tb.get(i));
        let same = matches!((a, b), (Some(x), Some(y)) if x.desc == y.desc && x.value == y.value);
        if !same {
            let end = "<end of plan>".to_string();
            return Err(EquivError::Diverged {
                step_a: a.map_or(original.steps.len(), |t| t.step),
                step_b: b.map_or(rewritten.steps.len(), |t| t.step),
                term_a: a.map_or(end.clone(), fmt_term),
                term_b: b.map_or(end, fmt_term),
            });
        }
    }
    Ok(())
}

/// Axiom precondition: every fused epilogue compares the raw popcount.
fn epilogue_unbiased(plan: &Plan) -> Result<(), EquivError> {
    for (j, step) in plan.steps.iter().enumerate() {
        let bias = match step.kind {
            StepKind::ConvBinPackedThreshold { cmp_bias, .. }
            | StepKind::ConvBinWordsThreshold { cmp_bias, .. }
            | StepKind::BinarizeConvBinThreshold { cmp_bias, .. }
            | StepKind::FcBinThreshold { cmp_bias, .. } => cmp_bias,
            _ => 0,
        };
        if bias != 0 {
            return Err(EquivError::EpilogueBias { step: j, bias });
        }
    }
    Ok(())
}

/// Axiom precondition: every packed conv row is exactly `ceil(d/32)`
/// words — the pad-bit class the canonical primitives assume.
fn pad_class_sound(plan: &Plan) -> Result<(), EquivError> {
    for (j, step) in plan.steps.iter().enumerate() {
        let row = match step.kind {
            StepKind::ConvBinPacked { nw, d, .. }
            | StepKind::ConvBinPackedThreshold { nw, d, .. }
            | StepKind::BinarizeConvBin { nw, d, .. }
            | StepKind::BinarizeConvBinThreshold { nw, d, .. } => Some((nw, d)),
            _ => None,
        };
        if let Some((nw, d)) = row {
            if nw != packed_width(d, 32) {
                return Err(EquivError::PadClass {
                    step: j,
                    op: kind_name(&step.kind).to_string(),
                    why: format!(
                        "{nw} weight words per row for d={d} packed bits (canonical class \
                         is {}) — the popcount terms are not interchangeable",
                        packed_width(d, 32)
                    ),
                });
            }
        }
    }
    Ok(())
}

/// Axiom precondition: a fused step's counts edge (`scratch2`) is
/// private to its own epilogue.  The scan stops at the first later step
/// that re-defines the slot (output or scratch) — past that point the
/// slot holds a different edge entirely.
fn counts_single_reader(plan: &Plan) -> Result<(), EquivError> {
    for (j, step) in plan.steps.iter().enumerate() {
        let Some(s) = step.scratch2 else { continue };
        for (r, later) in plan.steps.iter().enumerate().skip(j + 1) {
            if later.input == Src::Buf(s) {
                return Err(EquivError::CountsSecondReader { fused_step: j, reader_step: r });
            }
            if later.output == s || later.scratch == Some(s) || later.scratch2 == Some(s) {
                break;
            }
        }
    }
    Ok(())
}

/// Pairwise axiom precondition: for every original step whose output
/// edge has two or more readers (counting both operand slots of every
/// later step, up to the slot's redefinition), the rewritten plan must
/// still contain a step with the identical `(label_a, label_b)` pair.
/// Honest passes never relabel a step they did not fuse, and the fusion
/// guards refuse multi-consumer sites — so a missing label pair means a
/// fusion crossed an edge somebody else still reads.
fn fusion_single_consumer(original: &Plan, rewritten: &Plan) -> Result<(), EquivError> {
    for (j, step) in original.steps.iter().enumerate() {
        let out = Src::Buf(step.output);
        let mut readers = 0usize;
        for later in &original.steps[j + 1..] {
            if later.input == out || later.input2 == Some(out) {
                readers += 1;
            }
            if later.output == step.output
                || later.scratch == Some(step.output)
                || later.scratch2 == Some(step.output)
            {
                break;
            }
        }
        if readers < 2 {
            continue;
        }
        let survives = rewritten
            .steps
            .iter()
            .any(|s| s.label_a == step.label_a && s.label_b == step.label_b);
        if !survives {
            let label = match &step.label_b {
                Some(b) => format!("{}/{b}", step.label_a),
                None => step.label_a.clone(),
            };
            return Err(EquivError::MultiConsumerFusion { step: j, label });
        }
    }
    Ok(())
}

// ---- symbolic value numbering --------------------------------------

/// The interner: a value number per distinct `(operand, descriptor)`
/// application.  Shared across both plans so equal chains intern equal.
struct Vn {
    table: BTreeMap<(u64, String), u64>,
    next: u64,
}

impl Vn {
    fn new() -> Self {
        Self { table: BTreeMap::new(), next: 1 }
    }

    /// Value number of applying `desc` to operand `v`.
    fn id(&mut self, v: u64, desc: &str) -> u64 {
        if let Some(&n) = self.table.get(&(v, desc.to_string())) {
            return n;
        }
        let n = self.next;
        self.next += 1;
        self.table.insert((v, desc.to_string()), n);
        n
    }

    /// A fresh opaque value no chain can reproduce — an undefined read
    /// (e.g. of a clobbered slot) poisons everything downstream of it.
    fn fresh(&mut self) -> u64 {
        let n = self.next;
        self.next += 1;
        n
    }
}

/// One emitted term: primitive `desc` applied at `step`, valued `value`.
struct Term {
    step: usize,
    desc: String,
    value: u64,
}

fn fmt_term(t: &Term) -> String {
    format!("{} = v{}", t.desc, t.value)
}

fn slot_key(b: BufId) -> (usize, usize) {
    (b.class as usize, b.idx)
}

/// Value-number every edge of `plan`, emitting one [`Term`] per
/// unfolded primitive.  Scratch clobbers poison their slot; reads of a
/// poisoned or never-written slot get a fresh opaque value (which can
/// never equal the other plan's term — divergence by construction).
fn symbolic_trace(plan: &Plan, vn: &mut Vn) -> Vec<Term> {
    let mut slot_values: BTreeMap<(usize, usize), u64> = BTreeMap::new();
    let mut trace = Vec::new();
    for (j, step) in plan.steps.iter().enumerate() {
        let mut v = match step.input {
            Src::External => vn.id(0, &format!("external#{}", step.in_ty.describe())),
            Src::Buf(b) => match slot_values.get(&slot_key(b)) {
                Some(&v) => v,
                None => vn.fresh(),
            },
        };
        // the second operand's value number is embedded in the binary
        // op's descriptor, so add/concat terms are sensitive to WHICH
        // edge the skip/branch carried, not just its shape
        let v2 = step.input2.map(|src| match src {
            Src::External => vn.id(0, &format!("external#{}", step.in_ty.describe())),
            Src::Buf(b) => match slot_values.get(&slot_key(b)) {
                Some(&v) => v,
                None => vn.fresh(),
            },
        });
        for desc in unfold(step, v2) {
            v = vn.id(v, &desc);
            trace.push(Term { step: j, desc, value: v });
        }
        if let Some(s) = step.scratch {
            slot_values.remove(&slot_key(s));
        }
        if let Some(s) = step.scratch2 {
            slot_values.remove(&slot_key(s));
        }
        slot_values.insert(slot_key(step.output), v);
    }
    trace
}

/// Unfold a step into its canonical primitive descriptors — one for a
/// base kind, the axiom's composition for a fused kind.  Descriptors
/// carry everything term equality must be sensitive to: op, resolved
/// parameters (the packed row width `nw` *is* the pad-bit class),
/// weight names, output extent/dtype, and — for binary ops — the value
/// number `v2` of the second operand edge.  They deliberately omit
/// `cmp_bias` and `elide` (judged structurally above — bias 0 and a
/// private counts edge make them semantically invisible) and timing
/// labels (cosmetic).
fn unfold(step: &Step, v2: Option<u64>) -> Vec<String> {
    let t = step.in_ty;
    let o = step.out_ty;
    let counts_mid = |c: usize| ValTy { kind: ValKind::Counts, h: o.h, w: o.w, c };
    match &step.kind {
        StepKind::Binarize { scheme } => vec![binarize_desc(*scheme, &o)],
        StepKind::ConvBinPacked { k, nw, d, w, .. } => {
            vec![conv_packed_desc(*k, *d, *nw, w, &o)]
        }
        StepKind::ConvBinWords { k, d, w, .. } => vec![conv_words_desc(*k, *d, w, &o)],
        StepKind::ConvFloat { k, relu, w, b, .. } => {
            vec![format!("conv_float[k={k},relu={relu},w={w},b={b:?}]->{}", o.describe())]
        }
        StepKind::MaxPool => vec![format!("maxpool->{}", o.describe())],
        StepKind::OrPool => vec![format!("orpool->{}", o.describe())],
        StepKind::ThresholdPack { f32_in, theta, flip } => {
            vec![threshold_pack_desc(*f32_in, theta, flip, &o)]
        }
        StepKind::ThresholdPm1 { theta, flip } => vec![threshold_pm1_desc(theta, flip, &o)],
        StepKind::FcBin { kw, d, w, .. } => vec![fc_bin_desc(*kw, *d, w, &o)],
        StepKind::FcFloat { d, act, w, b, .. } => {
            vec![format!("fc_float[d={d},act={},w={w},b={b:?}]->{}", act.name(), o.describe())]
        }
        // --- the axioms: fused kinds unfold to what they claim --------
        StepKind::ConvBinPackedThreshold { k, c_out, nw, d, w, theta, flip, .. } => vec![
            conv_packed_desc(*k, *d, *nw, w, &counts_mid(*c_out)),
            threshold_pack_desc(false, theta, flip, &o),
        ],
        StepKind::ConvBinWordsThreshold { k, c_out, d, w, theta, flip, .. } => vec![
            conv_words_desc(*k, *d, w, &counts_mid(*c_out)),
            threshold_pack_desc(false, theta, flip, &o),
        ],
        StepKind::BinarizeConvBin { scheme, k, nw, d, w, .. } => {
            let mid = ValTy { kind: ValKind::F32, h: t.h, w: t.w, c: scheme.input_channels() };
            vec![binarize_desc(*scheme, &mid), conv_packed_desc(*k, *d, *nw, w, &o)]
        }
        StepKind::BinarizeConvBinThreshold { scheme, k, c_out, nw, d, w, theta, flip, .. } => {
            let mid = ValTy { kind: ValKind::F32, h: t.h, w: t.w, c: scheme.input_channels() };
            vec![
                binarize_desc(*scheme, &mid),
                conv_packed_desc(*k, *d, *nw, w, &counts_mid(*c_out)),
                threshold_pack_desc(false, theta, flip, &o),
            ]
        }
        StepKind::FcBinThreshold { kw, c_out, d, w, theta, flip, .. } => vec![
            fc_bin_desc(*kw, *d, w, &counts_mid(*c_out)),
            threshold_pm1_desc(theta, flip, &o),
        ],
        // --- branch primitives: never fused, never reordered ----------
        // (a missing second operand renders as "undef", which can never
        // match a well-formed plan's term — divergence, not a panic)
        StepKind::Add => {
            let rhs = v2.map_or("undef".to_string(), |v| format!("v{v}"));
            vec![format!("add[rhs={rhs}]->{}", o.describe())]
        }
        StepKind::Concat => {
            let rhs = v2.map_or("undef".to_string(), |v| format!("v{v}"));
            vec![format!("concat[rhs={rhs}]->{}", o.describe())]
        }
        StepKind::SplitPart { lo } => {
            vec![format!("split[lo={lo}]->{}", o.describe())]
        }
        StepKind::Scale { alpha } => {
            vec![format!("scale[alpha={alpha}]->{}", o.describe())]
        }
    }
}

fn binarize_desc(scheme: crate::input::binarize::Scheme, ty: &ValTy) -> String {
    format!("binarize[{}]->{}", scheme.name(), ty.describe())
}

fn conv_packed_desc(k: usize, d: usize, nw: usize, w: &str, ty: &ValTy) -> String {
    format!("conv_bin_packed[k={k},d={d},nw={nw},w={w}]->{}", ty.describe())
}

fn conv_words_desc(k: usize, d: usize, w: &str, ty: &ValTy) -> String {
    format!("conv_bin_words[k={k},d={d},w={w}]->{}", ty.describe())
}

fn threshold_pack_desc(f32_in: bool, theta: &str, flip: &str, ty: &ValTy) -> String {
    format!("threshold_pack[f32_in={f32_in},theta={theta},flip={flip}]->{}", ty.describe())
}

fn threshold_pm1_desc(theta: &str, flip: &str, ty: &ValTy) -> String {
    format!("threshold_pm1[theta={theta},flip={flip}]->{}", ty.describe())
}

fn fc_bin_desc(kw: usize, d: usize, w: &str, ty: &ValTy) -> String {
    format!("fc_bin[kw={kw},d={d},w={w}]->{}", ty.describe())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::graph::plan::Corruption;
    use crate::bnn::graph::rewrite::{rewrite_plan, RewritePass};
    use crate::bnn::graph::verify::verify_plan;
    use crate::bnn::graph::NetworkSpec;
    use crate::input::binarize::Scheme;

    fn rgb_plan() -> Plan {
        NetworkSpec::legacy_bcnn(Scheme::Rgb).plan().unwrap()
    }

    #[test]
    fn a_plan_is_equivalent_to_itself_and_to_its_rewrites() {
        for scheme in Scheme::ALL {
            let plan = NetworkSpec::legacy_bcnn(scheme).plan().unwrap();
            check_equiv(&plan, &plan).unwrap();
            let rw = rewrite_plan(&plan, &RewritePass::ALL);
            check_equiv(&plan, &rw).unwrap_or_else(|e| panic!("{scheme:?}: {e}"));
        }
        let float = NetworkSpec::legacy_float().plan().unwrap();
        check_equiv(&float, &rewrite_plan(&float, &RewritePass::ALL)).unwrap();
    }

    // ---- the mutation suite: every rewrite-shaped corruption ---------
    // (Corruption::REWRITE_SHAPED) is judged here, with the intended
    // EquivError variant — not just any refusal

    #[test]
    fn a_biased_epilogue_is_refused_as_epilogue_bias() {
        // the verifier-blind bug: resource-sound, semantically wrong
        let plan = rgb_plan();
        let bad = rewrite_plan(&plan, &RewritePass::ALL)
            .corrupt_for_test(Corruption::EpilogueThresholdOffByOne);
        verify_plan(&bad).expect("cmp_bias is invisible to the slot/shape verifier");
        let err = check_equiv(&plan, &bad).unwrap_err();
        assert!(
            matches!(err, EquivError::EpilogueBias { bias: 1, .. }),
            "wrong variant: {err}"
        );
    }

    #[test]
    fn a_pad_class_change_is_refused_as_pad_class() {
        let plan = rgb_plan();
        let bad = rewrite_plan(&plan, &RewritePass::ALL)
            .corrupt_for_test(Corruption::EpilogueThresholdPadBitClassChange);
        let err = check_equiv(&plan, &bad).unwrap_err();
        assert!(matches!(err, EquivError::PadClass { .. }), "wrong variant: {err}");
    }

    #[test]
    fn a_second_counts_reader_is_refused_as_counts_second_reader() {
        // site needs a live scratch2: the staged fold, before elision
        let plan = rgb_plan();
        let bad = rewrite_plan(&plan, &[RewritePass::FoldThreshold])
            .corrupt_for_test(Corruption::CountsElisionSecondReader);
        let err = check_equiv(&plan, &bad).unwrap_err();
        match err {
            EquivError::CountsSecondReader { fused_step, reader_step } => {
                assert_eq!(reader_step, fused_step + 1);
            }
            other => panic!("wrong variant: {other}"),
        }
    }

    #[test]
    fn fusing_across_a_multi_consumer_edge_is_refused_by_the_named_axiom() {
        // the branch-shaped rewrite lie: fold conv+threshold even though
        // a skip edge still reads the conv's counts.  The corrupted plan
        // is slot- and shape-clean (the orphaned reader is rewired onto
        // a same-typed edge, the dead slot compacted), so ONLY the
        // multi-consumer fusion axiom can refuse it.
        use crate::bnn::graph::test_specs;
        let plan = test_specs::residual_binary().plan().unwrap();
        let bad = plan.clone().corrupt_for_test(Corruption::MultiConsumerFusedAcross);
        verify_plan(&bad).expect("the illegal fold is invisible to the slot/shape verifier");
        let err = check_equiv(&plan, &bad).unwrap_err();
        assert!(
            matches!(err, EquivError::MultiConsumerFusion { .. }),
            "wrong variant: {err}"
        );
    }

    #[test]
    fn honest_rewrites_of_branch_fixtures_are_accepted() {
        // the false-positive guard for the new axiom: the rewriter's
        // multi-consumer guards skip the protected sites, so the
        // rewritten DAGs still prove equivalent and resource-sound
        use crate::bnn::graph::test_specs;
        for (name, spec) in test_specs::all() {
            let plan = spec.plan().unwrap();
            let rw = rewrite_plan(&plan, &RewritePass::ALL);
            check_equiv(&plan, &rw).unwrap_or_else(|e| panic!("{name}: refused: {e}"));
            verify_plan(&rw).unwrap_or_else(|e| panic!("{name}: unsound rewrite: {e}"));
        }
    }

    #[test]
    fn a_sound_commuting_reorder_is_still_accepted() {
        // the false-positive guard: consistent slot renames + reordered
        // weight declarations change no value term, so BOTH gates accept
        let plan = rgb_plan();
        let reordered = rewrite_plan(&plan, &RewritePass::ALL)
            .corrupt_for_test(Corruption::ReorderedCommutingSteps);
        check_equiv(&plan, &reordered).expect("dataflow is untouched");
        verify_plan(&reordered).expect("renamed slots stay resource-sound");
    }

    #[test]
    fn different_architectures_diverge_with_both_terms_named() {
        let rgb = rgb_plan();
        let gray = NetworkSpec::legacy_bcnn(Scheme::Gray).plan().unwrap();
        let err = check_equiv(&rgb, &gray).unwrap_err();
        match &err {
            EquivError::Diverged { term_a, term_b, .. } => {
                assert!(term_a.contains("rgb"), "{err}");
                assert!(term_b.contains("gray"), "{err}");
            }
            other => panic!("wrong variant: {other}"),
        }
    }

    #[test]
    fn a_dropped_tail_step_diverges_at_end_of_plan() {
        let plan = rgb_plan();
        let mut truncated = plan.clone();
        truncated.steps.pop();
        let err = check_equiv(&plan, &truncated).unwrap_err();
        match &err {
            EquivError::Diverged { term_b, .. } => {
                assert_eq!(term_b, "<end of plan>", "{err}");
            }
            other => panic!("wrong variant: {other}"),
        }
    }

    #[test]
    fn corrupt_rewrites_on_the_arch_plan_are_refused_too() {
        // sites are found structurally — the mutation suite must bite on
        // manifest-compiled deeper archs, not just the legacy topology
        use crate::bnn::graph::{Activation, LayerOp};
        use crate::bnn::network::NUM_CLASSES;
        let spec = NetworkSpec {
            ops: vec![
                LayerOp::Binarize { scheme: Scheme::Gray },
                LayerOp::ConvBin { k: 5, c_out: 32 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::ConvBin { k: 3, c_out: 32 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::ConvBin { k: 3, c_out: 32 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::FcBin { c_out: 64 },
                LayerOp::Threshold,
                LayerOp::FcFloat { c_out: NUM_CLASSES, bias: true, act: Activation::None },
            ],
        };
        let plan = spec.plan().unwrap();
        for c in [
            Corruption::EpilogueThresholdOffByOne,
            Corruption::EpilogueThresholdPadBitClassChange,
        ] {
            let bad = rewrite_plan(&plan, &RewritePass::ALL).corrupt_for_test(c);
            assert!(check_equiv(&plan, &bad).is_err(), "{} accepted", c.name());
        }
        let bad = rewrite_plan(&plan, &[RewritePass::FoldThreshold])
            .corrupt_for_test(Corruption::CountsElisionSecondReader);
        assert!(check_equiv(&plan, &bad).is_err());
    }
}
