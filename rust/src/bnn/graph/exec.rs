//! [`CompiledNetwork`]: a compiled [`Plan`] with weights bound, executing
//! batches over a planned scratch arena.
//!
//! Binding happens once at load time: every weight tensor is fetched by
//! its resolved name, length-checked against the plan's declared shape,
//! and packed conv weights are pre-widened to u64 lanes
//! ([`crate::bnn::bgemm::widen_weights`]) so the hot path never touches
//! them again.  Execution walks the lowered steps in order; each step
//! reads its input slot (or the caller's image payload), the join
//! steps (Add/Concat) additionally read a second planned input slot,
//! each writes its planned output slot, and uses at most one planned
//! per-step scratch slot (patch gathers, the LBP gray plane).  Every kernel either
//! assigns its entire exact-resized output range or identity-fills it
//! first, so arena slots reused across steps, batches, and even
//! different plans can never leak state — the same contract the
//! hand-named `ForwardScratch` arena relied on, now enforced per
//! planned slot.
//!
//! Per-image arithmetic is exactly the legacy fixed pipeline's (same
//! kernels, same accumulation order, batched along the leading
//! dimension only), so logits are bit-identical to the pre-refactor
//! `BcnnNetwork`/`FloatNetwork` paths — property-tested below against
//! independent reference compositions of the allocating kernels.

use std::sync::Mutex;
use std::time::Instant;

use crate::bnn::network::{LayerTimings, IMG_C, IMG_H, IMG_W};
use crate::bnn::scratch::PlanScratch;
use crate::bnn::{bgemm, fc, float_ops, im2col, maxpool, packing};
use crate::input::binarize::{self, Scheme};
use crate::util::histogram::Histogram;
use crate::util::json::{Json, JsonObj};
use crate::util::tensorio::TensorFile;

use super::plan::{BufClass, Plan, Src, StepKind, ValKind};
use super::{Activation, GraphError, NetworkSpec};

/// The weights one step binds — and nothing else.  Placement, extents,
/// kernel parameters, and timing labels all live on [`Plan::steps`];
/// execution reads them from the plan directly, so the verifier and the
/// executor see the SAME step data (there is no second bound copy that
/// could drift from what was verified).  One variant per weight
/// *layout*, not per step kind: both packed convs pre-widen to u64
/// lanes, both thresholds carry `theta`+`flip`, and the float conv/FC
/// share the `w`+`b` pair.
enum StepWeights {
    /// Pools, and the weight-less binarize schemes (LBP).
    None,
    /// Binarize thresholds (`input_t`: 3 for rgb, 1 for gray).
    Binarize { t: Vec<f32> },
    /// Packed conv weights, pre-widened to u64 lanes at bind time.
    Packed { w64: Vec<u64> },
    /// Float conv / float FC weights (+ optional bias).
    Float { w: Vec<f32>, b: Option<Vec<f32>> },
    /// Per-channel threshold parameters (both packing and ±1 forms).
    Threshold { theta: Vec<f32>, flip: Vec<u32> },
    /// Packed FC rows (u32 words; the FC kernel widens on the fly).
    FcBin { w: Vec<u32> },
    /// Fused conv + threshold epilogue: pre-widened conv weights plus the
    /// epilogue's per-channel threshold parameters.
    PackedThreshold { w64: Vec<u64>, theta: Vec<f32>, flip: Vec<u32> },
    /// Fused binarize + gather conv: binarize thresholds (`input_t`) plus
    /// pre-widened conv weights.
    BinarizePacked { t: Vec<f32>, w64: Vec<u64> },
    /// Both fusions at once: binarize thresholds, pre-widened conv
    /// weights, and the epilogue threshold parameters.
    BinarizePackedThreshold { t: Vec<f32>, w64: Vec<u64>, theta: Vec<f32>, flip: Vec<u32> },
    /// Fused packed FC + threshold: FC rows plus the ±1 compare's
    /// per-channel parameters.
    FcBinThreshold { w: Vec<u32>, theta: Vec<f32>, flip: Vec<u32> },
    /// XNOR-Net per-output-channel rescale factors (the paper's
    /// `x_mean` vector), length-checked against the edge's channels.
    Scale { alpha: Vec<f32> },
}

/// A plan with weights bound — the executable form of a network.
pub struct CompiledNetwork {
    /// Parallel to [`Plan::steps`]: `weights[j]` belongs to step `j`.
    weights: Vec<StepWeights>,
    plan: Plan,
    /// Per-step latency histograms, updated on every batch (see
    /// [`StepProfile`]) — the live-traffic per-layer breakdown.
    profile: StepProfile,
}

/// Per-step serving profile: one [`Histogram`] per plan step, recorded
/// on EVERY executed batch (traced or not).  Recording is an
/// `Instant::now()` pair plus one uncontended short-held mutex per step
/// — no allocation, so the zero-allocation steady-state contract holds.
/// Each mutex is a leaf: nothing else is locked while it is held.
pub struct StepProfile {
    hists: Vec<Mutex<Histogram>>,
}

impl StepProfile {
    fn new(steps: usize) -> Self {
        Self { hists: (0..steps).map(|_| Mutex::new(Histogram::new())).collect() }
    }

    fn record(&self, step: usize, ns: u64) {
        self.hists[step].lock().unwrap().record(ns);
    }
}

/// Wall-clock recorder for the timed single-image path (`None` on the
/// serving path — zero timing overhead for batches).
struct TimingRec {
    times: LayerTimings,
    mark: Instant,
}

impl TimingRec {
    fn lap(&mut self, label: &str) {
        let now = Instant::now();
        self.times.push((label.to_string(), now - self.mark));
        self.mark = now;
    }
}

fn lap(rec: &mut Option<TimingRec>, label: &str) {
    if let Some(r) = rec {
        r.lap(label);
    }
}

impl CompiledNetwork {
    /// Compile `spec` and bind every declared weight from `tf`.
    pub fn from_tensor_file(tf: &TensorFile, spec: &NetworkSpec) -> Result<Self, GraphError> {
        Self::from_plan(spec.plan()?, tf)
    }

    /// Bind an already-compiled plan (the registry loader compiles once,
    /// verifies, then binds).
    ///
    /// Debug builds re-run [`super::verify::verify_plan`] here: the
    /// loader already verified manifest plans, but plans can also reach
    /// binding from tests, tools, or future rewrite passes — in debug,
    /// nothing unverified executes.  Release builds trust the loader's
    /// gate (verification is load-time-only work either way, never on
    /// the request path).
    pub fn from_plan(plan: Plan, tf: &TensorFile) -> Result<Self, GraphError> {
        #[cfg(debug_assertions)]
        super::verify::verify_plan(&plan)
            .map_err(|e| GraphError::Internal(format!("plan failed verification: {e}")))?;
        let fetch_f32 = |name: &str, want: usize| -> Result<Vec<f32>, GraphError> {
            let v = tf.f32(name).map_err(|e| GraphError::Weight(e.to_string()))?;
            if v.len() != want {
                return Err(GraphError::Weight(format!(
                    "tensor {name:?} has {} elements, plan expects {want}",
                    v.len()
                )));
            }
            Ok(v)
        };
        let fetch_u32 = |name: &str, want: usize| -> Result<Vec<u32>, GraphError> {
            let v = tf.u32(name).map_err(|e| GraphError::Weight(e.to_string()))?;
            if v.len() != want {
                return Err(GraphError::Weight(format!(
                    "tensor {name:?} has {} elements, plan expects {want}",
                    v.len()
                )));
            }
            Ok(v)
        };

        let mut weights = Vec::with_capacity(plan.steps.len());
        for step in &plan.steps {
            let c_in = step.in_ty.c;
            weights.push(match &step.kind {
                StepKind::Binarize { scheme } => match scheme {
                    Scheme::Rgb => StepWeights::Binarize { t: fetch_f32("input_t", 3)? },
                    Scheme::Gray => StepWeights::Binarize { t: fetch_f32("input_t", 1)? },
                    _ => StepWeights::None,
                },
                StepKind::ConvBinPacked { c_out, nw, d, w, .. } => {
                    let mut packed = fetch_u32(w, c_out * nw)?;
                    mask_row_tail_pads(&mut packed, *c_out, *nw, *d);
                    StepWeights::Packed { w64: bgemm::widen_weights(&packed, *c_out, *nw) }
                }
                StepKind::ConvBinWords { k, c_out, w, .. } => {
                    let mut packed = fetch_u32(w, c_out * k * k)?;
                    mask_channel_pads(&mut packed, c_in);
                    StepWeights::Packed { w64: bgemm::widen_weights(&packed, *c_out, k * k) }
                }
                StepKind::ConvFloat { k, c_out, w, b, .. } => StepWeights::Float {
                    w: fetch_f32(w, c_out * k * k * c_in)?,
                    b: match b {
                        Some(b) => Some(fetch_f32(b, *c_out)?),
                        None => None,
                    },
                },
                StepKind::MaxPool
                | StepKind::OrPool
                | StepKind::Add
                | StepKind::Concat
                | StepKind::SplitPart { .. } => StepWeights::None,
                StepKind::Scale { alpha } => {
                    StepWeights::Scale { alpha: fetch_f32(alpha, c_in)? }
                }
                StepKind::ThresholdPack { theta, flip, .. }
                | StepKind::ThresholdPm1 { theta, flip } => StepWeights::Threshold {
                    theta: fetch_f32(theta, c_in)?,
                    flip: fetch_u32(flip, c_in)?,
                },
                StepKind::FcBin { kw, c_out, w, .. } => {
                    let mut packed = fetch_u32(w, c_out * kw)?;
                    mask_channel_pads(&mut packed, c_in);
                    StepWeights::FcBin { w: packed }
                }
                StepKind::FcFloat { d, c_out, w, b, .. } => StepWeights::Float {
                    w: fetch_f32(w, c_out * d)?,
                    b: match b {
                        Some(b) => Some(fetch_f32(b, *c_out)?),
                        None => None,
                    },
                },
                StepKind::ConvBinPackedThreshold { c_out, nw, d, w, theta, flip, .. } => {
                    let mut packed = fetch_u32(w, c_out * nw)?;
                    mask_row_tail_pads(&mut packed, *c_out, *nw, *d);
                    StepWeights::PackedThreshold {
                        w64: bgemm::widen_weights(&packed, *c_out, *nw),
                        theta: fetch_f32(theta, *c_out)?,
                        flip: fetch_u32(flip, *c_out)?,
                    }
                }
                StepKind::ConvBinWordsThreshold { k, c_out, w, theta, flip, .. } => {
                    let mut packed = fetch_u32(w, c_out * k * k)?;
                    mask_channel_pads(&mut packed, c_in);
                    StepWeights::PackedThreshold {
                        w64: bgemm::widen_weights(&packed, *c_out, k * k),
                        theta: fetch_f32(theta, *c_out)?,
                        flip: fetch_u32(flip, *c_out)?,
                    }
                }
                StepKind::BinarizeConvBin { scheme, c_out, nw, d, w, .. } => {
                    let mut packed = fetch_u32(w, c_out * nw)?;
                    mask_row_tail_pads(&mut packed, *c_out, *nw, *d);
                    StepWeights::BinarizePacked {
                        t: fetch_binarize_t(&fetch_f32, *scheme)?,
                        w64: bgemm::widen_weights(&packed, *c_out, *nw),
                    }
                }
                StepKind::BinarizeConvBinThreshold {
                    scheme, c_out, nw, d, w, theta, flip, ..
                } => {
                    let mut packed = fetch_u32(w, c_out * nw)?;
                    mask_row_tail_pads(&mut packed, *c_out, *nw, *d);
                    StepWeights::BinarizePackedThreshold {
                        t: fetch_binarize_t(&fetch_f32, *scheme)?,
                        w64: bgemm::widen_weights(&packed, *c_out, *nw),
                        theta: fetch_f32(theta, *c_out)?,
                        flip: fetch_u32(flip, *c_out)?,
                    }
                }
                StepKind::FcBinThreshold { kw, c_out, w, theta, flip, .. } => {
                    let mut packed = fetch_u32(w, c_out * kw)?;
                    mask_channel_pads(&mut packed, c_in);
                    StepWeights::FcBinThreshold {
                        w: packed,
                        theta: fetch_f32(theta, *c_out)?,
                        flip: fetch_u32(flip, *c_out)?,
                    }
                }
            });
        }
        let profile = StepProfile::new(plan.steps.len());
        Ok(Self { weights, plan, profile })
    }

    /// The compiled plan (arena layout, weight declarations, labels).
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// Logit rows per image.
    pub fn num_classes(&self) -> usize {
        self.plan.classes
    }

    /// Batched forward through a fresh arena (convenience; hot paths
    /// hold a pooled arena and call
    /// [`CompiledNetwork::infer_batch_with`]).
    pub fn infer_batch(&self, images: &[f32]) -> Result<Vec<f32>, GraphError> {
        self.infer_batch_with(images, &mut PlanScratch::new())
    }

    /// Batched forward over `n` contiguous (96,96,3) images through a
    /// reusable planned arena.  Returns `n * num_classes()` logits,
    /// row-major — the row width is whatever the plan's final edge
    /// declares, so non-four-class heads round-trip unharmed.
    /// Malformed input is a recoverable [`GraphError::BadInput`],
    /// never a panic — this is the serving-reachable entry point.
    pub fn infer_batch_with(
        &self,
        images: &[f32],
        scratch: &mut PlanScratch,
    ) -> Result<Vec<f32>, GraphError> {
        const IMG: usize = IMG_H * IMG_W * IMG_C;
        if images.len() % IMG != 0 {
            return Err(GraphError::BadInput(format!(
                "batch payload {} is not a multiple of {IMG}",
                images.len()
            )));
        }
        let n = images.len() / IMG;
        if n == 0 {
            return Ok(Vec::new());
        }
        let mut rec = None;
        self.execute(images, n, scratch, &mut rec)?;
        let out = self.read_logits(n, scratch);
        scratch.end_batch();
        Ok(out)
    }

    /// [`CompiledNetwork::infer_batch_with`] plus per-step wall times —
    /// the traced serving path.  Identical validation, identical
    /// arithmetic; only the timing recorder differs (it allocates, which
    /// is fine: this path only runs for sampled/forced-trace batches).
    pub fn infer_batch_timed(
        &self,
        images: &[f32],
        scratch: &mut PlanScratch,
    ) -> Result<(Vec<f32>, LayerTimings), GraphError> {
        const IMG: usize = IMG_H * IMG_W * IMG_C;
        if images.len() % IMG != 0 {
            return Err(GraphError::BadInput(format!(
                "batch payload {} is not a multiple of {IMG}",
                images.len()
            )));
        }
        let n = images.len() / IMG;
        if n == 0 {
            return Ok((Vec::new(), Vec::new()));
        }
        let mut rec = Some(TimingRec { times: Vec::new(), mark: Instant::now() });
        self.execute(images, n, scratch, &mut rec)?;
        let out = self.read_logits(n, scratch);
        scratch.end_batch();
        Ok((out, rec.take().expect("timing rec").times))
    }

    /// The per-step serving profile as a JSON array: one row per plan
    /// step with the step's (possibly fused) label, observed batch
    /// count, p50/p95 in µs, and share of the summed step time.
    pub fn profile_json(&self) -> Json {
        let snaps: Vec<(String, Histogram)> = self
            .plan
            .steps
            .iter()
            .zip(&self.profile.hists)
            .map(|(step, h)| {
                let label = match &step.label_b {
                    Some(b) => format!("{}+{}", step.label_a, b),
                    None => step.label_a.clone(),
                };
                (label, h.lock().unwrap().clone())
            })
            .collect();
        let total: f64 = snaps.iter().map(|(_, h)| h.sum_ns()).sum();
        let rows = snaps
            .into_iter()
            .map(|(label, h)| {
                let mut row = JsonObj::new();
                row.insert("step", Json::from(label));
                row.insert("count", Json::Num(h.count() as f64));
                row.insert("p50_us", Json::Num(h.quantile_ns(0.50) / 1_000.0));
                row.insert("p95_us", Json::Num(h.quantile_ns(0.95) / 1_000.0));
                let share = if total > 0.0 { h.sum_ns() / total } else { 0.0 };
                row.insert("share", Json::Num(share));
                Json::Obj(row)
            })
            .collect();
        Json::Arr(rows)
    }

    /// Single-image forward with per-step wall times (the Table 2 /
    /// Nvidia-Visual-Profiler instrument).  Allocates a fresh arena —
    /// this is a diagnostic path, not the serving path.
    pub fn forward_timed(&self, x: &[f32]) -> Result<(Vec<f32>, LayerTimings), GraphError> {
        const IMG: usize = IMG_H * IMG_W * IMG_C;
        if x.len() != IMG {
            return Err(GraphError::BadInput(format!(
                "single-image payload must be {IMG} floats, got {}",
                x.len()
            )));
        }
        let mut scratch = PlanScratch::new();
        let mut rec = Some(TimingRec { times: Vec::new(), mark: Instant::now() });
        self.execute(x, 1, &mut scratch, &mut rec)?;
        let logits = self.read_logits(1, &scratch);
        Ok((logits, rec.take().expect("timing rec").times))
    }

    /// Copy the final step's output slot into a flat row-major logit
    /// buffer: `n * classes` floats, where `classes` is the plan's
    /// declared head width (whatever the graph's final edge carries —
    /// the verifier pins `plan.classes` to it, so the slice below can
    /// never be short).
    fn read_logits(&self, n: usize, scratch: &PlanScratch) -> Vec<f32> {
        let last = self.plan.steps.last().expect("plan has >= 1 step");
        let out = scratch.f32_slot(last.output.idx);
        out[..n * self.plan.classes].to_vec()
    }

    /// Run every step for a batch of `n` images.
    fn execute(
        &self,
        images: &[f32],
        n: usize,
        scratch: &mut PlanScratch,
        rec: &mut Option<TimingRec>,
    ) -> Result<(), GraphError> {
        scratch.ensure(self.plan.nbufs);
        // the plan validator guarantees even pool extents, so a runtime
        // PoolError can only mean a compiler bug — surface it as such,
        // never as a client-attributed bad payload
        let bad = |e: maxpool::PoolError| GraphError::Internal(e.to_string());
        // a weight variant that doesn't fit its step kind can only mean
        // bind and plan fell out of sync — a compiler bug, never input
        let desync =
            || GraphError::Internal("bound weights out of sync with the plan steps".into());
        for (j, (step, wts)) in self.plan.steps.iter().zip(&self.weights).enumerate() {
            let step_started = Instant::now();
            let (h, w) = (step.in_ty.h, step.in_ty.w);
            let c_in = step.in_ty.c;
            let px = h * w;
            match (&step.kind, wts) {
                (StepKind::Binarize { scheme }, wts) => {
                    let t: &[f32] = match wts {
                        StepWeights::Binarize { t } => t,
                        StepWeights::None => &[],
                        _ => return Err(desync()),
                    };
                    let c_out = scheme.input_channels();
                    let mut gray = match step.scratch {
                        Some(s) => scratch.take_f32(s.idx),
                        None => Vec::new(),
                    };
                    let mut out = scratch.take_f32(step.output.idx);
                    {
                        let x = input_f32(scratch, images, step.input);
                        // resize without clear: every per-image slice is
                        // fully overwritten below
                        out.resize(n * px * c_out, 0.0);
                        if *scheme == Scheme::Lbp {
                            gray.resize(px, 0.0); // only LBP reads it
                        }
                        for i in 0..n {
                            let xi = &x[i * px * 3..(i + 1) * px * 3];
                            let oi = &mut out[i * px * c_out..(i + 1) * px * c_out];
                            match scheme {
                                Scheme::Rgb => {
                                    binarize::threshold_rgb_into(xi, &[t[0], t[1], t[2]], oi)
                                }
                                Scheme::Gray => binarize::threshold_gray_into(xi, t[0], oi),
                                Scheme::Lbp => binarize::lbp_into(xi, h, w, &mut gray, oi),
                                Scheme::None => unreachable!("rejected at plan time"),
                            }
                        }
                    }
                    if let Some(s) = step.scratch {
                        scratch.put_f32(s.idx, gray);
                    }
                    scratch.put_f32(step.output.idx, out);
                    lap(rec, &step.label_a);
                }
                (StepKind::ConvBinPacked { k, c_out, nw, d, .. }, StepWeights::Packed { w64 }) => {
                    let sc = step.scratch.expect("conv has a patch-gather slot");
                    let mut cols = scratch.take_u32(sc.idx);
                    let mut counts = scratch.take_i32(step.output.idx);
                    {
                        let x = input_f32(scratch, images, step.input);
                        im2col::im2col_pack_batch_into(x, n, h, w, c_in, *k, 32, &mut cols);
                        lap(rec, &step.label_a);
                        counts.resize(n * px * c_out, 0); // the GEMM assigns every element
                        bgemm::bgemm_prewidened(&cols, w64, n * px, *c_out, *nw, *d, &mut counts);
                        lap(rec, step.label_b.as_deref().unwrap_or(""));
                    }
                    scratch.put_u32(sc.idx, cols);
                    scratch.put_i32(step.output.idx, counts);
                }
                (StepKind::ConvBinWords { k, c_out, d, .. }, StepWeights::Packed { w64 }) => {
                    let sc = step.scratch.expect("conv has a patch-gather slot");
                    let mut cols = scratch.take_u32(sc.idx);
                    let mut counts = scratch.take_i32(step.output.idx);
                    {
                        let x = input_u32(scratch, step.input)?;
                        im2col::im2col_words_batch_into(x, n, h, w, 1, *k, &mut cols);
                        lap(rec, &step.label_a);
                        counts.resize(n * px * c_out, 0); // the GEMM assigns every element
                        bgemm::bgemm_prewidened(&cols, w64, n * px, *c_out, k * k, *d, &mut counts);
                        lap(rec, step.label_b.as_deref().unwrap_or(""));
                    }
                    scratch.put_u32(sc.idx, cols);
                    scratch.put_i32(step.output.idx, counts);
                }
                (StepKind::ConvFloat { k, c_out, relu, .. }, StepWeights::Float { w: cw, b }) => {
                    let sc = step.scratch.expect("conv has a patch-gather slot");
                    let mut cols = scratch.take_f32(sc.idx);
                    let mut act = scratch.take_f32(step.output.idx);
                    {
                        let x = input_f32(scratch, images, step.input);
                        im2col::im2col_float_batch_into(x, n, h, w, c_in, *k, &mut cols);
                        lap(rec, &step.label_a);
                        act.resize(n * px * c_out, 0.0); // the GEMM assigns every element
                        float_ops::gemm_blocked_into(
                            &cols,
                            cw,
                            n * px,
                            *c_out,
                            k * k * c_in,
                            &mut act,
                        );
                        if let Some(b) = b {
                            float_ops::add_bias(&mut act, b);
                        }
                        if *relu {
                            float_ops::relu(&mut act);
                        }
                        lap(rec, step.label_b.as_deref().unwrap_or(""));
                    }
                    scratch.put_f32(sc.idx, cols);
                    scratch.put_f32(step.output.idx, act);
                }
                (StepKind::MaxPool, StepWeights::None) => {
                    let mut out = scratch.take_f32(step.output.idx);
                    {
                        let x = input_f32(scratch, images, step.input);
                        maxpool::maxpool2x2_batch_into(x, n, h, w, c_in, &mut out).map_err(bad)?;
                    }
                    scratch.put_f32(step.output.idx, out);
                    lap(rec, &step.label_a);
                }
                (StepKind::OrPool, StepWeights::None) => {
                    let mut out = scratch.take_u32(step.output.idx);
                    {
                        let x = input_u32(scratch, step.input)?;
                        maxpool::orpool2x2_batch_into(x, n, h, w, 1, &mut out).map_err(bad)?;
                    }
                    scratch.put_u32(step.output.idx, out);
                    lap(rec, &step.label_a);
                }
                (
                    StepKind::ThresholdPack { f32_in, .. },
                    StepWeights::Threshold { theta, flip },
                ) => {
                    let mut out = scratch.take_u32(step.output.idx);
                    if *f32_in {
                        let x = input_f32(scratch, images, step.input);
                        threshold_pack_words(x, theta, flip, n * px, &mut out, |v| v);
                    } else {
                        let x = input_i32(scratch, step.input)?;
                        threshold_pack_words(x, theta, flip, n * px, &mut out, |v| v as f32);
                    }
                    scratch.put_u32(step.output.idx, out);
                    lap(rec, &step.label_a);
                }
                (StepKind::ThresholdPm1 { .. }, StepWeights::Threshold { theta, flip }) => {
                    let c = c_in;
                    let mut out = scratch.take_f32(step.output.idx);
                    {
                        let x = input_i32(scratch, step.input)?;
                        // resize without clear: every element is assigned
                        out.resize(n * c, 0.0);
                        for (o, (&v, j)) in out
                            .iter_mut()
                            .zip(x.iter().zip((0..c).cycle()))
                        {
                            *o = if packing::threshold_bit(v as f32, theta[j], flip[j]) == 1 {
                                1.0
                            } else {
                                -1.0
                            };
                        }
                    }
                    scratch.put_f32(step.output.idx, out);
                    lap(rec, &step.label_a);
                }
                (StepKind::FcBin { kw, c_out, d, .. }, StepWeights::FcBin { w: fw }) => {
                    let mut out = scratch.take_i32(step.output.idx);
                    {
                        let x = input_u32(scratch, step.input)?;
                        fc::fc_packed_batch_into(x, fw, n, *c_out, *kw, *d, &mut out);
                    }
                    scratch.put_i32(step.output.idx, out);
                    lap(rec, &step.label_a);
                }
                (StepKind::FcFloat { d, c_out, act, .. }, StepWeights::Float { w: fw, b }) => {
                    let mut out = scratch.take_f32(step.output.idx);
                    {
                        let x = input_f32(scratch, images, step.input);
                        // resize without clear: every row is assigned by
                        // the FC kernel below
                        out.resize(n * c_out, 0.0);
                        for i in 0..n {
                            let xi = &x[i * d..(i + 1) * d];
                            let oi = &mut out[i * c_out..(i + 1) * c_out];
                            match b {
                                Some(b) => fc::fc_float_bias_into(xi, fw, b, *c_out, *d, oi),
                                None => fc::fc_float_into(xi, fw, *c_out, *d, oi),
                            }
                            match act {
                                Activation::None => {}
                                Activation::Relu => float_ops::relu(oi),
                                Activation::Sign => {
                                    for v in oi.iter_mut() {
                                        *v = packing::sign_pm1(*v);
                                    }
                                }
                            }
                        }
                    }
                    scratch.put_f32(step.output.idx, out);
                    lap(rec, &step.label_a);
                }
                (
                    StepKind::ConvBinPackedThreshold { k, c_out, nw, d, cmp_bias, .. },
                    StepWeights::PackedThreshold { w64, theta, flip },
                ) => {
                    let sc = step.scratch.expect("conv has a patch-gather slot");
                    let mut cols = scratch.take_u32(sc.idx);
                    let mut out = scratch.take_u32(step.output.idx);
                    let mut counts = step.scratch2.map(|s| scratch.take_i32(s.idx));
                    {
                        let x = input_f32(scratch, images, step.input);
                        im2col::im2col_pack_batch_into(x, n, h, w, c_in, *k, 32, &mut cols);
                        lap(rec, &step.label_a);
                        bgemm::bgemm_threshold_into(
                            &cols, w64, n * px, *c_out, *nw, *d, theta, flip, *cmp_bias,
                            &mut out, counts.as_mut(),
                        );
                        lap(rec, step.label_b.as_deref().unwrap_or(""));
                    }
                    scratch.put_u32(sc.idx, cols);
                    if let (Some(s), Some(c)) = (step.scratch2, counts) {
                        scratch.put_i32(s.idx, c);
                    }
                    scratch.put_u32(step.output.idx, out);
                }
                (
                    StepKind::ConvBinWordsThreshold { k, c_out, d, cmp_bias, .. },
                    StepWeights::PackedThreshold { w64, theta, flip },
                ) => {
                    let sc = step.scratch.expect("conv has a patch-gather slot");
                    let mut cols = scratch.take_u32(sc.idx);
                    let mut out = scratch.take_u32(step.output.idx);
                    let mut counts = step.scratch2.map(|s| scratch.take_i32(s.idx));
                    {
                        let x = input_u32(scratch, step.input)?;
                        im2col::im2col_words_batch_into(x, n, h, w, 1, *k, &mut cols);
                        lap(rec, &step.label_a);
                        bgemm::bgemm_threshold_into(
                            &cols, w64, n * px, *c_out, k * k, *d, theta, flip, *cmp_bias,
                            &mut out, counts.as_mut(),
                        );
                        lap(rec, step.label_b.as_deref().unwrap_or(""));
                    }
                    scratch.put_u32(sc.idx, cols);
                    if let (Some(s), Some(c)) = (step.scratch2, counts) {
                        scratch.put_i32(s.idx, c);
                    }
                    scratch.put_u32(step.output.idx, out);
                }
                (
                    StepKind::BinarizeConvBin { scheme, k, c_out, nw, d, .. },
                    StepWeights::BinarizePacked { t, w64 },
                ) => {
                    let sc = step.scratch.expect("conv has a patch-gather slot");
                    let mut cols = scratch.take_u32(sc.idx);
                    let mut counts = scratch.take_i32(step.output.idx);
                    {
                        let x = input_f32(scratch, images, step.input);
                        let c_bin = scheme.input_channels();
                        im2col::im2col_binarize_pack_batch_into(
                            x, n, h, w, c_in, c_bin, *k, 32,
                            |pxl| fused_binarize_bits(*scheme, t, pxl),
                            &mut cols,
                        );
                        lap(rec, &step.label_a);
                        counts.resize(n * px * c_out, 0); // the GEMM assigns every element
                        bgemm::bgemm_prewidened(&cols, w64, n * px, *c_out, *nw, *d, &mut counts);
                        lap(rec, step.label_b.as_deref().unwrap_or(""));
                    }
                    scratch.put_u32(sc.idx, cols);
                    scratch.put_i32(step.output.idx, counts);
                }
                (
                    StepKind::BinarizeConvBinThreshold { scheme, k, c_out, nw, d, cmp_bias, .. },
                    StepWeights::BinarizePackedThreshold { t, w64, theta, flip },
                ) => {
                    let sc = step.scratch.expect("conv has a patch-gather slot");
                    let mut cols = scratch.take_u32(sc.idx);
                    let mut out = scratch.take_u32(step.output.idx);
                    let mut counts = step.scratch2.map(|s| scratch.take_i32(s.idx));
                    {
                        let x = input_f32(scratch, images, step.input);
                        let c_bin = scheme.input_channels();
                        im2col::im2col_binarize_pack_batch_into(
                            x, n, h, w, c_in, c_bin, *k, 32,
                            |pxl| fused_binarize_bits(*scheme, t, pxl),
                            &mut cols,
                        );
                        lap(rec, &step.label_a);
                        bgemm::bgemm_threshold_into(
                            &cols, w64, n * px, *c_out, *nw, *d, theta, flip, *cmp_bias,
                            &mut out, counts.as_mut(),
                        );
                        lap(rec, step.label_b.as_deref().unwrap_or(""));
                    }
                    scratch.put_u32(sc.idx, cols);
                    if let (Some(s), Some(c)) = (step.scratch2, counts) {
                        scratch.put_i32(s.idx, c);
                    }
                    scratch.put_u32(step.output.idx, out);
                }
                (StepKind::Add, StepWeights::None) => {
                    let in2 = step.input2.ok_or_else(desync)?;
                    let elems = n * px * c_in;
                    match step.out_ty.kind {
                        ValKind::F32 => {
                            let mut out = scratch.take_f32(step.output.idx);
                            {
                                let x = input_f32(scratch, images, step.input);
                                let y = input_f32(scratch, images, in2);
                                add_rows(x, y, elems, &mut out);
                            }
                            scratch.put_f32(step.output.idx, out);
                        }
                        ValKind::Counts => {
                            let mut out = scratch.take_i32(step.output.idx);
                            {
                                let x = input_i32(scratch, step.input)?;
                                let y = input_i32(scratch, in2)?;
                                add_rows(x, y, elems, &mut out);
                            }
                            scratch.put_i32(step.output.idx, out);
                        }
                        ValKind::Words => return Err(desync()),
                    }
                    lap(rec, &step.label_a);
                }
                (StepKind::Concat, StepWeights::None) => {
                    let in2 = step.input2.ok_or_else(desync)?;
                    let c2 = step.out_ty.c - c_in;
                    match step.out_ty.kind {
                        ValKind::F32 => {
                            let mut out = scratch.take_f32(step.output.idx);
                            {
                                let x = input_f32(scratch, images, step.input);
                                let y = input_f32(scratch, images, in2);
                                concat_rows(x, y, c_in, c2, n * px, &mut out);
                            }
                            scratch.put_f32(step.output.idx, out);
                        }
                        ValKind::Counts => {
                            let mut out = scratch.take_i32(step.output.idx);
                            {
                                let x = input_i32(scratch, step.input)?;
                                let y = input_i32(scratch, in2)?;
                                concat_rows(x, y, c_in, c2, n * px, &mut out);
                            }
                            scratch.put_i32(step.output.idx, out);
                        }
                        ValKind::Words => return Err(desync()),
                    }
                    lap(rec, &step.label_a);
                }
                (StepKind::SplitPart { lo }, StepWeights::None) => {
                    let c_out = step.out_ty.c;
                    match step.out_ty.kind {
                        ValKind::F32 => {
                            let mut out = scratch.take_f32(step.output.idx);
                            {
                                let x = input_f32(scratch, images, step.input);
                                split_rows(x, c_in, *lo, c_out, n * px, &mut out);
                            }
                            scratch.put_f32(step.output.idx, out);
                        }
                        ValKind::Counts => {
                            let mut out = scratch.take_i32(step.output.idx);
                            {
                                let x = input_i32(scratch, step.input)?;
                                split_rows(x, c_in, *lo, c_out, n * px, &mut out);
                            }
                            scratch.put_i32(step.output.idx, out);
                        }
                        ValKind::Words => return Err(desync()),
                    }
                    lap(rec, &step.label_a);
                }
                (StepKind::Scale { .. }, StepWeights::Scale { alpha }) => {
                    let mut out = scratch.take_f32(step.output.idx);
                    {
                        let elems = n * px * c_in;
                        // resize without clear: every element is assigned
                        out.resize(elems, 0.0);
                        match step.in_ty.kind {
                            ValKind::F32 => {
                                let x = input_f32(scratch, images, step.input);
                                for (o, (&v, j)) in out
                                    .iter_mut()
                                    .zip(x[..elems].iter().zip((0..c_in).cycle()))
                                {
                                    *o = v * alpha[j];
                                }
                            }
                            ValKind::Counts => {
                                let x = input_i32(scratch, step.input)?;
                                for (o, (&v, j)) in out
                                    .iter_mut()
                                    .zip(x[..elems].iter().zip((0..c_in).cycle()))
                                {
                                    *o = v as f32 * alpha[j];
                                }
                            }
                            ValKind::Words => return Err(desync()),
                        }
                    }
                    scratch.put_f32(step.output.idx, out);
                    lap(rec, &step.label_a);
                }
                (
                    StepKind::FcBinThreshold { kw, c_out, d, cmp_bias, .. },
                    StepWeights::FcBinThreshold { w: fw, theta, flip },
                ) => {
                    let mut out = scratch.take_f32(step.output.idx);
                    {
                        let x = input_u32(scratch, step.input)?;
                        fc::fc_packed_threshold_batch_into(
                            x, fw, n, *c_out, *kw, *d, theta, flip, *cmp_bias, &mut out,
                        );
                    }
                    scratch.put_f32(step.output.idx, out);
                    lap(rec, &step.label_a);
                }
                _ => return Err(desync()),
            }
            self.profile.record(j, step_started.elapsed().as_nanos() as u64);
        }
        Ok(())
    }
}

/// Zero each packed weight row's tail-word pad bits (`d` real bits over
/// `nw` 32-bit words per row): activations pack with zero pads
/// (`BitWriter`), so nonzero weight pads would pollute every popcount
/// with a constant offset.
fn mask_row_tail_pads(packed: &mut [u32], c_out: usize, nw: usize, d: usize) {
    let tail = d % 32;
    if tail != 0 {
        let mask = !0u32 << (32 - tail);
        for row in 0..c_out {
            packed[row * nw + (nw - 1)] &= mask;
        }
    }
}

/// Fetch the binarize thresholds a fused binarize+gather step binds
/// (`input_t`: 3 floats for rgb, 1 for gray; the plan verifier rejects
/// every other scheme in fused form, so reaching the fallback arm is a
/// compiler bug).
fn fetch_binarize_t(
    fetch_f32: &impl Fn(&str, usize) -> Result<Vec<f32>, GraphError>,
    scheme: Scheme,
) -> Result<Vec<f32>, GraphError> {
    match scheme {
        Scheme::Rgb => fetch_f32("input_t", 3),
        Scheme::Gray => fetch_f32("input_t", 1),
        _ => Err(GraphError::Internal("fused binarize bound a non-rgb/gray scheme".into())),
    }
}

/// Per-pixel sign bits for the fused binarize+gather kernels — the SAME
/// compare expressions as `binarize::threshold_rgb_into` /
/// `threshold_gray_into` (identical operation order, so identical
/// rounding), packed MSB-first into the low `c_bin` bits as
/// `im2col_binarize_pack_batch_into` expects.
#[inline]
fn fused_binarize_bits(scheme: Scheme, t: &[f32], px: &[f32]) -> u32 {
    match scheme {
        Scheme::Rgb => {
            (u32::from(px[0] + t[0] > 0.0) << 2)
                | (u32::from(px[1] + t[1] > 0.0) << 1)
                | u32::from(px[2] + t[2] > 0.0)
        }
        _ => u32::from(
            px[0] * binarize::LUMA[0] + px[1] * binarize::LUMA[1] + px[2] * binarize::LUMA[2]
                + t[0]
                > 0.0,
        ),
    }
}

/// Zero the pad bits of channel-packed weight words (`c` live channels
/// occupy the TOP `c` bits of each word, matching the threshold
/// packer's layout).  Word-domain activations always carry zero pads,
/// so `d - 2·popcount(x ^ w)` is the declared XNOR dot only if weight
/// pads are zero too — exporters that leave them uninitialized would
/// otherwise get a silent constant offset per output channel.
fn mask_channel_pads(packed: &mut [u32], c: usize) {
    if c < 32 {
        let mask = !0u32 << (32 - c);
        for w in packed.iter_mut() {
            *w &= mask;
        }
    }
}

/// Resolve a step's float input: the external image payload or a planned
/// f32 slot.
fn input_f32<'a>(scratch: &'a PlanScratch, images: &'a [f32], src: Src) -> &'a [f32] {
    match src {
        Src::External => images,
        Src::Buf(b) => {
            debug_assert_eq!(b.class, BufClass::F32);
            scratch.f32_slot(b.idx)
        }
    }
}

/// Packed-words inputs only ever come from a planned slot (the external
/// payload is float pixels); a violation is a compiler bug, reported as
/// [`GraphError::Internal`] so it can never masquerade as a malformed
/// client payload.
fn input_u32(scratch: &PlanScratch, src: Src) -> Result<&[u32], GraphError> {
    match src {
        Src::Buf(b) if b.class == BufClass::U32 => Ok(scratch.u32_slot(b.idx)),
        _ => Err(GraphError::Internal("packed step without a packed slot".into())),
    }
}

fn input_i32(scratch: &PlanScratch, src: Src) -> Result<&[i32], GraphError> {
    match src {
        Src::Buf(b) if b.class == BufClass::I32 => Ok(scratch.i32_slot(b.idx)),
        _ => Err(GraphError::Internal("counts step without a counts slot".into())),
    }
}

/// Elementwise residual sum (floats or popcount counts — f32 addition
/// is bitwise commutative, so operand order can never skew logits).
/// Resized without clear: every element of `0..elems` is assigned.
fn add_rows<T: Copy + Default + std::ops::Add<Output = T>>(
    x: &[T],
    y: &[T],
    elems: usize,
    out: &mut Vec<T>,
) {
    out.resize(elems, T::default());
    for (o, (&a, &b)) in out.iter_mut().zip(x[..elems].iter().zip(&y[..elems])) {
        *o = a + b;
    }
}

/// Per-pixel channel concatenation in HWC layout: `c1` channels from
/// `x` then `c2` from `y`.  Resized without clear: every element is
/// assigned.
fn concat_rows<T: Copy + Default>(
    x: &[T],
    y: &[T],
    c1: usize,
    c2: usize,
    pixels: usize,
    out: &mut Vec<T>,
) {
    let co = c1 + c2;
    out.resize(pixels * co, T::default());
    for p in 0..pixels {
        out[p * co..p * co + c1].copy_from_slice(&x[p * c1..(p + 1) * c1]);
        out[p * co + c1..(p + 1) * co].copy_from_slice(&y[p * c2..(p + 1) * c2]);
    }
}

/// Per-pixel channel slice `[lo, lo + c_out)` of an HWC edge.  Resized
/// without clear: every element is assigned.
fn split_rows<T: Copy + Default>(
    x: &[T],
    c_in: usize,
    lo: usize,
    c_out: usize,
    pixels: usize,
    out: &mut Vec<T>,
) {
    out.resize(pixels * c_out, T::default());
    for p in 0..pixels {
        out[p * c_out..(p + 1) * c_out]
            .copy_from_slice(&x[p * c_in + lo..p * c_in + lo + c_out]);
    }
}

/// Threshold per-channel values and channel-pack ≤ 32 channels into one
/// word per pixel, MSB-first — the ONE definition of the layout that
/// `im2col_words` gathers and `mask_channel_pads` assumes (integer and
/// float counts share it via `to_f32`, so the two domains can never
/// drift).  Resized without clear: every element of `0..pixels` is
/// assigned.
fn threshold_pack_words<T: Copy>(
    counts: &[T],
    theta: &[f32],
    flip: &[u32],
    pixels: usize,
    out: &mut Vec<u32>,
    to_f32: impl Fn(T) -> f32,
) {
    let c = theta.len();
    debug_assert!(c <= 32);
    out.resize(pixels, 0);
    for px in 0..pixels {
        let row = &counts[px * c..(px + 1) * c];
        let mut word = 0u32;
        for ch in 0..c {
            word |= packing::threshold_bit(to_f32(row[ch]), theta[ch], flip[ch]) << (31 - ch);
        }
        out[px] = word;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::network::tests_support::{
        synth_bcnn_tf, synth_float_tf, synth_image, synth_tf_for_spec,
    };
    use crate::bnn::network::NUM_CLASSES;
    use crate::bnn::packing::packed_width;
    use crate::util::prop::{self, ensure_eq};

    const IMG: usize = IMG_H * IMG_W * IMG_C;

    // --- independent reference compositions -----------------------------
    // These re-derive the pre-refactor forward passes from the simple
    // ALLOCATING kernels (non-widened bgemm, per-image im2col, fresh
    // vectors everywhere) — a different code path from the planned
    // executor, so agreement is a real oracle, not a tautology.

    fn ref_thr_pack(counts: &[f32], theta: &[f32], flip: &[u32], pixels: usize) -> Vec<u32> {
        let c = theta.len();
        let mut out = vec![0u32; pixels];
        for px in 0..pixels {
            let mut word = 0u32;
            for ch in 0..c {
                word |= packing::threshold_bit(counts[px * c + ch], theta[ch], flip[ch])
                    << (31 - ch);
            }
            out[px] = word;
        }
        out
    }

    fn ref_bcnn_forward(tf: &TensorFile, scheme: Scheme, x: &[f32]) -> [f32; NUM_CLASSES] {
        let c_in = scheme.input_channels();
        let d1 = 25 * c_in;
        let nw1 = packed_width(d1, 32);
        let theta1 = tf.f32("theta1").unwrap();
        let flip1 = tf.u32("flip1").unwrap();
        let words1 = match scheme {
            Scheme::None => {
                let cols = im2col::im2col_float(x, 96, 96, 3, 5);
                let counts = float_ops::gemm_blocked(
                    &cols,
                    &tf.f32("w1_pm1").unwrap(),
                    96 * 96,
                    32,
                    75,
                );
                ref_thr_pack(&counts, &theta1, &flip1, 96 * 96)
            }
            _ => {
                let t = tf.f32("input_t").ok();
                let xb = match scheme {
                    Scheme::Rgb => {
                        let t = t.unwrap();
                        binarize::threshold_rgb(x, &[t[0], t[1], t[2]])
                    }
                    Scheme::Gray => binarize::threshold_gray(x, t.unwrap()[0]),
                    Scheme::Lbp => binarize::lbp(x, 96, 96),
                    Scheme::None => unreachable!(),
                };
                let cols = im2col::im2col_pack(&xb, 96, 96, c_in, 5, 32);
                let counts =
                    bgemm::bgemm(&cols, &tf.u32("w1_packed").unwrap(), 96 * 96, 32, nw1, d1);
                let f: Vec<f32> = counts.iter().map(|&v| v as f32).collect();
                ref_thr_pack(&f, &theta1, &flip1, 96 * 96)
            }
        };
        let pooled1 = maxpool::orpool2x2(&words1, 96, 96, 1);
        let cols2 = im2col::im2col_words(&pooled1, 48, 48, 1, 5);
        let counts2 =
            bgemm::bgemm(&cols2, &tf.u32("w2_packed").unwrap(), 48 * 48, 32, 25, 25 * 32);
        let f2: Vec<f32> = counts2.iter().map(|&v| v as f32).collect();
        let words2 = ref_thr_pack(&f2, &tf.f32("theta2").unwrap(), &tf.u32("flip2").unwrap(), 48 * 48);
        let pooled2 = maxpool::orpool2x2(&words2, 48, 48, 1);
        let counts3 =
            fc::fc_packed(&pooled2, &tf.u32("wfc1_packed").unwrap(), 100, 576, 576 * 32);
        let theta3 = tf.f32("theta3").unwrap();
        let flip3 = tf.u32("flip3").unwrap();
        let h3: Vec<f32> = counts3
            .iter()
            .enumerate()
            .map(|(i, &v)| {
                if packing::threshold_bit(v as f32, theta3[i], flip3[i]) == 1 {
                    1.0
                } else {
                    -1.0
                }
            })
            .collect();
        let mut h4 = fc::fc_float_bias(
            &h3,
            &tf.f32("wfc2").unwrap(),
            &tf.f32("bfc2").unwrap(),
            100,
            100,
        );
        for v in h4.iter_mut() {
            *v = packing::sign_pm1(*v);
        }
        let logits_v = fc::fc_float_bias(
            &h4,
            &tf.f32("wfc3").unwrap(),
            &tf.f32("bfc3").unwrap(),
            NUM_CLASSES,
            100,
        );
        let mut logits = [0f32; NUM_CLASSES];
        logits.copy_from_slice(&logits_v);
        logits
    }

    fn ref_float_forward(tf: &TensorFile, x: &[f32]) -> [f32; NUM_CLASSES] {
        let cols1 = im2col::im2col_float(x, 96, 96, 3, 5);
        let mut a1 = float_ops::gemm_blocked(&cols1, &tf.f32("w1").unwrap(), 96 * 96, 32, 75);
        float_ops::add_bias(&mut a1, &tf.f32("b1").unwrap());
        float_ops::relu(&mut a1);
        let p1 = maxpool::maxpool2x2(&a1, 96, 96, 32);
        let cols2 = im2col::im2col_float(&p1, 48, 48, 32, 5);
        let mut a2 =
            float_ops::gemm_blocked(&cols2, &tf.f32("w2").unwrap(), 48 * 48, 32, 25 * 32);
        float_ops::add_bias(&mut a2, &tf.f32("b2").unwrap());
        float_ops::relu(&mut a2);
        let p2 = maxpool::maxpool2x2(&a2, 48, 48, 32);
        let mut h1 = fc::fc_float_bias(
            &p2,
            &tf.f32("wfc1").unwrap(),
            &tf.f32("bfc1").unwrap(),
            100,
            24 * 24 * 32,
        );
        float_ops::relu(&mut h1);
        let mut h2 = fc::fc_float_bias(
            &h1,
            &tf.f32("wfc2").unwrap(),
            &tf.f32("bfc2").unwrap(),
            100,
            100,
        );
        float_ops::relu(&mut h2);
        let logits_v = fc::fc_float_bias(
            &h2,
            &tf.f32("wfc3").unwrap(),
            &tf.f32("bfc3").unwrap(),
            NUM_CLASSES,
            100,
        );
        let mut logits = [0f32; NUM_CLASSES];
        logits.copy_from_slice(&logits_v);
        logits
    }

    fn images(n: usize, seed: u64) -> Vec<f32> {
        let mut xs = Vec::with_capacity(n * IMG);
        for i in 0..n {
            xs.extend(synth_image(seed.wrapping_add(i as u64)));
        }
        xs
    }

    #[test]
    fn compiled_bcnn_is_bit_identical_to_the_legacy_reference() {
        // THE tentpole property: for every scheme, random batch sizes,
        // ONE arena reused across all cases (so slots shrink and grow),
        // the planned executor must equal (a) a fresh arena and (b) the
        // independent allocating reference, bitwise.
        let cases: Vec<(Scheme, TensorFile, CompiledNetwork)> = Scheme::ALL
            .iter()
            .map(|&s| {
                let tf = synth_bcnn_tf(s, 310);
                let net =
                    CompiledNetwork::from_tensor_file(&tf, &NetworkSpec::legacy_bcnn(s)).unwrap();
                (s, tf, net)
            })
            .collect();
        let mut reused = PlanScratch::new();
        prop::check(12, |g| {
            let (scheme, tf, net) = g.pick(&cases);
            let n = g.usize_in(1, 5);
            let xs = images(n, g.u64());
            let with_reused = net.infer_batch_with(&xs, &mut reused).unwrap();
            let with_fresh = net.infer_batch(&xs).unwrap();
            ensure_eq(with_reused.clone(), with_fresh, "reused arena == fresh arena")?;
            for i in 0..n {
                let want = ref_bcnn_forward(tf, *scheme, &xs[i * IMG..(i + 1) * IMG]);
                ensure_eq(
                    with_reused[i * NUM_CLASSES..(i + 1) * NUM_CLASSES].to_vec(),
                    want.to_vec(),
                    "compiled == legacy reference",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn compiled_float_is_bit_identical_to_the_legacy_reference() {
        let tf = synth_float_tf(311);
        let net = CompiledNetwork::from_tensor_file(&tf, &NetworkSpec::legacy_float()).unwrap();
        let mut reused = PlanScratch::new();
        prop::check(6, |g| {
            let n = g.usize_in(1, 4);
            let xs = images(n, g.u64());
            let got = net.infer_batch_with(&xs, &mut reused).unwrap();
            ensure_eq(got.clone(), net.infer_batch(&xs).unwrap(), "reused == fresh")?;
            for i in 0..n {
                let want = ref_float_forward(&tf, &xs[i * IMG..(i + 1) * IMG]);
                ensure_eq(
                    got[i * NUM_CLASSES..(i + 1) * NUM_CLASSES].to_vec(),
                    want.to_vec(),
                    "compiled float == legacy reference",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn one_arena_serves_different_plans_interleaved() {
        // the backend pool hands arenas to whatever plan runs next;
        // slots are role-less, so nothing may bleed across plans
        let btf = synth_bcnn_tf(Scheme::Gray, 321);
        let bnet =
            CompiledNetwork::from_tensor_file(&btf, &NetworkSpec::legacy_bcnn(Scheme::Gray))
                .unwrap();
        let ftf = synth_float_tf(322);
        let fnet = CompiledNetwork::from_tensor_file(&ftf, &NetworkSpec::legacy_float()).unwrap();
        let mut arena = PlanScratch::new();
        for round in 0..3u64 {
            let xs = images(2, 4000 + round);
            let b = bnet.infer_batch_with(&xs, &mut arena).unwrap();
            let f = fnet.infer_batch_with(&xs, &mut arena).unwrap();
            for i in 0..2 {
                assert_eq!(
                    b[i * NUM_CLASSES..(i + 1) * NUM_CLASSES],
                    ref_bcnn_forward(&btf, Scheme::Gray, &xs[i * IMG..(i + 1) * IMG])
                );
                assert_eq!(
                    f[i * NUM_CLASSES..(i + 1) * NUM_CLASSES],
                    ref_float_forward(&ftf, &xs[i * IMG..(i + 1) * IMG])
                );
            }
        }
    }

    #[test]
    fn a_custom_three_conv_plan_executes_and_batches_consistently() {
        // no legacy twin exists for this topology — the invariant is
        // batch-of-n == n batches-of-1, bitwise, through a reused arena
        let spec = NetworkSpec {
            ops: vec![
                crate::bnn::graph::LayerOp::Binarize { scheme: Scheme::Rgb },
                crate::bnn::graph::LayerOp::ConvBin { k: 3, c_out: 16 },
                crate::bnn::graph::LayerOp::Threshold,
                crate::bnn::graph::LayerOp::OrPool,
                crate::bnn::graph::LayerOp::ConvBin { k: 3, c_out: 16 },
                crate::bnn::graph::LayerOp::Threshold,
                crate::bnn::graph::LayerOp::OrPool,
                crate::bnn::graph::LayerOp::ConvBin { k: 3, c_out: 16 },
                crate::bnn::graph::LayerOp::Threshold,
                crate::bnn::graph::LayerOp::OrPool,
                crate::bnn::graph::LayerOp::FcBin { c_out: 32 },
                crate::bnn::graph::LayerOp::Threshold,
                crate::bnn::graph::LayerOp::FcFloat {
                    c_out: NUM_CLASSES,
                    bias: true,
                    act: Activation::None,
                },
            ],
        };
        let tf = synth_tf_for_spec(&spec, 333);
        let net = CompiledNetwork::from_tensor_file(&tf, &spec).unwrap();
        let mut arena = PlanScratch::new();
        prop::check(8, |g| {
            let n = g.usize_in(1, 4);
            let xs = images(n, g.u64());
            let batched = net.infer_batch_with(&xs, &mut arena).unwrap();
            ensure_eq(batched.len(), n * NUM_CLASSES, "NUM_CLASSES floats per image")?;
            for i in 0..n {
                let single = net.infer_batch(&xs[i * IMG..(i + 1) * IMG]).unwrap();
                ensure_eq(
                    batched[i * NUM_CLASSES..(i + 1) * NUM_CLASSES].to_vec(),
                    single,
                    "batched == single (bitwise)",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    #[cfg(debug_assertions)]
    fn binding_reverifies_the_plan_in_debug_builds() {
        // the debug gate: a corrupted plan must never bind, even when it
        // arrives via from_plan directly (bypassing the loader's check)
        use crate::bnn::graph::plan::Corruption;
        let tf = synth_bcnn_tf(Scheme::Rgb, 360);
        let plan = NetworkSpec::legacy_bcnn(Scheme::Rgb)
            .plan()
            .unwrap()
            .corrupt_for_test(Corruption::LogitShapeLie);
        let err = CompiledNetwork::from_plan(plan, &tf).unwrap_err();
        assert!(matches!(err, GraphError::Internal(_)), "{err}");
        assert!(err.to_string().contains("verification"), "{err}");
    }

    #[test]
    fn packed_weight_pad_bits_are_masked_at_bind() {
        // regression (code review): with < 32 live channels, nonzero pad
        // bits in an exporter's packed weights would add a constant
        // popcount offset per output channel — binding must zero them,
        // so two containers differing ONLY in pad bits are equivalent
        use crate::bnn::graph::LayerOp;
        use crate::util::tensorio::Tensor;
        let spec = NetworkSpec {
            ops: vec![
                LayerOp::Binarize { scheme: Scheme::Gray },
                LayerOp::ConvBin { k: 3, c_out: 16 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::ConvBin { k: 3, c_out: 16 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::FcBin { c_out: 32 },
                LayerOp::Threshold,
                LayerOp::FcFloat { c_out: NUM_CLASSES, bias: true, act: Activation::None },
            ],
        };
        let tf = synth_tf_for_spec(&spec, 940);
        let x = synth_image(12);
        let base = CompiledNetwork::from_tensor_file(&tf, &spec)
            .unwrap()
            .infer_batch(&x)
            .unwrap();
        // pollute ONLY pad bits: conv2's words-domain weights have 16
        // live (top) bits per word, so the low 16 are padding; fc1's
        // words also carry 16 live channels
        let mut tf2 = synth_tf_for_spec(&spec, 940);
        let mut w2 = tf.u32("w2_packed").unwrap();
        for w in w2.iter_mut() {
            *w ^= 0x0000_ffff;
        }
        tf2.insert("w2_packed", Tensor::from_u32(vec![16, 9], &w2));
        let mut wfc1 = tf.u32("wfc1_packed").unwrap();
        for w in wfc1.iter_mut() {
            *w ^= 0x0000_ffff;
        }
        tf2.insert("wfc1_packed", Tensor::from_u32(vec![32, 24 * 24], &wfc1));
        let polluted = CompiledNetwork::from_tensor_file(&tf2, &spec)
            .unwrap()
            .infer_batch(&x)
            .unwrap();
        assert_eq!(base, polluted, "pad bits leaked into the popcount");
    }

    #[test]
    fn rewritten_plans_are_bit_identical_to_unrewritten_execution() {
        // THE rewrite acceptance property: for every architecture (all
        // four legacy schemes, the float baseline, and a 3-conv manifest
        // topology), every pass subset that the loader could enable must
        // execute bit-identically to the unrewritten plan — random batch
        // sizes, ONE arena reused across all plans so slot shapes shrink
        // and grow between cases.
        use crate::bnn::graph::{check_equiv, rewrite_plan, LayerOp, RewritePass};
        let three_conv = NetworkSpec {
            ops: vec![
                LayerOp::Binarize { scheme: Scheme::Gray },
                LayerOp::ConvBin { k: 5, c_out: 32 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::ConvBin { k: 3, c_out: 32 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::ConvBin { k: 3, c_out: 32 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::FcBin { c_out: 64 },
                LayerOp::Threshold,
                LayerOp::FcFloat { c_out: NUM_CLASSES, bias: true, act: Activation::None },
            ],
        };
        let mut specs: Vec<(NetworkSpec, TensorFile)> = Scheme::ALL
            .iter()
            .map(|&s| (NetworkSpec::legacy_bcnn(s), synth_bcnn_tf(s, 520)))
            .collect();
        specs.push((NetworkSpec::legacy_float(), synth_float_tf(521)));
        let tf3 = synth_tf_for_spec(&three_conv, 522);
        specs.push((three_conv, tf3));
        // the branch fixtures: rewrites must stay bit-identical on DAGs
        // too (the fusion guard skips the protected pairs, recolor
        // re-runs interval liveness over the skip edges)
        for (_, spec) in crate::bnn::graph::test_specs::all() {
            let tf = synth_tf_for_spec(&spec, 523);
            specs.push((spec, tf));
        }
        let combos: Vec<Vec<RewritePass>> = vec![
            vec![RewritePass::FoldThreshold],
            vec![RewritePass::FusePack],
            vec![RewritePass::ElideCounts],
            vec![RewritePass::FoldThreshold, RewritePass::ElideCounts],
            RewritePass::ALL.to_vec(),
        ];
        let mut cases: Vec<(usize, CompiledNetwork)> = Vec::new();
        let mut bases: Vec<CompiledNetwork> = Vec::new();
        for (i, (spec, tf)) in specs.iter().enumerate() {
            let plan = spec.plan().unwrap();
            bases.push(CompiledNetwork::from_plan(plan.clone(), tf).unwrap());
            for passes in &combos {
                let rw = rewrite_plan(&plan, passes);
                check_equiv(&plan, &rw).unwrap();
                cases.push((i, CompiledNetwork::from_plan(rw, tf).unwrap()));
            }
        }
        let mut arena = PlanScratch::new();
        prop::check(20, |g| {
            let (i, opt) = g.pick(&cases);
            let n = g.usize_in(1, 4);
            let xs = images(n, g.u64());
            let want = bases[*i].infer_batch_with(&xs, &mut arena).unwrap();
            let got = opt.infer_batch_with(&xs, &mut arena).unwrap();
            ensure_eq(got, want, "rewritten == unrewritten (bitwise)")
        });
    }

    #[test]
    fn forward_timed_fused_labels_match_the_rewritten_plan() {
        // Table 2 attribution must survive fusion: the timed label list
        // is exactly the rewritten plan's step label list, and every
        // fused step names BOTH constituent ops
        use crate::bnn::graph::{rewrite_plan, RewritePass};
        let tf = synth_bcnn_tf(Scheme::Rgb, 530);
        let plan = NetworkSpec::legacy_bcnn(Scheme::Rgb).plan().unwrap();
        let rw = rewrite_plan(&plan, &RewritePass::ALL);
        let net = CompiledNetwork::from_plan(rw, &tf).unwrap();
        let (logits, times) = net.forward_timed(&synth_image(7)).unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));
        let labels: Vec<String> = times.iter().map(|(l, _)| l.clone()).collect();
        assert_eq!(labels, net.plan().step_names(), "one timing lap per plan label");
        for want in ["binarize+im2col1", "gemm1+threshold_pack1", "fc1+threshold3"] {
            assert!(labels.iter().any(|l| l == want), "missing {want} in {labels:?}");
        }
    }

    #[test]
    fn forward_timed_labels_cover_the_plan() {
        let tf = synth_bcnn_tf(Scheme::Rgb, 350);
        let net =
            CompiledNetwork::from_tensor_file(&tf, &NetworkSpec::legacy_bcnn(Scheme::Rgb)).unwrap();
        let (logits, times) = net.forward_timed(&synth_image(1)).unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));
        assert!(times.len() >= 9, "{times:?}");
        assert!(times.iter().any(|(n, _)| n == "gemm2"));
        assert!(times.iter().any(|(n, _)| n == "input_binarize"));
    }

    #[test]
    fn ragged_and_empty_payloads_are_recoverable() {
        let tf = synth_bcnn_tf(Scheme::Rgb, 351);
        let net =
            CompiledNetwork::from_tensor_file(&tf, &NetworkSpec::legacy_bcnn(Scheme::Rgb)).unwrap();
        assert!(matches!(net.infer_batch(&[0.0; 100]), Err(GraphError::BadInput(_))));
        assert!(net.infer_batch(&[]).unwrap().is_empty());
    }

    #[test]
    fn timed_batch_is_bit_identical_and_labels_cover_the_plan() {
        let tf = synth_bcnn_tf(Scheme::Rgb, 353);
        let net =
            CompiledNetwork::from_tensor_file(&tf, &NetworkSpec::legacy_bcnn(Scheme::Rgb)).unwrap();
        let xs = images(2, 77);
        let plain = net.infer_batch(&xs).unwrap();
        let mut scratch = PlanScratch::new();
        let (timed, times) = net.infer_batch_timed(&xs, &mut scratch).unwrap();
        assert_eq!(timed, plain, "timed batch must not change logits");
        let labels: Vec<String> = times.iter().map(|(l, _)| l.clone()).collect();
        assert_eq!(labels, net.plan().step_names());
        // validation parity with the untimed entry point
        assert!(matches!(
            net.infer_batch_timed(&[0.0; 100], &mut scratch),
            Err(GraphError::BadInput(_))
        ));
        let (empty, no_times) = net.infer_batch_timed(&[], &mut scratch).unwrap();
        assert!(empty.is_empty() && no_times.is_empty());
    }

    #[test]
    fn step_profile_records_every_batch_and_shares_sum_to_one() {
        let tf = synth_bcnn_tf(Scheme::Gray, 354);
        let net = CompiledNetwork::from_tensor_file(&tf, &NetworkSpec::legacy_bcnn(Scheme::Gray))
            .unwrap();
        // fresh network: profile exists but is empty
        let rows = net.profile_json();
        let rows = rows.as_arr().unwrap();
        assert_eq!(rows.len(), net.plan().steps.len());
        assert!(rows.iter().all(|r| r.get("count").unwrap().as_f64().unwrap() == 0.0));
        // three batches (one untimed, one pooled-arena, one timed) all land
        let xs = images(1, 78);
        net.infer_batch(&xs).unwrap();
        let mut scratch = PlanScratch::new();
        net.infer_batch_with(&xs, &mut scratch).unwrap();
        net.infer_batch_timed(&xs, &mut scratch).unwrap();
        let rows = net.profile_json();
        let rows = rows.as_arr().unwrap();
        let mut share_sum = 0.0;
        for r in rows {
            assert_eq!(r.get("count").unwrap().as_f64().unwrap(), 3.0);
            assert!(r.get("p50_us").unwrap().as_f64().unwrap() >= 0.0);
            assert!(r.get("p95_us").unwrap().as_f64().unwrap() >= 0.0);
            share_sum += r.get("share").unwrap().as_f64().unwrap();
        }
        assert!((share_sum - 1.0).abs() < 1e-9, "shares sum to 1, got {share_sum}");
    }

    #[test]
    fn weight_binding_rejects_missing_and_misshaped_tensors() {
        // empty container: first missing tensor is a structured error
        let err = CompiledNetwork::from_tensor_file(
            &TensorFile::new(),
            &NetworkSpec::legacy_bcnn(Scheme::Rgb),
        )
        .unwrap_err();
        assert!(matches!(err, GraphError::Weight(_)), "{err}");
        // scheme mismatch: a gray plan binding an rgb container trips the
        // packed-width length check (nw differs per input channel count)
        let rgb_tf = synth_bcnn_tf(Scheme::Rgb, 352);
        let err =
            CompiledNetwork::from_tensor_file(&rgb_tf, &NetworkSpec::legacy_bcnn(Scheme::Gray))
                .unwrap_err();
        assert!(matches!(err, GraphError::Weight(_)), "{err}");
    }

    // --- branch-shaped differential references --------------------------
    // Hand-composed from the simple allocating kernels, one per fixture
    // in `test_specs` — independent of the planned executor's slot
    // arithmetic, its interval liveness, and its second-operand fetch.

    fn ref_residual_float(tf: &TensorFile, x: &[f32]) -> Vec<f32> {
        let conv = |x: &[f32], c_in: usize, k: usize, relu: bool, w: &str, b: &str| {
            let cols = im2col::im2col_float(x, 96, 96, c_in, k);
            let mut a =
                float_ops::gemm_blocked(&cols, &tf.f32(w).unwrap(), 96 * 96, 8, k * k * c_in);
            float_ops::add_bias(&mut a, &tf.f32(b).unwrap());
            if relu {
                float_ops::relu(&mut a);
            }
            a
        };
        let a1 = conv(x, 3, 5, true, "w1", "b1");
        let skip = conv(&a1, 8, 1, true, "w2", "b2");
        let trunk = conv(&skip, 8, 1, false, "w3", "b3");
        let sum: Vec<f32> = trunk.iter().zip(&skip).map(|(a, b)| a + b).collect();
        let p = maxpool::maxpool2x2(&sum, 96, 96, 8);
        fc::fc_float_bias(&p, &tf.f32("wfc1").unwrap(), &tf.f32("bfc1").unwrap(), 4, 48 * 48 * 8)
    }

    fn ref_residual_binary(tf: &TensorFile, x: &[f32]) -> Vec<f32> {
        let t = tf.f32("input_t").unwrap();
        let xb = binarize::threshold_rgb(x, &[t[0], t[1], t[2]]);
        let cols1 = im2col::im2col_pack(&xb, 96, 96, 3, 5, 32);
        let nw1 = packed_width(75, 32);
        let skip = bgemm::bgemm(&cols1, &tf.u32("w1_packed").unwrap(), 96 * 96, 32, nw1, 75);
        let f1: Vec<f32> = skip.iter().map(|&v| v as f32).collect();
        let words =
            ref_thr_pack(&f1, &tf.f32("theta1").unwrap(), &tf.u32("flip1").unwrap(), 96 * 96);
        let cols2 = im2col::im2col_words(&words, 96, 96, 1, 1);
        let trunk = bgemm::bgemm(&cols2, &tf.u32("w2_packed").unwrap(), 96 * 96, 32, 1, 32);
        let alpha = tf.f32("alpha1").unwrap();
        let scaled: Vec<f32> = trunk
            .iter()
            .zip(&skip)
            .enumerate()
            .map(|(i, (a, b))| (a + b) as f32 * alpha[i % 32])
            .collect();
        let p = maxpool::maxpool2x2(&scaled, 96, 96, 32);
        fc::fc_float_bias(&p, &tf.f32("wfc1").unwrap(), &tf.f32("bfc1").unwrap(), 4, 48 * 48 * 32)
    }

    fn ref_split_concat(tf: &TensorFile, x: &[f32]) -> Vec<f32> {
        let cols = im2col::im2col_float(x, 96, 96, 3, 5);
        let mut a = float_ops::gemm_blocked(&cols, &tf.f32("w1").unwrap(), 96 * 96, 8, 75);
        float_ops::add_bias(&mut a, &tf.f32("b1").unwrap());
        float_ops::relu(&mut a);
        // split [3, 5] → scale part 0 → concat back, all in HWC
        let alpha = tf.f32("alpha1").unwrap();
        let mut merged = vec![0f32; 96 * 96 * 8];
        for p in 0..96 * 96 {
            for j in 0..3 {
                merged[p * 8 + j] = a[p * 8 + j] * alpha[j];
            }
            merged[p * 8 + 3..p * 8 + 8].copy_from_slice(&a[p * 8 + 3..p * 8 + 8]);
        }
        let pl = maxpool::maxpool2x2(&merged, 96, 96, 8);
        fc::fc_float_bias(&pl, &tf.f32("wfc1").unwrap(), &tf.f32("bfc1").unwrap(), 6, 48 * 48 * 8)
    }

    #[test]
    fn branching_plans_match_hand_composed_references() {
        // THE branch differential property: every fixture topology
        // (Add skip, counts-domain residual + Scale, Split/Scale/Concat
        // with a six-class head), random batch sizes, ONE arena reused
        // across all fixtures so slot shapes shrink and grow — planned
        // execution must equal the fresh arena AND the independent
        // allocating reference, bitwise.
        use crate::bnn::graph::test_specs;
        let cases: Vec<(&str, TensorFile, CompiledNetwork)> = test_specs::all()
            .into_iter()
            .map(|(name, spec)| {
                let tf = synth_tf_for_spec(&spec, 600);
                let net = CompiledNetwork::from_tensor_file(&tf, &spec).unwrap();
                (name, tf, net)
            })
            .collect();
        let mut reused = PlanScratch::new();
        prop::check(12, |g| {
            let (name, tf, net) = g.pick(&cases);
            let classes = net.num_classes();
            let n = g.usize_in(1, 4);
            let xs = images(n, g.u64());
            let with_reused = net.infer_batch_with(&xs, &mut reused).unwrap();
            let with_fresh = net.infer_batch(&xs).unwrap();
            ensure_eq(with_reused.clone(), with_fresh, "reused arena == fresh arena")?;
            for i in 0..n {
                let x = &xs[i * IMG..(i + 1) * IMG];
                let want = match *name {
                    "residual_float" => ref_residual_float(tf, x),
                    "residual_binary" => ref_residual_binary(tf, x),
                    "split_concat" => ref_split_concat(tf, x),
                    other => panic!("no reference for fixture {other}"),
                };
                ensure_eq(
                    with_reused[i * classes..(i + 1) * classes].to_vec(),
                    want,
                    "compiled == hand-composed reference",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn rewritten_branch_plans_match_the_same_references() {
        // the fixtures again, but through the full rewrite gauntlet: the
        // fusion guard + DAG recolor must leave logits bit-identical
        use crate::bnn::graph::{rewrite_plan, test_specs, RewritePass};
        let mut arena = PlanScratch::new();
        for (name, spec) in test_specs::all() {
            let tf = synth_tf_for_spec(&spec, 601);
            let rw = rewrite_plan(&spec.plan().unwrap(), &RewritePass::ALL);
            let net = CompiledNetwork::from_plan(rw, &tf).unwrap();
            let classes = net.num_classes();
            let xs = images(2, 9000);
            let got = net.infer_batch_with(&xs, &mut arena).unwrap();
            for i in 0..2 {
                let x = &xs[i * IMG..(i + 1) * IMG];
                let want = match name {
                    "residual_float" => ref_residual_float(&tf, x),
                    "residual_binary" => ref_residual_binary(&tf, x),
                    _ => ref_split_concat(&tf, x),
                };
                assert_eq!(
                    got[i * classes..(i + 1) * classes],
                    want[..],
                    "{name}: rewritten branch plan drifted from the reference"
                );
            }
        }
    }

    #[test]
    fn forward_timed_labels_follow_the_dag_plan_order() {
        // branch regression: the timed label list must equal the
        // compiled step order exactly — topological and deterministic,
        // one lap per label, including the split fan-out
        use crate::bnn::graph::test_specs;
        let spec = test_specs::split_concat();
        let tf = synth_tf_for_spec(&spec, 610);
        let net = CompiledNetwork::from_tensor_file(&tf, &spec).unwrap();
        let (logits, times) = net.forward_timed(&synth_image(9)).unwrap();
        assert_eq!(logits.len(), 6, "six-class head");
        assert!(logits.iter().all(|v| v.is_finite()));
        let labels: Vec<String> = times.iter().map(|(l, _)| l.clone()).collect();
        assert_eq!(labels, net.plan().step_names(), "one timing lap per plan label, in order");
        assert_eq!(
            labels,
            ["im2col1", "gemm1", "split1_part0", "split1_part1", "scale1", "concat1", "pool1",
             "fc1"]
        );
    }

    #[test]
    fn a_wrong_length_scale_vector_is_refused_at_bind() {
        use crate::bnn::graph::test_specs;
        use crate::util::tensorio::Tensor;
        let spec = test_specs::split_concat();
        let mut tf = synth_tf_for_spec(&spec, 620);
        // the plan declares alpha1 as [3] (split part 0); bind a [4]
        tf.insert("alpha1", Tensor::from_f32(vec![4], &[1.0, 1.0, 1.0, 1.0]));
        let err = CompiledNetwork::from_tensor_file(&tf, &spec).unwrap_err();
        assert!(matches!(err, GraphError::Weight(_)), "{err}");
        assert!(err.to_string().contains("alpha1"), "{err}");
    }
}
