//! Layer-graph IR: networks as *data*, not Rust structs.
//!
//! The paper's core claim is that a binarized layer is a drop-in
//! replacement for its float twin — pack → XNOR-GEMM →
//! popcount-threshold instead of im2col → SGEMM → ReLU.  FINN
//! (Umuroglu et al., 2016) turns that observation into an architecture:
//! a *compiler* from a layer graph to streaming compute, instead of a
//! hand-wired forward function per topology.  This module is that
//! factoring for the Rust engine:
//!
//! * [`LayerOp`] — the typed op vocabulary (binarize, packed/float
//!   conv, OR/max pool, packed/float FC, threshold), each op carrying
//!   only its *declared* metadata; every derived shape is inferred.
//! * [`NetworkSpec`] — an ordered op list.  Parsed from an
//!   `"arch": [...]` JSON array in the registry manifest
//!   ([`NetworkSpec::from_json`]), or synthesized for the legacy fixed
//!   2-conv/2-fc topologies ([`NetworkSpec::legacy_bcnn`] /
//!   [`NetworkSpec::legacy_float`]) so every pre-existing weight
//!   container keeps loading unchanged.
//! * [`plan`] — the compiler: shape inference + validation, weight-name
//!   resolution (positional, reproducing the legacy tensor names), and
//!   per-edge liveness analysis that assigns every intermediate tensor
//!   to a slot in a planned scratch arena
//!   ([`crate::bnn::scratch::PlanScratch`]).
//! * [`exec`] — [`CompiledNetwork`](exec::CompiledNetwork): the plan
//!   with weights bound (pre-widened at build time), executing batches
//!   over the planned arena.  `BcnnNetwork`/`FloatNetwork` are thin
//!   wrappers over it.
//! * [`verify`] — the independent static checker: every op declares an
//!   [`EffectSig`] and [`verify_plan`] re-proves aliasing, dataflow,
//!   shape, and weight-binding soundness from those effects alone,
//!   without trusting the compiler's liveness walk.  The registry
//!   loader refuses to publish a plan that fails it.
//!
//! Mixed precision per layer (XNOR-Net's motivation) falls out of the
//! vocabulary: a spec may open with a float conv and binarize later, or
//! stack three packed convs — no new forward function required.

pub mod equiv;
pub mod exec;
pub mod plan;
pub mod rewrite;
pub mod verify;

pub use equiv::{check_equiv, EquivError};
pub use exec::CompiledNetwork;
pub use plan::{Plan, WeightReq};
pub use rewrite::{pass_names, rewrite_plan, RewritePass};
pub use verify::{verify_plan, VerifyError, VerifyReport};

#[doc(hidden)]
pub use plan::Corruption;

/// The static effect signature of one op: what the verifier may assume
/// about its execution without running it.  Every op in the vocabulary
/// reads exactly one input edge and fully covers its output extent
/// (no partial writers exist in this IR — a property
/// [`verify::verify_plan`]'s single-writer dataflow rule depends on);
/// the per-op difference is whether a per-step scratch slot is
/// clobbered (patch gathers, the LBP gray plane).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EffectSig {
    /// The step consumes its input edge (all current ops do).
    pub reads_input: bool,
    /// The write covers the full declared output extent — the edge's
    /// previous contents are dead the moment this step runs.
    pub covers_output: bool,
    /// The step clobbers a scratch slot whose contents are garbage
    /// after the step (never a valid read source).
    pub clobbers_scratch: bool,
}

impl EffectSig {
    const fn new(clobbers_scratch: bool) -> Self {
        Self { reads_input: true, covers_output: true, clobbers_scratch }
    }
}

impl LayerOp {
    /// This op's declared effect signature.
    pub fn effect(&self) -> EffectSig {
        EffectSig::new(match self {
            // the LBP binarizer gathers a per-image grayscale plane
            LayerOp::Binarize { scheme } => *scheme == Scheme::Lbp,
            // convs gather patches (im2col / word gather) into scratch
            LayerOp::ConvBin { .. } | LayerOp::ConvFloat { .. } => true,
            LayerOp::MaxPool
            | LayerOp::OrPool
            | LayerOp::Threshold
            | LayerOp::FcBin { .. }
            | LayerOp::FcFloat { .. } => false,
            // branch ops are pure elementwise/copy kernels — no scratch
            LayerOp::Add { .. }
            | LayerOp::Concat { .. }
            | LayerOp::Split { .. }
            | LayerOp::Scale => false,
        })
    }

    /// How many plan steps this op lowers to.  Every op is 1:1 except
    /// [`LayerOp::Split`], which lowers to one copy step per part (so a
    /// DAG plan has `sum(lowered_steps)` steps, not `ops.len()`).
    pub fn lowered_steps(&self) -> usize {
        match self {
            LayerOp::Split { parts } => parts.len(),
            _ => 1,
        }
    }
}

/// Effect signature of a lowered step — must agree with the declaring
/// [`LayerOp::effect`] (the `effects_agree_between_ops_and_steps` test
/// pins this).
pub(crate) fn step_effect(kind: &plan::StepKind) -> EffectSig {
    use plan::StepKind;
    EffectSig::new(match kind {
        StepKind::Binarize { scheme } => *scheme == Scheme::Lbp,
        StepKind::ConvBinPacked { .. }
        | StepKind::ConvBinWords { .. }
        | StepKind::ConvFloat { .. } => true,
        // fused convs still gather patches into scratch (and, until the
        // elision pass runs, counts into scratch2)
        StepKind::ConvBinPackedThreshold { .. }
        | StepKind::ConvBinWordsThreshold { .. }
        | StepKind::BinarizeConvBin { .. }
        | StepKind::BinarizeConvBinThreshold { .. } => true,
        StepKind::MaxPool
        | StepKind::OrPool
        | StepKind::ThresholdPack { .. }
        | StepKind::ThresholdPm1 { .. }
        | StepKind::FcBin { .. }
        | StepKind::FcFloat { .. }
        // the fused FC keeps each count in a register — no scratch
        | StepKind::FcBinThreshold { .. } => false,
        // branch steps are pure elementwise/copy kernels — no scratch
        StepKind::Add
        | StepKind::Concat
        | StepKind::SplitPart { .. }
        | StepKind::Scale => false,
    })
}

use crate::input::binarize::Scheme;
use crate::util::json::Json;

/// Activation applied inside a float FC layer, after the bias add.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    None,
    Relu,
    /// `sign(x)` to ±1 — the BCNN tail's re-binarization.
    Sign,
}

impl Activation {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "none" => Activation::None,
            "relu" => Activation::Relu,
            "sign" => Activation::Sign,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Activation::None => "none",
            Activation::Relu => "relu",
            Activation::Sign => "sign",
        }
    }
}

/// A reference to an earlier op's output inside a branching spec — the
/// second operand of [`LayerOp::Add`] / [`LayerOp::Concat`].  `op` is
/// the 0-based index of the producing op in [`NetworkSpec::ops`] and
/// must be *strictly earlier* than the referencing op (a forward or
/// self reference is a cyclic-reference [`GraphError::Validate`]).
/// `part` selects a [`LayerOp::Split`] output (0 for every other op).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tap {
    pub op: usize,
    pub part: usize,
}

impl Tap {
    /// Tap the (sole) output of op `op` — `part` 0.
    pub const fn op(op: usize) -> Self {
        Self { op, part: 0 }
    }
}

/// One layer of a network graph.  Ops carry declared parameters only;
/// input shapes, value domains (float / packed words / integer counts),
/// buffer placement, and weight tensor names are resolved by the plan
/// compiler ([`NetworkSpec::plan`]).
#[derive(Debug, Clone, PartialEq)]
pub enum LayerOp {
    /// Input binarization (paper Section 2.3).  Float image → ±1 floats
    /// with the scheme's channel count.  `Scheme::None` networks simply
    /// omit this op (the float conv consumes the raw image directly).
    Binarize { scheme: Scheme },
    /// Packed binary convolution: fused im2col(+pack) + XNOR-popcount
    /// GEMM.  Accepts ±1 floats (first binary layer; Algorithm 1 pack)
    /// or channel-packed words (deeper layers; the word gather).
    /// Output is integer counts — follow with [`LayerOp::Threshold`].
    ConvBin { k: usize, c_out: usize },
    /// Float convolution: im2col + blocked SGEMM (+ bias + ReLU).
    /// `w` overrides the positional weight name (the legacy
    /// `Scheme::None` container calls conv1's ±1 float weights
    /// `w1_pm1`).
    ConvFloat { k: usize, c_out: usize, bias: bool, relu: bool, w: Option<String> },
    /// Float 2×2/2 max pool.
    MaxPool,
    /// Packed 2×2/2 OR pool (max in the {-1,+1} domain).
    OrPool,
    /// Per-channel learned threshold (the folded
    /// batchnorm/sign of the paper).  On spatial counts or float
    /// activations → channel-packed words (≤ 32 channels); on flat FC
    /// counts → ±1 floats for the float tail.
    Threshold,
    /// Packed binary fully-connected layer over channel-packed words;
    /// output is integer counts.
    FcBin { c_out: usize },
    /// Float fully-connected layer (flattens any float input).
    FcFloat { c_out: usize, bias: bool, act: Activation },
    /// Elementwise residual add: previous op's output + the tapped
    /// edge.  Both operands must have identical extents and the same
    /// value domain (floats or counts; packed words cannot be added).
    Add { with: Tap },
    /// Channel concatenation `[prev, tapped]`: same kind and spatial
    /// extents, output channels are the sum.  Floats or counts only.
    Concat { with: Tap },
    /// Channel split of the previous op's output into `parts` (channel
    /// widths summing to its channel count).  Part 0 feeds the next op
    /// in the chain; every other part must be consumed by a later
    /// [`Tap`] or the plan is refused (dangling split output).
    Split { parts: Vec<usize> },
    /// XNOR-Net-style per-output-channel rescale (Rastegari et al.'s
    /// `α` / SNIPPETS' `x_mean` pattern): multiplies each channel by a
    /// learned f32 factor.  Floats or counts in, floats out — the op
    /// that bridges a popcount-counts edge back into the float domain
    /// without a threshold.
    Scale,
}

#[derive(Debug)]
pub enum GraphError {
    /// Malformed `"arch"` JSON (unknown op, bad field, empty graph).
    Spec(String),
    /// Structurally-valid graph that fails shape inference.
    Validate { step: usize, op: String, why: String },
    /// A weight tensor missing from, or mis-shaped in, the container.
    Weight(String),
    /// Recoverable bad input on the inference path (ragged payload).
    BadInput(String),
    /// A broken plan/executor invariant — a compiler bug, NOT a client
    /// error (never mapped to the client-attributed `BadInput`).
    Internal(String),
}

crate::error_enum_impls!(GraphError {
    GraphError::Spec(msg) => ("graph spec: {msg}"),
    GraphError::Validate { step, op, why } => ("graph step {step} ({op}): {why}"),
    GraphError::Weight(msg) => ("graph weights: {msg}"),
    GraphError::BadInput(msg) => ("graph: {msg}"),
    GraphError::Internal(msg) => ("graph internal error (plan/executor bug): {msg}"),
});

/// An ordered layer graph.  By default each op consumes the previous
/// op's output (a linear chain); [`LayerOp::Add`] / [`LayerOp::Concat`]
/// additionally [`Tap`] an earlier op's output and [`LayerOp::Split`]
/// fans one edge out to several consumers, so the op list encodes an
/// arbitrary DAG — topologically ordered by construction, with edge
/// lifetimes resolved by [`plan`]'s interval-graph liveness pass.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkSpec {
    pub ops: Vec<LayerOp>,
}

impl NetworkSpec {
    /// The legacy 2-conv/2-fc BCNN topology for `scheme` — synthesized
    /// when a weight container or manifest entry declares no `arch`, so
    /// every pre-graph artifact keeps loading byte-compatibly (the
    /// positional weight-name rules reproduce `w1_packed`, `theta1`,
    /// `wfc1_packed`, … exactly; see [`plan`]).
    pub fn legacy_bcnn(scheme: Scheme) -> Self {
        let mut ops = Vec::new();
        match scheme {
            Scheme::None => {
                // conv1 stays full precision on the raw image; its float
                // counts are thresholded into the packed domain
                ops.push(LayerOp::ConvFloat {
                    k: 5,
                    c_out: 32,
                    bias: false,
                    relu: false,
                    w: Some("w1_pm1".to_string()),
                });
            }
            _ => {
                ops.push(LayerOp::Binarize { scheme });
                ops.push(LayerOp::ConvBin { k: 5, c_out: 32 });
            }
        }
        ops.push(LayerOp::Threshold);
        ops.push(LayerOp::OrPool);
        ops.push(LayerOp::ConvBin { k: 5, c_out: 32 });
        ops.push(LayerOp::Threshold);
        ops.push(LayerOp::OrPool);
        ops.push(LayerOp::FcBin { c_out: 100 });
        ops.push(LayerOp::Threshold);
        ops.push(LayerOp::FcFloat { c_out: 100, bias: true, act: Activation::Sign });
        ops.push(LayerOp::FcFloat {
            c_out: crate::bnn::network::NUM_CLASSES,
            bias: true,
            act: Activation::None,
        });
        Self { ops }
    }

    /// The legacy full-precision baseline (conv-pool ×2, fc ×3, ReLU).
    pub fn legacy_float() -> Self {
        let conv = |_i: usize| LayerOp::ConvFloat { k: 5, c_out: 32, bias: true, relu: true, w: None };
        Self {
            ops: vec![
                conv(1),
                LayerOp::MaxPool,
                conv(2),
                LayerOp::MaxPool,
                LayerOp::FcFloat { c_out: 100, bias: true, act: Activation::Relu },
                LayerOp::FcFloat { c_out: 100, bias: true, act: Activation::Relu },
                LayerOp::FcFloat {
                    c_out: crate::bnn::network::NUM_CLASSES,
                    bias: true,
                    act: Activation::None,
                },
            ],
        }
    }

    /// Parse an `"arch": [...]` JSON array (registry-manifest form).
    /// Every entry is an object with an `"op"` tag:
    ///
    /// ```text
    /// [{"op": "binarize", "scheme": "rgb"},
    ///  {"op": "conv_bin", "k": 5, "out": 32},
    ///  {"op": "threshold"},
    ///  {"op": "orpool"},
    ///  ...
    ///  {"op": "fc_float", "out": 4}]
    /// ```
    ///
    /// Optional fields: `conv_float` takes `"bias"` (default `true`),
    /// `"relu"` (default `false`) and `"w"` (weight-name override);
    /// `fc_float` takes `"bias"` and `"act"` (`none|relu|sign`).
    ///
    /// Branch ops: `add` and `concat` take `"with"` — either a plain
    /// 0-based op index (`{"op": "add", "with": 1}`) or an
    /// `[op, part]` pair selecting a `split` output
    /// (`{"op": "concat", "with": [2, 1]}`); `split` takes `"parts"`,
    /// a non-empty array of channel widths; `scale` takes no fields
    /// (its per-channel `alpha{n}` weight is named positionally).
    /// Shape legality — including cyclic or dangling branch
    /// references — is checked by [`NetworkSpec::plan`], not here.
    pub fn from_json(arch: &Json) -> Result<Self, GraphError> {
        let bad = GraphError::Spec; // variant constructor as error helper
        let arr = arch.as_arr().map_err(|e| bad(format!("arch must be an array: {e}")))?;
        if arr.is_empty() {
            return Err(bad("arch array is empty".to_string()));
        }
        let mut ops = Vec::with_capacity(arr.len());
        for (i, entry) in arr.iter().enumerate() {
            let ctx = |e: crate::util::json::JsonError| bad(format!("arch[{i}]: {e}"));
            let op = entry.get("op").and_then(|o| o.as_str()).map_err(ctx)?;
            let out = |field: &str| -> Result<usize, GraphError> {
                entry.get(field).and_then(|v| v.as_usize()).map_err(ctx)
            };
            let flag = |field: &str, default: bool| -> Result<bool, GraphError> {
                match entry.get_opt(field).map_err(ctx)? {
                    Some(v) => v.as_bool().map_err(ctx),
                    None => Ok(default),
                }
            };
            // "with": 2 (op index) or [2, 1] (op, split part)
            let tap = |field: &str| -> Result<Tap, GraphError> {
                let v = entry.get(field).map_err(ctx)?;
                if let Ok(op) = v.as_usize() {
                    return Ok(Tap::op(op));
                }
                match v.as_arr().map_err(ctx)? {
                    [op, part] => Ok(Tap {
                        op: op.as_usize().map_err(ctx)?,
                        part: part.as_usize().map_err(ctx)?,
                    }),
                    other => Err(bad(format!(
                        "arch[{i}]: {field:?} must be an op index or an [op, part] \
                         pair, got an array of {}",
                        other.len()
                    ))),
                }
            };
            ops.push(match op {
                "binarize" => {
                    let s = entry.get("scheme").and_then(|s| s.as_str()).map_err(ctx)?;
                    let scheme = Scheme::parse(s).ok_or_else(|| {
                        bad(format!("arch[{i}]: unknown scheme {s:?} (none|rgb|gray|lbp)"))
                    })?;
                    if scheme == Scheme::None {
                        return Err(bad(format!(
                            "arch[{i}]: scheme \"none\" has no binarize op — omit it \
                             and start with conv_float"
                        )));
                    }
                    LayerOp::Binarize { scheme }
                }
                "conv_bin" => LayerOp::ConvBin { k: out("k")?, c_out: out("out")? },
                "conv_float" => LayerOp::ConvFloat {
                    k: out("k")?,
                    c_out: out("out")?,
                    bias: flag("bias", true)?,
                    relu: flag("relu", false)?,
                    w: match entry.get_opt("w").map_err(ctx)? {
                        Some(v) => Some(v.as_str().map_err(ctx)?.to_string()),
                        None => None,
                    },
                },
                "maxpool" => LayerOp::MaxPool,
                "orpool" => LayerOp::OrPool,
                "threshold" => LayerOp::Threshold,
                "fc_bin" => LayerOp::FcBin { c_out: out("out")? },
                "fc_float" => LayerOp::FcFloat {
                    c_out: out("out")?,
                    bias: flag("bias", true)?,
                    act: match entry.get_opt("act").map_err(ctx)? {
                        Some(v) => {
                            let s = v.as_str().map_err(ctx)?;
                            Activation::parse(s).ok_or_else(|| {
                                bad(format!("arch[{i}]: unknown act {s:?} (none|relu|sign)"))
                            })?
                        }
                        None => Activation::None,
                    },
                },
                "add" => LayerOp::Add { with: tap("with")? },
                "concat" => LayerOp::Concat { with: tap("with")? },
                "split" => {
                    let arr = entry.get("parts").and_then(|v| v.as_arr()).map_err(ctx)?;
                    let parts = arr
                        .iter()
                        .map(|v| v.as_usize().map_err(ctx))
                        .collect::<Result<Vec<usize>, GraphError>>()?;
                    if parts.is_empty() {
                        return Err(bad(format!("arch[{i}]: split needs non-empty \"parts\"")));
                    }
                    LayerOp::Split { parts }
                }
                "scale" => LayerOp::Scale,
                other => return Err(bad(format!("arch[{i}]: unknown op {other:?}"))),
            });
        }
        Ok(Self { ops })
    }

    /// Compile the graph: shape inference, validation, weight-name
    /// resolution, and liveness-driven buffer assignment.
    pub fn plan(&self) -> Result<Plan, GraphError> {
        plan::compile(self)
    }
}

/// Shared branch-shaped spec fixtures for the graph test suites (plan /
/// verify / equiv / rewrite / exec all exercise the same DAGs).
#[cfg(test)]
pub(crate) mod test_specs {
    use super::{Activation, LayerOp, NetworkSpec, Tap};
    use crate::input::binarize::Scheme;

    /// The acceptance-criteria residual block: conv → conv → Add with
    /// the skip edge (k=1 convs keep extents add-compatible), 4-class.
    pub fn residual_float() -> NetworkSpec {
        let conv = |k: usize, relu: bool| LayerOp::ConvFloat {
            k,
            c_out: 8,
            bias: true,
            relu,
            w: None,
        };
        NetworkSpec {
            ops: vec![
                conv(5, true),                       // 0: f32(96,96,8)
                conv(1, true),                       // 1: f32(96,96,8)  — skip source
                conv(1, false),                      // 2: f32(96,96,8)
                LayerOp::Add { with: Tap::op(1) },   // 3: 2 + skip(1)
                LayerOp::MaxPool,                    // 4: f32(48,48,8)
                LayerOp::FcFloat { c_out: 4, bias: true, act: Activation::None },
            ],
        }
    }

    /// Binary residual: the conv's popcount-counts edge has TWO readers
    /// (the threshold chain and the Add skip), and the XNOR-Net `Scale`
    /// bridges the summed counts back into the float domain.  The
    /// multi-consumer conv→threshold pair here is exactly the shape the
    /// fold pass must refuse to fuse across.
    pub fn residual_binary() -> NetworkSpec {
        NetworkSpec {
            ops: vec![
                LayerOp::Binarize { scheme: Scheme::Rgb },  // 0
                LayerOp::ConvBin { k: 5, c_out: 32 },       // 1: counts(96,96,32), readers {2, 4}
                LayerOp::Threshold,                         // 2: words(96,96,32)
                LayerOp::ConvBin { k: 1, c_out: 32 },       // 3: counts(96,96,32)
                LayerOp::Add { with: Tap::op(1) },          // 4: 3 + skip(1)
                LayerOp::Scale,                             // 5: f32(96,96,32)
                LayerOp::MaxPool,                           // 6: f32(48,48,32)
                LayerOp::FcFloat { c_out: 4, bias: true, act: Activation::None },
            ],
        }
    }

    /// Split/Concat round trip with a scaled branch and a SIX-class
    /// head — the non-`NUM_CLASSES` logit shape served end-to-end.
    pub fn split_concat() -> NetworkSpec {
        NetworkSpec {
            ops: vec![
                LayerOp::ConvFloat { k: 5, c_out: 8, bias: true, relu: true, w: None }, // 0
                LayerOp::Split { parts: vec![3, 5] },            // 1: parts f32(96,96,{3,5})
                LayerOp::Scale,                                  // 2: scales part 0
                LayerOp::Concat { with: Tap { op: 1, part: 1 } }, // 3: f32(96,96,8)
                LayerOp::MaxPool,                                // 4: f32(48,48,8)
                LayerOp::FcFloat { c_out: 6, bias: true, act: Activation::None },
            ],
        }
    }

    /// All three branch fixtures (for suites that sweep architectures).
    pub fn all() -> Vec<(&'static str, NetworkSpec)> {
        vec![
            ("residual_float", residual_float()),
            ("residual_binary", residual_binary()),
            ("split_concat", split_concat()),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legacy_specs_have_expected_shapes() {
        for scheme in Scheme::ALL {
            let spec = NetworkSpec::legacy_bcnn(scheme);
            if scheme == Scheme::None {
                // no binarize op: the float conv consumes the raw image
                assert_eq!(spec.ops.len(), 10);
                assert!(matches!(spec.ops[0], LayerOp::ConvFloat { bias: false, .. }));
            } else {
                assert_eq!(spec.ops.len(), 11);
                assert!(matches!(spec.ops[0], LayerOp::Binarize { .. }));
            }
        }
        assert_eq!(NetworkSpec::legacy_float().ops.len(), 7);
    }

    #[test]
    fn arch_json_roundtrips_the_legacy_bcnn_topology() {
        let arch = Json::parse(
            r#"[{"op": "binarize", "scheme": "rgb"},
                {"op": "conv_bin", "k": 5, "out": 32},
                {"op": "threshold"},
                {"op": "orpool"},
                {"op": "conv_bin", "k": 5, "out": 32},
                {"op": "threshold"},
                {"op": "orpool"},
                {"op": "fc_bin", "out": 100},
                {"op": "threshold"},
                {"op": "fc_float", "out": 100, "act": "sign"},
                {"op": "fc_float", "out": 4}]"#,
        )
        .unwrap();
        let spec = NetworkSpec::from_json(&arch).unwrap();
        assert_eq!(spec, NetworkSpec::legacy_bcnn(Scheme::Rgb));
    }

    #[test]
    fn effects_agree_between_ops_and_steps() {
        // every op lowers to `lowered_steps` consecutive steps (1 for
        // all but Split), and both layers of the effect declaration
        // must tell the verifier the same story
        let mut specs = vec![
            NetworkSpec::legacy_bcnn(Scheme::Rgb),
            NetworkSpec::legacy_bcnn(Scheme::Lbp),
            NetworkSpec::legacy_bcnn(Scheme::None),
            NetworkSpec::legacy_float(),
        ];
        specs.extend(test_specs::all().into_iter().map(|(_, s)| s));
        for spec in specs {
            let plan = spec.plan().unwrap();
            let lowered: usize = spec.ops.iter().map(LayerOp::lowered_steps).sum();
            assert_eq!(lowered, plan.steps.len());
            let mut s = 0;
            for op in &spec.ops {
                for _ in 0..op.lowered_steps() {
                    let step = &plan.steps[s];
                    assert_eq!(op.effect(), step_effect(&step.kind), "{op:?}");
                    // the plan's scratch placement must match the signature
                    assert_eq!(
                        step.scratch.is_some(),
                        step_effect(&step.kind).clobbers_scratch,
                        "{op:?}"
                    );
                    s += 1;
                }
            }
        }
    }

    #[test]
    fn arch_json_roundtrips_a_branching_topology() {
        // the JSON surface of every branch op: plain-index and
        // [op, part] taps, split parts, and the weightless scale tag
        let arch = Json::parse(
            r#"[{"op": "conv_float", "k": 5, "out": 8, "relu": true},
                {"op": "split", "parts": [3, 5]},
                {"op": "scale"},
                {"op": "concat", "with": [1, 1]},
                {"op": "maxpool"},
                {"op": "fc_float", "out": 6}]"#,
        )
        .unwrap();
        let spec = NetworkSpec::from_json(&arch).unwrap();
        assert_eq!(spec, test_specs::split_concat());
        let residual = Json::parse(
            r#"[{"op": "conv_float", "k": 5, "out": 8, "relu": true},
                {"op": "conv_float", "k": 1, "out": 8, "relu": true},
                {"op": "conv_float", "k": 1, "out": 8},
                {"op": "add", "with": 1},
                {"op": "maxpool"},
                {"op": "fc_float", "out": 4}]"#,
        )
        .unwrap();
        assert_eq!(NetworkSpec::from_json(&residual).unwrap(), test_specs::residual_float());
    }

    #[test]
    fn arch_json_rejects_malformed_entries() {
        for (tag, arch) in [
            ("empty", "[]"),
            ("unknown-op", r#"[{"op": "teleport"}]"#),
            ("missing-out", r#"[{"op": "conv_bin", "k": 5}]"#),
            ("bad-scheme", r#"[{"op": "binarize", "scheme": "sepia"}]"#),
            ("none-binarize", r#"[{"op": "binarize", "scheme": "none"}]"#),
            ("bad-act", r#"[{"op": "fc_float", "out": 4, "act": "gelu"}]"#),
            ("not-an-array", r#"{"op": "fc_float"}"#),
            ("add-missing-with", r#"[{"op": "add"}]"#),
            ("concat-bad-with", r#"[{"op": "concat", "with": [1, 2, 3]}]"#),
            ("split-missing-parts", r#"[{"op": "split"}]"#),
            ("split-empty-parts", r#"[{"op": "split", "parts": []}]"#),
        ] {
            let j = Json::parse(arch).unwrap();
            let err = NetworkSpec::from_json(&j).unwrap_err();
            assert!(matches!(err, GraphError::Spec(_)), "{tag}: {err}");
        }
    }
}
