//! Static plan verifier: prove a compiled [`Plan`] sound before it
//! binds, publishes, or serves.
//!
//! Since networks became *data* (registry manifests compile `"arch"`
//! arrays into plans), an unsound plan — aliased arena slots, a step
//! reading an edge another step already clobbered, a mis-shaped weight
//! binding — is a data bug that would silently corrupt logits instead
//! of a code bug caught in review.  The binarized pipeline is maximally
//! sensitive to exactly this class of error: one polluted pad bit
//! offsets every popcount (paper §III).  So the loader refuses to
//! publish any plan this module cannot prove sound.
//!
//! The proof is independent of the compiler: every step kind declares a
//! static effect signature ([`super::EffectSig`] — reads its input
//! edge, fully covers its output extent, clobbers per-step scratch) and
//! [`verify_plan`] recomputes per-edge live intervals from those
//! effects alone, then checks them *against* the free-list coloring the
//! compiler produced rather than assuming it.  Four passes, in order:
//!
//! 1. **Kinds & slots.**  Each step's kind parameters are consistent
//!    with its declared edge types (patch depth `d = k·k·c`, halved
//!    pool extents, odd kernels, the packed-width pad-bit rules), and
//!    each slot's storage class matches the value mapped to it.
//! 2. **Dataflow.**  Every read edge has exactly one prior
//!    full-coverage writer of the exact value type, no step's output is
//!    dead, and the final edge is the declared logit shape.
//! 3. **Liveness & aliasing.**  No two edges with overlapping live
//!    intervals share a slot, every referenced slot is inside the
//!    declared arena, and every declared slot is actually used.
//! 4. **Weights.**  Bindings are total (every tensor a step needs is
//!    declared), length-exact per the step's own shape arithmetic, and
//!    unique — with pad-bit cleanliness for packed weights proven in
//!    pass 1's width checks.
//!
//! On success, [`VerifyReport`] carries the proven resource envelope
//! (slots, live-interval count, peak bytes per pool), surfaced per
//! model in the admin plane's `list_models`.  Failure is a structured
//! [`VerifyError`] naming the step, edge, slot, and — for aliasing —
//! the two conflicting live intervals.  The mutation-testing suite in
//! [`super::plan`] injects sixteen corruption classes: eleven are
//! judged here ([`super::plan::Corruption::VERIFY_REJECTED`], three of
//! them branch-shaped — a clobbered skip edge, a concat extent lie, a
//! scale channel-count lie), five rewrite-shaped ones by
//! [`super::equiv::check_equiv`].
//!
//! Plans are DAGs, not chains: `Add`/`Concat` steps carry a second
//! operand edge and a `Split` fans one edge out to several readers.
//! The dataflow and interval passes treat the second operand exactly
//! like the first (it extends the producing edge's live interval), so
//! a liveness bug that releases a multi-reader edge after its first
//! reader surfaces as [`VerifyError::SlotAliased`] — the clobberer's
//! definition overlaps the edge's extended interval.

use std::collections::BTreeMap;
use std::fmt;

use crate::bnn::network::{IMG_C, IMG_H, IMG_W};
use crate::bnn::packing::packed_width;
use crate::input::binarize::Scheme;
use crate::util::json::{Json, JsonObj};

use super::plan::{BufClass, BufId, Plan, Src, Step, StepKind, ValKind, ValTy, WeightDType};
use super::step_effect;

/// Role an edge plays within its defining step (for error reports).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeRole {
    /// A step's covering output write.
    Output,
    /// A per-step scratch clobber (patch gathers, the LBP gray plane);
    /// garbage after the step, so never a valid read source.
    Scratch,
}

/// One live edge the interval analysis tracked: defined (written) at
/// step `step`, live through `live.1` inclusive.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EdgeRef {
    pub step: usize,
    pub role: EdgeRole,
    /// Live interval `[def, last_use]` in step indices, inclusive.  The
    /// logits edge extends one past the last step (read after
    /// execution).
    pub live: (usize, usize),
}

impl fmt::Display for EdgeRef {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let role = match self.role {
            EdgeRole::Output => "output",
            EdgeRole::Scratch => "scratch",
        };
        write!(f, "the {role} of step {} (live [{}, {}])", self.step, self.live.0, self.live.1)
    }
}

/// A structured verification failure.  Every variant names the step,
/// slot, edge, or weight at fault so a refused manifest entry is
/// diagnosable from the error string alone.
#[derive(Debug)]
pub enum VerifyError {
    /// Two edges with overlapping live intervals share one arena slot.
    SlotAliased { class: BufClass, slot: usize, a: EdgeRef, b: EdgeRef },
    /// A slot's storage class cannot hold the value mapped to it.
    SlotDtype { step: usize, slot: BufId, want: String },
    /// A step references a slot outside the declared arena.
    SlotOutOfRange { step: usize, slot: BufId, nbufs: usize },
    /// The declared arena has a slot no edge ever maps to — the
    /// coloring summary overstates the free-list walk.
    UnusedSlot { class: BufClass, slot: usize },
    /// A step reads an edge with no prior full-coverage writer.
    ReadWithoutWriter { step: usize, slot: BufId, why: String },
    /// A reader expects a different value type than the edge's writer
    /// produced.
    EdgeType { step: usize, src: String, want: String, got: String },
    /// A step's output is never consumed and is not the logits.
    DeadStep { step: usize, label: String },
    /// The final edge is not the declared logit shape.
    BadLogits { step: usize, got: String, want: String },
    /// A step's kind parameters are inconsistent with its edge types.
    KindShape { step: usize, op: String, why: String },
    /// A packed-bit width rule is violated — pad masking (the popcount
    /// soundness precondition) would be undefined.
    PadBits { step: usize, op: String, why: String },
    /// A step binds a weight the plan never declares.
    WeightMissing { step: usize, name: String },
    /// A declared weight's dtype/shape differs from what its step's own
    /// shape arithmetic requires.
    WeightShape { step: usize, name: String, want: String, got: String },
    /// One tensor name declared twice — it would bind two roles.
    WeightDup { name: String },
    /// A declared weight no step binds.
    WeightUnused { name: String },
}

crate::error_enum_impls!(VerifyError {
    VerifyError::SlotAliased { class, slot, a, b } =>
        ("slot {}[{slot}] aliased: {a} overlaps {b}", class_name(*class)),
    VerifyError::SlotDtype { step, slot, want } =>
        ("step {step}: slot {} cannot hold {want}", slot_name(*slot)),
    VerifyError::SlotOutOfRange { step, slot, nbufs } =>
        ("step {step}: slot {} is outside the declared arena ({nbufs} slots in its class)",
         slot_name(*slot)),
    VerifyError::UnusedSlot { class, slot } =>
        ("declared slot {}[{slot}] is never written by any step", class_name(*class)),
    VerifyError::ReadWithoutWriter { step, slot, why } =>
        ("step {step} reads slot {} with no prior full-coverage writer: {why}", slot_name(*slot)),
    VerifyError::EdgeType { step, src, want, got } =>
        ("step {step} expects {want} but {src} carries {got}"),
    VerifyError::DeadStep { step, label } =>
        ("step {step} ({label}): output is never consumed and is not the logits"),
    VerifyError::BadLogits { step, got, want } =>
        ("step {step}: final edge is {got}; the serving contract wants {want}"),
    VerifyError::KindShape { step, op, why } => ("step {step} ({op}): {why}"),
    VerifyError::PadBits { step, op, why } => ("step {step} ({op}): pad-bit soundness: {why}"),
    VerifyError::WeightMissing { step, name } =>
        ("step {step} binds weight {name:?}, which the plan never declares"),
    VerifyError::WeightShape { step, name, want, got } =>
        ("weight {name:?} (step {step}): declared {got}, the step requires {want}"),
    VerifyError::WeightDup { name } =>
        ("weight {name:?} is declared twice — one tensor would bind two roles"),
    VerifyError::WeightUnused { name } => ("declared weight {name:?} is bound by no step"),
});

/// The proven resource envelope of a verified plan, surfaced per model
/// in the admin plane's `list_models`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VerifyReport {
    /// Lowered steps proven sound.
    pub steps: usize,
    /// Weight tensors with total, length-exact bindings.
    pub weights: usize,
    /// Arena slots per storage class, `[f32, u32, i32]`.
    pub slots: [usize; 3],
    /// Live edges (covering outputs + per-step scratch clobbers) the
    /// interval analysis tracked.
    pub intervals: usize,
    /// Per-image peak bytes per pool `[f32, u32, i32]`: each slot costs
    /// its largest resident edge (all three classes are 4-byte).
    pub peak_bytes: [usize; 3],
}

impl VerifyReport {
    /// Peak elements per pool (`peak_bytes / 4` — all classes 4-byte).
    pub fn peak_elems(&self) -> [usize; 3] {
        [self.peak_bytes[0] / 4, self.peak_bytes[1] / 4, self.peak_bytes[2] / 4]
    }

    /// The `list_models` wire form.
    pub fn to_json(&self) -> Json {
        let arr = |xs: &[usize; 3]| Json::Arr(xs.iter().map(|&n| Json::from(n)).collect());
        let mut o = JsonObj::new();
        o.insert("steps", Json::from(self.steps));
        o.insert("weights", Json::from(self.weights));
        o.insert("slots", arr(&self.slots));
        o.insert("intervals", Json::from(self.intervals));
        o.insert("peak_bytes", arr(&self.peak_bytes));
        Json::Obj(o)
    }
}

/// One tracked edge: a covering output write or a scratch clobber.
#[derive(Clone, Copy)]
struct Edge {
    slot: BufId,
    role: EdgeRole,
    def: usize,
    last_use: usize,
    /// The value type written — `None` for scratch clobbers, whose
    /// contents are garbage after the step.
    ty: Option<ValTy>,
    /// Per-image element footprint while resident in the slot.
    elems: usize,
}

fn edge_ref(e: &Edge) -> EdgeRef {
    EdgeRef { step: e.def, role: e.role, live: (e.def, e.last_use) }
}

fn class_name(c: BufClass) -> &'static str {
    match c {
        BufClass::F32 => "f32",
        BufClass::U32 => "u32",
        BufClass::I32 => "i32",
    }
}

fn class_of(c: usize) -> BufClass {
    match c {
        0 => BufClass::F32,
        1 => BufClass::U32,
        _ => BufClass::I32,
    }
}

fn slot_name(b: BufId) -> String {
    format!("{}[{}]", class_name(b.class), b.idx)
}

fn slot_key(b: BufId) -> (usize, usize) {
    (b.class as usize, b.idx)
}

pub(crate) fn kind_name(kind: &StepKind) -> &'static str {
    match kind {
        StepKind::Binarize { .. } => "binarize",
        StepKind::ConvBinPacked { .. } => "conv_bin_packed",
        StepKind::ConvBinWords { .. } => "conv_bin_words",
        StepKind::ConvFloat { .. } => "conv_float",
        StepKind::MaxPool => "maxpool",
        StepKind::OrPool => "orpool",
        StepKind::ThresholdPack { .. } => "threshold_pack",
        StepKind::ThresholdPm1 { .. } => "threshold_pm1",
        StepKind::FcBin { .. } => "fc_bin",
        StepKind::FcFloat { .. } => "fc_float",
        StepKind::ConvBinPackedThreshold { .. } => "conv_bin_packed+threshold",
        StepKind::ConvBinWordsThreshold { .. } => "conv_bin_words+threshold",
        StepKind::BinarizeConvBin { .. } => "binarize+conv_bin_packed",
        StepKind::BinarizeConvBinThreshold { .. } => "binarize+conv_bin_packed+threshold",
        StepKind::FcBinThreshold { .. } => "fc_bin+threshold",
        StepKind::Add => "add",
        StepKind::Concat => "concat",
        StepKind::SplitPart { .. } => "split_part",
        StepKind::Scale { .. } => "scale",
    }
}

/// Storage class of a step's scratch clobber, per its effect signature.
fn scratch_class(kind: &StepKind) -> Option<BufClass> {
    match kind {
        StepKind::Binarize { scheme } => (*scheme == Scheme::Lbp).then_some(BufClass::F32),
        StepKind::ConvBinPacked { .. } | StepKind::ConvBinWords { .. } => Some(BufClass::U32),
        StepKind::ConvFloat { .. } => Some(BufClass::F32),
        // fused convs still gather patches into a u32 scratch
        StepKind::ConvBinPackedThreshold { .. }
        | StepKind::ConvBinWordsThreshold { .. }
        | StepKind::BinarizeConvBin { .. }
        | StepKind::BinarizeConvBinThreshold { .. } => Some(BufClass::U32),
        _ => None,
    }
}

/// Storage class of a step's *second* scratch clobber: the i32 counts
/// buffer a fused conv+threshold step still writes until the elision
/// pass drops it.  `None` everywhere else.
fn scratch2_class(kind: &StepKind) -> Option<BufClass> {
    match kind {
        StepKind::ConvBinPackedThreshold { elide, .. }
        | StepKind::ConvBinWordsThreshold { elide, .. }
        | StepKind::BinarizeConvBinThreshold { elide, .. } => {
            (!elide).then_some(BufClass::I32)
        }
        _ => None,
    }
}

/// Per-image element footprint of a step's scratch clobber (the
/// executor's patch-gather / gray-plane sizing, recomputed here).
fn scratch_elems(step: &Step) -> usize {
    let px = step.in_ty.h * step.in_ty.w;
    match &step.kind {
        StepKind::Binarize { .. } => px, // the LBP grayscale plane
        StepKind::ConvBinPacked { nw, .. }
        | StepKind::ConvBinPackedThreshold { nw, .. }
        | StepKind::BinarizeConvBin { nw, .. }
        | StepKind::BinarizeConvBinThreshold { nw, .. } => px * nw,
        StepKind::ConvBinWords { k, .. } | StepKind::ConvBinWordsThreshold { k, .. } => {
            px * k * k
        }
        StepKind::ConvFloat { k, .. } => px * k * k * step.in_ty.c,
        _ => 0,
    }
}

/// Per-image element footprint of the i32 counts scratch of a
/// non-elided fused conv+threshold step.
fn scratch2_elems(step: &Step) -> usize {
    step.out_ty.h * step.out_ty.w * step.out_ty.c
}

/// Per-image element footprint of a value while resident in its slot
/// (channel-packed words hold one `u32` per pixel regardless of `c`).
fn ty_elems(ty: &ValTy) -> usize {
    match ty.kind {
        ValKind::Words => ty.h * ty.w,
        _ => ty.h * ty.w * ty.c,
    }
}

fn logits_want(classes: usize) -> String {
    format!("f32(1,1,{classes})")
}

/// Prove `plan` sound without executing it.  See the module docs for
/// the pass order; the first violation found is returned.
pub fn verify_plan(plan: &Plan) -> Result<VerifyReport, VerifyError> {
    let last_step = match plan.steps.len().checked_sub(1) {
        Some(l) => l,
        None => {
            return Err(VerifyError::BadLogits {
                step: 0,
                got: "an empty plan".to_string(),
                want: logits_want(plan.classes.max(1)),
            })
        }
    };

    // ---- pass 1: kinds & slots --------------------------------------
    for (j, step) in plan.steps.iter().enumerate() {
        check_step_kind(j, step)?;
        check_step_slots(j, step)?;
    }

    // ---- pass 2: dataflow -------------------------------------------
    // Walk the steps replaying each one's effect signature, tracking the
    // last writer of every slot.  Reads must hit a live covering write
    // of the exact value type; scratch clobbers invalidate their slot.
    let mut edges: Vec<Edge> = Vec::new();
    let mut last_writer: BTreeMap<(usize, usize), usize> = BTreeMap::new();
    for (j, step) in plan.steps.iter().enumerate() {
        let eff = step_effect(&step.kind);
        if eff.reads_input {
            match step.input {
                Src::External => {
                    let ext = ValTy { kind: ValKind::F32, h: IMG_H, w: IMG_W, c: IMG_C };
                    if step.in_ty != ext {
                        return Err(VerifyError::EdgeType {
                            step: j,
                            src: "the external image payload".to_string(),
                            want: step.in_ty.describe(),
                            got: ext.describe(),
                        });
                    }
                }
                Src::Buf(b) => {
                    let ei = match last_writer.get(&slot_key(b)).copied() {
                        Some(ei) => ei,
                        None => {
                            return Err(VerifyError::ReadWithoutWriter {
                                step: j,
                                slot: b,
                                why: "no prior step writes it".to_string(),
                            })
                        }
                    };
                    let (wty, wdef) = (edges[ei].ty, edges[ei].def);
                    match wty {
                        None => {
                            return Err(VerifyError::ReadWithoutWriter {
                                step: j,
                                slot: b,
                                why: format!(
                                    "its last write is the scratch clobber of step {wdef}"
                                ),
                            })
                        }
                        Some(ty) if ty != step.in_ty => {
                            return Err(VerifyError::EdgeType {
                                step: j,
                                src: format!("the output of step {wdef}"),
                                want: step.in_ty.describe(),
                                got: ty.describe(),
                            })
                        }
                        Some(_) => edges[ei].last_use = j,
                    }
                }
            }
        }
        // the second operand (Add/Concat) reads like the first: it must
        // hit a live covering write of the expected type, and it extends
        // that edge's interval — THIS is what keeps a skip edge alive
        // past intermediate steps on the trunk
        if let Some(src) = step.input2 {
            let want = match step.input2_ty() {
                Some(t) => t,
                // pass 1 already refused a second operand on a unary
                // kind; nothing to type-check here
                None => step.in_ty,
            };
            match src {
                Src::External => {
                    let ext = ValTy { kind: ValKind::F32, h: IMG_H, w: IMG_W, c: IMG_C };
                    if want != ext {
                        return Err(VerifyError::EdgeType {
                            step: j,
                            src: "the external image payload (second operand)".to_string(),
                            want: want.describe(),
                            got: ext.describe(),
                        });
                    }
                }
                Src::Buf(b) => {
                    let ei = match last_writer.get(&slot_key(b)).copied() {
                        Some(ei) => ei,
                        None => {
                            return Err(VerifyError::ReadWithoutWriter {
                                step: j,
                                slot: b,
                                why: "no prior step writes its second operand".to_string(),
                            })
                        }
                    };
                    let (wty, wdef) = (edges[ei].ty, edges[ei].def);
                    match wty {
                        None => {
                            return Err(VerifyError::ReadWithoutWriter {
                                step: j,
                                slot: b,
                                why: format!(
                                    "its last write is the scratch clobber of step {wdef}"
                                ),
                            })
                        }
                        Some(ty) if ty != want => {
                            return Err(VerifyError::EdgeType {
                                step: j,
                                src: format!("the output of step {wdef} (second operand)"),
                                want: want.describe(),
                                got: ty.describe(),
                            })
                        }
                        Some(_) => edges[ei].last_use = j,
                    }
                }
            }
        }
        if let Some(s) = step.scratch {
            // presence/class consistency with the effect signature was
            // proven in pass 1; here it only occupies its interval
            let ei = edges.len();
            edges.push(Edge {
                slot: s,
                role: EdgeRole::Scratch,
                def: j,
                last_use: j,
                ty: None,
                elems: scratch_elems(step),
            });
            last_writer.insert(slot_key(s), ei);
        }
        if let Some(s) = step.scratch2 {
            // the fused counts buffer: a second per-step clobber
            let ei = edges.len();
            edges.push(Edge {
                slot: s,
                role: EdgeRole::Scratch,
                def: j,
                last_use: j,
                ty: None,
                elems: scratch2_elems(step),
            });
            last_writer.insert(slot_key(s), ei);
        }
        if eff.covers_output {
            let ei = edges.len();
            edges.push(Edge {
                slot: step.output,
                role: EdgeRole::Output,
                def: j,
                last_use: j,
                ty: Some(step.out_ty),
                elems: ty_elems(&step.out_ty),
            });
            last_writer.insert(slot_key(step.output), ei);
        }
    }

    // the serving contract: the final edge is one float logit row per
    // image, and the class count the plan declares IS that edge's
    // channel width — no hard-wired head size
    let logits_ty = plan.steps[last_step].out_ty;
    let want_ty = ValTy { kind: ValKind::F32, h: 1, w: 1, c: plan.classes };
    if plan.classes == 0 || logits_ty != want_ty {
        return Err(VerifyError::BadLogits {
            step: last_step,
            got: format!("{} with {} declared classes", logits_ty.describe(), plan.classes),
            want: logits_want(plan.classes.max(1)),
        });
    }
    // the logits edge is read after execution (`read_logits`): extend it
    // one step past the end so no in-plan write may overlap it
    if let Some(&ei) = last_writer.get(&slot_key(plan.steps[last_step].output)) {
        if edges[ei].def == last_step {
            edges[ei].last_use = plan.steps.len();
        }
    }
    for e in &edges {
        if e.role == EdgeRole::Output && e.last_use == e.def {
            return Err(VerifyError::DeadStep {
                step: e.def,
                label: plan.steps[e.def].label_a.clone(),
            });
        }
    }

    // ---- pass 3: liveness & aliasing --------------------------------
    let mut by_slot: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
    for (ei, e) in edges.iter().enumerate() {
        by_slot.entry(slot_key(e.slot)).or_default().push(ei);
    }
    for group in by_slot.values() {
        for (i, &a) in group.iter().enumerate() {
            for &b in &group[i + 1..] {
                let (ea, eb) = (&edges[a], &edges[b]);
                if ea.def <= eb.last_use && eb.def <= ea.last_use {
                    return Err(VerifyError::SlotAliased {
                        class: ea.slot.class,
                        slot: ea.slot.idx,
                        a: edge_ref(ea),
                        b: edge_ref(eb),
                    });
                }
            }
        }
    }
    for e in &edges {
        let n = plan.nbufs[e.slot.class as usize];
        if e.slot.idx >= n {
            return Err(VerifyError::SlotOutOfRange { step: e.def, slot: e.slot, nbufs: n });
        }
    }
    for (c, &n) in plan.nbufs.iter().enumerate() {
        for idx in 0..n {
            if !by_slot.contains_key(&(c, idx)) {
                return Err(VerifyError::UnusedSlot { class: class_of(c), slot: idx });
            }
        }
    }

    // ---- pass 4: weights --------------------------------------------
    for (i, req) in plan.weights.iter().enumerate() {
        if plan.weights[..i].iter().any(|r| r.name == req.name) {
            return Err(VerifyError::WeightDup { name: req.name.clone() });
        }
    }
    let mut used = vec![false; plan.weights.len()];
    {
        let mut need = |step: usize,
                        name: &str,
                        dtype: WeightDType,
                        shape: Vec<usize>|
         -> Result<(), VerifyError> {
            match plan.weights.iter().position(|r| r.name == name) {
                None => Err(VerifyError::WeightMissing { step, name: name.to_string() }),
                Some(i) => {
                    let req = &plan.weights[i];
                    if req.dtype != dtype || req.shape != shape {
                        return Err(VerifyError::WeightShape {
                            step,
                            name: name.to_string(),
                            want: weight_desc(dtype, &shape),
                            got: weight_desc(req.dtype, &req.shape),
                        });
                    }
                    used[i] = true;
                    Ok(())
                }
            }
        };
        for (j, step) in plan.steps.iter().enumerate() {
            let t = &step.in_ty;
            match &step.kind {
                StepKind::Binarize { scheme } => match scheme {
                    Scheme::Rgb => need(j, "input_t", WeightDType::F32, vec![3])?,
                    Scheme::Gray => need(j, "input_t", WeightDType::F32, vec![1])?,
                    Scheme::Lbp | Scheme::None => {}
                },
                StepKind::ConvBinPacked { c_out, nw, w, .. } => {
                    need(j, w, WeightDType::U32, vec![*c_out, *nw])?;
                }
                StepKind::ConvBinWords { k, c_out, w, .. } => {
                    need(j, w, WeightDType::U32, vec![*c_out, k * k])?;
                }
                StepKind::ConvFloat { k, c_out, w, b, .. } => {
                    need(j, w, WeightDType::F32, vec![*c_out, k * k * t.c])?;
                    if let Some(b) = b {
                        need(j, b, WeightDType::F32, vec![*c_out])?;
                    }
                }
                StepKind::ThresholdPack { theta, flip, .. }
                | StepKind::ThresholdPm1 { theta, flip } => {
                    need(j, theta, WeightDType::F32, vec![t.c])?;
                    need(j, flip, WeightDType::U32, vec![t.c])?;
                }
                StepKind::FcBin { kw, c_out, w, .. } => {
                    need(j, w, WeightDType::U32, vec![*c_out, *kw])?;
                }
                StepKind::FcFloat { d, c_out, w, b, .. } => {
                    need(j, w, WeightDType::F32, vec![*c_out, *d])?;
                    if let Some(b) = b {
                        need(j, b, WeightDType::F32, vec![*c_out])?;
                    }
                }
                StepKind::ConvBinPackedThreshold { c_out, nw, w, theta, flip, .. } => {
                    need(j, w, WeightDType::U32, vec![*c_out, *nw])?;
                    need(j, theta, WeightDType::F32, vec![*c_out])?;
                    need(j, flip, WeightDType::U32, vec![*c_out])?;
                }
                StepKind::ConvBinWordsThreshold { k, c_out, w, theta, flip, .. } => {
                    need(j, w, WeightDType::U32, vec![*c_out, k * k])?;
                    need(j, theta, WeightDType::F32, vec![*c_out])?;
                    need(j, flip, WeightDType::U32, vec![*c_out])?;
                }
                StepKind::BinarizeConvBin { scheme, c_out, nw, w, .. } => {
                    match scheme {
                        Scheme::Rgb => need(j, "input_t", WeightDType::F32, vec![3])?,
                        Scheme::Gray => need(j, "input_t", WeightDType::F32, vec![1])?,
                        Scheme::Lbp | Scheme::None => {}
                    }
                    need(j, w, WeightDType::U32, vec![*c_out, *nw])?;
                }
                StepKind::BinarizeConvBinThreshold {
                    scheme, c_out, nw, w, theta, flip, ..
                } => {
                    match scheme {
                        Scheme::Rgb => need(j, "input_t", WeightDType::F32, vec![3])?,
                        Scheme::Gray => need(j, "input_t", WeightDType::F32, vec![1])?,
                        Scheme::Lbp | Scheme::None => {}
                    }
                    need(j, w, WeightDType::U32, vec![*c_out, *nw])?;
                    need(j, theta, WeightDType::F32, vec![*c_out])?;
                    need(j, flip, WeightDType::U32, vec![*c_out])?;
                }
                StepKind::FcBinThreshold { kw, c_out, w, theta, flip, .. } => {
                    need(j, w, WeightDType::U32, vec![*c_out, *kw])?;
                    need(j, theta, WeightDType::F32, vec![*c_out])?;
                    need(j, flip, WeightDType::U32, vec![*c_out])?;
                }
                StepKind::Scale { alpha } => {
                    // the per-output-channel XNOR-Net rescale vector
                    need(j, alpha, WeightDType::F32, vec![t.c])?;
                }
                StepKind::MaxPool
                | StepKind::OrPool
                | StepKind::Add
                | StepKind::Concat
                | StepKind::SplitPart { .. } => {}
            }
        }
    }
    if let Some(i) = used.iter().position(|&u| !u) {
        return Err(VerifyError::WeightUnused { name: plan.weights[i].name.clone() });
    }

    // ---- the proven envelope ----------------------------------------
    let mut peak: [Vec<usize>; 3] = [
        vec![0; plan.nbufs[0]],
        vec![0; plan.nbufs[1]],
        vec![0; plan.nbufs[2]],
    ];
    for e in &edges {
        let p = &mut peak[e.slot.class as usize][e.slot.idx];
        *p = (*p).max(e.elems);
    }
    let peak_bytes = [
        peak[0].iter().sum::<usize>() * 4,
        peak[1].iter().sum::<usize>() * 4,
        peak[2].iter().sum::<usize>() * 4,
    ];
    Ok(VerifyReport {
        steps: plan.steps.len(),
        weights: plan.weights.len(),
        slots: plan.nbufs,
        intervals: edges.len(),
        peak_bytes,
    })
}

fn weight_desc(dtype: WeightDType, shape: &[usize]) -> String {
    let d = match dtype {
        WeightDType::F32 => "f32",
        WeightDType::U32 => "u32",
    };
    format!("{d} {shape:?}")
}

/// Pass 1, per step: kind parameters vs edge types.  Pad-bit rules are
/// checked before plain shape arithmetic so a packed-width violation is
/// always reported as [`VerifyError::PadBits`].
fn check_step_kind(j: usize, step: &Step) -> Result<(), VerifyError> {
    let t = step.in_ty;
    let o = step.out_ty;
    let op = kind_name(&step.kind);
    let ks = |why: String| VerifyError::KindShape { step: j, op: op.to_string(), why };
    let pad = |why: String| VerifyError::PadBits { step: j, op: op.to_string(), why };
    let want_out = |want: ValTy| -> Result<(), VerifyError> {
        if o != want {
            return Err(VerifyError::KindShape {
                step: j,
                op: op.to_string(),
                why: format!(
                    "output edge is {}, the effect signature covers {}",
                    o.describe(),
                    want.describe()
                ),
            });
        }
        Ok(())
    };
    // only Add/Concat are binary; a second operand anywhere else means
    // the plan was assembled by something other than the compiler
    if step.input2.is_some() && !matches!(step.kind, StepKind::Add | StepKind::Concat) {
        return Err(ks("binds a second operand but the kind is unary".to_string()));
    }
    let conv_params = |k: usize, c_out: usize| -> Result<(), VerifyError> {
        if k == 0 || k % 2 == 0 {
            return Err(VerifyError::KindShape {
                step: j,
                op: op.to_string(),
                why: format!("kernel size {k} must be odd ('same' convolution)"),
            });
        }
        if c_out == 0 {
            return Err(VerifyError::KindShape {
                step: j,
                op: op.to_string(),
                why: "output channels must be >= 1".to_string(),
            });
        }
        Ok(())
    };
    let pool_extents = || -> Result<(), VerifyError> {
        if t.h < 2 || t.w < 2 || t.h % 2 != 0 || t.w % 2 != 0 {
            return Err(VerifyError::KindShape {
                step: j,
                op: op.to_string(),
                why: format!("2x2 pool needs even extents >= 2, got {}", t.describe()),
            });
        }
        Ok(())
    };
    match &step.kind {
        StepKind::Binarize { scheme } => {
            if *scheme == Scheme::None {
                return Err(ks("scheme \"none\" has no binarize step".to_string()));
            }
            if t.kind != ValKind::F32 || t.c != IMG_C {
                return Err(ks(format!("expects 3-channel float pixels, got {}", t.describe())));
            }
            want_out(ValTy { kind: ValKind::F32, h: t.h, w: t.w, c: scheme.input_channels() })?;
        }
        StepKind::ConvBinPacked { k, c_out, nw, d, .. } => {
            if *nw != packed_width(*d, 32) {
                return Err(pad(format!(
                    "{nw} weight words per row cannot hold exactly d={d} packed bits \
                     (want {}) — tail-pad masking would be unsound",
                    packed_width(*d, 32)
                )));
            }
            conv_params(*k, *c_out)?;
            if t.kind != ValKind::F32 {
                return Err(ks(format!("expects ±1 float input, got {}", t.describe())));
            }
            if *d != k * k * t.c {
                return Err(ks(format!("patch depth d={d} != k*k*c = {}", k * k * t.c)));
            }
            want_out(ValTy { kind: ValKind::Counts, h: t.h, w: t.w, c: *c_out })?;
        }
        StepKind::ConvBinWords { k, c_out, d, .. } => {
            if t.kind != ValKind::Words {
                return Err(ks(format!("expects channel-packed words, got {}", t.describe())));
            }
            if t.c > 32 {
                return Err(pad(format!(
                    "channel-packed words carry at most 32 live channels, got {}",
                    t.c
                )));
            }
            conv_params(*k, *c_out)?;
            if *d != k * k * t.c {
                return Err(ks(format!("patch depth d={d} != k*k*c = {}", k * k * t.c)));
            }
            want_out(ValTy { kind: ValKind::Counts, h: t.h, w: t.w, c: *c_out })?;
        }
        StepKind::ConvFloat { k, c_out, .. } => {
            conv_params(*k, *c_out)?;
            if t.kind != ValKind::F32 {
                return Err(ks(format!("expects float input, got {}", t.describe())));
            }
            want_out(ValTy { kind: ValKind::F32, h: t.h, w: t.w, c: *c_out })?;
        }
        StepKind::MaxPool => {
            if t.kind != ValKind::F32 {
                return Err(ks(format!("expects float input, got {}", t.describe())));
            }
            pool_extents()?;
            want_out(ValTy { kind: ValKind::F32, h: t.h / 2, w: t.w / 2, c: t.c })?;
        }
        StepKind::OrPool => {
            if t.kind != ValKind::Words {
                return Err(ks(format!("expects channel-packed words, got {}", t.describe())));
            }
            if t.c > 32 {
                return Err(pad(format!(
                    "channel-packed words carry at most 32 live channels, got {}",
                    t.c
                )));
            }
            pool_extents()?;
            want_out(ValTy { kind: ValKind::Words, h: t.h / 2, w: t.w / 2, c: t.c })?;
        }
        StepKind::ThresholdPack { f32_in, .. } => {
            if t.kind != ValKind::F32 && t.kind != ValKind::Counts {
                return Err(ks(format!(
                    "expects conv counts or float activations, got {}",
                    t.describe()
                )));
            }
            if *f32_in != (t.kind == ValKind::F32) {
                return Err(ks(format!(
                    "f32_in={f32_in} disagrees with the input edge kind ({})",
                    t.describe()
                )));
            }
            if t.c > 32 {
                return Err(pad(format!(
                    "threshold packs into one word per pixel; {} channels > 32",
                    t.c
                )));
            }
            want_out(ValTy { kind: ValKind::Words, h: t.h, w: t.w, c: t.c })?;
        }
        StepKind::ThresholdPm1 { .. } => {
            if t.kind != ValKind::Counts || (t.h, t.w) != (1, 1) {
                return Err(ks(format!("expects flat FC counts, got {}", t.describe())));
            }
            want_out(ValTy { kind: ValKind::F32, h: 1, w: 1, c: t.c })?;
        }
        StepKind::FcBin { kw, c_out, d, .. } => {
            if t.kind != ValKind::Words {
                return Err(ks(format!("expects channel-packed words, got {}", t.describe())));
            }
            if t.c > 32 {
                return Err(pad(format!(
                    "channel-packed words carry at most 32 live channels, got {}",
                    t.c
                )));
            }
            if *c_out == 0 {
                return Err(ks("output width must be >= 1".to_string()));
            }
            if *kw != t.h * t.w {
                return Err(ks(format!("row width kw={kw} != h*w = {}", t.h * t.w)));
            }
            if *d != kw * t.c {
                return Err(ks(format!("real bit depth d={d} != kw*c = {}", kw * t.c)));
            }
            want_out(ValTy { kind: ValKind::Counts, h: 1, w: 1, c: *c_out })?;
        }
        StepKind::FcFloat { d, c_out, .. } => {
            if t.kind != ValKind::F32 {
                return Err(ks(format!("expects float features, got {}", t.describe())));
            }
            if *c_out == 0 {
                return Err(ks("output width must be >= 1".to_string()));
            }
            if *d != t.h * t.w * t.c {
                return Err(ks(format!("input depth d={d} != h*w*c = {}", t.h * t.w * t.c)));
            }
            want_out(ValTy { kind: ValKind::F32, h: 1, w: 1, c: *c_out })?;
        }
        StepKind::ConvBinPackedThreshold { k, c_out, nw, d, .. } => {
            if *nw != packed_width(*d, 32) {
                return Err(pad(format!(
                    "{nw} weight words per row cannot hold exactly d={d} packed bits \
                     (want {}) — tail-pad masking would be unsound",
                    packed_width(*d, 32)
                )));
            }
            if *c_out > 32 {
                return Err(pad(format!(
                    "the fused epilogue packs into one word per pixel; {c_out} channels > 32"
                )));
            }
            conv_params(*k, *c_out)?;
            if t.kind != ValKind::F32 {
                return Err(ks(format!("expects ±1 float input, got {}", t.describe())));
            }
            if *d != k * k * t.c {
                return Err(ks(format!("patch depth d={d} != k*k*c = {}", k * k * t.c)));
            }
            want_out(ValTy { kind: ValKind::Words, h: t.h, w: t.w, c: *c_out })?;
        }
        StepKind::ConvBinWordsThreshold { k, c_out, d, .. } => {
            if t.kind != ValKind::Words {
                return Err(ks(format!("expects channel-packed words, got {}", t.describe())));
            }
            if t.c > 32 {
                return Err(pad(format!(
                    "channel-packed words carry at most 32 live channels, got {}",
                    t.c
                )));
            }
            if *c_out > 32 {
                return Err(pad(format!(
                    "the fused epilogue packs into one word per pixel; {c_out} channels > 32"
                )));
            }
            conv_params(*k, *c_out)?;
            if *d != k * k * t.c {
                return Err(ks(format!("patch depth d={d} != k*k*c = {}", k * k * t.c)));
            }
            want_out(ValTy { kind: ValKind::Words, h: t.h, w: t.w, c: *c_out })?;
        }
        StepKind::BinarizeConvBin { scheme, k, c_out, nw, d, .. } => {
            if !matches!(scheme, Scheme::Rgb | Scheme::Gray) {
                return Err(ks(format!(
                    "only rgb/gray binarization fuses into the gather, got {:?}",
                    scheme
                )));
            }
            if t.kind != ValKind::F32 || t.c != IMG_C {
                return Err(ks(format!("expects 3-channel float pixels, got {}", t.describe())));
            }
            if *nw != packed_width(*d, 32) {
                return Err(pad(format!(
                    "{nw} weight words per row cannot hold exactly d={d} packed bits \
                     (want {}) — tail-pad masking would be unsound",
                    packed_width(*d, 32)
                )));
            }
            conv_params(*k, *c_out)?;
            if *d != k * k * scheme.input_channels() {
                return Err(ks(format!(
                    "patch depth d={d} != k*k*{} binarized channels",
                    scheme.input_channels()
                )));
            }
            want_out(ValTy { kind: ValKind::Counts, h: t.h, w: t.w, c: *c_out })?;
        }
        StepKind::BinarizeConvBinThreshold { scheme, k, c_out, nw, d, .. } => {
            if !matches!(scheme, Scheme::Rgb | Scheme::Gray) {
                return Err(ks(format!(
                    "only rgb/gray binarization fuses into the gather, got {:?}",
                    scheme
                )));
            }
            if t.kind != ValKind::F32 || t.c != IMG_C {
                return Err(ks(format!("expects 3-channel float pixels, got {}", t.describe())));
            }
            if *nw != packed_width(*d, 32) {
                return Err(pad(format!(
                    "{nw} weight words per row cannot hold exactly d={d} packed bits \
                     (want {}) — tail-pad masking would be unsound",
                    packed_width(*d, 32)
                )));
            }
            if *c_out > 32 {
                return Err(pad(format!(
                    "the fused epilogue packs into one word per pixel; {c_out} channels > 32"
                )));
            }
            conv_params(*k, *c_out)?;
            if *d != k * k * scheme.input_channels() {
                return Err(ks(format!(
                    "patch depth d={d} != k*k*{} binarized channels",
                    scheme.input_channels()
                )));
            }
            want_out(ValTy { kind: ValKind::Words, h: t.h, w: t.w, c: *c_out })?;
        }
        StepKind::FcBinThreshold { kw, c_out, d, .. } => {
            if t.kind != ValKind::Words {
                return Err(ks(format!("expects channel-packed words, got {}", t.describe())));
            }
            if t.c > 32 {
                return Err(pad(format!(
                    "channel-packed words carry at most 32 live channels, got {}",
                    t.c
                )));
            }
            if *c_out == 0 {
                return Err(ks("output width must be >= 1".to_string()));
            }
            if *kw != t.h * t.w {
                return Err(ks(format!("row width kw={kw} != h*w = {}", t.h * t.w)));
            }
            if *d != kw * t.c {
                return Err(ks(format!("real bit depth d={d} != kw*c = {}", kw * t.c)));
            }
            want_out(ValTy { kind: ValKind::F32, h: 1, w: 1, c: *c_out })?;
        }
        StepKind::Add => {
            if step.input2.is_none() {
                return Err(ks("add has no second operand edge".to_string()));
            }
            if t.kind == ValKind::Words {
                return Err(ks(format!("cannot add packed words, got {}", t.describe())));
            }
            want_out(t)?;
        }
        StepKind::Concat => {
            if step.input2.is_none() {
                return Err(ks("concat has no second operand edge".to_string()));
            }
            if t.kind == ValKind::Words {
                return Err(ks(format!("cannot concat packed words, got {}", t.describe())));
            }
            if o.kind != t.kind || (o.h, o.w) != (t.h, t.w) || o.c <= t.c {
                return Err(ks(format!(
                    "output {} must extend input {} along channels only",
                    o.describe(),
                    t.describe()
                )));
            }
        }
        StepKind::SplitPart { lo } => {
            if t.kind == ValKind::Words {
                return Err(ks(format!("cannot slice packed words, got {}", t.describe())));
            }
            if o.kind != t.kind || (o.h, o.w) != (t.h, t.w) || o.c == 0 || lo + o.c > t.c {
                return Err(ks(format!(
                    "part [{lo}, {}) is not a channel slice of {}",
                    lo + o.c,
                    t.describe()
                )));
            }
        }
        StepKind::Scale { .. } => {
            if t.kind != ValKind::F32 && t.kind != ValKind::Counts {
                return Err(ks(format!(
                    "expects float activations or conv counts, got {}",
                    t.describe()
                )));
            }
            want_out(ValTy { kind: ValKind::F32, h: t.h, w: t.w, c: t.c })?;
        }
    }
    Ok(())
}

/// Pass 1, per step: every slot's storage class matches the value
/// mapped to it, and scratch presence matches the effect signature.
fn check_step_slots(j: usize, step: &Step) -> Result<(), VerifyError> {
    if step.output.class != step.out_ty.class() {
        return Err(VerifyError::SlotDtype {
            step: j,
            slot: step.output,
            want: format!("the {} output value", step.out_ty.describe()),
        });
    }
    if let Src::Buf(b) = step.input {
        if b.class != step.in_ty.class() {
            return Err(VerifyError::SlotDtype {
                step: j,
                slot: b,
                want: format!("the {} input value", step.in_ty.describe()),
            });
        }
    }
    if let (Some(Src::Buf(b)), Some(t2)) = (step.input2, step.input2_ty()) {
        if b.class != t2.class() {
            return Err(VerifyError::SlotDtype {
                step: j,
                slot: b,
                want: format!("the {} second operand", t2.describe()),
            });
        }
    }
    let eff = step_effect(&step.kind);
    for (slot, class, what) in [
        (step.scratch, scratch_class(&step.kind), "scratch"),
        (step.scratch2, scratch2_class(&step.kind), "counts scratch"),
    ] {
        match (slot, class) {
            (None, None) => {}
            (Some(s), Some(c)) => {
                if s.class != c {
                    return Err(VerifyError::SlotDtype {
                        step: j,
                        slot: s,
                        want: format!("the step's {} {what}", class_name(c)),
                    });
                }
            }
            (Some(_), None) => {
                return Err(VerifyError::KindShape {
                    step: j,
                    op: kind_name(&step.kind).to_string(),
                    why: format!(
                        "binds a {what} slot but its effect signature clobbers none"
                    ),
                })
            }
            (None, Some(_)) => {
                return Err(VerifyError::KindShape {
                    step: j,
                    op: kind_name(&step.kind).to_string(),
                    why: format!("effect signature clobbers a {what} but no slot is bound"),
                })
            }
        }
    }
    debug_assert_eq!(eff.clobbers_scratch, scratch_class(&step.kind).is_some());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::graph::{test_specs, Activation, LayerOp, NetworkSpec};
    use crate::bnn::network::NUM_CLASSES;

    fn all_specs() -> Vec<NetworkSpec> {
        vec![
            NetworkSpec::legacy_bcnn(Scheme::Rgb),
            NetworkSpec::legacy_bcnn(Scheme::Gray),
            NetworkSpec::legacy_bcnn(Scheme::Lbp),
            NetworkSpec::legacy_bcnn(Scheme::None),
            NetworkSpec::legacy_float(),
        ]
    }

    fn three_conv_spec() -> NetworkSpec {
        NetworkSpec {
            ops: vec![
                LayerOp::Binarize { scheme: Scheme::Gray },
                LayerOp::ConvBin { k: 5, c_out: 32 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::ConvBin { k: 3, c_out: 32 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::ConvBin { k: 3, c_out: 32 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::FcBin { c_out: 64 },
                LayerOp::Threshold,
                LayerOp::FcFloat { c_out: NUM_CLASSES, bias: true, act: Activation::None },
            ],
        }
    }

    #[test]
    fn every_legacy_plan_verifies_clean() {
        for spec in all_specs() {
            let plan = spec.plan().unwrap();
            let report = verify_plan(&plan).unwrap_or_else(|e| panic!("clean plan refused: {e}"));
            assert_eq!(report.steps, plan.steps.len());
            assert_eq!(report.slots, plan.nbufs);
            assert_eq!(report.weights, plan.weights.len());
            // every step contributes at least its output edge, and the
            // interval count never exceeds outputs + one scratch each
            assert!(report.intervals >= plan.steps.len());
            assert!(report.intervals <= 2 * plan.steps.len());
            assert!(report.peak_bytes[0] > 0, "every plan holds float logits");
        }
    }

    #[test]
    fn the_three_conv_arch_plan_verifies_clean() {
        let plan = three_conv_spec().plan().unwrap();
        let report = verify_plan(&plan).unwrap();
        assert_eq!(report.slots, [2, 2, 1]);
        assert_eq!(report.weights, plan.weights.len());
    }

    #[test]
    fn the_report_prices_the_legacy_rgb_arena_exactly() {
        // hand-computed envelope for the legacy rgb plan: each slot
        // costs its largest resident edge (per image, 4-byte elements)
        let plan = NetworkSpec::legacy_bcnn(Scheme::Rgb).plan().unwrap();
        let report = verify_plan(&plan).unwrap();
        // f32: slot 0 peaks at the binarized image (96*96*3), slot 1 at
        // the 100-wide fc tail; u32: slot 0 at conv1's packed patch
        // gather (96*96*3 words), slot 1 at the pooled words (48*48);
        // i32: slot 0 at conv1's counts (96*96*32)
        assert_eq!(report.peak_elems(), [96 * 96 * 3 + 100, 96 * 96 * 3 + 48 * 48, 96 * 96 * 32]);
    }

    #[test]
    fn report_json_carries_the_envelope_fields() {
        let plan = NetworkSpec::legacy_float().plan().unwrap();
        let j = verify_plan(&plan).unwrap().to_json();
        for key in ["steps", "weights", "slots", "intervals", "peak_bytes"] {
            assert!(j.get(key).is_ok(), "missing {key}");
        }
        assert_eq!(j.get("steps").unwrap().as_usize().unwrap(), plan.steps.len());
    }

    #[test]
    fn every_branch_fixture_verifies_clean() {
        // the DAG fixtures: skip-add residuals and a split/scale/concat
        // diamond — the interval pass must prove the multi-reader edges
        // held live to their last reader, not refuse them
        for (name, spec) in test_specs::all() {
            let plan = spec.plan().unwrap();
            let report =
                verify_plan(&plan).unwrap_or_else(|e| panic!("{name}: clean DAG refused: {e}"));
            assert_eq!(report.steps, plan.steps.len(), "{name}");
            assert_eq!(report.slots, plan.nbufs, "{name}");
        }
    }

    #[test]
    fn a_clobbered_skip_edge_reports_the_overlapping_intervals() {
        // the branch-shaped liveness lie: the skip edge's interval now
        // extends to its second reader, so the clobbering write overlaps
        use crate::bnn::graph::plan::Corruption;
        let plan = test_specs::residual_float()
            .plan()
            .unwrap()
            .corrupt_for_test(Corruption::SkipEdgeClobberedBeforeSecondReader);
        match verify_plan(&plan).unwrap_err() {
            VerifyError::SlotAliased { a, b, .. } => {
                assert!(a.live.1 >= b.live.0 && b.live.1 >= a.live.0, "intervals overlap");
            }
            other => panic!("wrong variant: {other}"),
        }
    }

    #[test]
    fn a_six_class_head_verifies_with_its_declared_width() {
        // the NUM_CLASSES relaxation: classes come from the plan's final
        // edge; a lying declaration is still BadLogits
        let mut plan = test_specs::split_concat().plan().unwrap();
        assert_eq!(plan.classes, 6);
        assert!(verify_plan(&plan).is_ok());
        plan.classes = NUM_CLASSES;
        assert!(
            matches!(verify_plan(&plan).unwrap_err(), VerifyError::BadLogits { .. }),
            "declared classes must match the final edge"
        );
    }

    #[test]
    fn verify_errors_name_the_site() {
        // structured errors: the aliasing report names the slot and both
        // conflicting intervals (the loader's refusal message relies on
        // this being diagnosable without a debugger)
        use crate::bnn::graph::plan::Corruption;
        let plan = NetworkSpec::legacy_bcnn(Scheme::Rgb)
            .plan()
            .unwrap()
            .corrupt_for_test(Corruption::SlotMerge);
        let err = verify_plan(&plan).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("aliased") && msg.contains("live ["), "{msg}");
        match err {
            VerifyError::SlotAliased { a, b, .. } => {
                assert!(a.live.1 >= b.live.0 && b.live.1 >= a.live.0, "intervals overlap");
            }
            other => panic!("wrong variant: {other}"),
        }
    }
}
