//! The plan compiler: [`NetworkSpec`] → [`Plan`].
//!
//! Three passes over the op chain, all at load time (never on the
//! request path):
//!
//! 1. **Shape inference + validation.**  Every edge between two ops
//!    carries a typed value — float activations, channel-packed words,
//!    or integer popcount counts — with a spatial extent.  Each op
//!    declares what it accepts and what it produces; a mismatch (OR-pool
//!    on floats, threshold on > 32 channels, odd extent into a 2×2
//!    pool, a graph that doesn't end in `NUM_CLASSES` float logits) is a
//!    structured [`GraphError::Validate`] naming the step.
//! 2. **Weight-name resolution.**  Tensor names are positional —
//!    conv `i` → `w{i}_packed` / `w{i}`+`b{i}`, threshold `t` →
//!    `theta{t}`+`flip{t}`, fc `f` → `wfc{f}_packed` / `wfc{f}`+`bfc{f}`
//!    — which reproduces the legacy container names exactly on the
//!    synthesized legacy specs, so every existing artifact binds
//!    unchanged.  The resolved list (with dtypes and shapes) is exposed
//!    as [`Plan::weights`] for generators and docs.
//! 3. **Liveness analysis + buffer assignment.**  In a linear chain
//!    each op's output dies as soon as the next op has consumed it, and
//!    an op's internal patch-gather scratch dies within the step.  The
//!    compiler walks the chain with a free-list per storage class
//!    (f32 / u32 / i32), allocating a slot for each output and scratch
//!    and releasing slots the moment they die — interval coloring on
//!    the edge live-ranges.  The result is the minimal planned arena
//!    ([`crate::bnn::scratch::PlanScratch`] slots): the legacy 2-conv
//!    BCNN plans 2 f32 + 2 u32 + 1 i32 buffers (plus the LBP gray
//!    scratch when used) where the hand-named `ForwardScratch` carried
//!    11 fixed roles, and a deeper graph gets exactly what its own
//!    liveness demands, not another hand-audited struct.

use crate::bnn::network::{IMG_C, IMG_H, IMG_W, NUM_CLASSES};
use crate::bnn::packing::packed_width;
use crate::input::binarize::Scheme;

use super::{Activation, GraphError, LayerOp, NetworkSpec};

/// Storage class of a planned buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BufClass {
    F32 = 0,
    U32 = 1,
    I32 = 2,
}

/// One slot in the planned arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BufId {
    pub class: BufClass,
    pub idx: usize,
}

/// Where a step reads its input from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Src {
    /// The caller's image payload (only ever float pixels).
    External,
    Buf(BufId),
}

/// A value type on one edge of the graph.  `h == w == 1` encodes flat
/// feature vectors (the FC tail).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ValTy {
    pub kind: ValKind,
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ValKind {
    /// Float activations / images / ±1 binarized pixels.
    F32,
    /// Channel-packed words, one `u32` per pixel (`c` ≤ 32 live bits).
    Words,
    /// Integer XNOR-popcount counts.
    Counts,
}

impl ValTy {
    fn f32(h: usize, w: usize, c: usize) -> Self {
        Self { kind: ValKind::F32, h, w, c }
    }
    fn words(h: usize, w: usize, c: usize) -> Self {
        Self { kind: ValKind::Words, h, w, c }
    }
    fn counts(h: usize, w: usize, c: usize) -> Self {
        Self { kind: ValKind::Counts, h, w, c }
    }
    /// Storage class of a value of this type.
    pub(crate) fn class(&self) -> BufClass {
        match self.kind {
            ValKind::F32 => BufClass::F32,
            ValKind::Words => BufClass::U32,
            ValKind::Counts => BufClass::I32,
        }
    }
    pub fn describe(&self) -> String {
        let k = match self.kind {
            ValKind::F32 => "f32",
            ValKind::Words => "words",
            ValKind::Counts => "counts",
        };
        format!("{k}({},{},{})", self.h, self.w, self.c)
    }
}

/// Dtype of a declared weight tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightDType {
    F32,
    U32,
}

/// One weight tensor the plan will bind from the container.
#[derive(Debug, Clone, PartialEq)]
pub struct WeightReq {
    pub name: String,
    pub dtype: WeightDType,
    pub shape: Vec<usize>,
}

impl WeightReq {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A lowered, placement-resolved step.  `kind` carries the resolved
/// kernel parameters and weight names; weights themselves bind in
/// [`super::exec::CompiledNetwork::from_tensor_file`].
#[derive(Debug, Clone)]
pub(crate) struct Step {
    pub kind: StepKind,
    pub input: Src,
    pub output: BufId,
    /// Per-step internal scratch (patch gathers, the LBP gray plane);
    /// live only within the step, so liveness reuses it freely.
    pub scratch: Option<BufId>,
    /// Second internal scratch, used only by fused conv+threshold steps
    /// that have not (yet) elided the i32 counts buffer: `scratch` holds
    /// the patch gather, `scratch2` the popcount counts the epilogue
    /// thresholds from.  `compile` never emits it — only
    /// [`super::rewrite`] does.
    pub scratch2: Option<BufId>,
    pub in_ty: ValTy,
    pub out_ty: ValTy,
    /// Timing label(s): convs lap twice (`im2colN`, `gemmN`), everything
    /// else once.
    pub label_a: String,
    pub label_b: Option<String>,
}

#[derive(Debug, Clone)]
pub(crate) enum StepKind {
    Binarize { scheme: Scheme },
    /// ±1 floats → counts: fused im2col+pack (Algorithm 1) + XNOR-GEMM.
    ConvBinPacked { k: usize, c_out: usize, nw: usize, d: usize, w: String },
    /// Packed words → counts: word gather + XNOR-GEMM over (c_out, k*k).
    ConvBinWords { k: usize, c_out: usize, d: usize, w: String },
    ConvFloat { k: usize, c_out: usize, relu: bool, w: String, b: Option<String> },
    MaxPool,
    OrPool,
    /// Spatial counts/activations → channel-packed words.
    ThresholdPack { f32_in: bool, theta: String, flip: String },
    /// Flat FC counts → ±1 floats for the float tail.
    ThresholdPm1 { theta: String, flip: String },
    FcBin { kw: usize, c_out: usize, d: usize, w: String },
    FcFloat { d: usize, c_out: usize, act: Activation, w: String, b: Option<String> },

    // --- fused kinds: emitted only by `super::rewrite`, never by -------
    // `compile`.  Every fused kind carries `cmp_bias`, an offset the
    // epilogue adds to each popcount before comparing against theta.  A
    // sound rewrite always sets it to 0; it exists so an off-by-one
    // epilogue is *expressible* in plan structure — `verify_plan` cannot
    // know its semantics, but `super::equiv::check_equiv` refuses any
    // nonzero bias, which is exactly the class of bug the equivalence
    // gauntlet catches and the slot/shape verifier cannot.
    /// ±1 floats → words: packed conv with the following threshold
    /// folded into the popcount epilogue.  `elide: false` still writes
    /// the raw counts to `scratch2` (the staged rewrite before counts
    /// elision); `elide: true` keeps each count in a register.
    ConvBinPackedThreshold {
        k: usize,
        c_out: usize,
        nw: usize,
        d: usize,
        w: String,
        theta: String,
        flip: String,
        cmp_bias: i32,
        elide: bool,
    },
    /// Packed words → words: word-gather conv with the fused threshold
    /// epilogue.  Same `scratch2`/`elide` contract as the packed form.
    ConvBinWordsThreshold {
        k: usize,
        c_out: usize,
        d: usize,
        w: String,
        theta: String,
        flip: String,
        cmp_bias: i32,
        elide: bool,
    },
    /// External f32 image → counts: input binarization fused into the
    /// im2col pack (each gathered pixel's sign bit is computed on the
    /// fly — the ±1 float image is never materialized).  LBP is never
    /// fused (it needs the whole grayscale plane before any patch).
    BinarizeConvBin { scheme: Scheme, k: usize, c_out: usize, nw: usize, d: usize, w: String },
    /// External f32 image → words: both fusions at once
    /// (binarize-while-gather + threshold epilogue).
    BinarizeConvBinThreshold {
        scheme: Scheme,
        k: usize,
        c_out: usize,
        nw: usize,
        d: usize,
        w: String,
        theta: String,
        flip: String,
        cmp_bias: i32,
        elide: bool,
    },
    /// Packed words → ±1 floats: FC with the threshold folded in.  Each
    /// output's count lives in a register between the popcount and the
    /// compare, so the counts buffer is gone by construction (no `elide`
    /// flag needed).
    FcBinThreshold {
        kw: usize,
        c_out: usize,
        d: usize,
        w: String,
        theta: String,
        flip: String,
        cmp_bias: i32,
    },
}

/// The compiled plan: lowered steps, arena layout, declared weights.
#[derive(Debug, Clone)]
pub struct Plan {
    pub(crate) steps: Vec<Step>,
    /// Planned arena slots per storage class, `[f32, u32, i32]`.
    pub nbufs: [usize; 3],
    /// Every weight tensor the plan binds, in graph order.
    pub weights: Vec<WeightReq>,
    /// Output logits per image (validated == `NUM_CLASSES`).
    pub classes: usize,
}

impl Plan {
    /// Total planned arena slots across all storage classes.
    pub fn num_buffers(&self) -> usize {
        self.nbufs.iter().sum()
    }

    /// Human-readable step labels, in execution order (docs + tests).
    pub fn step_names(&self) -> Vec<String> {
        let mut names = Vec::new();
        for s in &self.steps {
            names.push(s.label_a.clone());
            if let Some(b) = &s.label_b {
                names.push(b.clone());
            }
        }
        names
    }
}

/// A corruption class the mutation-testing suite injects via
/// [`Plan::corrupt_for_test`].  `compile` never emits an unsound plan,
/// so the verifier's rejection paths can only be exercised by breaking
/// a sound plan on purpose — each class models one way a hand-written
/// or future-rewritten plan could go wrong.
#[doc(hidden)]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Corruption {
    /// Collapse two consecutive same-class outputs into one slot
    /// (models a broken coalescing rewrite) → aliased live intervals.
    SlotMerge,
    /// Point a step's scratch at its own input slot (models a liveness
    /// pass under-counting an interval) → the clobber overlaps the
    /// still-live input edge.
    IntervalTruncation,
    /// Halve a conv's declared output channels (models an undersized
    /// slot extent) → kind/edge shape disagreement.
    ExtentShrink,
    /// Move a words output into the f32 pool (models a storage-class
    /// mixup) → slot dtype violation.
    DtypeSwap,
    /// Delete a pool step outright (models a dropped writer) → a later
    /// step reads an edge nothing wrote.
    WriterDeletion,
    /// Widen a packed conv's weight row past `ceil(d/32)` (models
    /// unmasked tail pad bits — the popcount soundness precondition).
    PadBitPollution,
    /// Declare one weight tensor twice → it would bind two roles.
    DuplicateWeightBind,
    /// Lie about the logit width → breaks the serving contract.
    LogitShapeLie,
    /// Rewrite-shaped: bump a fused threshold epilogue's `cmp_bias`
    /// (models an off-by-one in the folded compare — bit-plausible,
    /// invisible to the slot/shape verifier, semantically wrong).
    /// Caught only by `check_equiv`.
    EpilogueThresholdOffByOne,
    /// Rewrite-shaped: widen a fused packed conv's row past
    /// `ceil(d/32)` with a consistently-widened weight declaration
    /// (models a fusion that changes the pad-bit class).
    EpilogueThresholdPadBitClassChange,
    /// Rewrite-shaped: point a later step's input at a fused step's
    /// internal counts buffer (models eliding / privatizing the counts
    /// edge while a second reader still exists — the single-reader
    /// precondition of the elision axiom).
    CountsElisionSecondReader,
    /// Rewrite-shaped but *sound*: rename arena slots within a storage
    /// class and reorder the weight declarations.  Dataflow, value
    /// terms, and extents are untouched, so both `verify_plan` and
    /// `check_equiv` must still ACCEPT the plan — the mutation suite's
    /// false-positive guard.
    ReorderedCommutingSteps,
}

impl Corruption {
    pub const ALL: [Corruption; 12] = [
        Corruption::SlotMerge,
        Corruption::IntervalTruncation,
        Corruption::ExtentShrink,
        Corruption::DtypeSwap,
        Corruption::WriterDeletion,
        Corruption::PadBitPollution,
        Corruption::DuplicateWeightBind,
        Corruption::LogitShapeLie,
        Corruption::EpilogueThresholdOffByOne,
        Corruption::EpilogueThresholdPadBitClassChange,
        Corruption::CountsElisionSecondReader,
        Corruption::ReorderedCommutingSteps,
    ];

    /// The classes `verify_plan` alone must reject on an *unrewritten*
    /// plan (the PR 6 suite).  The rewrite-shaped classes need fused
    /// steps to find a site and are judged by `check_equiv` instead —
    /// see the mutation tests in [`super::equiv`].
    pub const VERIFY_REJECTED: [Corruption; 8] = [
        Corruption::SlotMerge,
        Corruption::IntervalTruncation,
        Corruption::ExtentShrink,
        Corruption::DtypeSwap,
        Corruption::WriterDeletion,
        Corruption::PadBitPollution,
        Corruption::DuplicateWeightBind,
        Corruption::LogitShapeLie,
    ];

    /// The rewrite-shaped classes: applied to a *rewritten* plan and
    /// judged by `check_equiv` against the original.
    pub const REWRITE_SHAPED: [Corruption; 4] = [
        Corruption::EpilogueThresholdOffByOne,
        Corruption::EpilogueThresholdPadBitClassChange,
        Corruption::CountsElisionSecondReader,
        Corruption::ReorderedCommutingSteps,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Corruption::SlotMerge => "slot-merge",
            Corruption::IntervalTruncation => "interval-truncation",
            Corruption::ExtentShrink => "extent-shrink",
            Corruption::DtypeSwap => "dtype-swap",
            Corruption::WriterDeletion => "writer-deletion",
            Corruption::PadBitPollution => "pad-bit-pollution",
            Corruption::DuplicateWeightBind => "duplicate-weight-bind",
            Corruption::LogitShapeLie => "logit-shape-lie",
            Corruption::EpilogueThresholdOffByOne => "epilogue-threshold-off-by-one",
            Corruption::EpilogueThresholdPadBitClassChange => "pad-bit-class-change",
            Corruption::CountsElisionSecondReader => "counts-elision-second-reader",
            Corruption::ReorderedCommutingSteps => "reordered-commuting-steps",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|c| c.name() == s)
    }
}

impl Plan {
    /// Break this plan on purpose (mutation testing + the loader's
    /// fault-injection hook).  Each class finds its first applicable
    /// site and panics if the plan has none — a corruption that
    /// silently no-ops would turn the mutation suite into a lie.
    #[doc(hidden)]
    pub fn corrupt_for_test(mut self, c: Corruption) -> Plan {
        match c {
            Corruption::SlotMerge => {
                let i = (0..self.steps.len() - 1)
                    .find(|&i| self.steps[i].output.class == self.steps[i + 1].output.class)
                    .expect("plan has two consecutive same-class outputs");
                let dead = self.steps[i + 1].output;
                let merged = self.steps[i].output;
                self.steps[i + 1].output = merged;
                for s in &mut self.steps[i + 2..] {
                    if s.input == Src::Buf(dead) {
                        s.input = Src::Buf(merged);
                    }
                }
            }
            Corruption::IntervalTruncation => {
                let step = self
                    .steps
                    .iter_mut()
                    .find(|s| {
                        matches!((s.scratch, s.input),
                            (Some(sc), Src::Buf(b)) if sc.class == b.class)
                    })
                    .expect("plan has a step whose scratch shares a class with its input");
                if let Src::Buf(b) = step.input {
                    step.scratch = Some(b);
                }
            }
            Corruption::ExtentShrink => {
                let step = self
                    .steps
                    .iter_mut()
                    .find(|s| {
                        matches!(
                            s.kind,
                            StepKind::ConvBinPacked { .. }
                                | StepKind::ConvBinWords { .. }
                                | StepKind::ConvFloat { .. }
                        ) && s.out_ty.c > 1
                    })
                    .expect("plan has a conv with more than one output channel");
                step.out_ty.c /= 2;
            }
            Corruption::DtypeSwap => {
                let i = (0..self.steps.len())
                    .find(|&i| self.steps[i].output.class == BufClass::U32)
                    .expect("plan has a u32-class output");
                let old = self.steps[i].output;
                let swapped = BufId { class: BufClass::F32, idx: old.idx };
                self.steps[i].output = swapped;
                for s in &mut self.steps[i + 1..] {
                    if s.input == Src::Buf(old) {
                        s.input = Src::Buf(swapped);
                    }
                }
            }
            Corruption::WriterDeletion => {
                let i = self
                    .steps
                    .iter()
                    .position(|s| matches!(s.kind, StepKind::MaxPool | StepKind::OrPool))
                    .expect("plan has a pool step to delete");
                self.steps.remove(i);
            }
            Corruption::PadBitPollution => {
                let (wname, bad_shape) = {
                    let step = self
                        .steps
                        .iter_mut()
                        .find(|s| matches!(s.kind, StepKind::ConvBinPacked { .. }))
                        .expect("plan has a packed conv");
                    match &mut step.kind {
                        StepKind::ConvBinPacked { c_out, nw, w, .. } => {
                            *nw += 1;
                            (w.clone(), vec![*c_out, *nw])
                        }
                        _ => unreachable!(),
                    }
                };
                // keep the declared weight consistent with the widened
                // row so only the pad-bit rule is violated
                let req = self
                    .weights
                    .iter_mut()
                    .find(|r| r.name == wname)
                    .expect("packed conv declares its weight");
                req.shape = bad_shape;
            }
            Corruption::DuplicateWeightBind => {
                let dup = self.weights.first().expect("plan declares weights").clone();
                self.weights.push(dup);
            }
            Corruption::LogitShapeLie => {
                self.classes += 3;
            }
            Corruption::EpilogueThresholdOffByOne => {
                let step = self
                    .steps
                    .iter_mut()
                    .find(|s| {
                        matches!(
                            s.kind,
                            StepKind::ConvBinPackedThreshold { .. }
                                | StepKind::ConvBinWordsThreshold { .. }
                                | StepKind::BinarizeConvBinThreshold { .. }
                                | StepKind::FcBinThreshold { .. }
                        )
                    })
                    .expect("plan has a fused threshold epilogue");
                match &mut step.kind {
                    StepKind::ConvBinPackedThreshold { cmp_bias, .. }
                    | StepKind::ConvBinWordsThreshold { cmp_bias, .. }
                    | StepKind::BinarizeConvBinThreshold { cmp_bias, .. }
                    | StepKind::FcBinThreshold { cmp_bias, .. } => *cmp_bias += 1,
                    _ => unreachable!(),
                }
            }
            Corruption::EpilogueThresholdPadBitClassChange => {
                let (wname, bad_shape) = {
                    let step = self
                        .steps
                        .iter_mut()
                        .find(|s| {
                            matches!(
                                s.kind,
                                StepKind::ConvBinPackedThreshold { .. }
                                    | StepKind::BinarizeConvBin { .. }
                                    | StepKind::BinarizeConvBinThreshold { .. }
                            )
                        })
                        .expect("plan has a fused packed conv");
                    match &mut step.kind {
                        StepKind::ConvBinPackedThreshold { c_out, nw, w, .. }
                        | StepKind::BinarizeConvBin { c_out, nw, w, .. }
                        | StepKind::BinarizeConvBinThreshold { c_out, nw, w, .. } => {
                            *nw += 1;
                            (w.clone(), vec![*c_out, *nw])
                        }
                        _ => unreachable!(),
                    }
                };
                let req = self
                    .weights
                    .iter_mut()
                    .find(|r| r.name == wname)
                    .expect("fused packed conv declares its weight");
                req.shape = bad_shape;
            }
            Corruption::CountsElisionSecondReader => {
                let (i, counts) = self
                    .steps
                    .iter()
                    .enumerate()
                    .find_map(|(i, s)| s.scratch2.map(|sc| (i, sc)))
                    .expect("plan has a non-elided fused conv (scratch2 counts)");
                let reader = self
                    .steps
                    .get_mut(i + 1)
                    .expect("fused conv has a successor step");
                reader.input = Src::Buf(counts);
            }
            Corruption::ReorderedCommutingSteps => {
                assert!(self.weights.len() >= 2, "plan declares at least two weights");
                self.weights.reverse();
                if let Some(class) = [BufClass::F32, BufClass::U32, BufClass::I32]
                    .into_iter()
                    .find(|&c| self.nbufs[c as usize] >= 2)
                {
                    let rename = |b: &mut BufId| {
                        if b.class == class && b.idx < 2 {
                            b.idx ^= 1;
                        }
                    };
                    for s in &mut self.steps {
                        if let Src::Buf(b) = &mut s.input {
                            rename(b);
                        }
                        rename(&mut s.output);
                        if let Some(b) = &mut s.scratch {
                            rename(b);
                        }
                        if let Some(b) = &mut s.scratch2 {
                            rename(b);
                        }
                    }
                }
            }
        }
        self
    }
}

/// Per-class free-list allocator for the liveness walk.  Shared with
/// [`super::rewrite`], whose recoloring pass re-runs the same walk over
/// a fused step list.
pub(crate) struct Slots {
    free: [Vec<usize>; 3],
    /// High-water slot count per class — the plan's `nbufs`.
    pub(crate) next: [usize; 3],
}

impl Slots {
    pub(crate) fn new() -> Self {
        Self { free: [Vec::new(), Vec::new(), Vec::new()], next: [0; 3] }
    }

    pub(crate) fn alloc(&mut self, class: BufClass) -> BufId {
        let c = class as usize;
        let idx = self.free[c].pop().unwrap_or_else(|| {
            let idx = self.next[c];
            self.next[c] += 1;
            idx
        });
        BufId { class, idx }
    }

    pub(crate) fn release(&mut self, buf: BufId) {
        self.free[buf.class as usize].push(buf.idx);
    }
}

pub(crate) fn compile(spec: &NetworkSpec) -> Result<Plan, GraphError> {
    if spec.ops.is_empty() {
        return Err(GraphError::Spec("graph has no ops".to_string()));
    }
    let mut steps: Vec<Step> = Vec::with_capacity(spec.ops.len());
    let mut weights: Vec<WeightReq> = Vec::new();
    let mut slots = Slots::new();

    let mut cur = ValTy::f32(IMG_H, IMG_W, IMG_C);
    let mut cur_src = Src::External;
    // positional ordinals — these generate the legacy tensor names
    let (mut conv_ord, mut thr_ord, mut pool_ord, mut fc_ord) = (0usize, 0usize, 0usize, 0usize);

    fn require(name: &str, dtype: WeightDType, shape: Vec<usize>, ws: &mut Vec<WeightReq>) {
        ws.push(WeightReq { name: name.to_string(), dtype, shape });
    }

    for (i, op) in spec.ops.iter().enumerate() {
        let opname = op_name(op);
        let bad = |why: String| GraphError::Validate { step: i, op: opname.to_string(), why };
        // (kind, out_ty, scratch class, labels)
        let (kind, out_ty, scratch_class, label_a, label_b) = match op {
            LayerOp::Binarize { scheme } => {
                if cur.kind != ValKind::F32 || cur.c != 3 {
                    return Err(bad(format!(
                        "binarize expects 3-channel float pixels, got {}",
                        cur.describe()
                    )));
                }
                match scheme {
                    Scheme::None => {
                        return Err(bad("scheme \"none\" has no binarize op".to_string()))
                    }
                    Scheme::Rgb => require("input_t", WeightDType::F32, vec![3], &mut weights),
                    Scheme::Gray => require("input_t", WeightDType::F32, vec![1], &mut weights),
                    Scheme::Lbp => {}
                }
                (
                    StepKind::Binarize { scheme: *scheme },
                    ValTy::f32(cur.h, cur.w, scheme.input_channels()),
                    // LBP reads a per-image grayscale plane
                    (*scheme == Scheme::Lbp).then_some(BufClass::F32),
                    "input_binarize".to_string(),
                    None,
                )
            }
            LayerOp::ConvBin { k, c_out } => {
                check_conv(*k, *c_out, &bad)?;
                conv_ord += 1;
                let wname = format!("w{conv_ord}_packed");
                match cur.kind {
                    ValKind::F32 => {
                        // first packed layer: pixels are ±1 floats
                        let d = k * k * cur.c;
                        let nw = packed_width(d, 32);
                        require(&wname, WeightDType::U32, vec![*c_out, nw], &mut weights);
                        (
                            StepKind::ConvBinPacked { k: *k, c_out: *c_out, nw, d, w: wname },
                            ValTy::counts(cur.h, cur.w, *c_out),
                            Some(BufClass::U32),
                            format!("im2col{conv_ord}"),
                            Some(format!("gemm{conv_ord}")),
                        )
                    }
                    ValKind::Words => {
                        // deeper packed layer: activations already packed
                        let d = k * k * cur.c;
                        require(&wname, WeightDType::U32, vec![*c_out, k * k], &mut weights);
                        (
                            StepKind::ConvBinWords { k: *k, c_out: *c_out, d, w: wname },
                            ValTy::counts(cur.h, cur.w, *c_out),
                            Some(BufClass::U32),
                            format!("im2col{conv_ord}"),
                            Some(format!("gemm{conv_ord}")),
                        )
                    }
                    ValKind::Counts => {
                        return Err(bad(format!(
                            "conv_bin cannot consume raw counts ({}); threshold first",
                            cur.describe()
                        )))
                    }
                }
            }
            LayerOp::ConvFloat { k, c_out, bias, relu, w } => {
                check_conv(*k, *c_out, &bad)?;
                if cur.kind != ValKind::F32 {
                    return Err(bad(format!(
                        "conv_float expects float input, got {}",
                        cur.describe()
                    )));
                }
                conv_ord += 1;
                let wname = w.clone().unwrap_or_else(|| format!("w{conv_ord}"));
                let bname = bias.then(|| format!("b{conv_ord}"));
                require(&wname, WeightDType::F32, vec![*c_out, k * k * cur.c], &mut weights);
                if let Some(b) = &bname {
                    require(b, WeightDType::F32, vec![*c_out], &mut weights);
                }
                (
                    StepKind::ConvFloat { k: *k, c_out: *c_out, relu: *relu, w: wname, b: bname },
                    ValTy::f32(cur.h, cur.w, *c_out),
                    Some(BufClass::F32),
                    format!("im2col{conv_ord}"),
                    Some(format!("gemm{conv_ord}")),
                )
            }
            LayerOp::MaxPool => {
                check_pool(&cur, ValKind::F32, "maxpool", &bad)?;
                pool_ord += 1;
                (
                    StepKind::MaxPool,
                    ValTy::f32(cur.h / 2, cur.w / 2, cur.c),
                    None,
                    format!("pool{pool_ord}"),
                    None,
                )
            }
            LayerOp::OrPool => {
                check_pool(&cur, ValKind::Words, "orpool", &bad)?;
                pool_ord += 1;
                (
                    StepKind::OrPool,
                    ValTy::words(cur.h / 2, cur.w / 2, cur.c),
                    None,
                    format!("pool{pool_ord}"),
                    None,
                )
            }
            LayerOp::Threshold => {
                thr_ord += 1;
                let theta = format!("theta{thr_ord}");
                let flip = format!("flip{thr_ord}");
                require(&theta, WeightDType::F32, vec![cur.c], &mut weights);
                require(&flip, WeightDType::U32, vec![cur.c], &mut weights);
                let spatial = cur.h * cur.w > 1;
                match (cur.kind, spatial) {
                    (ValKind::Counts, true) | (ValKind::F32, true) => {
                        if cur.c > 32 {
                            return Err(bad(format!(
                                "threshold packs into one word per pixel; {} channels > 32",
                                cur.c
                            )));
                        }
                        (
                            StepKind::ThresholdPack {
                                f32_in: cur.kind == ValKind::F32,
                                theta,
                                flip,
                            },
                            ValTy::words(cur.h, cur.w, cur.c),
                            None,
                            format!("threshold_pack{thr_ord}"),
                            None,
                        )
                    }
                    (ValKind::Counts, false) => (
                        StepKind::ThresholdPm1 { theta, flip },
                        ValTy::f32(1, 1, cur.c),
                        None,
                        format!("threshold{thr_ord}"),
                        None,
                    ),
                    _ => {
                        return Err(bad(format!(
                            "threshold expects conv/fc counts or conv activations, got {}",
                            cur.describe()
                        )))
                    }
                }
            }
            LayerOp::FcBin { c_out } => {
                if cur.kind != ValKind::Words {
                    return Err(bad(format!(
                        "fc_bin expects packed words, got {}",
                        cur.describe()
                    )));
                }
                if *c_out == 0 {
                    return Err(bad("output width must be >= 1".to_string()));
                }
                fc_ord += 1;
                let wname = format!("wfc{fc_ord}_packed");
                let kw = cur.h * cur.w;
                let d = kw * cur.c;
                require(&wname, WeightDType::U32, vec![*c_out, kw], &mut weights);
                (
                    StepKind::FcBin { kw, c_out: *c_out, d, w: wname },
                    ValTy::counts(1, 1, *c_out),
                    None,
                    format!("fc{fc_ord}"),
                    None,
                )
            }
            LayerOp::FcFloat { c_out, bias, act } => {
                if cur.kind != ValKind::F32 {
                    return Err(bad(format!(
                        "fc_float expects float features, got {}",
                        cur.describe()
                    )));
                }
                if *c_out == 0 {
                    return Err(bad("output width must be >= 1".to_string()));
                }
                fc_ord += 1;
                let wname = format!("wfc{fc_ord}");
                let bname = bias.then(|| format!("bfc{fc_ord}"));
                let d = cur.h * cur.w * cur.c;
                require(&wname, WeightDType::F32, vec![*c_out, d], &mut weights);
                if let Some(b) = &bname {
                    require(b, WeightDType::F32, vec![*c_out], &mut weights);
                }
                (
                    StepKind::FcFloat { d, c_out: *c_out, act: *act, w: wname, b: bname },
                    ValTy::f32(1, 1, *c_out),
                    None,
                    format!("fc{fc_ord}"),
                    None,
                )
            }
        };

        // --- liveness: place this step's buffers, retire dead ones ----
        let scratch = scratch_class.map(|c| slots.alloc(c));
        let output = slots.alloc(out_ty.class());
        // the input edge and the step scratch die here; the output is
        // live into the next step.  (Releasing AFTER the output alloc
        // guarantees input/scratch/output are pairwise distinct slots —
        // every kernel requires disjoint in/out.)
        if let Src::Buf(b) = cur_src {
            slots.release(b);
        }
        if let Some(s) = scratch {
            slots.release(s);
        }
        steps.push(Step {
            kind,
            input: cur_src,
            output,
            scratch,
            scratch2: None,
            in_ty: cur,
            out_ty,
            label_a,
            label_b,
        });
        cur = out_ty;
        cur_src = Src::Buf(output);
    }

    // the serving contract: the graph ends in one float logit row per
    // image, sized for the class set
    if cur.kind != ValKind::F32 || (cur.h, cur.w, cur.c) != (1, 1, NUM_CLASSES) {
        return Err(GraphError::Validate {
            step: spec.ops.len() - 1,
            op: op_name(spec.ops.last().unwrap()).to_string(),
            why: format!(
                "graph must end in f32(1,1,{NUM_CLASSES}) logits, got {}",
                cur.describe()
            ),
        });
    }

    // weight names must be unique — a positional name colliding with an
    // explicit override would silently bind one tensor twice
    for (a, req) in weights.iter().enumerate() {
        if weights[..a].iter().any(|r| r.name == req.name) {
            return Err(GraphError::Spec(format!(
                "weight name {:?} is declared twice (override collides with a positional name?)",
                req.name
            )));
        }
    }

    Ok(Plan { steps, nbufs: slots.next, weights, classes: NUM_CLASSES })
}

fn op_name(op: &LayerOp) -> &'static str {
    match op {
        LayerOp::Binarize { .. } => "binarize",
        LayerOp::ConvBin { .. } => "conv_bin",
        LayerOp::ConvFloat { .. } => "conv_float",
        LayerOp::MaxPool => "maxpool",
        LayerOp::OrPool => "orpool",
        LayerOp::Threshold => "threshold",
        LayerOp::FcBin { .. } => "fc_bin",
        LayerOp::FcFloat { .. } => "fc_float",
    }
}

fn check_conv(
    k: usize,
    c_out: usize,
    bad: &impl Fn(String) -> GraphError,
) -> Result<(), GraphError> {
    if k == 0 || k % 2 == 0 {
        return Err(bad(format!("kernel size {k} must be odd ('same' convolution)")));
    }
    if c_out == 0 {
        return Err(bad("output channels must be >= 1".to_string()));
    }
    Ok(())
}

fn check_pool(
    cur: &ValTy,
    want: ValKind,
    name: &str,
    bad: &impl Fn(String) -> GraphError,
) -> Result<(), GraphError> {
    if cur.kind != want {
        return Err(bad(format!("{name} expects {want:?} input, got {}", cur.describe())));
    }
    if cur.h < 2 || cur.w < 2 || cur.h % 2 != 0 || cur.w % 2 != 0 {
        return Err(bad(format!("2x2 pool needs even extents >= 2, got {}", cur.describe())));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legacy_bcnn_plan_names_match_the_legacy_container() {
        let plan = NetworkSpec::legacy_bcnn(Scheme::Rgb).plan().unwrap();
        let names: Vec<&str> = plan.weights.iter().map(|w| w.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "input_t",
                "w1_packed",
                "theta1",
                "flip1",
                "w2_packed",
                "theta2",
                "flip2",
                "wfc1_packed",
                "theta3",
                "flip3",
                "wfc2",
                "bfc2",
                "wfc3",
                "bfc3",
            ]
        );
        // the legacy shapes, byte for byte
        let by_name = |n: &str| plan.weights.iter().find(|w| w.name == n).unwrap();
        assert_eq!(by_name("w1_packed").shape, vec![32, packed_width(5 * 5 * 3, 32)]);
        assert_eq!(by_name("w2_packed").shape, vec![32, 25]);
        assert_eq!(by_name("wfc1_packed").shape, vec![100, 576]);
        assert_eq!(by_name("wfc2").shape, vec![100, 100]);
        assert_eq!(by_name("wfc3").shape, vec![NUM_CLASSES, 100]);
    }

    #[test]
    fn legacy_none_plan_uses_the_pm1_override() {
        let plan = NetworkSpec::legacy_bcnn(Scheme::None).plan().unwrap();
        assert_eq!(plan.weights[0].name, "w1_pm1");
        assert_eq!(plan.weights[0].shape, vec![32, 75]);
        assert!(plan.weights.iter().all(|w| w.name != "b1"), "pm1 conv has no bias");
    }

    #[test]
    fn legacy_float_plan_names_match_the_legacy_container() {
        let plan = NetworkSpec::legacy_float().plan().unwrap();
        let names: Vec<&str> = plan.weights.iter().map(|w| w.name.as_str()).collect();
        assert_eq!(
            names,
            vec!["w1", "b1", "w2", "b2", "wfc1", "bfc1", "wfc2", "bfc2", "wfc3", "bfc3"]
        );
    }

    #[test]
    fn liveness_plans_far_fewer_buffers_than_the_11_hand_named_roles() {
        // rgb: binarize(f32) + 2 packed convs + fc tail
        let plan = NetworkSpec::legacy_bcnn(Scheme::Rgb).plan().unwrap();
        assert_eq!(plan.nbufs, [2, 2, 1], "f32/u32/i32 slots");
        assert!(plan.num_buffers() <= 5);
        // lbp adds one f32 slot for the per-image gray plane
        let plan = NetworkSpec::legacy_bcnn(Scheme::Lbp).plan().unwrap();
        assert_eq!(plan.nbufs[0], 2, "gray scratch reuses a dead f32 slot or adds one");
        // float: everything in the f32 class
        let plan = NetworkSpec::legacy_float().plan().unwrap();
        assert_eq!(plan.nbufs, [3, 0, 0]);
    }

    #[test]
    fn step_in_scratch_out_slots_are_pairwise_distinct() {
        for spec in [
            NetworkSpec::legacy_bcnn(Scheme::Rgb),
            NetworkSpec::legacy_bcnn(Scheme::None),
            NetworkSpec::legacy_bcnn(Scheme::Lbp),
            NetworkSpec::legacy_float(),
        ] {
            let plan = spec.plan().unwrap();
            // every edge type-checks: step i+1 consumes exactly what
            // step i produced
            for pair in plan.steps.windows(2) {
                assert_eq!(pair[0].out_ty, pair[1].in_ty, "edge type mismatch");
                assert_eq!(Src::Buf(pair[0].output), pair[1].input, "edge slot mismatch");
            }
            for s in &plan.steps {
                if let Src::Buf(b) = s.input {
                    assert_ne!(b, s.output, "input aliases output");
                    if let Some(sc) = s.scratch {
                        assert_ne!(b, sc, "input aliases scratch");
                    }
                }
                if let Some(sc) = s.scratch {
                    assert_ne!(sc, s.output, "scratch aliases output");
                }
            }
        }
    }

    #[test]
    fn step_names_cover_the_legacy_timing_labels() {
        let names = NetworkSpec::legacy_bcnn(Scheme::Gray).plan().unwrap().step_names();
        for want in
            ["input_binarize", "im2col1", "gemm1", "threshold_pack1", "pool1", "gemm2", "fc1"]
        {
            assert!(names.iter().any(|n| n == want), "missing {want} in {names:?}");
        }
    }

    #[test]
    fn shape_violations_are_structured_errors() {
        use LayerOp::*;
        let cases: Vec<(&str, Vec<LayerOp>)> = vec![
            ("empty", vec![]),
            ("orpool-on-floats", vec![OrPool]),
            ("maxpool-on-words", vec![
                Binarize { scheme: Scheme::Rgb },
                ConvBin { k: 5, c_out: 32 },
                Threshold,
                MaxPool,
            ]),
            ("conv-on-counts", vec![
                Binarize { scheme: Scheme::Rgb },
                ConvBin { k: 5, c_out: 32 },
                ConvBin { k: 5, c_out: 32 },
            ]),
            ("threshold-over-32ch", vec![
                Binarize { scheme: Scheme::Rgb },
                ConvBin { k: 5, c_out: 64 },
                Threshold,
            ]),
            ("even-kernel", vec![
                Binarize { scheme: Scheme::Rgb },
                ConvBin { k: 4, c_out: 32 },
            ]),
            ("fcbin-on-floats", vec![FcBin { c_out: 10 }]),
            ("wrong-logit-width", vec![FcFloat {
                c_out: 7,
                bias: true,
                act: Activation::None,
            }]),
            ("ends-in-counts", vec![
                Binarize { scheme: Scheme::Rgb },
                ConvBin { k: 5, c_out: 32 },
            ]),
        ];
        for (tag, ops) in cases {
            let err = NetworkSpec { ops }.plan().unwrap_err();
            assert!(
                matches!(err, GraphError::Validate { .. } | GraphError::Spec(_)),
                "{tag}: {err}"
            );
        }
    }

    #[test]
    fn duplicate_weight_names_are_refused() {
        // an override colliding with conv2's positional name
        let spec = NetworkSpec {
            ops: vec![
                LayerOp::ConvFloat {
                    k: 5,
                    c_out: 32,
                    bias: false,
                    relu: false,
                    w: Some("w2".to_string()),
                },
                LayerOp::MaxPool,
                LayerOp::ConvFloat { k: 5, c_out: 32, bias: false, relu: false, w: None },
                LayerOp::MaxPool,
                LayerOp::FcFloat { c_out: NUM_CLASSES, bias: false, act: Activation::None },
            ],
        };
        let err = spec.plan().unwrap_err();
        assert!(err.to_string().contains("twice"), "{err}");
    }

    #[test]
    fn every_corruption_class_is_rejected_with_its_variant() {
        // the mutation suite: break a sound plan eight different ways
        // and prove the verifier catches each with the *intended*
        // structured error, not just any error
        use crate::bnn::graph::verify::{verify_plan, VerifyError};
        for c in Corruption::VERIFY_REJECTED {
            let plan = NetworkSpec::legacy_bcnn(Scheme::Rgb)
                .plan()
                .unwrap()
                .corrupt_for_test(c);
            let err = verify_plan(&plan)
                .err()
                .unwrap_or_else(|| panic!("{} verified clean", c.name()));
            let ok = match c {
                Corruption::SlotMerge | Corruption::IntervalTruncation => {
                    matches!(err, VerifyError::SlotAliased { .. })
                }
                Corruption::ExtentShrink => matches!(err, VerifyError::KindShape { .. }),
                Corruption::DtypeSwap => matches!(err, VerifyError::SlotDtype { .. }),
                Corruption::WriterDeletion => {
                    matches!(err, VerifyError::ReadWithoutWriter { .. })
                }
                Corruption::PadBitPollution => matches!(err, VerifyError::PadBits { .. }),
                Corruption::DuplicateWeightBind => matches!(err, VerifyError::WeightDup { .. }),
                Corruption::LogitShapeLie => matches!(err, VerifyError::BadLogits { .. }),
                // rewrite-shaped classes need fused steps; judged by
                // check_equiv in the equiv mutation suite instead
                _ => unreachable!("not a verify-rejected corruption"),
            };
            assert!(ok, "{}: wrong variant: {err}", c.name());
        }
    }

    #[test]
    fn corruption_names_roundtrip_through_parse() {
        for c in Corruption::ALL {
            assert_eq!(Corruption::parse(c.name()), Some(c));
        }
        assert_eq!(Corruption::parse("nonsense"), None);
    }

    #[test]
    fn corruptions_also_break_a_deeper_arch_plan() {
        // the hooks find their sites structurally, not by legacy step
        // indices — they must bite on manifest-compiled archs too
        use crate::bnn::graph::verify::verify_plan;
        let spec = || NetworkSpec {
            ops: vec![
                LayerOp::Binarize { scheme: Scheme::Gray },
                LayerOp::ConvBin { k: 5, c_out: 32 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::ConvBin { k: 3, c_out: 32 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::FcBin { c_out: 64 },
                LayerOp::Threshold,
                LayerOp::FcFloat { c_out: NUM_CLASSES, bias: true, act: Activation::None },
            ],
        };
        assert!(verify_plan(&spec().plan().unwrap()).is_ok());
        for c in Corruption::VERIFY_REJECTED {
            let plan = spec().plan().unwrap().corrupt_for_test(c);
            assert!(verify_plan(&plan).is_err(), "{} verified clean on the arch plan", c.name());
        }
    }

    #[test]
    fn corruption_subsets_partition_all() {
        // every class is judged somewhere: by verify_plan on unrewritten
        // plans or by check_equiv on rewritten ones — and nowhere twice
        let mut seen: Vec<&str> = Corruption::VERIFY_REJECTED
            .iter()
            .chain(Corruption::REWRITE_SHAPED.iter())
            .map(|c| c.name())
            .collect();
        seen.sort_unstable();
        let mut all: Vec<&str> = Corruption::ALL.iter().map(|c| c.name()).collect();
        all.sort_unstable();
        assert_eq!(seen, all);
    }

    #[test]
    fn a_three_conv_graph_plans_cleanly() {
        // the acceptance-criteria topology: 96 -> 48 -> 24 -> 12 spatial
        let spec = NetworkSpec {
            ops: vec![
                LayerOp::Binarize { scheme: Scheme::Gray },
                LayerOp::ConvBin { k: 5, c_out: 32 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::ConvBin { k: 3, c_out: 32 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::ConvBin { k: 3, c_out: 32 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::FcBin { c_out: 64 },
                LayerOp::Threshold,
                LayerOp::FcFloat { c_out: NUM_CLASSES, bias: true, act: Activation::None },
            ],
        };
        let plan = spec.plan().unwrap();
        // conv3 weights follow the positional convention; fc names restart
        let names: Vec<&str> = plan.weights.iter().map(|w| w.name.as_str()).collect();
        assert!(names.contains(&"w3_packed"));
        assert!(names.contains(&"theta4"), "fc threshold is ordinal 4: {names:?}");
        assert!(names.contains(&"wfc1_packed") && names.contains(&"wfc2"));
        // fc_bin consumes (12,12,32) words
        let wfc1 = plan.weights.iter().find(|w| w.name == "wfc1_packed").unwrap();
        assert_eq!(wfc1.shape, vec![64, 144]);
        // deeper graph, same planned arena shape as the 2-conv one —
        // liveness reuses the retired slots instead of adding roles
        assert_eq!(plan.nbufs, [2, 2, 1]);
    }
}
