//! The plan compiler: [`NetworkSpec`] → [`Plan`].
//!
//! Three passes over the op chain, all at load time (never on the
//! request path):
//!
//! 1. **Shape inference + validation.**  Every edge between two ops
//!    carries a typed value — float activations, channel-packed words,
//!    or integer popcount counts — with a spatial extent.  Each op
//!    declares what it accepts and what it produces; a mismatch (OR-pool
//!    on floats, threshold on > 32 channels, odd extent into a 2×2
//!    pool, mismatched residual-add operands, a cyclic or dangling
//!    branch reference, a graph that doesn't end in a flat float logit
//!    row) is a structured [`GraphError::Validate`] naming the step.
//! 2. **Weight-name resolution.**  Tensor names are positional —
//!    conv `i` → `w{i}_packed` / `w{i}`+`b{i}`, threshold `t` →
//!    `theta{t}`+`flip{t}`, fc `f` → `wfc{f}_packed` / `wfc{f}`+`bfc{f}`,
//!    scale `s` → `alpha{s}`
//!    — which reproduces the legacy container names exactly on the
//!    synthesized legacy specs, so every existing artifact binds
//!    unchanged.  The resolved list (with dtypes and shapes) is exposed
//!    as [`Plan::weights`] for generators and docs.
//! 3. **Interval-graph liveness + buffer assignment.**  Each edge is
//!    live from its producing step to its LAST reader — in a linear
//!    chain that is the very next step, but a branch tap
//!    ([`super::Tap`]) or split fan-out gives an edge arbitrarily many
//!    readers, and its slot may not be clobbered between any of them.
//!    The compiler first records every edge's last reader over the
//!    whole lowered step list, then walks the steps with a free-list
//!    per storage class (f32 / u32 / i32), allocating a slot for each
//!    output and scratch and releasing a slot only once its edge's
//!    last reader has run — interval coloring on the edge live-ranges.
//!    The result is the minimal planned arena
//!    ([`crate::bnn::scratch::PlanScratch`] slots): the legacy 2-conv
//!    BCNN plans 2 f32 + 2 u32 + 1 i32 buffers (plus the LBP gray
//!    scratch when used) where the hand-named `ForwardScratch` carried
//!    11 fixed roles, and a deeper or branching graph gets exactly what
//!    its own liveness demands, not another hand-audited struct.

use crate::bnn::network::{IMG_C, IMG_H, IMG_W};
use crate::bnn::packing::packed_width;
use crate::input::binarize::Scheme;

use super::{Activation, GraphError, LayerOp, NetworkSpec};

/// Storage class of a planned buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BufClass {
    F32 = 0,
    U32 = 1,
    I32 = 2,
}

/// One slot in the planned arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BufId {
    pub class: BufClass,
    pub idx: usize,
}

/// Where a step reads its input from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Src {
    /// The caller's image payload (only ever float pixels).
    External,
    Buf(BufId),
}

/// A value type on one edge of the graph.  `h == w == 1` encodes flat
/// feature vectors (the FC tail).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ValTy {
    pub kind: ValKind,
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ValKind {
    /// Float activations / images / ±1 binarized pixels.
    F32,
    /// Channel-packed words, one `u32` per pixel (`c` ≤ 32 live bits).
    Words,
    /// Integer XNOR-popcount counts.
    Counts,
}

impl ValTy {
    fn f32(h: usize, w: usize, c: usize) -> Self {
        Self { kind: ValKind::F32, h, w, c }
    }
    fn words(h: usize, w: usize, c: usize) -> Self {
        Self { kind: ValKind::Words, h, w, c }
    }
    fn counts(h: usize, w: usize, c: usize) -> Self {
        Self { kind: ValKind::Counts, h, w, c }
    }
    /// Storage class of a value of this type.
    pub(crate) fn class(&self) -> BufClass {
        match self.kind {
            ValKind::F32 => BufClass::F32,
            ValKind::Words => BufClass::U32,
            ValKind::Counts => BufClass::I32,
        }
    }
    pub fn describe(&self) -> String {
        let k = match self.kind {
            ValKind::F32 => "f32",
            ValKind::Words => "words",
            ValKind::Counts => "counts",
        };
        format!("{k}({},{},{})", self.h, self.w, self.c)
    }
}

/// Dtype of a declared weight tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightDType {
    F32,
    U32,
}

/// One weight tensor the plan will bind from the container.
#[derive(Debug, Clone, PartialEq)]
pub struct WeightReq {
    pub name: String,
    pub dtype: WeightDType,
    pub shape: Vec<usize>,
}

impl WeightReq {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A lowered, placement-resolved step.  `kind` carries the resolved
/// kernel parameters and weight names; weights themselves bind in
/// [`super::exec::CompiledNetwork::from_tensor_file`].
#[derive(Debug, Clone)]
pub(crate) struct Step {
    pub kind: StepKind,
    pub input: Src,
    /// Second input edge — only for two-operand kinds
    /// ([`StepKind::Add`] / [`StepKind::Concat`]); `None` otherwise.
    pub input2: Option<Src>,
    pub output: BufId,
    /// Per-step internal scratch (patch gathers, the LBP gray plane);
    /// live only within the step, so liveness reuses it freely.
    pub scratch: Option<BufId>,
    /// Second internal scratch, used only by fused conv+threshold steps
    /// that have not (yet) elided the i32 counts buffer: `scratch` holds
    /// the patch gather, `scratch2` the popcount counts the epilogue
    /// thresholds from.  `compile` never emits it — only
    /// [`super::rewrite`] does.
    pub scratch2: Option<BufId>,
    pub in_ty: ValTy,
    pub out_ty: ValTy,
    /// Timing label(s): convs lap twice (`im2colN`, `gemmN`), everything
    /// else once.
    pub label_a: String,
    pub label_b: Option<String>,
}

impl Step {
    /// The exact edge type `input2` must carry, for the two-operand
    /// kinds (`None` for every single-input kind): an Add reads a twin
    /// of its primary input, a Concat reads the channel remainder.
    /// Both the executor's length checks and the verifier's dataflow
    /// pass derive the expectation from here, so they cannot drift.
    pub(crate) fn input2_ty(&self) -> Option<ValTy> {
        match self.kind {
            StepKind::Add => Some(self.in_ty),
            StepKind::Concat => Some(ValTy {
                kind: self.in_ty.kind,
                h: self.in_ty.h,
                w: self.in_ty.w,
                c: self.out_ty.c.saturating_sub(self.in_ty.c),
            }),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub(crate) enum StepKind {
    Binarize { scheme: Scheme },
    /// ±1 floats → counts: fused im2col+pack (Algorithm 1) + XNOR-GEMM.
    ConvBinPacked { k: usize, c_out: usize, nw: usize, d: usize, w: String },
    /// Packed words → counts: word gather + XNOR-GEMM over (c_out, k*k).
    ConvBinWords { k: usize, c_out: usize, d: usize, w: String },
    ConvFloat { k: usize, c_out: usize, relu: bool, w: String, b: Option<String> },
    MaxPool,
    OrPool,
    /// Spatial counts/activations → channel-packed words.
    ThresholdPack { f32_in: bool, theta: String, flip: String },
    /// Flat FC counts → ±1 floats for the float tail.
    ThresholdPm1 { theta: String, flip: String },
    FcBin { kw: usize, c_out: usize, d: usize, w: String },
    FcFloat { d: usize, c_out: usize, act: Activation, w: String, b: Option<String> },

    // --- branch kinds (the DAG vocabulary) -----------------------------
    /// Elementwise residual add of `input` and `input2` (identical
    /// extents, floats or counts — never packed words).
    Add,
    /// Channel concatenation `[input, input2]`: same kind and spatial
    /// extents, output channels are the sum.
    Concat,
    /// Copy channels `[lo, lo + out.c)` of the input edge — one step
    /// per declared split part, all reading the same (multi-reader)
    /// input edge.
    SplitPart { lo: usize },
    /// XNOR-Net per-output-channel rescale by the f32 `alpha` vector
    /// (floats or counts in, floats out).
    Scale { alpha: String },

    // --- fused kinds: emitted only by `super::rewrite`, never by -------
    // `compile`.  Every fused kind carries `cmp_bias`, an offset the
    // epilogue adds to each popcount before comparing against theta.  A
    // sound rewrite always sets it to 0; it exists so an off-by-one
    // epilogue is *expressible* in plan structure — `verify_plan` cannot
    // know its semantics, but `super::equiv::check_equiv` refuses any
    // nonzero bias, which is exactly the class of bug the equivalence
    // gauntlet catches and the slot/shape verifier cannot.
    /// ±1 floats → words: packed conv with the following threshold
    /// folded into the popcount epilogue.  `elide: false` still writes
    /// the raw counts to `scratch2` (the staged rewrite before counts
    /// elision); `elide: true` keeps each count in a register.
    ConvBinPackedThreshold {
        k: usize,
        c_out: usize,
        nw: usize,
        d: usize,
        w: String,
        theta: String,
        flip: String,
        cmp_bias: i32,
        elide: bool,
    },
    /// Packed words → words: word-gather conv with the fused threshold
    /// epilogue.  Same `scratch2`/`elide` contract as the packed form.
    ConvBinWordsThreshold {
        k: usize,
        c_out: usize,
        d: usize,
        w: String,
        theta: String,
        flip: String,
        cmp_bias: i32,
        elide: bool,
    },
    /// External f32 image → counts: input binarization fused into the
    /// im2col pack (each gathered pixel's sign bit is computed on the
    /// fly — the ±1 float image is never materialized).  LBP is never
    /// fused (it needs the whole grayscale plane before any patch).
    BinarizeConvBin { scheme: Scheme, k: usize, c_out: usize, nw: usize, d: usize, w: String },
    /// External f32 image → words: both fusions at once
    /// (binarize-while-gather + threshold epilogue).
    BinarizeConvBinThreshold {
        scheme: Scheme,
        k: usize,
        c_out: usize,
        nw: usize,
        d: usize,
        w: String,
        theta: String,
        flip: String,
        cmp_bias: i32,
        elide: bool,
    },
    /// Packed words → ±1 floats: FC with the threshold folded in.  Each
    /// output's count lives in a register between the popcount and the
    /// compare, so the counts buffer is gone by construction (no `elide`
    /// flag needed).
    FcBinThreshold {
        kw: usize,
        c_out: usize,
        d: usize,
        w: String,
        theta: String,
        flip: String,
        cmp_bias: i32,
    },
}

/// The compiled plan: lowered steps, arena layout, declared weights.
#[derive(Debug, Clone)]
pub struct Plan {
    pub(crate) steps: Vec<Step>,
    /// Planned arena slots per storage class, `[f32, u32, i32]`.
    pub nbufs: [usize; 3],
    /// Every weight tensor the plan binds, in graph order.
    pub weights: Vec<WeightReq>,
    /// Output logits per image — the channel width of the plan's final
    /// edge (any `>= 1`; the serving protocol carries whatever the plan
    /// declares, so non-legacy heads round-trip their own width).
    pub classes: usize,
}

impl Plan {
    /// Total planned arena slots across all storage classes.
    pub fn num_buffers(&self) -> usize {
        self.nbufs.iter().sum()
    }

    /// Human-readable step labels, in execution order (docs + tests).
    pub fn step_names(&self) -> Vec<String> {
        let mut names = Vec::new();
        for s in &self.steps {
            names.push(s.label_a.clone());
            if let Some(b) = &s.label_b {
                names.push(b.clone());
            }
        }
        names
    }
}

/// A corruption class the mutation-testing suite injects via
/// [`Plan::corrupt_for_test`].  `compile` never emits an unsound plan,
/// so the verifier's rejection paths can only be exercised by breaking
/// a sound plan on purpose — each class models one way a hand-written
/// or future-rewritten plan could go wrong.
#[doc(hidden)]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Corruption {
    /// Collapse two consecutive same-class outputs into one slot
    /// (models a broken coalescing rewrite) → aliased live intervals.
    SlotMerge,
    /// Point a step's scratch at its own input slot (models a liveness
    /// pass under-counting an interval) → the clobber overlaps the
    /// still-live input edge.
    IntervalTruncation,
    /// Halve a conv's declared output channels (models an undersized
    /// slot extent) → kind/edge shape disagreement.
    ExtentShrink,
    /// Move a words output into the f32 pool (models a storage-class
    /// mixup) → slot dtype violation.
    DtypeSwap,
    /// Delete a pool step outright (models a dropped writer) → a later
    /// step reads an edge nothing wrote.
    WriterDeletion,
    /// Widen a packed conv's weight row past `ceil(d/32)` (models
    /// unmasked tail pad bits — the popcount soundness precondition).
    PadBitPollution,
    /// Declare one weight tensor twice → it would bind two roles.
    DuplicateWeightBind,
    /// Lie about the logit width → breaks the serving contract.
    LogitShapeLie,
    /// Point a multi-reader edge's first reader's output back at the
    /// edge's own slot (models a liveness pass that releases a skip
    /// edge after its FIRST reader instead of its last) → the skip
    /// interval overlaps the clobbering write.
    SkipEdgeClobberedBeforeSecondReader,
    /// Bump a concat's declared output channels (models a branch
    /// lowering that mis-sums its operand extents) → the second
    /// operand's edge type no longer matches.
    ConcatExtentMismatch,
    /// Widen a scale's declared per-channel `alpha` vector (models a
    /// rescale bound against the wrong layer's channel count).
    ScaleChannelCountLie,
    /// Rewrite-shaped: bump a fused threshold epilogue's `cmp_bias`
    /// (models an off-by-one in the folded compare — bit-plausible,
    /// invisible to the slot/shape verifier, semantically wrong).
    /// Caught only by `check_equiv`.
    EpilogueThresholdOffByOne,
    /// Rewrite-shaped: widen a fused packed conv's row past
    /// `ceil(d/32)` with a consistently-widened weight declaration
    /// (models a fusion that changes the pad-bit class).
    EpilogueThresholdPadBitClassChange,
    /// Rewrite-shaped: point a later step's input at a fused step's
    /// internal counts buffer (models eliding / privatizing the counts
    /// edge while a second reader still exists — the single-reader
    /// precondition of the elision axiom).
    CountsElisionSecondReader,
    /// Rewrite-shaped: fold a threshold into a conv whose output edge
    /// has a SECOND reader (a skip tap), rewiring the orphaned reader
    /// onto a same-typed surviving edge.  Slot- and shape-clean, but
    /// the skip now reads the wrong value — only the multi-consumer
    /// fusion axiom in `check_equiv` refuses it.
    MultiConsumerFusedAcross,
    /// Rewrite-shaped but *sound*: rename arena slots within a storage
    /// class and reorder the weight declarations.  Dataflow, value
    /// terms, and extents are untouched, so both `verify_plan` and
    /// `check_equiv` must still ACCEPT the plan — the mutation suite's
    /// false-positive guard.
    ReorderedCommutingSteps,
}

impl Corruption {
    pub const ALL: [Corruption; 16] = [
        Corruption::SlotMerge,
        Corruption::IntervalTruncation,
        Corruption::ExtentShrink,
        Corruption::DtypeSwap,
        Corruption::WriterDeletion,
        Corruption::PadBitPollution,
        Corruption::DuplicateWeightBind,
        Corruption::LogitShapeLie,
        Corruption::SkipEdgeClobberedBeforeSecondReader,
        Corruption::ConcatExtentMismatch,
        Corruption::ScaleChannelCountLie,
        Corruption::EpilogueThresholdOffByOne,
        Corruption::EpilogueThresholdPadBitClassChange,
        Corruption::CountsElisionSecondReader,
        Corruption::MultiConsumerFusedAcross,
        Corruption::ReorderedCommutingSteps,
    ];

    /// The classes `verify_plan` alone must reject on an *unrewritten*
    /// plan (the PR 6 suite).  The rewrite-shaped classes need fused
    /// steps to find a site and are judged by `check_equiv` instead —
    /// see the mutation tests in [`super::equiv`].
    pub const VERIFY_REJECTED: [Corruption; 11] = [
        Corruption::SlotMerge,
        Corruption::IntervalTruncation,
        Corruption::ExtentShrink,
        Corruption::DtypeSwap,
        Corruption::WriterDeletion,
        Corruption::PadBitPollution,
        Corruption::DuplicateWeightBind,
        Corruption::LogitShapeLie,
        Corruption::SkipEdgeClobberedBeforeSecondReader,
        Corruption::ConcatExtentMismatch,
        Corruption::ScaleChannelCountLie,
    ];

    /// The verify-rejected classes whose sites only exist on a
    /// *branching* plan (a multi-reader skip edge, a concat, a scale) —
    /// the branch mutation suite drives these against the branch
    /// fixtures; the legacy linear plans have no such sites.
    pub const BRANCH_SHAPED: [Corruption; 3] = [
        Corruption::SkipEdgeClobberedBeforeSecondReader,
        Corruption::ConcatExtentMismatch,
        Corruption::ScaleChannelCountLie,
    ];

    /// The rewrite-shaped classes: applied to a *rewritten* plan and
    /// judged by `check_equiv` against the original.
    pub const REWRITE_SHAPED: [Corruption; 5] = [
        Corruption::EpilogueThresholdOffByOne,
        Corruption::EpilogueThresholdPadBitClassChange,
        Corruption::CountsElisionSecondReader,
        Corruption::MultiConsumerFusedAcross,
        Corruption::ReorderedCommutingSteps,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Corruption::SlotMerge => "slot-merge",
            Corruption::IntervalTruncation => "interval-truncation",
            Corruption::ExtentShrink => "extent-shrink",
            Corruption::DtypeSwap => "dtype-swap",
            Corruption::WriterDeletion => "writer-deletion",
            Corruption::PadBitPollution => "pad-bit-pollution",
            Corruption::DuplicateWeightBind => "duplicate-weight-bind",
            Corruption::LogitShapeLie => "logit-shape-lie",
            Corruption::SkipEdgeClobberedBeforeSecondReader => {
                "skip-edge-clobbered-before-second-reader"
            }
            Corruption::ConcatExtentMismatch => "concat-extent-mismatch",
            Corruption::ScaleChannelCountLie => "scale-channel-count-lie",
            Corruption::EpilogueThresholdOffByOne => "epilogue-threshold-off-by-one",
            Corruption::EpilogueThresholdPadBitClassChange => "pad-bit-class-change",
            Corruption::CountsElisionSecondReader => "counts-elision-second-reader",
            Corruption::MultiConsumerFusedAcross => "multi-consumer-fused-across",
            Corruption::ReorderedCommutingSteps => "reordered-commuting-steps",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|c| c.name() == s)
    }
}

impl Plan {
    /// Break this plan on purpose (mutation testing + the loader's
    /// fault-injection hook).  Each class finds its first applicable
    /// site and panics if the plan has none — a corruption that
    /// silently no-ops would turn the mutation suite into a lie.
    #[doc(hidden)]
    pub fn corrupt_for_test(mut self, c: Corruption) -> Plan {
        match c {
            Corruption::SlotMerge => {
                let i = (0..self.steps.len() - 1)
                    .find(|&i| self.steps[i].output.class == self.steps[i + 1].output.class)
                    .expect("plan has two consecutive same-class outputs");
                let dead = self.steps[i + 1].output;
                let merged = self.steps[i].output;
                self.steps[i + 1].output = merged;
                for s in &mut self.steps[i + 2..] {
                    if s.input == Src::Buf(dead) {
                        s.input = Src::Buf(merged);
                    }
                    if s.input2 == Some(Src::Buf(dead)) {
                        s.input2 = Some(Src::Buf(merged));
                    }
                }
            }
            Corruption::IntervalTruncation => {
                let step = self
                    .steps
                    .iter_mut()
                    .find(|s| {
                        matches!((s.scratch, s.input),
                            (Some(sc), Src::Buf(b)) if sc.class == b.class)
                    })
                    .expect("plan has a step whose scratch shares a class with its input");
                if let Src::Buf(b) = step.input {
                    step.scratch = Some(b);
                }
            }
            Corruption::ExtentShrink => {
                let step = self
                    .steps
                    .iter_mut()
                    .find(|s| {
                        matches!(
                            s.kind,
                            StepKind::ConvBinPacked { .. }
                                | StepKind::ConvBinWords { .. }
                                | StepKind::ConvFloat { .. }
                        ) && s.out_ty.c > 1
                    })
                    .expect("plan has a conv with more than one output channel");
                step.out_ty.c /= 2;
            }
            Corruption::DtypeSwap => {
                let i = (0..self.steps.len())
                    .find(|&i| self.steps[i].output.class == BufClass::U32)
                    .expect("plan has a u32-class output");
                let old = self.steps[i].output;
                let swapped = BufId { class: BufClass::F32, idx: old.idx };
                self.steps[i].output = swapped;
                for s in &mut self.steps[i + 1..] {
                    if s.input == Src::Buf(old) {
                        s.input = Src::Buf(swapped);
                    }
                    if s.input2 == Some(Src::Buf(old)) {
                        s.input2 = Some(Src::Buf(swapped));
                    }
                }
            }
            Corruption::WriterDeletion => {
                let i = self
                    .steps
                    .iter()
                    .position(|s| matches!(s.kind, StepKind::MaxPool | StepKind::OrPool))
                    .expect("plan has a pool step to delete");
                self.steps.remove(i);
            }
            Corruption::PadBitPollution => {
                let (wname, bad_shape) = {
                    let step = self
                        .steps
                        .iter_mut()
                        .find(|s| matches!(s.kind, StepKind::ConvBinPacked { .. }))
                        .expect("plan has a packed conv");
                    match &mut step.kind {
                        StepKind::ConvBinPacked { c_out, nw, w, .. } => {
                            *nw += 1;
                            (w.clone(), vec![*c_out, *nw])
                        }
                        _ => unreachable!(),
                    }
                };
                // keep the declared weight consistent with the widened
                // row so only the pad-bit rule is violated
                let req = self
                    .weights
                    .iter_mut()
                    .find(|r| r.name == wname)
                    .expect("packed conv declares its weight");
                req.shape = bad_shape;
            }
            Corruption::DuplicateWeightBind => {
                let dup = self.weights.first().expect("plan declares weights").clone();
                self.weights.push(dup);
            }
            Corruption::LogitShapeLie => {
                self.classes += 3;
            }
            Corruption::SkipEdgeClobberedBeforeSecondReader => {
                // find a multi-reader edge whose FIRST reader produces
                // the same storage class, then point that reader's
                // output back at the skip slot — a liveness pass that
                // released the edge after reader one would plan exactly
                // this clobber
                let edge_of = |s: &Step| Src::Buf(s.output);
                let site = (0..self.steps.len())
                    .find_map(|i| {
                        let edge = edge_of(&self.steps[i]);
                        let readers: Vec<usize> = (i + 1..self.steps.len())
                            .filter(|&j| {
                                self.steps[j].input == edge
                                    || self.steps[j].input2 == Some(edge)
                            })
                            .collect();
                        match readers.as_slice() {
                            [first, _, ..]
                                if self.steps[*first].output.class
                                    == self.steps[i].output.class =>
                            {
                                Some((i, *first))
                            }
                            _ => None,
                        }
                    })
                    .expect("plan has a multi-reader edge with a same-class first reader");
                let (i, first) = site;
                let skip = self.steps[i].output;
                let old = self.steps[first].output;
                self.steps[first].output = skip;
                for s in &mut self.steps[first + 1..] {
                    if s.input == Src::Buf(old) {
                        s.input = Src::Buf(skip);
                    }
                    if s.input2 == Some(Src::Buf(old)) {
                        s.input2 = Some(Src::Buf(skip));
                    }
                }
            }
            Corruption::ConcatExtentMismatch => {
                let step = self
                    .steps
                    .iter_mut()
                    .find(|s| matches!(s.kind, StepKind::Concat))
                    .expect("plan has a concat step");
                step.out_ty.c += 1;
            }
            Corruption::ScaleChannelCountLie => {
                let alpha = self
                    .steps
                    .iter()
                    .find_map(|s| match &s.kind {
                        StepKind::Scale { alpha } => Some(alpha.clone()),
                        _ => None,
                    })
                    .expect("plan has a scale step");
                let req = self
                    .weights
                    .iter_mut()
                    .find(|r| r.name == alpha)
                    .expect("scale declares its alpha vector");
                req.shape = vec![req.shape[0] + 1];
            }
            Corruption::EpilogueThresholdOffByOne => {
                let step = self
                    .steps
                    .iter_mut()
                    .find(|s| {
                        matches!(
                            s.kind,
                            StepKind::ConvBinPackedThreshold { .. }
                                | StepKind::ConvBinWordsThreshold { .. }
                                | StepKind::BinarizeConvBinThreshold { .. }
                                | StepKind::FcBinThreshold { .. }
                        )
                    })
                    .expect("plan has a fused threshold epilogue");
                match &mut step.kind {
                    StepKind::ConvBinPackedThreshold { cmp_bias, .. }
                    | StepKind::ConvBinWordsThreshold { cmp_bias, .. }
                    | StepKind::BinarizeConvBinThreshold { cmp_bias, .. }
                    | StepKind::FcBinThreshold { cmp_bias, .. } => *cmp_bias += 1,
                    _ => unreachable!(),
                }
            }
            Corruption::EpilogueThresholdPadBitClassChange => {
                let (wname, bad_shape) = {
                    let step = self
                        .steps
                        .iter_mut()
                        .find(|s| {
                            matches!(
                                s.kind,
                                StepKind::ConvBinPackedThreshold { .. }
                                    | StepKind::BinarizeConvBin { .. }
                                    | StepKind::BinarizeConvBinThreshold { .. }
                            )
                        })
                        .expect("plan has a fused packed conv");
                    match &mut step.kind {
                        StepKind::ConvBinPackedThreshold { c_out, nw, w, .. }
                        | StepKind::BinarizeConvBin { c_out, nw, w, .. }
                        | StepKind::BinarizeConvBinThreshold { c_out, nw, w, .. } => {
                            *nw += 1;
                            (w.clone(), vec![*c_out, *nw])
                        }
                        _ => unreachable!(),
                    }
                };
                let req = self
                    .weights
                    .iter_mut()
                    .find(|r| r.name == wname)
                    .expect("fused packed conv declares its weight");
                req.shape = bad_shape;
            }
            Corruption::CountsElisionSecondReader => {
                let (i, counts) = self
                    .steps
                    .iter()
                    .enumerate()
                    .find_map(|(i, s)| s.scratch2.map(|sc| (i, sc)))
                    .expect("plan has a non-elided fused conv (scratch2 counts)");
                let reader = self
                    .steps
                    .get_mut(i + 1)
                    .expect("fused conv has a successor step");
                reader.input = Src::Buf(counts);
            }
            Corruption::MultiConsumerFusedAcross => {
                // find an unfused conv→threshold pair whose counts edge
                // has a second reader (the fold pass's guard refused
                // it), then perform the fold anyway: fuse the pair,
                // rewire the orphaned skip reader onto the same-typed
                // other operand of its own step, and compact the
                // retired counts slot — the result is slot- and
                // shape-clean, so only the multi-consumer fusion axiom
                // in `check_equiv` can see the lie
                let site = (0..self.steps.len().saturating_sub(1))
                    .find(|&i| {
                        let out = Src::Buf(self.steps[i].output);
                        let fusable = matches!(
                            self.steps[i].kind,
                            StepKind::ConvBinPacked { .. }
                                | StepKind::ConvBinWords { .. }
                                | StepKind::BinarizeConvBin { .. }
                        );
                        let thr_next = self.steps[i + 1].input == out
                            && matches!(
                                self.steps[i + 1].kind,
                                StepKind::ThresholdPack { f32_in: false, .. }
                            );
                        let second_reader = (i + 2..self.steps.len()).any(|j| {
                            self.steps[j].input == out || self.steps[j].input2 == Some(out)
                        });
                        fusable && thr_next && second_reader
                    })
                    .expect("plan has an unfused multi-consumer conv+threshold pair");
                let thr = self.steps.remove(site + 1);
                let (theta, flip) = match thr.kind {
                    StepKind::ThresholdPack { theta, flip, .. } => (theta, flip),
                    _ => unreachable!(),
                };
                let dead = self.steps[site].output;
                let conv = &mut self.steps[site];
                conv.kind = match conv.kind.clone() {
                    StepKind::ConvBinPacked { k, c_out, nw, d, w } => {
                        StepKind::ConvBinPackedThreshold {
                            k,
                            c_out,
                            nw,
                            d,
                            w,
                            theta,
                            flip,
                            cmp_bias: 0,
                            elide: true,
                        }
                    }
                    StepKind::ConvBinWords { k, c_out, d, w } => {
                        StepKind::ConvBinWordsThreshold {
                            k,
                            c_out,
                            d,
                            w,
                            theta,
                            flip,
                            cmp_bias: 0,
                            elide: true,
                        }
                    }
                    StepKind::BinarizeConvBin { scheme, k, c_out, nw, d, w } => {
                        StepKind::BinarizeConvBinThreshold {
                            scheme,
                            k,
                            c_out,
                            nw,
                            d,
                            w,
                            theta,
                            flip,
                            cmp_bias: 0,
                            elide: true,
                        }
                    }
                    _ => unreachable!(),
                };
                conv.out_ty = thr.out_ty;
                conv.output = thr.output;
                let fused = match conv.label_b.take() {
                    Some(b) => format!("{b}+{}", thr.label_a),
                    None => format!("{}+{}", conv.label_a, thr.label_a),
                };
                conv.label_b = Some(fused);
                // orphaned readers of the fused-away counts edge read
                // their own other operand instead (same type, wrong
                // value — that is the point)
                for s in &mut self.steps[site + 1..] {
                    if s.input2 == Some(Src::Buf(dead)) {
                        s.input2 = Some(s.input);
                    } else if s.input == Src::Buf(dead) {
                        s.input = s.input2.expect("orphaned reader has a second operand");
                    }
                }
                // compact the retired counts slot out of the arena so
                // the verifier sees no unused slot
                let still_used = self.steps.iter().any(|s| {
                    s.input == Src::Buf(dead)
                        || s.input2 == Some(Src::Buf(dead))
                        || s.output == dead
                        || s.scratch == Some(dead)
                        || s.scratch2 == Some(dead)
                });
                if !still_used {
                    let shift = |b: &mut BufId| {
                        if b.class == dead.class && b.idx > dead.idx {
                            b.idx -= 1;
                        }
                    };
                    for s in &mut self.steps {
                        if let Src::Buf(b) = &mut s.input {
                            shift(b);
                        }
                        if let Some(Src::Buf(b)) = &mut s.input2 {
                            shift(b);
                        }
                        shift(&mut s.output);
                        if let Some(b) = &mut s.scratch {
                            shift(b);
                        }
                        if let Some(b) = &mut s.scratch2 {
                            shift(b);
                        }
                    }
                    self.nbufs[dead.class as usize] -= 1;
                }
            }
            Corruption::ReorderedCommutingSteps => {
                assert!(self.weights.len() >= 2, "plan declares at least two weights");
                self.weights.reverse();
                if let Some(class) = [BufClass::F32, BufClass::U32, BufClass::I32]
                    .into_iter()
                    .find(|&c| self.nbufs[c as usize] >= 2)
                {
                    let rename = |b: &mut BufId| {
                        if b.class == class && b.idx < 2 {
                            b.idx ^= 1;
                        }
                    };
                    for s in &mut self.steps {
                        if let Src::Buf(b) = &mut s.input {
                            rename(b);
                        }
                        if let Some(Src::Buf(b)) = &mut s.input2 {
                            rename(b);
                        }
                        rename(&mut s.output);
                        if let Some(b) = &mut s.scratch {
                            rename(b);
                        }
                        if let Some(b) = &mut s.scratch2 {
                            rename(b);
                        }
                    }
                }
            }
        }
        self
    }
}

/// Per-class free-list allocator for the liveness walk.  Shared with
/// [`super::rewrite`], whose recoloring pass re-runs the same walk over
/// a fused step list.
pub(crate) struct Slots {
    free: [Vec<usize>; 3],
    /// High-water slot count per class — the plan's `nbufs`.
    pub(crate) next: [usize; 3],
}

impl Slots {
    pub(crate) fn new() -> Self {
        Self { free: [Vec::new(), Vec::new(), Vec::new()], next: [0; 3] }
    }

    pub(crate) fn alloc(&mut self, class: BufClass) -> BufId {
        let c = class as usize;
        let idx = self.free[c].pop().unwrap_or_else(|| {
            let idx = self.next[c];
            self.next[c] += 1;
            idx
        });
        BufId { class, idx }
    }

    pub(crate) fn release(&mut self, buf: BufId) {
        self.free[buf.class as usize].push(buf.idx);
    }
}

/// An op's output edge during compilation, before buffer assignment:
/// the index of the producing proto-step (`None` = the external image).
type EdgeRef = Option<usize>;

/// A lowered step whose inputs still reference producing proto-steps
/// rather than arena slots — the intermediate form between shape
/// inference and the interval-liveness buffer assignment.
struct Proto {
    kind: StepKind,
    input: EdgeRef,
    input2: EdgeRef,
    in_ty: ValTy,
    out_ty: ValTy,
    scratch_class: Option<BufClass>,
    label_a: String,
    label_b: Option<String>,
}

/// Resolve a [`Tap`] at op `i` to its producing proto-step and type.
/// Forward/self references are cyclic (the op list is the topological
/// order); a part index only exceeds 0 on a split.
fn resolve_tap(
    i: usize,
    opname: &'static str,
    tap: Tap,
    op_edges: &[Vec<usize>],
    op_tys: &[Vec<ValTy>],
    tapped: &mut std::collections::BTreeSet<(usize, usize)>,
) -> Result<(usize, ValTy), GraphError> {
    let bad = |why: String| GraphError::Validate { step: i, op: opname.to_string(), why };
    if tap.op >= i {
        return Err(bad(format!(
            "cyclic reference: \"with\" points at op {}, but only ops before {} are upstream",
            tap.op, i
        )));
    }
    let parts = &op_tys[tap.op];
    if tap.part >= parts.len() {
        return Err(bad(format!(
            "op {} has {} output part(s), no part {}",
            tap.op,
            parts.len(),
            tap.part
        )));
    }
    tapped.insert((tap.op, tap.part));
    Ok((op_edges[tap.op][tap.part], parts[tap.part]))
}

pub(crate) fn compile(spec: &NetworkSpec) -> Result<Plan, GraphError> {
    if spec.ops.is_empty() {
        return Err(GraphError::Spec("graph has no ops".to_string()));
    }
    let mut protos: Vec<Proto> = Vec::with_capacity(spec.ops.len());
    let mut weights: Vec<WeightReq> = Vec::new();
    // per-op edge tables: producing proto index and type of each output
    // part (every op has exactly one part except Split)
    let mut op_edges: Vec<Vec<usize>> = Vec::with_capacity(spec.ops.len());
    let mut op_tys: Vec<Vec<ValTy>> = Vec::with_capacity(spec.ops.len());
    // (op, part) pairs some later tap consumes — the dangling-split check
    let mut tapped: std::collections::BTreeSet<(usize, usize)> = Default::default();

    let mut cur = ValTy::f32(IMG_H, IMG_W, IMG_C);
    let mut cur_edge: EdgeRef = None; // None = the external image
    // positional ordinals — these generate the legacy tensor names
    let (mut conv_ord, mut thr_ord, mut pool_ord, mut fc_ord) = (0usize, 0usize, 0usize, 0usize);
    let (mut add_ord, mut cat_ord, mut split_ord, mut scale_ord) = (0usize, 0usize, 0usize, 0usize);

    fn require(name: &str, dtype: WeightDType, shape: Vec<usize>, ws: &mut Vec<WeightReq>) {
        ws.push(WeightReq { name: name.to_string(), dtype, shape });
    }

    for (i, op) in spec.ops.iter().enumerate() {
        let opname = op_name(op);
        let bad = |why: String| GraphError::Validate { step: i, op: opname.to_string(), why };

        // Split lowers to one copy step per part (all reading the same
        // multi-reader input edge), so it bypasses the one-proto tail
        if let LayerOp::Split { parts } = op {
            if cur.kind == ValKind::Words {
                return Err(bad(format!(
                    "split cannot slice packed words, got {}",
                    cur.describe()
                )));
            }
            if parts.iter().any(|&p| p == 0) || parts.iter().sum::<usize>() != cur.c {
                return Err(bad(format!(
                    "split parts {:?} must be non-zero and sum to the {} input channels",
                    parts, cur.c
                )));
            }
            split_ord += 1;
            let (mut edges, mut tys) = (Vec::new(), Vec::new());
            let mut lo = 0usize;
            for (p, &width) in parts.iter().enumerate() {
                let out_ty = ValTy { kind: cur.kind, h: cur.h, w: cur.w, c: width };
                edges.push(protos.len());
                tys.push(out_ty);
                protos.push(Proto {
                    kind: StepKind::SplitPart { lo },
                    input: cur_edge,
                    input2: None,
                    in_ty: cur,
                    out_ty,
                    scratch_class: None,
                    label_a: format!("split{split_ord}_part{p}"),
                    label_b: None,
                });
                lo += width;
            }
            cur = tys[0];
            cur_edge = Some(edges[0]);
            op_edges.push(edges);
            op_tys.push(tys);
            continue;
        }

        // resolve the second operand (Add/Concat) before the shape match
        let tap2 = match op {
            LayerOp::Add { with } | LayerOp::Concat { with } => {
                Some(resolve_tap(i, opname, *with, &op_edges, &op_tys, &mut tapped)?)
            }
            _ => None,
        };

        // (kind, out_ty, scratch class, labels)
        let (kind, out_ty, scratch_class, label_a, label_b) = match op {
            LayerOp::Binarize { scheme } => {
                if cur.kind != ValKind::F32 || cur.c != 3 {
                    return Err(bad(format!(
                        "binarize expects 3-channel float pixels, got {}",
                        cur.describe()
                    )));
                }
                match scheme {
                    Scheme::None => {
                        return Err(bad("scheme \"none\" has no binarize op".to_string()))
                    }
                    Scheme::Rgb => require("input_t", WeightDType::F32, vec![3], &mut weights),
                    Scheme::Gray => require("input_t", WeightDType::F32, vec![1], &mut weights),
                    Scheme::Lbp => {}
                }
                (
                    StepKind::Binarize { scheme: *scheme },
                    ValTy::f32(cur.h, cur.w, scheme.input_channels()),
                    // LBP reads a per-image grayscale plane
                    (*scheme == Scheme::Lbp).then_some(BufClass::F32),
                    "input_binarize".to_string(),
                    None,
                )
            }
            LayerOp::ConvBin { k, c_out } => {
                check_conv(*k, *c_out, &bad)?;
                conv_ord += 1;
                let wname = format!("w{conv_ord}_packed");
                match cur.kind {
                    ValKind::F32 => {
                        // first packed layer: pixels are ±1 floats
                        let d = k * k * cur.c;
                        let nw = packed_width(d, 32);
                        require(&wname, WeightDType::U32, vec![*c_out, nw], &mut weights);
                        (
                            StepKind::ConvBinPacked { k: *k, c_out: *c_out, nw, d, w: wname },
                            ValTy::counts(cur.h, cur.w, *c_out),
                            Some(BufClass::U32),
                            format!("im2col{conv_ord}"),
                            Some(format!("gemm{conv_ord}")),
                        )
                    }
                    ValKind::Words => {
                        // deeper packed layer: activations already packed
                        let d = k * k * cur.c;
                        require(&wname, WeightDType::U32, vec![*c_out, k * k], &mut weights);
                        (
                            StepKind::ConvBinWords { k: *k, c_out: *c_out, d, w: wname },
                            ValTy::counts(cur.h, cur.w, *c_out),
                            Some(BufClass::U32),
                            format!("im2col{conv_ord}"),
                            Some(format!("gemm{conv_ord}")),
                        )
                    }
                    ValKind::Counts => {
                        return Err(bad(format!(
                            "conv_bin cannot consume raw counts ({}); threshold first",
                            cur.describe()
                        )))
                    }
                }
            }
            LayerOp::ConvFloat { k, c_out, bias, relu, w } => {
                check_conv(*k, *c_out, &bad)?;
                if cur.kind != ValKind::F32 {
                    return Err(bad(format!(
                        "conv_float expects float input, got {}",
                        cur.describe()
                    )));
                }
                conv_ord += 1;
                let wname = w.clone().unwrap_or_else(|| format!("w{conv_ord}"));
                let bname = bias.then(|| format!("b{conv_ord}"));
                require(&wname, WeightDType::F32, vec![*c_out, k * k * cur.c], &mut weights);
                if let Some(b) = &bname {
                    require(b, WeightDType::F32, vec![*c_out], &mut weights);
                }
                (
                    StepKind::ConvFloat { k: *k, c_out: *c_out, relu: *relu, w: wname, b: bname },
                    ValTy::f32(cur.h, cur.w, *c_out),
                    Some(BufClass::F32),
                    format!("im2col{conv_ord}"),
                    Some(format!("gemm{conv_ord}")),
                )
            }
            LayerOp::MaxPool => {
                check_pool(&cur, ValKind::F32, "maxpool", &bad)?;
                pool_ord += 1;
                (
                    StepKind::MaxPool,
                    ValTy::f32(cur.h / 2, cur.w / 2, cur.c),
                    None,
                    format!("pool{pool_ord}"),
                    None,
                )
            }
            LayerOp::OrPool => {
                check_pool(&cur, ValKind::Words, "orpool", &bad)?;
                pool_ord += 1;
                (
                    StepKind::OrPool,
                    ValTy::words(cur.h / 2, cur.w / 2, cur.c),
                    None,
                    format!("pool{pool_ord}"),
                    None,
                )
            }
            LayerOp::Threshold => {
                thr_ord += 1;
                let theta = format!("theta{thr_ord}");
                let flip = format!("flip{thr_ord}");
                require(&theta, WeightDType::F32, vec![cur.c], &mut weights);
                require(&flip, WeightDType::U32, vec![cur.c], &mut weights);
                let spatial = cur.h * cur.w > 1;
                match (cur.kind, spatial) {
                    (ValKind::Counts, true) | (ValKind::F32, true) => {
                        if cur.c > 32 {
                            return Err(bad(format!(
                                "threshold packs into one word per pixel; {} channels > 32",
                                cur.c
                            )));
                        }
                        (
                            StepKind::ThresholdPack {
                                f32_in: cur.kind == ValKind::F32,
                                theta,
                                flip,
                            },
                            ValTy::words(cur.h, cur.w, cur.c),
                            None,
                            format!("threshold_pack{thr_ord}"),
                            None,
                        )
                    }
                    (ValKind::Counts, false) => (
                        StepKind::ThresholdPm1 { theta, flip },
                        ValTy::f32(1, 1, cur.c),
                        None,
                        format!("threshold{thr_ord}"),
                        None,
                    ),
                    _ => {
                        return Err(bad(format!(
                            "threshold expects conv/fc counts or conv activations, got {}",
                            cur.describe()
                        )))
                    }
                }
            }
            LayerOp::FcBin { c_out } => {
                if cur.kind != ValKind::Words {
                    return Err(bad(format!(
                        "fc_bin expects packed words, got {}",
                        cur.describe()
                    )));
                }
                if *c_out == 0 {
                    return Err(bad("output width must be >= 1".to_string()));
                }
                fc_ord += 1;
                let wname = format!("wfc{fc_ord}_packed");
                let kw = cur.h * cur.w;
                let d = kw * cur.c;
                require(&wname, WeightDType::U32, vec![*c_out, kw], &mut weights);
                (
                    StepKind::FcBin { kw, c_out: *c_out, d, w: wname },
                    ValTy::counts(1, 1, *c_out),
                    None,
                    format!("fc{fc_ord}"),
                    None,
                )
            }
            LayerOp::FcFloat { c_out, bias, act } => {
                if cur.kind != ValKind::F32 {
                    return Err(bad(format!(
                        "fc_float expects float features, got {}",
                        cur.describe()
                    )));
                }
                if *c_out == 0 {
                    return Err(bad("output width must be >= 1".to_string()));
                }
                fc_ord += 1;
                let wname = format!("wfc{fc_ord}");
                let bname = bias.then(|| format!("bfc{fc_ord}"));
                let d = cur.h * cur.w * cur.c;
                require(&wname, WeightDType::F32, vec![*c_out, d], &mut weights);
                if let Some(b) = &bname {
                    require(b, WeightDType::F32, vec![*c_out], &mut weights);
                }
                (
                    StepKind::FcFloat { d, c_out: *c_out, act: *act, w: wname, b: bname },
                    ValTy::f32(1, 1, *c_out),
                    None,
                    format!("fc{fc_ord}"),
                    None,
                )
            }
            LayerOp::Add { .. } => {
                let (_, t2) = tap2.expect("tap resolved above");
                if cur.kind == ValKind::Words {
                    return Err(bad(format!(
                        "add cannot operate on packed words ({} + {})",
                        cur.describe(),
                        t2.describe()
                    )));
                }
                if t2 != cur {
                    return Err(bad(format!(
                        "add operands must match exactly: {} + {}",
                        cur.describe(),
                        t2.describe()
                    )));
                }
                add_ord += 1;
                (StepKind::Add, cur, None, format!("add{add_ord}"), None)
            }
            LayerOp::Concat { .. } => {
                let (_, t2) = tap2.expect("tap resolved above");
                if cur.kind == ValKind::Words {
                    return Err(bad(format!(
                        "concat cannot operate on packed words ({} ++ {})",
                        cur.describe(),
                        t2.describe()
                    )));
                }
                if t2.kind != cur.kind {
                    return Err(bad(format!(
                        "concat operands must share a value domain: {} vs {}",
                        cur.describe(),
                        t2.describe()
                    )));
                }
                if (t2.h, t2.w) != (cur.h, cur.w) {
                    return Err(bad(format!(
                        "concat operands must share spatial extents: {} vs {}",
                        cur.describe(),
                        t2.describe()
                    )));
                }
                cat_ord += 1;
                let out = ValTy { kind: cur.kind, h: cur.h, w: cur.w, c: cur.c + t2.c };
                (StepKind::Concat, out, None, format!("concat{cat_ord}"), None)
            }
            LayerOp::Scale => {
                if cur.kind == ValKind::Words {
                    return Err(bad(format!(
                        "scale cannot rescale packed words, got {}",
                        cur.describe()
                    )));
                }
                scale_ord += 1;
                let alpha = format!("alpha{scale_ord}");
                require(&alpha, WeightDType::F32, vec![cur.c], &mut weights);
                (
                    StepKind::Scale { alpha },
                    ValTy::f32(cur.h, cur.w, cur.c),
                    None,
                    format!("scale{scale_ord}"),
                    None,
                )
            }
            LayerOp::Split { .. } => unreachable!("split lowered before the match"),
        };

        let edge = protos.len();
        protos.push(Proto {
            kind,
            input: cur_edge,
            input2: tap2.map(|(e, _)| e),
            in_ty: cur,
            out_ty,
            scratch_class,
            label_a,
            label_b,
        });
        op_edges.push(vec![edge]);
        op_tys.push(vec![out_ty]);
        cur = out_ty;
        cur_edge = Some(edge);
    }

    // every split part must reach a consumer: parts other than part 0
    // (which continues the chain) are only reachable through taps, so an
    // untapped one is a buffer the executor would fill and nobody reads
    for (i, op) in spec.ops.iter().enumerate() {
        if let LayerOp::Split { parts } = op {
            for p in 1..parts.len() {
                if !tapped.contains(&(i, p)) {
                    return Err(GraphError::Validate {
                        step: i,
                        op: "split".to_string(),
                        why: format!("dangling split output: part {p} is never consumed"),
                    });
                }
            }
        }
    }

    // the serving contract: the graph ends in one float logit row per
    // image; its channel width IS the class count the plan declares
    if cur.kind != ValKind::F32 || (cur.h, cur.w) != (1, 1) || cur.c == 0 {
        return Err(GraphError::Validate {
            step: spec.ops.len() - 1,
            op: op_name(spec.ops.last().unwrap()).to_string(),
            why: format!(
                "graph must end in a flat f32(1,1,classes) logit row, got {}",
                cur.describe()
            ),
        });
    }
    let classes = cur.c;

    // weight names must be unique — a positional name colliding with an
    // explicit override would silently bind one tensor twice
    for (a, req) in weights.iter().enumerate() {
        if weights[..a].iter().any(|r| r.name == req.name) {
            return Err(GraphError::Spec(format!(
                "weight name {:?} is declared twice (override collides with a positional name?)",
                req.name
            )));
        }
    }

    // --- interval-graph liveness + buffer assignment -----------------
    // An edge is live from its producing step until its LAST reader; the
    // final edge (the logits) stays live past the end.  Allocating a
    // step's scratch+output before releasing its dying inputs keeps
    // in/scratch/out pairwise distinct (every kernel requires disjoint
    // in/out), and releasing dying inputs before scratch preserves the
    // free-list ordering linear chains have always had, so legacy plans
    // keep their exact historical slot assignment.
    let mut last_use: Vec<usize> = (0..protos.len()).collect();
    for (j, p) in protos.iter().enumerate() {
        if let Some(e) = p.input {
            last_use[e] = j;
        }
        if let Some(e) = p.input2 {
            last_use[e] = j;
        }
    }
    let final_edge = protos.len() - 1;

    let mut slots = Slots::new();
    let mut steps: Vec<Step> = Vec::with_capacity(protos.len());
    let mut buf_of: Vec<BufId> = Vec::with_capacity(protos.len());
    for (j, p) in protos.iter().enumerate() {
        let scratch = p.scratch_class.map(|c| slots.alloc(c));
        let output = slots.alloc(p.out_ty.class());
        buf_of.push(output);
        let mut dying: Vec<usize> = Vec::new();
        for e in [p.input, p.input2].into_iter().flatten() {
            if last_use[e] == j && e != final_edge && !dying.contains(&e) {
                dying.push(e);
            }
        }
        for e in dying {
            slots.release(buf_of[e]);
        }
        if let Some(s) = scratch {
            slots.release(s);
        }
        let to_src = |e: EdgeRef| e.map_or(Src::External, |e| Src::Buf(buf_of[e]));
        steps.push(Step {
            kind: p.kind.clone(),
            input: to_src(p.input),
            input2: p.input2.map(|e| Src::Buf(buf_of[e])),
            output,
            scratch,
            scratch2: None,
            in_ty: p.in_ty,
            out_ty: p.out_ty,
            label_a: p.label_a.clone(),
            label_b: p.label_b.clone(),
        });
    }

    Ok(Plan { steps, nbufs: slots.next, weights, classes })
}

fn op_name(op: &LayerOp) -> &'static str {
    match op {
        LayerOp::Binarize { .. } => "binarize",
        LayerOp::ConvBin { .. } => "conv_bin",
        LayerOp::ConvFloat { .. } => "conv_float",
        LayerOp::MaxPool => "maxpool",
        LayerOp::OrPool => "orpool",
        LayerOp::Threshold => "threshold",
        LayerOp::FcBin { .. } => "fc_bin",
        LayerOp::FcFloat { .. } => "fc_float",
        LayerOp::Add { .. } => "add",
        LayerOp::Concat { .. } => "concat",
        LayerOp::Split { .. } => "split",
        LayerOp::Scale => "scale",
    }
}

fn check_conv(
    k: usize,
    c_out: usize,
    bad: &impl Fn(String) -> GraphError,
) -> Result<(), GraphError> {
    if k == 0 || k % 2 == 0 {
        return Err(bad(format!("kernel size {k} must be odd ('same' convolution)")));
    }
    if c_out == 0 {
        return Err(bad("output channels must be >= 1".to_string()));
    }
    Ok(())
}

fn check_pool(
    cur: &ValTy,
    want: ValKind,
    name: &str,
    bad: &impl Fn(String) -> GraphError,
) -> Result<(), GraphError> {
    if cur.kind != want {
        return Err(bad(format!("{name} expects {want:?} input, got {}", cur.describe())));
    }
    if cur.h < 2 || cur.w < 2 || cur.h % 2 != 0 || cur.w % 2 != 0 {
        return Err(bad(format!("2x2 pool needs even extents >= 2, got {}", cur.describe())));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::graph::test_specs;
    use crate::bnn::network::NUM_CLASSES;

    #[test]
    fn legacy_bcnn_plan_names_match_the_legacy_container() {
        let plan = NetworkSpec::legacy_bcnn(Scheme::Rgb).plan().unwrap();
        let names: Vec<&str> = plan.weights.iter().map(|w| w.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "input_t",
                "w1_packed",
                "theta1",
                "flip1",
                "w2_packed",
                "theta2",
                "flip2",
                "wfc1_packed",
                "theta3",
                "flip3",
                "wfc2",
                "bfc2",
                "wfc3",
                "bfc3",
            ]
        );
        // the legacy shapes, byte for byte
        let by_name = |n: &str| plan.weights.iter().find(|w| w.name == n).unwrap();
        assert_eq!(by_name("w1_packed").shape, vec![32, packed_width(5 * 5 * 3, 32)]);
        assert_eq!(by_name("w2_packed").shape, vec![32, 25]);
        assert_eq!(by_name("wfc1_packed").shape, vec![100, 576]);
        assert_eq!(by_name("wfc2").shape, vec![100, 100]);
        assert_eq!(by_name("wfc3").shape, vec![NUM_CLASSES, 100]);
    }

    #[test]
    fn legacy_none_plan_uses_the_pm1_override() {
        let plan = NetworkSpec::legacy_bcnn(Scheme::None).plan().unwrap();
        assert_eq!(plan.weights[0].name, "w1_pm1");
        assert_eq!(plan.weights[0].shape, vec![32, 75]);
        assert!(plan.weights.iter().all(|w| w.name != "b1"), "pm1 conv has no bias");
    }

    #[test]
    fn legacy_float_plan_names_match_the_legacy_container() {
        let plan = NetworkSpec::legacy_float().plan().unwrap();
        let names: Vec<&str> = plan.weights.iter().map(|w| w.name.as_str()).collect();
        assert_eq!(
            names,
            vec!["w1", "b1", "w2", "b2", "wfc1", "bfc1", "wfc2", "bfc2", "wfc3", "bfc3"]
        );
    }

    #[test]
    fn liveness_plans_far_fewer_buffers_than_the_11_hand_named_roles() {
        // rgb: binarize(f32) + 2 packed convs + fc tail
        let plan = NetworkSpec::legacy_bcnn(Scheme::Rgb).plan().unwrap();
        assert_eq!(plan.nbufs, [2, 2, 1], "f32/u32/i32 slots");
        assert!(plan.num_buffers() <= 5);
        // lbp adds one f32 slot for the per-image gray plane
        let plan = NetworkSpec::legacy_bcnn(Scheme::Lbp).plan().unwrap();
        assert_eq!(plan.nbufs[0], 2, "gray scratch reuses a dead f32 slot or adds one");
        // float: everything in the f32 class
        let plan = NetworkSpec::legacy_float().plan().unwrap();
        assert_eq!(plan.nbufs, [3, 0, 0]);
    }

    #[test]
    fn step_in_scratch_out_slots_are_pairwise_distinct() {
        for spec in [
            NetworkSpec::legacy_bcnn(Scheme::Rgb),
            NetworkSpec::legacy_bcnn(Scheme::None),
            NetworkSpec::legacy_bcnn(Scheme::Lbp),
            NetworkSpec::legacy_float(),
        ] {
            let plan = spec.plan().unwrap();
            // every edge type-checks: step i+1 consumes exactly what
            // step i produced
            for pair in plan.steps.windows(2) {
                assert_eq!(pair[0].out_ty, pair[1].in_ty, "edge type mismatch");
                assert_eq!(Src::Buf(pair[0].output), pair[1].input, "edge slot mismatch");
            }
            for s in &plan.steps {
                if let Src::Buf(b) = s.input {
                    assert_ne!(b, s.output, "input aliases output");
                    if let Some(sc) = s.scratch {
                        assert_ne!(b, sc, "input aliases scratch");
                    }
                }
                if let Some(sc) = s.scratch {
                    assert_ne!(sc, s.output, "scratch aliases output");
                }
            }
        }
    }

    #[test]
    fn step_names_cover_the_legacy_timing_labels() {
        let names = NetworkSpec::legacy_bcnn(Scheme::Gray).plan().unwrap().step_names();
        for want in
            ["input_binarize", "im2col1", "gemm1", "threshold_pack1", "pool1", "gemm2", "fc1"]
        {
            assert!(names.iter().any(|n| n == want), "missing {want} in {names:?}");
        }
    }

    #[test]
    fn shape_violations_are_structured_errors() {
        use LayerOp::*;
        let cases: Vec<(&str, Vec<LayerOp>)> = vec![
            ("empty", vec![]),
            ("orpool-on-floats", vec![OrPool]),
            ("maxpool-on-words", vec![
                Binarize { scheme: Scheme::Rgb },
                ConvBin { k: 5, c_out: 32 },
                Threshold,
                MaxPool,
            ]),
            ("conv-on-counts", vec![
                Binarize { scheme: Scheme::Rgb },
                ConvBin { k: 5, c_out: 32 },
                ConvBin { k: 5, c_out: 32 },
            ]),
            ("threshold-over-32ch", vec![
                Binarize { scheme: Scheme::Rgb },
                ConvBin { k: 5, c_out: 64 },
                Threshold,
            ]),
            ("even-kernel", vec![
                Binarize { scheme: Scheme::Rgb },
                ConvBin { k: 4, c_out: 32 },
            ]),
            ("fcbin-on-floats", vec![FcBin { c_out: 10 }]),
            ("ends-in-counts", vec![
                Binarize { scheme: Scheme::Rgb },
                ConvBin { k: 5, c_out: 32 },
            ]),
            // --- malformed branches ------------------------------------
            ("dangling-split-output", vec![
                ConvFloat { k: 5, c_out: 8, bias: false, relu: true, w: None },
                Split { parts: vec![4, 4] },
                MaxPool,
                FcFloat { c_out: 4, bias: false, act: Activation::None },
            ]),
            ("add-extent-mismatch", vec![
                ConvFloat { k: 5, c_out: 8, bias: false, relu: true, w: None },
                ConvFloat { k: 1, c_out: 4, bias: false, relu: true, w: None },
                Add { with: Tap::op(0) },
            ]),
            ("concat-dtype-mix", vec![
                Binarize { scheme: Scheme::Rgb },
                ConvBin { k: 5, c_out: 32 },
                Scale,
                Concat { with: Tap::op(1) },
            ]),
            ("cyclic-reference", vec![
                ConvFloat { k: 5, c_out: 8, bias: false, relu: true, w: None },
                Add { with: Tap::op(1) },
            ]),
            ("split-parts-dont-sum", vec![
                ConvFloat { k: 5, c_out: 8, bias: false, relu: true, w: None },
                Split { parts: vec![3, 3] },
            ]),
            ("tap-part-out-of-range", vec![
                ConvFloat { k: 5, c_out: 8, bias: false, relu: true, w: None },
                Split { parts: vec![4, 4] },
                Concat { with: Tap { op: 1, part: 2 } },
            ]),
            ("add-on-words", vec![
                Binarize { scheme: Scheme::Rgb },
                Add { with: Tap::op(0) },
            ]),
        ];
        for (tag, ops) in cases {
            let err = NetworkSpec { ops }.plan().unwrap_err();
            assert!(
                matches!(err, GraphError::Validate { .. } | GraphError::Spec(_)),
                "{tag}: {err}"
            );
        }
    }

    #[test]
    fn duplicate_weight_names_are_refused() {
        // an override colliding with conv2's positional name
        let spec = NetworkSpec {
            ops: vec![
                LayerOp::ConvFloat {
                    k: 5,
                    c_out: 32,
                    bias: false,
                    relu: false,
                    w: Some("w2".to_string()),
                },
                LayerOp::MaxPool,
                LayerOp::ConvFloat { k: 5, c_out: 32, bias: false, relu: false, w: None },
                LayerOp::MaxPool,
                LayerOp::FcFloat { c_out: NUM_CLASSES, bias: false, act: Activation::None },
            ],
        };
        let err = spec.plan().unwrap_err();
        assert!(err.to_string().contains("twice"), "{err}");
    }

    #[test]
    fn every_corruption_class_is_rejected_with_its_variant() {
        // the mutation suite: break a sound plan eight different ways
        // and prove the verifier catches each with the *intended*
        // structured error, not just any error
        use crate::bnn::graph::verify::{verify_plan, VerifyError};
        for c in Corruption::VERIFY_REJECTED {
            // branch-shaped classes need a DAG to bite on; the rest
            // corrupt the legacy linear plan
            let base = if Corruption::BRANCH_SHAPED.contains(&c) {
                test_specs::split_concat()
            } else {
                NetworkSpec::legacy_bcnn(Scheme::Rgb)
            };
            let plan = base.plan().unwrap().corrupt_for_test(c);
            let err = verify_plan(&plan)
                .err()
                .unwrap_or_else(|| panic!("{} verified clean", c.name()));
            let ok = match c {
                Corruption::SlotMerge | Corruption::IntervalTruncation => {
                    matches!(err, VerifyError::SlotAliased { .. })
                }
                Corruption::ExtentShrink => matches!(err, VerifyError::KindShape { .. }),
                Corruption::DtypeSwap => matches!(err, VerifyError::SlotDtype { .. }),
                Corruption::WriterDeletion => {
                    matches!(err, VerifyError::ReadWithoutWriter { .. })
                }
                Corruption::PadBitPollution => matches!(err, VerifyError::PadBits { .. }),
                Corruption::DuplicateWeightBind => matches!(err, VerifyError::WeightDup { .. }),
                Corruption::LogitShapeLie => matches!(err, VerifyError::BadLogits { .. }),
                // a clobbered skip edge is exactly an interval overlap
                Corruption::SkipEdgeClobberedBeforeSecondReader => {
                    matches!(err, VerifyError::SlotAliased { .. })
                }
                // a widened concat output no longer matches its operands
                Corruption::ConcatExtentMismatch => matches!(err, VerifyError::EdgeType { .. }),
                // the declared alpha vector disagrees with the channels
                Corruption::ScaleChannelCountLie => {
                    matches!(err, VerifyError::WeightShape { .. })
                }
                // rewrite-shaped classes need fused steps; judged by
                // check_equiv in the equiv mutation suite instead
                _ => unreachable!("not a verify-rejected corruption"),
            };
            assert!(ok, "{}: wrong variant: {err}", c.name());
        }
    }

    #[test]
    fn branch_corruptions_also_bite_on_the_residual_fixture() {
        // the branch hooks find their sites structurally; prove they
        // bite on a second, differently-shaped DAG (skip-add residual)
        // as well as the split/concat fixture used above.  residual
        // has no concat or scale, so only the skip-edge class applies.
        use crate::bnn::graph::verify::{verify_plan, VerifyError};
        let plan = test_specs::residual_float()
            .plan()
            .unwrap()
            .corrupt_for_test(Corruption::SkipEdgeClobberedBeforeSecondReader);
        let err = verify_plan(&plan).unwrap_err();
        assert!(matches!(err, VerifyError::SlotAliased { .. }), "wrong variant: {err}");
    }

    #[test]
    fn corruption_names_roundtrip_through_parse() {
        for c in Corruption::ALL {
            assert_eq!(Corruption::parse(c.name()), Some(c));
        }
        assert_eq!(Corruption::parse("nonsense"), None);
    }

    #[test]
    fn corruptions_also_break_a_deeper_arch_plan() {
        // the hooks find their sites structurally, not by legacy step
        // indices — they must bite on manifest-compiled archs too
        use crate::bnn::graph::verify::verify_plan;
        let spec = || NetworkSpec {
            ops: vec![
                LayerOp::Binarize { scheme: Scheme::Gray },
                LayerOp::ConvBin { k: 5, c_out: 32 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::ConvBin { k: 3, c_out: 32 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::FcBin { c_out: 64 },
                LayerOp::Threshold,
                LayerOp::FcFloat { c_out: NUM_CLASSES, bias: true, act: Activation::None },
            ],
        };
        assert!(verify_plan(&spec().plan().unwrap()).is_ok());
        for c in Corruption::VERIFY_REJECTED {
            if Corruption::BRANCH_SHAPED.contains(&c) {
                // needs a DAG site; exercised on both branch fixtures in
                // the mutation tests above
                continue;
            }
            let plan = spec().plan().unwrap().corrupt_for_test(c);
            assert!(verify_plan(&plan).is_err(), "{} verified clean on the arch plan", c.name());
        }
    }

    #[test]
    fn corruption_subsets_partition_all() {
        // every class is judged somewhere: by verify_plan on unrewritten
        // plans or by check_equiv on rewritten ones — and nowhere twice
        let mut seen: Vec<&str> = Corruption::VERIFY_REJECTED
            .iter()
            .chain(Corruption::REWRITE_SHAPED.iter())
            .map(|c| c.name())
            .collect();
        seen.sort_unstable();
        let mut all: Vec<&str> = Corruption::ALL.iter().map(|c| c.name()).collect();
        all.sort_unstable();
        assert_eq!(seen, all);
    }

    #[test]
    fn a_three_conv_graph_plans_cleanly() {
        // the acceptance-criteria topology: 96 -> 48 -> 24 -> 12 spatial
        let spec = NetworkSpec {
            ops: vec![
                LayerOp::Binarize { scheme: Scheme::Gray },
                LayerOp::ConvBin { k: 5, c_out: 32 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::ConvBin { k: 3, c_out: 32 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::ConvBin { k: 3, c_out: 32 },
                LayerOp::Threshold,
                LayerOp::OrPool,
                LayerOp::FcBin { c_out: 64 },
                LayerOp::Threshold,
                LayerOp::FcFloat { c_out: NUM_CLASSES, bias: true, act: Activation::None },
            ],
        };
        let plan = spec.plan().unwrap();
        // conv3 weights follow the positional convention; fc names restart
        let names: Vec<&str> = plan.weights.iter().map(|w| w.name.as_str()).collect();
        assert!(names.contains(&"w3_packed"));
        assert!(names.contains(&"theta4"), "fc threshold is ordinal 4: {names:?}");
        assert!(names.contains(&"wfc1_packed") && names.contains(&"wfc2"));
        // fc_bin consumes (12,12,32) words
        let wfc1 = plan.weights.iter().find(|w| w.name == "wfc1_packed").unwrap();
        assert_eq!(wfc1.shape, vec![64, 144]);
        // deeper graph, same planned arena shape as the 2-conv one —
        // liveness reuses the retired slots instead of adding roles
        assert_eq!(plan.nbufs, [2, 2, 1]);
    }

    #[test]
    fn branching_fixtures_plan_cleanly_and_hold_skip_edges_live() {
        // residual: conv → conv → conv → add(skip) — the skip slot must
        // not be written between its producer and the add
        let plan = test_specs::residual_float().plan().unwrap();
        assert_eq!(plan.classes, NUM_CLASSES);
        let add_at =
            plan.steps.iter().position(|s| matches!(s.kind, StepKind::Add)).unwrap();
        let skip = match plan.steps[add_at].input2 {
            Some(Src::Buf(b)) => b,
            other => panic!("add has no buffer second operand: {other:?}"),
        };
        let producer = plan.steps.iter().position(|s| s.output == skip).unwrap();
        for (j, s) in plan.steps.iter().enumerate() {
            if j > producer && j < add_at {
                assert_ne!(s.output, skip, "step {j} clobbers the live skip edge");
                assert_ne!(s.scratch, Some(skip), "step {j} scratches over the skip edge");
            }
        }
        // the whole residual still fits the legacy three-slot f32 arena
        assert_eq!(plan.nbufs, [3, 0, 0]);

        // split/concat: a six-class head — classes come from the plan's
        // final edge, not a hard-wired constant
        let plan = test_specs::split_concat().plan().unwrap();
        assert_eq!(plan.classes, 6);
        assert!(
            plan.steps.iter().any(|s| matches!(s.kind, StepKind::SplitPart { lo: 3 })),
            "second split part starts at channel 3"
        );

        // binary residual: the scale op declares its per-channel alpha
        let plan = test_specs::residual_binary().plan().unwrap();
        assert_eq!(plan.classes, NUM_CLASSES);
        let alpha = plan.weights.iter().find(|w| w.name == "alpha1").unwrap();
        assert_eq!(alpha.dtype, WeightDType::F32);
        assert_eq!(alpha.shape, vec![32]);
    }

    #[test]
    fn branch_plan_step_order_is_topological_and_deterministic() {
        // forward_timed attributes per-step laps by label; a DAG plan's
        // compiled order must be the op order with split fan-out
        // expanded in part order, every time
        let names = test_specs::split_concat().plan().unwrap().step_names();
        assert_eq!(
            names,
            vec![
                "im2col1",
                "gemm1",
                "split1_part0",
                "split1_part1",
                "scale1",
                "concat1",
                "pool1",
                "fc1",
            ]
        );
        let again = test_specs::split_concat().plan().unwrap().step_names();
        assert_eq!(names, again, "plan compilation is deterministic");
    }
}
