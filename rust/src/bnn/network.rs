//! The legacy network types, now thin wrappers over the layer-graph
//! compiler.
//!
//! Up to PR 4 this file hard-wired ONE topology twice: `BcnnNetwork`
//! and `FloatNetwork` each carried their own 2-conv/2-fc forward AND a
//! near-duplicate batched loop over the hand-named scratch arena.  Both
//! now delegate to a [`CompiledNetwork`](crate::bnn::graph::CompiledNetwork)
//! built from the synthesized legacy [`NetworkSpec`] — the weight
//! containers written by `python/compile/aot.py` (`weights_float.bcnt`,
//! `weights_bcnn_<scheme>.bcnt`) keep loading unchanged because the
//! plan compiler's positional weight names reproduce the legacy tensor
//! names exactly, and the logits stay bit-identical to the pre-refactor
//! pipelines (property-tested in `bnn::graph::exec` against independent
//! reference compositions, and against `forward` below).

use std::time::Duration;

use crate::bnn::graph::{CompiledNetwork, GraphError, NetworkSpec};
use crate::bnn::scratch::PlanScratch;
use crate::input::binarize::Scheme;
use crate::util::tensorio::{TensorFile, TensorIoError};

pub const IMG_H: usize = 96;
pub const IMG_W: usize = 96;
pub const IMG_C: usize = 3;
pub const K: usize = 5;
pub const CONV1_OUT: usize = 32;
pub const CONV2_OUT: usize = 32;
pub const FC1_OUT: usize = 100;
pub const FC2_OUT: usize = 100;
pub const NUM_CLASSES: usize = 4;
pub const CLASSES: [&str; 4] = ["bus", "normal", "truck", "van"];

/// Named per-layer wall times for one forward pass (labels come from
/// the compiled plan's steps, e.g. `im2col1`, `gemm1`, `pool2`).
pub type LayerTimings = Vec<(String, Duration)>;

#[derive(Debug)]
pub enum NetworkError {
    Tensor(TensorIoError),
    /// Plan compilation or weight binding failed (bad spec, missing or
    /// mis-shaped tensor).
    Graph(GraphError),
    /// Recoverable bad-input error on the inference path (batched entry
    /// points return this instead of asserting).
    BadInput(String),
}

crate::error_enum_impls!(NetworkError {
    NetworkError::Tensor(e) => ("{e}"),
    NetworkError::Graph(e) => ("network: {e}"),
    NetworkError::BadInput(msg) => ("network: {msg}"),
}
source {
    NetworkError::Tensor(e) => e,
    NetworkError::Graph(e) => e,
}
from { TensorIoError => NetworkError::Tensor });

impl From<GraphError> for NetworkError {
    fn from(e: GraphError) -> Self {
        match e {
            // runtime bad input keeps its public identity; everything
            // else is a build-time graph failure
            GraphError::BadInput(msg) => NetworkError::BadInput(msg),
            other => NetworkError::Graph(other),
        }
    }
}

// ---------------------------------------------------------------------------
// BCNN
// ---------------------------------------------------------------------------

/// Packed + folded BCNN weights (see `model.export_inference_weights`),
/// compiled from the synthesized legacy 2-conv/2-fc graph.  The BCNN
/// forward is bit-identical to `model.bcnn_infer_ref` / `_pallas` in
/// Python (cross-checked against `expected_logits.bcnt` in the
/// integration tests).
pub struct BcnnNetwork {
    pub scheme: Scheme,
    compiled: CompiledNetwork,
}

impl BcnnNetwork {
    pub fn from_tensor_file(tf: &TensorFile, scheme: Scheme) -> Result<Self, NetworkError> {
        let compiled = CompiledNetwork::from_tensor_file(tf, &NetworkSpec::legacy_bcnn(scheme))?;
        Ok(Self { scheme, compiled })
    }

    pub fn load(path: impl AsRef<std::path::Path>, scheme: Scheme) -> Result<Self, NetworkError> {
        Self::from_tensor_file(&TensorFile::load(path)?, scheme)
    }

    /// The compiled plan executing this network.
    pub fn compiled(&self) -> &CompiledNetwork {
        &self.compiled
    }

    /// Unwrap into the compiled plan (backends keep only this).
    pub fn into_compiled(self) -> CompiledNetwork {
        self.compiled
    }

    /// Forward pass on one (96,96,3) image; returns logits + per-step
    /// layer times (the Nvidia-Visual-Profiler role in Table 2).
    pub fn forward(&self, x: &[f32]) -> ([f32; NUM_CLASSES], LayerTimings) {
        assert_eq!(x.len(), IMG_H * IMG_W * IMG_C);
        let (logits, times) =
            self.compiled.forward_timed(x).expect("payload length asserted above");
        (fixed_row(&logits), times)
    }

    /// Batched forward over `n` contiguous (96,96,3) images.
    ///
    /// Allocates a fresh [`PlanScratch`] per call; serving hot paths
    /// should hold a per-worker arena and call
    /// [`BcnnNetwork::infer_batch_with`] instead (bit-identical results).
    pub fn infer_batch(&self, images: &[f32]) -> Result<Vec<[f32; NUM_CLASSES]>, NetworkError> {
        self.infer_batch_with(images, &mut PlanScratch::new())
    }

    /// Batched forward through a reusable planned arena: one fused
    /// im2col+pack over the whole batch, one XNOR-GEMM per conv layer
    /// with M = batch × spatial positions, batched OR-pools, a batched
    /// packed fc1, and the per-image float tail — exactly the legacy
    /// pipeline, now driven by the compiled plan.  Malformed input is a
    /// recoverable `NetworkError::BadInput`, never a panic.
    pub fn infer_batch_with(
        &self,
        images: &[f32],
        scratch: &mut PlanScratch,
    ) -> Result<Vec<[f32; NUM_CLASSES]>, NetworkError> {
        self.compiled
            .infer_batch_with(images, scratch)
            .map(fixed_rows)
            .map_err(NetworkError::from)
    }

    /// argmax class index for one image.
    pub fn classify(&self, x: &[f32]) -> usize {
        let (logits, _) = self.forward(x);
        argmax(&logits)
    }
}

// ---------------------------------------------------------------------------
// Full-precision network
// ---------------------------------------------------------------------------

/// Full-precision baseline network (ReLU, biases), compiled from the
/// synthesized legacy conv-pool ×2 / fc ×3 graph.
pub struct FloatNetwork {
    compiled: CompiledNetwork,
}

impl FloatNetwork {
    pub fn from_tensor_file(tf: &TensorFile) -> Result<Self, NetworkError> {
        Ok(Self { compiled: CompiledNetwork::from_tensor_file(tf, &NetworkSpec::legacy_float())? })
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self, NetworkError> {
        Self::from_tensor_file(&TensorFile::load(path)?)
    }

    /// The compiled plan executing this network.
    pub fn compiled(&self) -> &CompiledNetwork {
        &self.compiled
    }

    /// Unwrap into the compiled plan (backends keep only this).
    pub fn into_compiled(self) -> CompiledNetwork {
        self.compiled
    }

    /// Forward pass on one (96,96,3) image; returns logits + layer times.
    pub fn forward(&self, x: &[f32]) -> ([f32; NUM_CLASSES], LayerTimings) {
        assert_eq!(x.len(), IMG_H * IMG_W * IMG_C);
        let (logits, times) =
            self.compiled.forward_timed(x).expect("payload length asserted above");
        (fixed_row(&logits), times)
    }

    /// Batched forward over `n` contiguous (96,96,3) images.  Allocates
    /// a fresh [`PlanScratch`] per call; hot paths should reuse one via
    /// [`FloatNetwork::infer_batch_with`].
    pub fn infer_batch(&self, images: &[f32]) -> Result<Vec<[f32; NUM_CLASSES]>, NetworkError> {
        self.infer_batch_with(images, &mut PlanScratch::new())
    }

    /// Batched forward through a reusable planned arena (bit-identical
    /// per image to `forward`; malformed input is a recoverable error).
    pub fn infer_batch_with(
        &self,
        images: &[f32],
        scratch: &mut PlanScratch,
    ) -> Result<Vec<[f32; NUM_CLASSES]>, NetworkError> {
        self.compiled
            .infer_batch_with(images, scratch)
            .map(fixed_rows)
            .map_err(NetworkError::from)
    }

    pub fn classify(&self, x: &[f32]) -> usize {
        let (logits, _) = self.forward(x);
        argmax(&logits)
    }
}

/// One legacy fixed-width logit row from the executor's flat output.
/// The legacy specs always compile to `NUM_CLASSES`-wide heads, so the
/// copy is exact.
fn fixed_row(flat: &[f32]) -> [f32; NUM_CLASSES] {
    let mut row = [0f32; NUM_CLASSES];
    row.copy_from_slice(flat);
    row
}

/// Chunk the executor's flat batch logits into legacy fixed rows.
fn fixed_rows(flat: Vec<f32>) -> Vec<[f32; NUM_CLASSES]> {
    flat.chunks_exact(NUM_CLASSES).map(fixed_row).collect()
}

/// Index of the maximum element (first wins ties, like jnp.argmax).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Sum per-layer timings into a total (helper for benches).
pub fn total_time(times: &LayerTimings) -> Duration {
    times.iter().map(|(_, d)| *d).sum()
}

/// Synthetic-weight builders shared by unit tests, integration tests,
/// and benches (random but internally consistent networks).  Compiled
/// unconditionally so integration tests and benches can use them without
/// a feature flag.
#[doc(hidden)]
pub mod tests_support {
    use super::*;
    use crate::bnn::graph::plan::WeightDType;
    use crate::bnn::packing;
    use crate::util::rng::Xoshiro256;
    use crate::util::tensorio::Tensor;

    /// Build a random-but-valid BCNN weight file for a scheme (the
    /// legacy container layout, byte-compatible with `aot.py` exports).
    pub fn synth_bcnn_tf(scheme: Scheme, seed: u64) -> TensorFile {
        let mut rng = Xoshiro256::new(seed);
        let c_in = scheme.input_channels();
        let d1 = K * K * c_in;
        let nw1 = packing::packed_width(d1, 32);
        let mut tf = TensorFile::new();
        // ±1 conv1 weights and their packed form (must be consistent!)
        let w1_pm1: Vec<f32> = (0..CONV1_OUT * d1).map(|_| rng.next_pm1()).collect();
        let mut w1_packed = Vec::new();
        for o in 0..CONV1_OUT {
            w1_packed.extend(packing::pack_pm1(&w1_pm1[o * d1..(o + 1) * d1], 32));
        }
        tf.insert("w1_pm1", Tensor::from_f32(vec![CONV1_OUT, d1], &w1_pm1));
        tf.insert("w1_packed", Tensor::from_u32(vec![CONV1_OUT, nw1], &w1_packed));
        tf.insert(
            "theta1",
            Tensor::from_f32(vec![CONV1_OUT], &(0..CONV1_OUT).map(|_| rng.next_normal_f32() * 5.0).collect::<Vec<_>>()),
        );
        tf.insert("flip1", Tensor::from_u32(vec![CONV1_OUT], &(0..CONV1_OUT).map(|_| (rng.next_u64() & 1) as u32).collect::<Vec<_>>()));
        tf.insert("w2_packed", Tensor::from_u32(vec![CONV2_OUT, K * K], &(0..CONV2_OUT * K * K).map(|_| rng.next_u32()).collect::<Vec<_>>()));
        tf.insert("theta2", Tensor::from_f32(vec![CONV2_OUT], &(0..CONV2_OUT).map(|_| rng.next_normal_f32() * 20.0).collect::<Vec<_>>()));
        tf.insert("flip2", Tensor::from_u32(vec![CONV2_OUT], &(0..CONV2_OUT).map(|_| (rng.next_u64() & 1) as u32).collect::<Vec<_>>()));
        tf.insert("wfc1_packed", Tensor::from_u32(vec![FC1_OUT, 576], &(0..FC1_OUT * 576).map(|_| rng.next_u32()).collect::<Vec<_>>()));
        tf.insert("theta3", Tensor::from_f32(vec![FC1_OUT], &(0..FC1_OUT).map(|_| rng.next_normal_f32() * 50.0).collect::<Vec<_>>()));
        tf.insert("flip3", Tensor::from_u32(vec![FC1_OUT], &(0..FC1_OUT).map(|_| (rng.next_u64() & 1) as u32).collect::<Vec<_>>()));
        tf.insert("wfc2", Tensor::from_f32(vec![FC2_OUT, FC1_OUT], &(0..FC2_OUT * FC1_OUT).map(|_| rng.next_normal_f32() * 0.1).collect::<Vec<_>>()));
        tf.insert("bfc2", Tensor::from_f32(vec![FC2_OUT], &[0.0; FC2_OUT]));
        tf.insert("wfc3", Tensor::from_f32(vec![NUM_CLASSES, FC2_OUT], &(0..NUM_CLASSES * FC2_OUT).map(|_| rng.next_normal_f32() * 0.1).collect::<Vec<_>>()));
        tf.insert("bfc3", Tensor::from_f32(vec![NUM_CLASSES], &[0.0; NUM_CLASSES]));
        match scheme {
            Scheme::Rgb => tf.insert("input_t", Tensor::from_f32(vec![3], &[-0.5, -0.5, -0.5])),
            Scheme::Gray => tf.insert("input_t", Tensor::from_f32(vec![1], &[-0.5])),
            _ => {}
        }
        tf
    }

    /// Random-but-consistent BCNN ready to run.
    pub fn synth_bcnn_network(scheme: Scheme, seed: u64) -> BcnnNetwork {
        BcnnNetwork::from_tensor_file(&synth_bcnn_tf(scheme, seed), scheme).unwrap()
    }

    /// Random float-network weight file.
    pub fn synth_float_tf(seed: u64) -> TensorFile {
        let mut rng = Xoshiro256::new(seed);
        let mut tf = TensorFile::new();
        tf.insert("w1", Tensor::from_f32(vec![CONV1_OUT, K * K * 3], &(0..CONV1_OUT * K * K * 3).map(|_| rng.next_normal_f32() * 0.1).collect::<Vec<_>>()));
        tf.insert("b1", Tensor::from_f32(vec![CONV1_OUT], &[0.0; CONV1_OUT]));
        tf.insert("w2", Tensor::from_f32(vec![CONV2_OUT, K * K * CONV1_OUT], &(0..CONV2_OUT * K * K * CONV1_OUT).map(|_| rng.next_normal_f32() * 0.05).collect::<Vec<_>>()));
        tf.insert("b2", Tensor::from_f32(vec![CONV2_OUT], &[0.0; CONV2_OUT]));
        tf.insert("wfc1", Tensor::from_f32(vec![FC1_OUT, 24 * 24 * CONV2_OUT], &(0..FC1_OUT * 24 * 24 * CONV2_OUT).map(|_| rng.next_normal_f32() * 0.01).collect::<Vec<_>>()));
        tf.insert("bfc1", Tensor::from_f32(vec![FC1_OUT], &[0.0; FC1_OUT]));
        tf.insert("wfc2", Tensor::from_f32(vec![FC2_OUT, FC1_OUT], &(0..FC2_OUT * FC1_OUT).map(|_| rng.next_normal_f32() * 0.1).collect::<Vec<_>>()));
        tf.insert("bfc2", Tensor::from_f32(vec![FC2_OUT], &[0.0; FC2_OUT]));
        tf.insert("wfc3", Tensor::from_f32(vec![NUM_CLASSES, FC2_OUT], &(0..NUM_CLASSES * FC2_OUT).map(|_| rng.next_normal_f32() * 0.1).collect::<Vec<_>>()));
        tf.insert("bfc3", Tensor::from_f32(vec![NUM_CLASSES], &[0.0; NUM_CLASSES]));
        tf
    }

    pub fn synth_float_network(seed: u64) -> FloatNetwork {
        FloatNetwork::from_tensor_file(&synth_float_tf(seed)).unwrap()
    }

    /// Random image in [0,1].
    pub fn synth_image(seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256::new(seed);
        (0..IMG_H * IMG_W * IMG_C).map(|_| rng.next_f32()).collect()
    }

    /// Build a random-but-consistent weight container for an ARBITRARY
    /// graph spec: the plan declares every tensor it will bind (name,
    /// dtype, shape), so the generator just walks that list.  This is
    /// how tests and manifests exercise non-legacy topologies (e.g. the
    /// 3-conv acceptance network) without a Python export.
    pub fn synth_tf_for_spec(spec: &NetworkSpec, seed: u64) -> TensorFile {
        let plan = spec.plan().expect("spec must compile");
        let mut rng = Xoshiro256::new(seed);
        let mut tf = TensorFile::new();
        for req in &plan.weights {
            let n = req.elements();
            match req.dtype {
                WeightDType::F32 => {
                    let values: Vec<f32> = if req.name == "input_t" {
                        vec![-0.5; n]
                    } else if req.name.starts_with('b') {
                        vec![0.0; n] // biases start at zero, like aot.py
                    } else if req.name.starts_with("theta") {
                        (0..n).map(|_| rng.next_normal_f32() * 10.0).collect()
                    } else {
                        (0..n).map(|_| rng.next_normal_f32() * 0.1).collect()
                    };
                    tf.insert(&req.name, Tensor::from_f32(req.shape.clone(), &values));
                }
                WeightDType::U32 => {
                    let values: Vec<u32> = if req.name.starts_with("flip") {
                        (0..n).map(|_| (rng.next_u64() & 1) as u32).collect()
                    } else {
                        (0..n).map(|_| rng.next_u32()).collect()
                    };
                    tf.insert(&req.name, Tensor::from_u32(req.shape.clone(), &values));
                }
            }
        }
        tf
    }
}

#[cfg(test)]
mod tests {
    use super::tests_support::*;
    use super::*;

    #[test]
    fn bcnn_forward_all_schemes_shapes() {
        for scheme in Scheme::ALL {
            let tf = synth_bcnn_tf(scheme, 42);
            let net = BcnnNetwork::from_tensor_file(&tf, scheme).unwrap();
            let (logits, times) = net.forward(&synth_image(1));
            assert!(logits.iter().all(|v| v.is_finite()), "{scheme:?}: finite logits");
            assert!(times.len() >= 9, "{scheme:?}: all layers timed");
        }
    }

    #[test]
    fn bcnn_forward_deterministic() {
        let tf = synth_bcnn_tf(Scheme::Rgb, 7);
        let net = BcnnNetwork::from_tensor_file(&tf, Scheme::Rgb).unwrap();
        let x = synth_image(2);
        let (a, _) = net.forward(&x);
        let (b, _) = net.forward(&x);
        assert_eq!(a, b);
    }

    #[test]
    fn float_network_roundtrip() {
        let net = synth_float_network(3);
        let (logits, times) = net.forward(&synth_image(4));
        assert!(logits.iter().all(|v| v.is_finite()));
        assert!(times.iter().any(|(n, _)| n == "gemm2"));
    }

    #[test]
    fn missing_tensor_is_reported() {
        let tf = TensorFile::new();
        let err = BcnnNetwork::from_tensor_file(&tf, Scheme::Rgb).unwrap_err();
        assert!(matches!(err, NetworkError::Graph(_)), "{err}");
    }

    #[test]
    fn argmax_first_wins_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 0.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn classify_in_range() {
        let tf = synth_bcnn_tf(Scheme::Lbp, 9);
        let net = BcnnNetwork::from_tensor_file(&tf, Scheme::Lbp).unwrap();
        assert!(net.classify(&synth_image(5)) < NUM_CLASSES);
    }

    #[test]
    fn bcnn_infer_batch_bit_identical_to_singles() {
        use crate::util::prop::{self, ensure_eq};
        // Every scheme (packed conv1 and the float-conv1 None scheme),
        // random batch sizes: batched logits must be BIT-identical to n
        // independent single-image forwards.
        let nets: Vec<BcnnNetwork> = Scheme::ALL
            .iter()
            .map(|&s| BcnnNetwork::from_tensor_file(&synth_bcnn_tf(s, 33), s).unwrap())
            .collect();
        prop::check(6, |g| {
            let net = g.pick(&nets);
            let n = g.usize_in(1, 5);
            let seed = g.u64();
            let mut images = Vec::with_capacity(n * IMG_H * IMG_W * IMG_C);
            for i in 0..n {
                images.extend(synth_image(seed.wrapping_add(i as u64)));
            }
            let batched = net.infer_batch(&images).unwrap();
            ensure_eq(batched.len(), n, "one logit row per image")?;
            for i in 0..n {
                let x = &images[i * IMG_H * IMG_W * IMG_C..(i + 1) * IMG_H * IMG_W * IMG_C];
                let (single, _) = net.forward(x);
                ensure_eq(batched[i], single, "batched == single (bitwise)")?;
            }
            Ok(())
        });
    }

    #[test]
    fn float_infer_batch_bit_identical_to_singles() {
        use crate::util::prop::{self, ensure_eq};
        let net = synth_float_network(44);
        prop::check(4, |g| {
            let n = g.usize_in(1, 4);
            let seed = g.u64();
            let mut images = Vec::with_capacity(n * IMG_H * IMG_W * IMG_C);
            for i in 0..n {
                images.extend(synth_image(seed.wrapping_add(i as u64)));
            }
            let batched = net.infer_batch(&images).unwrap();
            for i in 0..n {
                let x = &images[i * IMG_H * IMG_W * IMG_C..(i + 1) * IMG_H * IMG_W * IMG_C];
                let (single, _) = net.forward(x);
                ensure_eq(batched[i], single, "float batched == single (bitwise)")?;
            }
            Ok(())
        });
    }

    #[test]
    fn infer_batch_rejects_ragged_and_accepts_empty() {
        let net = synth_bcnn_network(Scheme::Rgb, 8);
        assert!(matches!(net.infer_batch(&[0.0; 100]), Err(NetworkError::BadInput(_))));
        assert!(net.infer_batch(&[]).unwrap().is_empty());
        let fnet = synth_float_network(8);
        assert!(matches!(fnet.infer_batch(&[0.0; 7]), Err(NetworkError::BadInput(_))));
    }

    #[test]
    fn synth_tf_for_spec_binds_any_compiling_spec() {
        // the generic generator must satisfy the legacy plans too
        for scheme in Scheme::ALL {
            let spec = NetworkSpec::legacy_bcnn(scheme);
            let tf = synth_tf_for_spec(&spec, 60);
            assert!(CompiledNetwork::from_tensor_file(&tf, &spec).is_ok(), "{scheme:?}");
        }
        let spec = NetworkSpec::legacy_float();
        let tf = synth_tf_for_spec(&spec, 61);
        assert!(CompiledNetwork::from_tensor_file(&tf, &spec).is_ok());
    }
}
