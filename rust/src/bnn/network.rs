//! Full inference networks assembled from the layer kernels, with
//! per-layer timers (the Nvidia-Visual-Profiler role in Table 2).
//!
//! Loads the weight containers written by `python/compile/aot.py`:
//! `weights_float.bcnt` and `weights_bcnn_<scheme>.bcnt`.  The BCNN
//! forward is bit-identical to `model.bcnn_infer_ref` / `_pallas` in
//! Python (cross-checked against `expected_logits.bcnt` in the
//! integration tests).

use std::time::{Duration, Instant};

use crate::bnn::scratch::ForwardScratch;
use crate::bnn::{bgemm, fc, float_ops, im2col, maxpool, packing};
use crate::input::binarize::{self, Scheme};
use crate::util::tensorio::{TensorFile, TensorIoError};

pub const IMG_H: usize = 96;
pub const IMG_W: usize = 96;
pub const IMG_C: usize = 3;
pub const K: usize = 5;
pub const CONV1_OUT: usize = 32;
pub const CONV2_OUT: usize = 32;
pub const FC1_OUT: usize = 100;
pub const FC2_OUT: usize = 100;
pub const NUM_CLASSES: usize = 4;
pub const CLASSES: [&str; 4] = ["bus", "normal", "truck", "van"];

/// Named per-layer wall times for one forward pass.
pub type LayerTimings = Vec<(&'static str, Duration)>;

#[derive(Debug)]
pub enum NetworkError {
    Tensor(TensorIoError),
    Shape { name: &'static str, got: usize, want: usize },
    /// Recoverable bad-input error on the inference path (batched entry
    /// points return this instead of asserting).
    BadInput(String),
}

crate::error_enum_impls!(NetworkError {
    NetworkError::Tensor(e) => ("{e}"),
    NetworkError::Shape { name, got, want } =>
        ("network: tensor {name} has {got} elements, expected {want}"),
    NetworkError::BadInput(msg) => ("network: {msg}"),
}
source { NetworkError::Tensor(e) => e }
from { TensorIoError => NetworkError::Tensor });

fn expect_len(name: &'static str, v: &[impl Sized], want: usize) -> Result<(), NetworkError> {
    if v.len() == want {
        Ok(())
    } else {
        Err(NetworkError::Shape { name, got: v.len(), want })
    }
}

// ---------------------------------------------------------------------------
// BCNN
// ---------------------------------------------------------------------------

/// Packed + folded BCNN weights (see `model.export_inference_weights`).
pub struct BcnnNetwork {
    pub scheme: Scheme,
    w1_pm1: Vec<f32>,    // (32, K*K*Cin) — used by Scheme::None
    w1_packed: Vec<u32>, // (32, NW1)
    w1_64: Vec<u64>,     // w1_packed pre-widened to u64 lanes (load-time)
    nw1: usize,
    d1: usize,
    theta1: Vec<f32>,
    flip1: Vec<u32>,
    w2_packed: Vec<u32>, // (32, K*K) channel-packed
    w2_64: Vec<u64>,     // w2_packed pre-widened to u64 lanes (load-time)
    theta2: Vec<f32>,
    flip2: Vec<u32>,
    wfc1_packed: Vec<u32>, // (100, 576)
    theta3: Vec<f32>,
    flip3: Vec<u32>,
    wfc2: Vec<f32>,
    bfc2: Vec<f32>,
    wfc3: Vec<f32>,
    bfc3: Vec<f32>,
    input_t: Vec<f32>, // (3,) rgb / (1,) gray / empty otherwise
}

impl BcnnNetwork {
    pub fn from_tensor_file(tf: &TensorFile, scheme: Scheme) -> Result<Self, NetworkError> {
        let c_in = scheme.input_channels();
        let d1 = K * K * c_in;
        let nw1 = packing::packed_width(d1, 32);
        let mut net = Self {
            scheme,
            w1_pm1: tf.f32("w1_pm1")?,
            w1_packed: tf.u32("w1_packed")?,
            w1_64: Vec::new(),
            nw1,
            d1,
            theta1: tf.f32("theta1")?,
            flip1: tf.u32("flip1")?,
            w2_packed: tf.u32("w2_packed")?,
            w2_64: Vec::new(),
            theta2: tf.f32("theta2")?,
            flip2: tf.u32("flip2")?,
            wfc1_packed: tf.u32("wfc1_packed")?,
            theta3: tf.f32("theta3")?,
            flip3: tf.u32("flip3")?,
            wfc2: tf.f32("wfc2")?,
            bfc2: tf.f32("bfc2")?,
            wfc3: tf.f32("wfc3")?,
            bfc3: tf.f32("bfc3")?,
            input_t: if tf.contains("input_t") { tf.f32("input_t")? } else { Vec::new() },
        };
        expect_len("w1_pm1", &net.w1_pm1, CONV1_OUT * d1)?;
        expect_len("w1_packed", &net.w1_packed, CONV1_OUT * nw1)?;
        expect_len("theta1", &net.theta1, CONV1_OUT)?;
        expect_len("w2_packed", &net.w2_packed, CONV2_OUT * K * K)?;
        expect_len("wfc1_packed", &net.wfc1_packed, FC1_OUT * 24 * 24)?;
        expect_len("wfc2", &net.wfc2, FC2_OUT * FC1_OUT)?;
        expect_len("wfc3", &net.wfc3, NUM_CLASSES * FC2_OUT)?;
        // Pre-widen the packed conv weights once (after the length checks)
        // so the scratch-arena forward path never widens per call.
        net.w1_64 = bgemm::widen_weights(&net.w1_packed, CONV1_OUT, nw1);
        net.w2_64 = bgemm::widen_weights(&net.w2_packed, CONV2_OUT, K * K);
        Ok(net)
    }

    pub fn load(path: impl AsRef<std::path::Path>, scheme: Scheme) -> Result<Self, NetworkError> {
        Self::from_tensor_file(&TensorFile::load(path)?, scheme)
    }

    /// Apply the input-binarization scheme (Section 2.3).
    pub fn binarize_input(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; x.len() / IMG_C * self.scheme.input_channels()];
        // only the LBP scheme reads the grayscale scratch
        let mut gray =
            if self.scheme == Scheme::Lbp { vec![0f32; IMG_H * IMG_W] } else { Vec::new() };
        self.binarize_input_into(x, &mut gray, &mut out);
        out
    }

    /// `binarize_input` into caller-provided buffers: `gray` is the LBP
    /// grayscale scratch (len `IMG_H * IMG_W`), `out` is sized for the
    /// scheme's channel count.  Both are fully overwritten.
    pub fn binarize_input_into(&self, x: &[f32], gray: &mut [f32], out: &mut [f32]) {
        match self.scheme {
            Scheme::None => out.copy_from_slice(x),
            Scheme::Rgb => {
                let t = [self.input_t[0], self.input_t[1], self.input_t[2]];
                binarize::threshold_rgb_into(x, &t, out)
            }
            Scheme::Gray => binarize::threshold_gray_into(x, self.input_t[0], out),
            Scheme::Lbp => binarize::lbp_into(x, IMG_H, IMG_W, gray, out),
        }
    }

    /// Threshold integer counts and channel-pack 32 channels per word.
    fn threshold_pack(counts: &[i32], theta: &[f32], flip: &[u32], pixels: usize) -> Vec<u32> {
        let mut out = Vec::new();
        Self::threshold_pack_into(counts, theta, flip, pixels, &mut out);
        out
    }

    /// `threshold_pack` into a caller-owned buffer (resized + fully
    /// re-initialized every call; capacity grows monotonically).
    fn threshold_pack_into(
        counts: &[i32],
        theta: &[f32],
        flip: &[u32],
        pixels: usize,
        out: &mut Vec<u32>,
    ) {
        let c = theta.len();
        debug_assert!(c <= 32);
        // resize without clear: every element of 0..pixels is assigned
        // below, so no pre-zeroing pass (or stale state) is possible
        out.resize(pixels, 0);
        for px in 0..pixels {
            let row = &counts[px * c..(px + 1) * c];
            let mut word = 0u32;
            for ch in 0..c {
                word |= packing::threshold_bit(row[ch] as f32, theta[ch], flip[ch]) << (31 - ch);
            }
            out[px] = word;
        }
    }

    /// Same for float counts (Scheme::None conv1 output).
    fn threshold_pack_f32(counts: &[f32], theta: &[f32], flip: &[u32], pixels: usize) -> Vec<u32> {
        let mut out = Vec::new();
        Self::threshold_pack_f32_into(counts, theta, flip, pixels, &mut out);
        out
    }

    /// `threshold_pack_f32` into a caller-owned buffer.
    fn threshold_pack_f32_into(
        counts: &[f32],
        theta: &[f32],
        flip: &[u32],
        pixels: usize,
        out: &mut Vec<u32>,
    ) {
        let c = theta.len();
        // resize without clear: fully overwritten below
        out.resize(pixels, 0);
        for px in 0..pixels {
            let row = &counts[px * c..(px + 1) * c];
            let mut word = 0u32;
            for ch in 0..c {
                word |= packing::threshold_bit(row[ch], theta[ch], flip[ch]) << (31 - ch);
            }
            out[px] = word;
        }
    }

    /// Forward pass on one (96,96,3) image; returns logits + layer times.
    pub fn forward(&self, x: &[f32]) -> ([f32; NUM_CLASSES], LayerTimings) {
        assert_eq!(x.len(), IMG_H * IMG_W * IMG_C);
        let mut times: LayerTimings = Vec::with_capacity(12);
        let mut mark = Instant::now();
        let lap = |name: &'static str, t: &mut Instant, times: &mut LayerTimings| {
            let now = Instant::now();
            times.push((name, now - *t));
            *t = now;
        };

        // --- input binarization -----------------------------------------
        let xb = self.binarize_input(x);
        lap("input_binarize", &mut mark, &mut times);

        // --- conv1 -------------------------------------------------------
        let words1: Vec<u32>;
        if self.scheme == Scheme::None {
            let cols = im2col::im2col_float(&xb, IMG_H, IMG_W, IMG_C, K);
            lap("im2col1", &mut mark, &mut times);
            let counts =
                float_ops::gemm_blocked(&cols, &self.w1_pm1, IMG_H * IMG_W, CONV1_OUT, self.d1);
            lap("gemm1", &mut mark, &mut times);
            words1 =
                Self::threshold_pack_f32(&counts, &self.theta1, &self.flip1, IMG_H * IMG_W);
        } else {
            let c_in = self.scheme.input_channels();
            let cols = im2col::im2col_pack(&xb, IMG_H, IMG_W, c_in, K, 32);
            lap("im2col1", &mut mark, &mut times);
            let counts = bgemm::bgemm(
                &cols,
                &self.w1_packed,
                IMG_H * IMG_W,
                CONV1_OUT,
                self.nw1,
                self.d1,
            );
            lap("gemm1", &mut mark, &mut times);
            words1 = Self::threshold_pack(&counts, &self.theta1, &self.flip1, IMG_H * IMG_W);
        }
        lap("threshold_pack1", &mut mark, &mut times);
        let pooled1 = maxpool::orpool2x2(&words1, IMG_H, IMG_W, 1); // (48,48,1)
        lap("pool1", &mut mark, &mut times);

        // --- conv2 (channel-packed domain) --------------------------------
        let cols2 = im2col::im2col_words(&pooled1, 48, 48, 1, K); // (2304, 25)
        lap("im2col2", &mut mark, &mut times);
        let counts2 = bgemm::bgemm(
            &cols2,
            &self.w2_packed,
            48 * 48,
            CONV2_OUT,
            K * K,
            K * K * CONV1_OUT,
        );
        lap("gemm2", &mut mark, &mut times);
        let words2 = Self::threshold_pack(&counts2, &self.theta2, &self.flip2, 48 * 48);
        lap("threshold_pack2", &mut mark, &mut times);
        let pooled2 = maxpool::orpool2x2(&words2, 48, 48, 1); // (24,24,1) = 576 words
        lap("pool2", &mut mark, &mut times);

        // --- fc1 (packed) --------------------------------------------------
        let counts3 = fc::fc_packed(
            &pooled2,
            &self.wfc1_packed,
            FC1_OUT,
            24 * 24,
            24 * 24 * CONV2_OUT,
        );
        lap("fc1", &mut mark, &mut times);

        // --- float CPU tail -------------------------------------------------
        let logits = self.float_tail(&counts3);
        lap("fc_tail", &mut mark, &mut times);
        (logits, times)
    }

    /// The float CPU tail after fc1: threshold to ±1, fc2 + sign, fc3.
    /// Shared verbatim by the single-image and batched paths so they are
    /// bit-identical.
    fn float_tail(&self, counts3: &[i32]) -> [f32; NUM_CLASSES] {
        self.float_tail_into(counts3, &mut Vec::new(), &mut Vec::new())
    }

    /// `float_tail` with caller-owned hidden-layer buffers (the scratch
    /// arena's `h_a`/`h_b`); every buffer is cleared + rewritten, and the
    /// accumulation order matches the allocating path exactly.
    fn float_tail_into(
        &self,
        counts3: &[i32],
        h3: &mut Vec<f32>,
        h4: &mut Vec<f32>,
    ) -> [f32; NUM_CLASSES] {
        h3.clear();
        h3.resize(FC1_OUT, 0.0);
        for i in 0..FC1_OUT {
            h3[i] = if packing::threshold_bit(counts3[i] as f32, self.theta3[i], self.flip3[i])
                == 1
            {
                1.0
            } else {
                -1.0
            };
        }
        h4.clear();
        h4.resize(FC2_OUT, 0.0);
        fc::fc_float_bias_into(h3, &self.wfc2, &self.bfc2, FC2_OUT, FC1_OUT, h4);
        for v in h4.iter_mut() {
            *v = packing::sign_pm1(*v);
        }
        let mut logits = [0f32; NUM_CLASSES];
        fc::fc_float_bias_into(h4, &self.wfc3, &self.bfc3, NUM_CLASSES, FC2_OUT, &mut logits);
        logits
    }

    /// Batched forward over `n` contiguous (96,96,3) images.
    ///
    /// Allocates a fresh [`ForwardScratch`] per call; serving hot paths
    /// should hold a per-worker scratch and call
    /// [`BcnnNetwork::infer_batch_with`] instead (bit-identical results —
    /// property-tested in `bnn::scratch`).
    pub fn infer_batch(&self, images: &[f32]) -> Result<Vec<[f32; NUM_CLASSES]>, NetworkError> {
        self.infer_batch_with(images, &mut ForwardScratch::new())
    }

    /// Batched forward through a reusable scratch arena.
    ///
    /// This is the tentpole batching path: one fused im2col+pack over the
    /// whole batch, one `bgemm` call per conv layer with
    /// M = batch × spatial positions (the packed weight matrix is widened
    /// once at load time and its rows stay L1-hot across every image),
    /// batched OR-pools, and a batched packed fc1.  Per image the
    /// arithmetic is exactly the single-image pipeline, so logits are
    /// bit-identical to `forward`.
    ///
    /// Every intermediate tensor lives in `scratch`; after the arena has
    /// grown to the largest batch seen, steady-state calls perform no
    /// intermediate-tensor allocation.  Stages with disjoint lifetimes
    /// share buffers (noted inline); every `_into` kernel assigns every
    /// element of its output range or pre-fills it with its identity
    /// first, so reuse cannot leak state.
    ///
    /// Malformed input is a recoverable `NetworkError::BadInput`, never a
    /// panic — this is the serving-reachable entry point.
    pub fn infer_batch_with(
        &self,
        images: &[f32],
        scratch: &mut ForwardScratch,
    ) -> Result<Vec<[f32; NUM_CLASSES]>, NetworkError> {
        const IMG: usize = IMG_H * IMG_W * IMG_C;
        if images.len() % IMG != 0 {
            return Err(NetworkError::BadInput(format!(
                "batch payload {} is not a multiple of {IMG}",
                images.len()
            )));
        }
        let n = images.len() / IMG;
        if n == 0 {
            return Ok(Vec::new());
        }
        let px = IMG_H * IMG_W;
        let bad = |e: maxpool::PoolError| NetworkError::BadInput(e.to_string());
        let ForwardScratch { xb, gray, cols_p, counts, words, pooled, cols_f, act_f, .. } =
            &mut *scratch;

        // --- conv1 over the whole batch ----------------------------------
        // (`words` carries conv1's threshold-packed activations)
        if self.scheme == Scheme::None {
            // Scheme::None consumes the raw input directly — no binarize
            // pass, no intermediate copy of the batch.
            im2col::im2col_float_batch_into(images, n, IMG_H, IMG_W, IMG_C, K, cols_f);
            // resize without clear: the GEMM assigns every element
            act_f.resize(n * px * CONV1_OUT, 0.0);
            float_ops::gemm_blocked_into(cols_f, &self.w1_pm1, n * px, CONV1_OUT, self.d1, act_f);
            Self::threshold_pack_f32_into(act_f, &self.theta1, &self.flip1, n * px, words);
        } else {
            // binarize per image, concatenated (±1 domain); each per-image
            // binarize fully overwrites its xb slice
            let c_in = self.scheme.input_channels();
            xb.resize(n * px * c_in, 0.0);
            if self.scheme == Scheme::Lbp {
                gray.resize(px, 0.0); // only LBP reads the gray scratch
            }
            for i in 0..n {
                self.binarize_input_into(
                    &images[i * IMG..(i + 1) * IMG],
                    gray,
                    &mut xb[i * px * c_in..(i + 1) * px * c_in],
                );
            }
            im2col::im2col_pack_batch_into(xb, n, IMG_H, IMG_W, c_in, K, 32, cols_p);
            counts.resize(n * px * CONV1_OUT, 0); // bgemm assigns every element
            bgemm::bgemm_prewidened(cols_p, &self.w1_64, n * px, CONV1_OUT, self.nw1, self.d1, counts);
            Self::threshold_pack_into(counts, &self.theta1, &self.flip1, n * px, words);
        }
        maxpool::orpool2x2_batch_into(words, n, IMG_H, IMG_W, 1, pooled).map_err(bad)?;

        // counts/words/pooled peak at conv1/pool1 and shrink from here on;
        // sample for the decay window before conv2 resizes them (cols_p
        // peaks at conv2's gather and is caught by end_batch's sample)
        scratch.note_batch_peaks();
        let ForwardScratch { cols_p, counts, words, pooled, h_a, h_b, .. } = &mut *scratch;

        // --- conv2 over the whole batch ----------------------------------
        // conv1's patch rows (`cols_p`) and counts are dead once `words`
        // was packed, so both buffers are reused for conv2.
        im2col::im2col_words_batch_into(pooled, n, 48, 48, 1, K, cols_p);
        counts.resize(n * 48 * 48 * CONV2_OUT, 0); // bgemm assigns every element
        bgemm::bgemm_prewidened(
            cols_p,
            &self.w2_64,
            n * 48 * 48,
            CONV2_OUT,
            K * K,
            K * K * CONV1_OUT,
            counts,
        );
        Self::threshold_pack_into(counts, &self.theta2, &self.flip2, n * 48 * 48, words);
        // pool1's output was consumed by the word gather above — reuse it
        maxpool::orpool2x2_batch_into(words, n, 48, 48, 1, pooled).map_err(bad)?;

        // --- fc1 (batched packed) + per-image float tail ------------------
        // conv2's counts are dead once `words` was packed; fc1's counts
        // land in the same buffer.
        fc::fc_packed_batch_into(
            pooled,
            &self.wfc1_packed,
            n,
            FC1_OUT,
            24 * 24,
            24 * 24 * CONV2_OUT,
            counts,
        );
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            out.push(self.float_tail_into(&counts[i * FC1_OUT..(i + 1) * FC1_OUT], h_a, h_b));
        }
        scratch.end_batch(); // decay bookkeeping (no-op unless enabled)
        Ok(out)
    }

    /// argmax class index for one image.
    pub fn classify(&self, x: &[f32]) -> usize {
        let (logits, _) = self.forward(x);
        argmax(&logits)
    }
}

// ---------------------------------------------------------------------------
// Full-precision network
// ---------------------------------------------------------------------------

/// Full-precision baseline network (ReLU, biases).
pub struct FloatNetwork {
    w1: Vec<f32>, // (32, K*K*3)
    b1: Vec<f32>,
    w2: Vec<f32>, // (32, K*K*32)
    b2: Vec<f32>,
    wfc1: Vec<f32>, // (100, 18432)
    bfc1: Vec<f32>,
    wfc2: Vec<f32>,
    bfc2: Vec<f32>,
    wfc3: Vec<f32>,
    bfc3: Vec<f32>,
}

impl FloatNetwork {
    pub fn from_tensor_file(tf: &TensorFile) -> Result<Self, NetworkError> {
        let net = Self {
            w1: tf.f32("w1")?,
            b1: tf.f32("b1")?,
            w2: tf.f32("w2")?,
            b2: tf.f32("b2")?,
            wfc1: tf.f32("wfc1")?,
            bfc1: tf.f32("bfc1")?,
            wfc2: tf.f32("wfc2")?,
            bfc2: tf.f32("bfc2")?,
            wfc3: tf.f32("wfc3")?,
            bfc3: tf.f32("bfc3")?,
        };
        expect_len("w1", &net.w1, CONV1_OUT * K * K * IMG_C)?;
        expect_len("w2", &net.w2, CONV2_OUT * K * K * CONV1_OUT)?;
        expect_len("wfc1", &net.wfc1, FC1_OUT * 24 * 24 * CONV2_OUT)?;
        Ok(net)
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self, NetworkError> {
        Self::from_tensor_file(&TensorFile::load(path)?)
    }

    /// Forward pass on one (96,96,3) image; returns logits + layer times.
    pub fn forward(&self, x: &[f32]) -> ([f32; NUM_CLASSES], LayerTimings) {
        assert_eq!(x.len(), IMG_H * IMG_W * IMG_C);
        let mut times: LayerTimings = Vec::with_capacity(12);
        let mut mark = Instant::now();
        let lap = |name: &'static str, t: &mut Instant, times: &mut LayerTimings| {
            let now = Instant::now();
            times.push((name, now - *t));
            *t = now;
        };

        let cols1 = im2col::im2col_float(x, IMG_H, IMG_W, IMG_C, K);
        lap("im2col1", &mut mark, &mut times);
        let mut a1 =
            float_ops::gemm_blocked(&cols1, &self.w1, IMG_H * IMG_W, CONV1_OUT, K * K * IMG_C);
        lap("gemm1", &mut mark, &mut times);
        float_ops::add_bias(&mut a1, &self.b1);
        float_ops::relu(&mut a1);
        lap("relu1", &mut mark, &mut times);
        let p1 = maxpool::maxpool2x2(&a1, IMG_H, IMG_W, CONV1_OUT); // (48,48,32)
        lap("pool1", &mut mark, &mut times);

        let cols2 = im2col::im2col_float(&p1, 48, 48, CONV1_OUT, K);
        lap("im2col2", &mut mark, &mut times);
        let mut a2 =
            float_ops::gemm_blocked(&cols2, &self.w2, 48 * 48, CONV2_OUT, K * K * CONV1_OUT);
        lap("gemm2", &mut mark, &mut times);
        float_ops::add_bias(&mut a2, &self.b2);
        float_ops::relu(&mut a2);
        lap("relu2", &mut mark, &mut times);
        let p2 = maxpool::maxpool2x2(&a2, 48, 48, CONV2_OUT); // (24,24,32)
        lap("pool2", &mut mark, &mut times);

        let mut h1 = fc::fc_float_bias(&p2, &self.wfc1, &self.bfc1, FC1_OUT, 24 * 24 * CONV2_OUT);
        float_ops::relu(&mut h1);
        lap("fc1", &mut mark, &mut times);
        let mut h2 = fc::fc_float_bias(&h1, &self.wfc2, &self.bfc2, FC2_OUT, FC1_OUT);
        float_ops::relu(&mut h2);
        let logits_v = fc::fc_float_bias(&h2, &self.wfc3, &self.bfc3, NUM_CLASSES, FC2_OUT);
        lap("fc_tail", &mut mark, &mut times);

        let mut logits = [0f32; NUM_CLASSES];
        logits.copy_from_slice(&logits_v);
        (logits, times)
    }

    /// Batched forward over `n` contiguous (96,96,3) images.  Allocates a
    /// fresh [`ForwardScratch`] per call; hot paths should reuse one via
    /// [`FloatNetwork::infer_batch_with`].
    pub fn infer_batch(&self, images: &[f32]) -> Result<Vec<[f32; NUM_CLASSES]>, NetworkError> {
        self.infer_batch_with(images, &mut ForwardScratch::new())
    }

    /// Batched forward through a reusable scratch arena: batched
    /// im2col + GEMM (M = batch × spatial) and batched max-pools, with a
    /// per-image FC tail.  Bit-identical per image to `forward` (every
    /// row of every GEMM is accumulated in the same order), and
    /// allocation-free once the arena has grown to the largest batch
    /// seen.  Malformed input is a recoverable error, never a panic.
    pub fn infer_batch_with(
        &self,
        images: &[f32],
        scratch: &mut ForwardScratch,
    ) -> Result<Vec<[f32; NUM_CLASSES]>, NetworkError> {
        const IMG: usize = IMG_H * IMG_W * IMG_C;
        if images.len() % IMG != 0 {
            return Err(NetworkError::BadInput(format!(
                "batch payload {} is not a multiple of {IMG}",
                images.len()
            )));
        }
        let n = images.len() / IMG;
        if n == 0 {
            return Ok(Vec::new());
        }
        let px = IMG_H * IMG_W;
        let bad = |e: maxpool::PoolError| NetworkError::BadInput(e.to_string());
        let ForwardScratch { cols_f, act_f, pool_f, .. } = &mut *scratch;

        im2col::im2col_float_batch_into(images, n, IMG_H, IMG_W, IMG_C, K, cols_f);
        act_f.resize(n * px * CONV1_OUT, 0.0); // the GEMM assigns every element
        float_ops::gemm_blocked_into(cols_f, &self.w1, n * px, CONV1_OUT, K * K * IMG_C, act_f);
        float_ops::add_bias(act_f, &self.b1);
        float_ops::relu(act_f);
        maxpool::maxpool2x2_batch_into(act_f, n, IMG_H, IMG_W, CONV1_OUT, pool_f).map_err(bad)?;

        // act_f/pool_f peak at conv1/pool1 and shrink from here on; sample
        // for the decay window before conv2 resizes them (cols_f peaks at
        // conv2's gather and is caught by end_batch's sample)
        scratch.note_batch_peaks();
        let ForwardScratch { cols_f, act_f, pool_f, h_a, h_b, .. } = &mut *scratch;

        // conv1's patch rows and activations are dead once pool1 is
        // written, so `cols_f` and `act_f` are reused for conv2
        im2col::im2col_float_batch_into(pool_f, n, 48, 48, CONV1_OUT, K, cols_f);
        act_f.resize(n * 48 * 48 * CONV2_OUT, 0.0); // the GEMM assigns every element
        float_ops::gemm_blocked_into(
            cols_f,
            &self.w2,
            n * 48 * 48,
            CONV2_OUT,
            K * K * CONV1_OUT,
            act_f,
        );
        float_ops::add_bias(act_f, &self.b2);
        float_ops::relu(act_f);
        // pool1 was consumed by conv2's im2col above — reuse its buffer
        maxpool::maxpool2x2_batch_into(act_f, n, 48, 48, CONV2_OUT, pool_f).map_err(bad)?;

        let feat = 24 * 24 * CONV2_OUT;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let f = &pool_f[i * feat..(i + 1) * feat];
            h_a.clear();
            h_a.resize(FC1_OUT, 0.0);
            fc::fc_float_bias_into(f, &self.wfc1, &self.bfc1, FC1_OUT, feat, h_a);
            float_ops::relu(h_a);
            h_b.clear();
            h_b.resize(FC2_OUT, 0.0);
            fc::fc_float_bias_into(h_a, &self.wfc2, &self.bfc2, FC2_OUT, FC1_OUT, h_b);
            float_ops::relu(h_b);
            let mut logits = [0f32; NUM_CLASSES];
            fc::fc_float_bias_into(h_b, &self.wfc3, &self.bfc3, NUM_CLASSES, FC2_OUT, &mut logits);
            out.push(logits);
        }
        scratch.end_batch(); // decay bookkeeping (no-op unless enabled)
        Ok(out)
    }

    pub fn classify(&self, x: &[f32]) -> usize {
        let (logits, _) = self.forward(x);
        argmax(&logits)
    }
}

/// Index of the maximum element (first wins ties, like jnp.argmax).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Sum per-layer timings into a map-like vec (helper for benches).
pub fn total_time(times: &LayerTimings) -> Duration {
    times.iter().map(|(_, d)| *d).sum()
}

/// Synthetic-weight builders shared by unit tests, integration tests,
/// and benches (random but internally consistent networks).  Compiled
/// unconditionally so integration tests and benches can use them without
/// a feature flag.
#[doc(hidden)]
pub mod tests_support {
    use super::*;
    use crate::util::rng::Xoshiro256;
    use crate::util::tensorio::Tensor;

    /// Build a random-but-valid BCNN weight file for a scheme.
    pub fn synth_bcnn_tf(scheme: Scheme, seed: u64) -> TensorFile {
        let mut rng = Xoshiro256::new(seed);
        let c_in = scheme.input_channels();
        let d1 = K * K * c_in;
        let nw1 = packing::packed_width(d1, 32);
        let mut tf = TensorFile::new();
        // ±1 conv1 weights and their packed form (must be consistent!)
        let w1_pm1: Vec<f32> = (0..CONV1_OUT * d1).map(|_| rng.next_pm1()).collect();
        let mut w1_packed = Vec::new();
        for o in 0..CONV1_OUT {
            w1_packed.extend(packing::pack_pm1(&w1_pm1[o * d1..(o + 1) * d1], 32));
        }
        tf.insert("w1_pm1", Tensor::from_f32(vec![CONV1_OUT, d1], &w1_pm1));
        tf.insert("w1_packed", Tensor::from_u32(vec![CONV1_OUT, nw1], &w1_packed));
        tf.insert(
            "theta1",
            Tensor::from_f32(vec![CONV1_OUT], &(0..CONV1_OUT).map(|_| rng.next_normal_f32() * 5.0).collect::<Vec<_>>()),
        );
        tf.insert("flip1", Tensor::from_u32(vec![CONV1_OUT], &(0..CONV1_OUT).map(|_| (rng.next_u64() & 1) as u32).collect::<Vec<_>>()));
        tf.insert("w2_packed", Tensor::from_u32(vec![CONV2_OUT, K * K], &(0..CONV2_OUT * K * K).map(|_| rng.next_u32()).collect::<Vec<_>>()));
        tf.insert("theta2", Tensor::from_f32(vec![CONV2_OUT], &(0..CONV2_OUT).map(|_| rng.next_normal_f32() * 20.0).collect::<Vec<_>>()));
        tf.insert("flip2", Tensor::from_u32(vec![CONV2_OUT], &(0..CONV2_OUT).map(|_| (rng.next_u64() & 1) as u32).collect::<Vec<_>>()));
        tf.insert("wfc1_packed", Tensor::from_u32(vec![FC1_OUT, 576], &(0..FC1_OUT * 576).map(|_| rng.next_u32()).collect::<Vec<_>>()));
        tf.insert("theta3", Tensor::from_f32(vec![FC1_OUT], &(0..FC1_OUT).map(|_| rng.next_normal_f32() * 50.0).collect::<Vec<_>>()));
        tf.insert("flip3", Tensor::from_u32(vec![FC1_OUT], &(0..FC1_OUT).map(|_| (rng.next_u64() & 1) as u32).collect::<Vec<_>>()));
        tf.insert("wfc2", Tensor::from_f32(vec![FC2_OUT, FC1_OUT], &(0..FC2_OUT * FC1_OUT).map(|_| rng.next_normal_f32() * 0.1).collect::<Vec<_>>()));
        tf.insert("bfc2", Tensor::from_f32(vec![FC2_OUT], &[0.0; FC2_OUT]));
        tf.insert("wfc3", Tensor::from_f32(vec![NUM_CLASSES, FC2_OUT], &(0..NUM_CLASSES * FC2_OUT).map(|_| rng.next_normal_f32() * 0.1).collect::<Vec<_>>()));
        tf.insert("bfc3", Tensor::from_f32(vec![NUM_CLASSES], &[0.0; NUM_CLASSES]));
        match scheme {
            Scheme::Rgb => tf.insert("input_t", Tensor::from_f32(vec![3], &[-0.5, -0.5, -0.5])),
            Scheme::Gray => tf.insert("input_t", Tensor::from_f32(vec![1], &[-0.5])),
            _ => {}
        }
        tf
    }

    /// Random-but-consistent BCNN ready to run.
    pub fn synth_bcnn_network(scheme: Scheme, seed: u64) -> BcnnNetwork {
        BcnnNetwork::from_tensor_file(&synth_bcnn_tf(scheme, seed), scheme).unwrap()
    }

    /// Random float-network weight file.
    pub fn synth_float_tf(seed: u64) -> TensorFile {
        let mut rng = Xoshiro256::new(seed);
        let mut tf = TensorFile::new();
        tf.insert("w1", Tensor::from_f32(vec![CONV1_OUT, K * K * 3], &(0..CONV1_OUT * K * K * 3).map(|_| rng.next_normal_f32() * 0.1).collect::<Vec<_>>()));
        tf.insert("b1", Tensor::from_f32(vec![CONV1_OUT], &[0.0; CONV1_OUT]));
        tf.insert("w2", Tensor::from_f32(vec![CONV2_OUT, K * K * CONV1_OUT], &(0..CONV2_OUT * K * K * CONV1_OUT).map(|_| rng.next_normal_f32() * 0.05).collect::<Vec<_>>()));
        tf.insert("b2", Tensor::from_f32(vec![CONV2_OUT], &[0.0; CONV2_OUT]));
        tf.insert("wfc1", Tensor::from_f32(vec![FC1_OUT, 24 * 24 * CONV2_OUT], &(0..FC1_OUT * 24 * 24 * CONV2_OUT).map(|_| rng.next_normal_f32() * 0.01).collect::<Vec<_>>()));
        tf.insert("bfc1", Tensor::from_f32(vec![FC1_OUT], &[0.0; FC1_OUT]));
        tf.insert("wfc2", Tensor::from_f32(vec![FC2_OUT, FC1_OUT], &(0..FC2_OUT * FC1_OUT).map(|_| rng.next_normal_f32() * 0.1).collect::<Vec<_>>()));
        tf.insert("bfc2", Tensor::from_f32(vec![FC2_OUT], &[0.0; FC2_OUT]));
        tf.insert("wfc3", Tensor::from_f32(vec![NUM_CLASSES, FC2_OUT], &(0..NUM_CLASSES * FC2_OUT).map(|_| rng.next_normal_f32() * 0.1).collect::<Vec<_>>()));
        tf.insert("bfc3", Tensor::from_f32(vec![NUM_CLASSES], &[0.0; NUM_CLASSES]));
        tf
    }

    pub fn synth_float_network(seed: u64) -> FloatNetwork {
        FloatNetwork::from_tensor_file(&synth_float_tf(seed)).unwrap()
    }

    /// Random image in [0,1].
    pub fn synth_image(seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256::new(seed);
        (0..IMG_H * IMG_W * IMG_C).map(|_| rng.next_f32()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::tests_support::*;
    use super::*;

    #[test]
    fn bcnn_forward_all_schemes_shapes() {
        for scheme in Scheme::ALL {
            let tf = synth_bcnn_tf(scheme, 42);
            let net = BcnnNetwork::from_tensor_file(&tf, scheme).unwrap();
            let (logits, times) = net.forward(&synth_image(1));
            assert!(logits.iter().all(|v| v.is_finite()), "{scheme:?}: finite logits");
            assert!(times.len() >= 9, "{scheme:?}: all layers timed");
        }
    }

    #[test]
    fn bcnn_forward_deterministic() {
        let tf = synth_bcnn_tf(Scheme::Rgb, 7);
        let net = BcnnNetwork::from_tensor_file(&tf, Scheme::Rgb).unwrap();
        let x = synth_image(2);
        let (a, _) = net.forward(&x);
        let (b, _) = net.forward(&x);
        assert_eq!(a, b);
    }

    #[test]
    fn float_network_roundtrip() {
        let net = synth_float_network(3);
        let (logits, times) = net.forward(&synth_image(4));
        assert!(logits.iter().all(|v| v.is_finite()));
        assert!(times.iter().any(|(n, _)| *n == "gemm2"));
    }

    #[test]
    fn missing_tensor_is_reported() {
        let tf = TensorFile::new();
        assert!(BcnnNetwork::from_tensor_file(&tf, Scheme::Rgb).is_err());
    }

    #[test]
    fn argmax_first_wins_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 0.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn classify_in_range() {
        let tf = synth_bcnn_tf(Scheme::Lbp, 9);
        let net = BcnnNetwork::from_tensor_file(&tf, Scheme::Lbp).unwrap();
        assert!(net.classify(&synth_image(5)) < NUM_CLASSES);
    }

    #[test]
    fn bcnn_infer_batch_bit_identical_to_singles() {
        use crate::util::prop::{self, ensure_eq};
        // Every scheme (packed conv1 and the float-conv1 None scheme),
        // random batch sizes: batched logits must be BIT-identical to n
        // independent single-image forwards.
        let nets: Vec<BcnnNetwork> = Scheme::ALL
            .iter()
            .map(|&s| BcnnNetwork::from_tensor_file(&synth_bcnn_tf(s, 33), s).unwrap())
            .collect();
        prop::check(6, |g| {
            let net = g.pick(&nets);
            let n = g.usize_in(1, 5);
            let seed = g.u64();
            let mut images = Vec::with_capacity(n * IMG_H * IMG_W * IMG_C);
            for i in 0..n {
                images.extend(synth_image(seed.wrapping_add(i as u64)));
            }
            let batched = net.infer_batch(&images).unwrap();
            ensure_eq(batched.len(), n, "one logit row per image")?;
            for i in 0..n {
                let x = &images[i * IMG_H * IMG_W * IMG_C..(i + 1) * IMG_H * IMG_W * IMG_C];
                let (single, _) = net.forward(x);
                ensure_eq(batched[i], single, "batched == single (bitwise)")?;
            }
            Ok(())
        });
    }

    #[test]
    fn float_infer_batch_bit_identical_to_singles() {
        use crate::util::prop::{self, ensure_eq};
        let net = synth_float_network(44);
        prop::check(4, |g| {
            let n = g.usize_in(1, 4);
            let seed = g.u64();
            let mut images = Vec::with_capacity(n * IMG_H * IMG_W * IMG_C);
            for i in 0..n {
                images.extend(synth_image(seed.wrapping_add(i as u64)));
            }
            let batched = net.infer_batch(&images).unwrap();
            for i in 0..n {
                let x = &images[i * IMG_H * IMG_W * IMG_C..(i + 1) * IMG_H * IMG_W * IMG_C];
                let (single, _) = net.forward(x);
                ensure_eq(batched[i], single, "float batched == single (bitwise)")?;
            }
            Ok(())
        });
    }

    #[test]
    fn infer_batch_rejects_ragged_and_accepts_empty() {
        let net = synth_bcnn_network(Scheme::Rgb, 8);
        assert!(matches!(net.infer_batch(&[0.0; 100]), Err(NetworkError::BadInput(_))));
        assert!(net.infer_batch(&[]).unwrap().is_empty());
        let fnet = synth_float_network(8);
        assert!(matches!(fnet.infer_batch(&[0.0; 7]), Err(NetworkError::BadInput(_))));
    }
}
