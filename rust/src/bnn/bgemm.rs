//! Packed XNOR-popcount GEMM (paper Eq. 4 + Section 3.1) — the binarized
//! replacement for the FMA GEMM of explicit-GEMM convolution.
//!
//! `a` is (M, KW) packed patch rows, `wt` is (N, KW) packed weight rows
//! (one per output channel); output is (M, N) i32 counts, row-major.
//!
//! The CUDA kernel tiles both operands through shared memory with one
//! output element per thread.  The CPU translation keeps the same
//! blocking idea (an A-row stays register/L1-hot across all N weight
//! rows) and widens the popcount to u64: both operands are repacked once
//! into padded u64 rows, so the hot loop is a branch-free
//! xor+popcount+add over `ceil(KW/2)` u64 lanes — no per-pair slicing or
//! alignment checks (which dominated the first, naive version; see
//! EXPERIMENTS.md §Perf).

/// u64 lanes per row for a KW-word operand.
#[inline]
pub(crate) fn lanes(kw: usize) -> usize {
    kw.div_ceil(2)
}

/// Repack u32 rows into padded u64 rows (tail lane zero-padded).
/// Word order within a lane is irrelevant as long as both operands agree.
#[inline]
fn widen_rows(src: &[u32], rows: usize, kw: usize, dst: &mut Vec<u64>) {
    let l = lanes(kw);
    dst.clear();
    dst.resize(rows * l, 0);
    for r in 0..rows {
        let s = &src[r * kw..(r + 1) * kw];
        let d = &mut dst[r * l..(r + 1) * l];
        let mut i = 0;
        while i + 1 < kw {
            d[i / 2] = (s[i] as u64) | ((s[i + 1] as u64) << 32);
            i += 2;
        }
        if i < kw {
            d[i / 2] = s[i] as u64;
        }
    }
}

/// out[m, n] = d_real - 2 * popcount(a[m] ^ wt[n]).
pub fn bgemm(a: &[u32], wt: &[u32], m: usize, n: usize, kw: usize, d_real: usize) -> Vec<i32> {
    let mut out = vec![0i32; m * n];
    bgemm_into(a, wt, m, n, kw, d_real, &mut out);
    out
}

/// Widen one row into a caller-provided lane buffer.
///
/// Write coverage: assigns every element of `dst` (len
/// `lanes(src.len())`) — interior lanes from fused word pairs, the tail
/// lane (odd KW) from the final word alone, high half zero.  Prior
/// contents are never read and never survive, so callers may pass a
/// dirty scratch buffer without pre-zeroing (the regression test below
/// pins this; the per-row `fill(0)` the dyn kernels once carried was
/// redundant).
#[inline]
pub(crate) fn widen_row(src: &[u32], dst: &mut [u64]) {
    let kw = src.len();
    debug_assert_eq!(dst.len(), lanes(kw));
    let mut i = 0;
    while i + 1 < kw {
        dst[i / 2] = (src[i] as u64) | ((src[i + 1] as u64) << 32);
        i += 2;
    }
    if i < kw {
        dst[i / 2] = src[i] as u64;
    }
}

/// Allocation-light variant for the serving hot path: the weight matrix
/// is widened once (n·L u64s — L1-resident for this network); each A row
/// is widened into a reused scratch row.  Fixed-lane kernels let the
/// compiler fully unroll conv1 (L=1/2) and conv2 (L=13).
///
/// Write coverage: assigns every element of `out` (len M·N) exactly
/// once; prior contents are never read, so a dirty scratch buffer is
/// safe to pass.
pub fn bgemm_into(
    a: &[u32],
    wt: &[u32],
    m: usize,
    n: usize,
    kw: usize,
    d_real: usize,
    out: &mut [i32],
) {
    assert_eq!(wt.len(), n * kw);
    let mut wbuf = Vec::new();
    widen_rows(wt, n, kw, &mut wbuf);
    bgemm_prewidened(a, &wbuf, m, n, kw, d_real, out);
}

/// Widen a packed weight matrix once at load time (rows padded to u64
/// lanes, layout of `widen_rows`) so the serving hot path can skip the
/// per-call widening pass entirely — see [`bgemm_prewidened`].
pub fn widen_weights(wt: &[u32], n: usize, kw: usize) -> Vec<u64> {
    assert_eq!(wt.len(), n * kw);
    let mut buf = Vec::new();
    widen_rows(wt, n, kw, &mut buf);
    buf
}

/// `bgemm_into` against a pre-widened weight matrix ([`widen_weights`]).
///
/// This is the zero-allocation steady-state kernel: the only per-call
/// work besides the popcount loop is widening each A row into a stack
/// buffer — no heap traffic for this network's lane counts (1, 2, 13).
/// Bit-identical to `bgemm` (widening is a pure re-layout), on every
/// dispatched kernel tier: this entry routes through the runtime
/// microkernel dispatcher ([`crate::bnn::microkernel`]), selecting the
/// tiled/SWAR/SIMD kernel `platform::dispatch` chose for this process
/// (or the `BCNN_KERNEL` override) — all tiers are property-tested
/// bit-identical to [`bgemm_scalar`], the seed kernel below.
pub fn bgemm_prewidened(
    a: &[u32],
    w64: &[u64],
    m: usize,
    n: usize,
    kw: usize,
    d_real: usize,
    out: &mut [i32],
) {
    crate::bnn::microkernel::bgemm_with(
        crate::platform::dispatch::current(),
        a,
        w64,
        m,
        n,
        kw,
        d_real,
        out,
    );
}

/// The seed scalar GEMM: fixed-lane kernels for this network's widths
/// (the compiler fully unrolls L=1/2/13), dyn-lane walk otherwise.
/// This is the bit-identity reference for every microkernel tier.
/// Shape invariants are the caller's (`bgemm_with` asserts them).
pub(crate) fn bgemm_scalar(
    a: &[u32],
    w64: &[u64],
    m: usize,
    n: usize,
    kw: usize,
    d: i32,
    out: &mut [i32],
) {
    match lanes(kw) {
        1 => bgemm_lanes::<1>(a, w64, m, n, kw, d, out),
        2 => bgemm_lanes::<2>(a, w64, m, n, kw, d, out),
        13 => bgemm_lanes::<13>(a, w64, m, n, kw, d, out),
        l => bgemm_lanes_dyn(a, w64, m, n, kw, l, d, out),
    }
}

/// Fixed-lane inner kernel: the compiler fully unrolls the L-loop.
fn bgemm_lanes<const L: usize>(
    a: &[u32],
    w64: &[u64],
    m: usize,
    n: usize,
    kw: usize,
    d: i32,
    out: &mut [i32],
) {
    let mut arow = [0u64; L];
    for mi in 0..m {
        widen_row(&a[mi * kw..(mi + 1) * kw], &mut arow);
        let orow = &mut out[mi * n..(mi + 1) * n];
        for ni in 0..n {
            let wrow = &w64[ni * L..(ni + 1) * L];
            let mut pc = 0u32;
            for i in 0..L {
                pc += (arow[i] ^ wrow[i]).count_ones();
            }
            orow[ni] = d - 2 * pc as i32;
        }
    }
}

fn bgemm_lanes_dyn(
    a: &[u32],
    w64: &[u64],
    m: usize,
    n: usize,
    kw: usize,
    l: usize,
    d: i32,
    out: &mut [i32],
) {
    // no per-row re-zeroing: widen_row's write-coverage contract
    // guarantees every lane (tail included) is overwritten
    let mut arow = vec![0u64; l];
    for mi in 0..m {
        widen_row(&a[mi * kw..(mi + 1) * kw], &mut arow);
        let orow = &mut out[mi * n..(mi + 1) * n];
        for ni in 0..n {
            let wrow = &w64[ni * l..(ni + 1) * l];
            let mut pc = 0u32;
            for (x, y) in arow.iter().zip(wrow) {
                pc += (x ^ y).count_ones();
            }
            orow[ni] = d - 2 * pc as i32;
        }
    }
}

/// Fused XNOR-popcount GEMM + threshold epilogue: each output channel's
/// count is compared against its per-channel threshold while still in a
/// register, and the resulting bits are channel-packed MSB-first into
/// ONE u32 word per patch row (channel `ni` at bit `31 - ni` — the
/// threshold packer's layout, so `im2col_words` gathers the output
/// directly).  `counts`, when present, also receives the raw (M, N) i32
/// counts — the staging buffer the elide-counts rewrite removes; when
/// `None` the counts never touch memory.
///
/// `cmp_bias` is added to each count before the compare.  The rewriter
/// always emits 0 (a biased epilogue is NOT equivalent to threshold ∘
/// popcount); the knob exists so the equivalence checker's refusal of
/// biased epilogues is testable against a real kernel parameter.
///
/// Write coverage: resizes `out` to exactly M and assigns every word;
/// resizes `counts` (when present) to exactly M·N and assigns every
/// element; prior contents are never read.
#[allow(clippy::too_many_arguments)]
pub fn bgemm_threshold_into(
    a: &[u32],
    w64: &[u64],
    m: usize,
    n: usize,
    kw: usize,
    d_real: usize,
    theta: &[f32],
    flip: &[u32],
    cmp_bias: i32,
    out: &mut Vec<u32>,
    counts: Option<&mut Vec<i32>>,
) {
    // dispatched like bgemm_prewidened; the scalar tier's rowwise loop
    // (the seed epilogue) lives in microkernel::bgemm_threshold_with
    crate::bnn::microkernel::bgemm_threshold_with(
        crate::platform::dispatch::current(),
        a,
        w64,
        m,
        n,
        kw,
        d_real,
        theta,
        flip,
        cmp_bias,
        out,
        counts,
    );
}

/// bgemm at an arbitrary packing bitwidth `b` (for the E5 ablation):
/// words still arrive as u32s but only `b` bits per word are meaningful.
/// Identical results for any `b` as long as both operands share a layout.
pub fn bgemm_bitwidth(
    a: &[u32],
    wt: &[u32],
    m: usize,
    n: usize,
    kw: usize,
    d_real: usize,
) -> Vec<i32> {
    // The arithmetic is bit-layout independent; this exists so the
    // ablation bench exercises the differing KW word counts per B.
    bgemm(a, wt, m, n, kw, d_real)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::packing::pack_bits;
    use crate::util::prop::{self, ensure_eq};

    /// ±1-domain reference GEMM.
    fn naive_gemm(a_bits: &[u32], w_bits: &[u32], m: usize, n: usize, d: usize) -> Vec<i32> {
        let mut out = vec![0i32; m * n];
        for mi in 0..m {
            for ni in 0..n {
                let mut acc = 0i32;
                for kk in 0..d {
                    let x = a_bits[mi * d + kk] as i32 * 2 - 1;
                    let y = w_bits[ni * d + kk] as i32 * 2 - 1;
                    acc += x * y;
                }
                out[mi * n + ni] = acc;
            }
        }
        out
    }

    fn pack_rows(bits: &[u32], rows: usize, d: usize, b: usize) -> (Vec<u32>, usize) {
        let nw = crate::bnn::packing::packed_width(d, b);
        let mut out = Vec::with_capacity(rows * nw);
        for r in 0..rows {
            out.extend(pack_bits(&bits[r * d..(r + 1) * d], b));
        }
        (out, nw)
    }

    #[test]
    fn matches_naive_gemm() {
        prop::check(64, |g| {
            let m = g.usize_in(1, 20);
            let n = g.usize_in(1, 8);
            let d = g.usize_in(1, 200);
            let b = *g.pick(&[16usize, 25, 32]);
            let a_bits = g.bits(m * d);
            let w_bits = g.bits(n * d);
            let (ap, kw) = pack_rows(&a_bits, m, d, b);
            let (wp, _) = pack_rows(&w_bits, n, d, b);
            ensure_eq(
                bgemm(&ap, &wp, m, n, kw, d),
                naive_gemm(&a_bits, &w_bits, m, n, d),
                "bgemm == ±1 GEMM",
            )
        });
    }

    #[test]
    fn exercises_both_fixed_lane_kernels() {
        // KW = 3 -> L = 2 (conv1) and KW = 25 -> L = 13 (conv2)
        prop::check(32, |g| {
            for (d, kw) in [(75usize, 3usize), (800, 25)] {
                let a_bits = g.bits(2 * d);
                let w_bits = g.bits(3 * d);
                let (ap, got_kw) = pack_rows(&a_bits, 2, d, 32);
                let (wp, _) = pack_rows(&w_bits, 3, d, 32);
                ensure_eq(got_kw, kw, "packed width")?;
                ensure_eq(
                    bgemm(&ap, &wp, 2, 3, kw, d),
                    naive_gemm(&a_bits, &w_bits, 2, 3, d),
                    "fixed-lane kernel",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn conv1_shape_smoke() {
        // the paper's first layer: M=9216 patches, N=32 filters, D=75
        let m = 96 * 96;
        let n = 32;
        let d = 75;
        let kw = 3;
        let a = vec![0u32; m * kw];
        let w = vec![u32::MAX << (96 - 75); n * kw];
        let out = bgemm(&a, &w, m, n, kw, d);
        assert_eq!(out.len(), m * n);
    }

    #[test]
    fn identical_rows_give_d() {
        let d = 100;
        let bits: Vec<u32> = (0..d).map(|i| (i % 3 == 0) as u32).collect();
        let p = pack_bits(&bits, 32);
        let out = bgemm(&p, &p, 1, 1, p.len(), d);
        assert_eq!(out, vec![d as i32]);
    }

    #[test]
    fn complementary_rows_give_minus_d() {
        let d = 77;
        let bits: Vec<u32> = (0..d).map(|i| (i % 2) as u32).collect();
        let inv: Vec<u32> = bits.iter().map(|&b| 1 - b).collect();
        let pa = pack_bits(&bits, 32);
        let pb = pack_bits(&inv, 32);
        let out = bgemm(&pa, &pb, 1, 1, pa.len(), d);
        assert_eq!(out, vec![-(d as i32)]);
    }

    #[test]
    fn into_variant_matches() {
        prop::check(32, |g| {
            let m = g.usize_in(1, 10);
            let n = g.usize_in(1, 5);
            let d = g.usize_in(1, 64);
            let a_bits = g.bits(m * d);
            let w_bits = g.bits(n * d);
            let (ap, kw) = pack_rows(&a_bits, m, d, 32);
            let (wp, _) = pack_rows(&w_bits, n, d, 32);
            let alloc = bgemm(&ap, &wp, m, n, kw, d);
            let mut pre = vec![0i32; m * n];
            bgemm_into(&ap, &wp, m, n, kw, d, &mut pre);
            ensure_eq(alloc, pre, "bgemm_into == bgemm")
        });
    }

    #[test]
    fn prewidened_matches_bgemm_all_lane_kernels() {
        // KW = 1 (gray conv1, L=1), 3 (rgb conv1, L=2), 25 (conv2, L=13),
        // and a dyn-path width — the pre-widened weights must be a pure
        // re-layout with bit-identical counts
        prop::check(32, |g| {
            for kw in [1usize, 3, 25, 7] {
                let d = kw * 32;
                let m = g.usize_in(1, 6);
                let n = g.usize_in(1, 4);
                let a = g.words(m * kw);
                let w = g.words(n * kw);
                let w64 = widen_weights(&w, n, kw);
                ensure_eq(w64.len(), n * lanes(kw), "widened rows")?;
                let mut got = vec![0i32; m * n];
                bgemm_prewidened(&a, &w64, m, n, kw, d, &mut got);
                ensure_eq(got, bgemm(&a, &w, m, n, kw, d), "prewidened == bgemm")?;
            }
            Ok(())
        });
    }

    #[test]
    fn fused_threshold_epilogue_matches_bgemm_then_pack() {
        // the fold-threshold axiom at the kernel level: fused epilogue ==
        // bgemm counts, then per-channel threshold bits packed MSB-first;
        // staged counts (when requested) are the raw bgemm output, and
        // eliding them never changes the packed words
        use crate::bnn::packing::threshold_bit;
        prop::check(32, |g| {
            let m = g.usize_in(1, 10);
            let n = g.usize_in(1, 32);
            let kw = g.usize_in(1, 8);
            let d = kw * 32;
            let a = g.words(m * kw);
            let w = g.words(n * kw);
            let theta = g.normals(n);
            let flip = g.bits(n);
            let bias = *g.pick(&[0i32, 1, -3]);
            let w64 = widen_weights(&w, n, kw);
            // dirty buffers: the kernel must fully overwrite both
            let mut words = vec![9u32; 3];
            let mut counts = vec![7i32; 1];
            bgemm_threshold_into(
                &a, &w64, m, n, kw, d, &theta, &flip, bias, &mut words, Some(&mut counts),
            );
            let want_counts = bgemm(&a, &w, m, n, kw, d);
            ensure_eq(counts, want_counts.clone(), "staged counts == bgemm")?;
            let mut want_words = vec![0u32; m];
            for mi in 0..m {
                for ni in 0..n {
                    let v = (want_counts[mi * n + ni] + bias) as f32;
                    want_words[mi] |= threshold_bit(v, theta[ni], flip[ni]) << (31 - ni);
                }
            }
            ensure_eq(words.clone(), want_words, "fused epilogue == count-then-pack")?;
            let mut elided = Vec::new();
            bgemm_threshold_into(&a, &w64, m, n, kw, d, &theta, &flip, bias, &mut elided, None);
            ensure_eq(elided, words, "elided counts == staged counts (words)")
        });
    }

    #[test]
    fn widen_row_overwrites_every_lane_of_a_dirty_buffer() {
        // the write-coverage contract that justified dropping the
        // per-row fill(0) from the dyn kernels: widening into a
        // poisoned buffer must equal widening into a zeroed one, for
        // even and odd KW (the odd tail lane is the risky one)
        prop::check(48, |g| {
            let kw = g.usize_in(1, 33);
            let src = g.words(kw);
            let l = lanes(kw);
            let mut clean = vec![0u64; l];
            widen_row(&src, &mut clean);
            let mut dirty = vec![u64::MAX; l];
            widen_row(&src, &mut dirty);
            ensure_eq(dirty, clean, "dirty-buffer widen_row")
        });
    }

    #[test]
    fn odd_kw_tail_lane() {
        // odd KW exercises the zero-padded tail lane
        prop::check(32, |g| {
            let kw = 2 * g.usize_in(0, 6) + 1; // odd
            let d = kw * 32;
            let a = g.words(kw);
            let w = g.words(kw);
            let scalar: u32 = a.iter().zip(&w).map(|(x, y)| (x ^ y).count_ones()).sum();
            let got = bgemm(&a, &w, 1, 1, kw, d)[0];
            ensure_eq(got, d as i32 - 2 * scalar as i32, "odd-KW")
        });
    }
}
