//! Fully-connected layers: packed xnor-popcount matrix×vector (paper
//! Section 3.2) and the float baseline.
//!
//! The CUDA kernel splits each weight-row dot into 64 segments with a
//! warp reduction; on CPU the u64 popcount loop over a row is already a
//! single-pass reduction, and the segment structure survives as the
//! chunked accumulation below (which also helps ILP: four independent
//! accumulators).

use super::packing::fuse64;
use crate::platform::dispatch::{self, KernelKind};

/// Packed FC: `x` (KW,) u32, `wt` (L, KW) u32 -> (L,) i32 counts.
pub fn fc_packed(x: &[u32], wt: &[u32], l: usize, kw: usize, d_real: usize) -> Vec<i32> {
    let mut out = vec![0i32; l];
    fc_packed_into(x, wt, l, kw, d_real, &mut out);
    out
}

/// Allocation-free packed FC for the serving hot path.  Routed through
/// the runtime microkernel dispatcher (the kernel is resolved once per
/// call, not per weight row).
///
/// Write coverage: assigns every element of `out` (len L) exactly once;
/// prior contents are never read.
pub fn fc_packed_into(
    x: &[u32],
    wt: &[u32],
    l: usize,
    kw: usize,
    d_real: usize,
    out: &mut [i32],
) {
    fc_packed_into_with(dispatch::current(), x, wt, l, kw, d_real, out);
}

/// `fc_packed_into` under an explicit kernel choice (shared by the
/// batch drivers so the env override is read once per entry point).
fn fc_packed_into_with(
    kind: KernelKind,
    x: &[u32],
    wt: &[u32],
    l: usize,
    kw: usize,
    d_real: usize,
    out: &mut [i32],
) {
    assert_eq!(x.len(), kw);
    assert_eq!(wt.len(), l * kw);
    assert_eq!(out.len(), l);
    let d = d_real as i32;
    for li in 0..l {
        out[li] = xnor_dot(kind, x, &wt[li * kw..(li + 1) * kw], d);
    }
}

/// One weight-row XNOR dot, dispatched.  The scalar/tiled tiers keep
/// the seed 4-way unrolled accumulation (it IS the register-blocked
/// form of this dot — tiling proper is a GEMM loop structure); the
/// SWAR and SIMD tiers swap in their word-popcount primitives.  All
/// tiers are exact integer popcount sums, hence bit-identical.
#[inline]
fn xnor_dot(kind: KernelKind, x: &[u32], wrow: &[u32], d: i32) -> i32 {
    match kind {
        KernelKind::Scalar | KernelKind::Tiled => xnor_dot_scalar(x, wrow, d),
        _ => d - 2 * crate::bnn::microkernel::xorpop_words(kind, x, wrow) as i32,
    }
}

/// The seed weight-row XNOR dot: 4-way unrolled u64 accumulation (the
/// "segments" of Section 3.2) — eight u32 words, four fused u64 pairs,
/// per iteration on four independent accumulators for ILP.  Shared by
/// the plain and fused-threshold FC kernels so their counts are
/// identical by construction.
#[inline]
fn xnor_dot_scalar(x: &[u32], wrow: &[u32], d: i32) -> i32 {
    let x8 = x.chunks_exact(8);
    let w8 = wrow.chunks_exact(8);
    let (xr, wr) = (x8.remainder(), w8.remainder());
    let mut acc = [0u32; 4];
    for (p, q) in x8.zip(w8) {
        acc[0] += (fuse64(p[0], p[1]) ^ fuse64(q[0], q[1])).count_ones();
        acc[1] += (fuse64(p[2], p[3]) ^ fuse64(q[2], q[3])).count_ones();
        acc[2] += (fuse64(p[4], p[5]) ^ fuse64(q[4], q[5])).count_ones();
        acc[3] += (fuse64(p[6], p[7]) ^ fuse64(q[6], q[7])).count_ones();
    }
    for (&a, &b) in xr.iter().zip(wr) {
        acc[0] += (a ^ b).count_ones();
    }
    let pc: u32 = acc.iter().sum();
    d - 2 * pc as i32
}

/// Fused packed FC + ±1 threshold: each output's count stays in a
/// register between the popcount accumulation and the per-channel
/// compare, so the (L,) i32 counts row never exists in memory — the
/// counts buffer is gone by construction, not by elision.  `cmp_bias`
/// is added before the compare (the rewriter emits 0; the knob exists
/// so the equivalence checker's bias refusal is testable against a real
/// kernel parameter).  Bit-identical to `fc_packed_batch` followed by
/// the ±1 threshold map.
///
/// Write coverage: resizes `out` to exactly N·L and assigns every
/// element exactly once; prior contents are never read.
#[allow(clippy::too_many_arguments)]
pub fn fc_packed_threshold_batch_into(
    xs: &[u32],
    wt: &[u32],
    n: usize,
    l: usize,
    kw: usize,
    d_real: usize,
    theta: &[f32],
    flip: &[u32],
    cmp_bias: i32,
    out: &mut Vec<f32>,
) {
    use super::packing::threshold_bit;
    assert_eq!(xs.len(), n * kw);
    assert_eq!(wt.len(), l * kw);
    assert_eq!(theta.len(), l);
    assert_eq!(flip.len(), l);
    let d = d_real as i32;
    out.resize(n * l, 0.0);
    let kind = dispatch::current();
    for i in 0..n {
        let x = &xs[i * kw..(i + 1) * kw];
        let orow = &mut out[i * l..(i + 1) * l];
        for li in 0..l {
            let count = xnor_dot(kind, x, &wt[li * kw..(li + 1) * kw], d);
            orow[li] = if threshold_bit((count + cmp_bias) as f32, theta[li], flip[li]) == 1 {
                1.0
            } else {
                -1.0
            };
        }
    }
}

/// Batched packed FC: `xs` is N contiguous (KW,) activation rows,
/// output is N contiguous (L,) count rows.  Bit-identical per row to
/// `fc_packed`; the weight matrix streams once per image but stays
/// L1-resident across the batch (576 words/row for this network).
pub fn fc_packed_batch(
    xs: &[u32],
    wt: &[u32],
    n: usize,
    l: usize,
    kw: usize,
    d_real: usize,
) -> Vec<i32> {
    let mut out = Vec::new();
    fc_packed_batch_into(xs, wt, n, l, kw, d_real, &mut out);
    out
}

/// `fc_packed_batch` into a caller-owned buffer (capacity grows
/// monotonically; no pre-zeroing — every output count is assigned).
///
/// Write coverage: resizes `out` to exactly N·L and assigns every
/// element via per-row `fc_packed_into`; prior contents are never read.
pub fn fc_packed_batch_into(
    xs: &[u32],
    wt: &[u32],
    n: usize,
    l: usize,
    kw: usize,
    d_real: usize,
    out: &mut Vec<i32>,
) {
    assert_eq!(xs.len(), n * kw);
    out.resize(n * l, 0);
    let kind = dispatch::current();
    for i in 0..n {
        fc_packed_into_with(
            kind,
            &xs[i * kw..(i + 1) * kw],
            wt,
            l,
            kw,
            d_real,
            &mut out[i * l..(i + 1) * l],
        );
    }
}

/// Float FC: `x` (D,), `wt` (L, D) row-major -> (L,).
pub fn fc_float(x: &[f32], wt: &[f32], l: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0f32; l];
    fc_float_into(x, wt, l, d, &mut out);
    out
}

/// Allocation-free float FC.
///
/// Write coverage: overwrites `out` (len L) entirely; prior contents
/// are never read (a NaN-poisoned buffer comes out clean).
pub fn fc_float_into(x: &[f32], wt: &[f32], l: usize, d: usize, out: &mut [f32]) {
    assert_eq!(x.len(), d);
    assert_eq!(wt.len(), l * d);
    assert_eq!(out.len(), l);
    for li in 0..l {
        let row = &wt[li * d..(li + 1) * d];
        let mut acc = 0f32;
        for (a, b) in x.iter().zip(row) {
            acc += a * b;
        }
        out[li] = acc;
    }
}

/// Float FC with bias + optional sign activation (the CPU tail layers:
/// fc2 with sign, fc3 raw logits).
pub fn fc_float_bias(x: &[f32], wt: &[f32], bias: &[f32], l: usize, d: usize) -> Vec<f32> {
    let mut out = fc_float(x, wt, l, d);
    for (o, b) in out.iter_mut().zip(bias) {
        *o += b;
    }
    out
}

/// Allocation-free `fc_float_bias` (same accumulation order, so the
/// results are bit-identical to the allocating variant).
///
/// Write coverage: assigns every element of `out` (len L) through
/// `fc_float_into`, then adds bias in place; prior contents are never
/// read.
pub fn fc_float_bias_into(
    x: &[f32],
    wt: &[f32],
    bias: &[f32],
    l: usize,
    d: usize,
    out: &mut [f32],
) {
    fc_float_into(x, wt, l, d, out);
    for (o, b) in out.iter_mut().zip(bias) {
        *o += b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::packing::pack_bits;
    use crate::util::prop::{self, ensure, ensure_eq};

    #[test]
    fn fc_packed_matches_pm1_dot() {
        prop::check(64, |g| {
            let l = g.usize_in(1, 32);
            let d = g.usize_in(1, 1024);
            let xb = g.bits(d);
            let wb = g.bits(l * d);
            let xp = pack_bits(&xb, 32);
            let kw = xp.len();
            let mut wp = Vec::with_capacity(l * kw);
            for li in 0..l {
                wp.extend(pack_bits(&wb[li * d..(li + 1) * d], 32));
            }
            let got = fc_packed(&xp, &wp, l, kw, d);
            let want: Vec<i32> = (0..l)
                .map(|li| {
                    (0..d)
                        .map(|i| {
                            let a = xb[i] as i32 * 2 - 1;
                            let b = wb[li * d + i] as i32 * 2 - 1;
                            a * b
                        })
                        .sum()
                })
                .collect();
            ensure_eq(got, want, "fc_packed == ±1 dot")
        });
    }

    #[test]
    fn fc_packed_paper_dims() {
        // paper's FC1: L=100, D=18432 -> KW=576
        let d = 18432;
        let kw = 576;
        let x = vec![0xAAAA_AAAAu32; kw];
        let wt = vec![0x5555_5555u32; 100 * kw];
        let out = fc_packed(&x, &wt, 100, kw, d);
        // complete disagreement: every bit differs -> dot = -D
        assert!(out.iter().all(|&v| v == -(d as i32)));
    }

    #[test]
    fn fc_float_known_values() {
        let x = [1.0, 2.0];
        let wt = [3.0, 4.0, -1.0, 0.5]; // rows [3,4], [-1,0.5]
        let out = fc_float(&x, &wt, 2, 2);
        assert_eq!(out, vec![11.0, 0.0]);
    }

    #[test]
    fn fc_float_bias_adds() {
        let x = [1.0];
        let wt = [2.0, -2.0];
        let out = fc_float_bias(&x, &wt, &[0.5, 0.25], 2, 1);
        assert_eq!(out, vec![2.5, -1.75]);
    }

    #[test]
    fn batch_matches_per_row() {
        prop::check(32, |g| {
            let n = g.usize_in(1, 6);
            let l = g.usize_in(1, 12);
            let kw = g.usize_in(1, 40);
            let d = kw * 32;
            let xs = g.words(n * kw);
            let wt = g.words(l * kw);
            let got = fc_packed_batch(&xs, &wt, n, l, kw, d);
            for i in 0..n {
                ensure_eq(
                    got[i * l..(i + 1) * l].to_vec(),
                    fc_packed(&xs[i * kw..(i + 1) * kw], &wt, l, kw, d),
                    "fc batch == single",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn into_matches_alloc() {
        prop::check(32, |g| {
            let l = g.usize_in(1, 16);
            let kw = g.usize_in(1, 80);
            let d = kw * 32;
            let x = g.words(kw);
            let wt = g.words(l * kw);
            let a = fc_packed(&x, &wt, l, kw, d);
            let mut b = vec![0i32; l];
            fc_packed_into(&x, &wt, l, kw, d, &mut b);
            ensure_eq(a, b, "into == alloc")
        });
    }

    #[test]
    fn float_into_variants_match_alloc() {
        prop::check(32, |g| {
            let l = g.usize_in(1, 10);
            let d = g.usize_in(1, 64);
            let x = g.normals(d);
            let wt = g.normals(l * d);
            let bias = g.normals(l);
            // dirty output buffer: _into must fully overwrite it
            let mut out = vec![f32::NAN; l];
            fc_float_into(&x, &wt, l, d, &mut out);
            ensure_eq(out.clone(), fc_float(&x, &wt, l, d), "fc_float_into")?;
            let mut outb = vec![f32::NAN; l];
            fc_float_bias_into(&x, &wt, &bias, l, d, &mut outb);
            ensure_eq(outb, fc_float_bias(&x, &wt, &bias, l, d), "fc_float_bias_into")
        });
    }

    #[test]
    fn batch_into_reuse_matches_alloc() {
        let mut buf = Vec::new();
        prop::check(24, |g| {
            let n = g.usize_in(1, 5);
            let l = g.usize_in(1, 8);
            let kw = g.usize_in(1, 30);
            let d = kw * 32;
            let xs = g.words(n * kw);
            let wt = g.words(l * kw);
            fc_packed_batch_into(&xs, &wt, n, l, kw, d, &mut buf);
            ensure_eq(buf.clone(), fc_packed_batch(&xs, &wt, n, l, kw, d), "fc batch reuse")
        });
    }

    #[test]
    fn fused_threshold_matches_fc_then_threshold() {
        // the FC fold axiom at the kernel level: register-resident counts
        // compared in place == materialized counts then the ±1 map
        use crate::bnn::packing::threshold_bit;
        prop::check(32, |g| {
            let n = g.usize_in(1, 5);
            let l = g.usize_in(1, 12);
            let kw = g.usize_in(1, 30);
            let d = kw * 32;
            let xs = g.words(n * kw);
            let wt = g.words(l * kw);
            let theta = g.normals(l);
            let flip = g.bits(l);
            let bias = *g.pick(&[0i32, 2, -1]);
            let mut got = vec![f32::NAN; 2]; // dirty
            fc_packed_threshold_batch_into(
                &xs, &wt, n, l, kw, d, &theta, &flip, bias, &mut got,
            );
            let counts = fc_packed_batch(&xs, &wt, n, l, kw, d);
            let want: Vec<f32> = counts
                .iter()
                .enumerate()
                .map(|(i, &v)| {
                    let li = i % l;
                    if threshold_bit((v + bias) as f32, theta[li], flip[li]) == 1 {
                        1.0
                    } else {
                        -1.0
                    }
                })
                .collect();
            ensure_eq(got, want, "fused FC threshold == staged")
        });
    }

    #[test]
    fn unroll_boundaries() {
        // exercise kw that is not a multiple of 8 u32s (4 u64s) and odd kw
        prop::check(32, |g| {
            let kw = g.usize_in(1, 17);
            let x = g.words(kw);
            let wt = g.words(kw);
            let scalar: u32 = x.iter().zip(&wt).map(|(&a, &b)| (a ^ b).count_ones()).sum();
            let got = fc_packed(&x, &wt, 1, kw, kw * 32)[0];
            ensure(
                got == (kw * 32) as i32 - 2 * scalar as i32,
                format!("kw={kw}: {got}"),
            )
        });
    }
}
