//! 2x2/stride-2 pooling: float max-pool (the paper's layer) and the
//! packed-domain OR-pool (our binary-domain optimization, ablation E8):
//! sign is monotone, so `sign(max(x)) == or(sign(x))` bit-wise — 32
//! channels pooled per OR instruction.

/// Float 2x2 max pool.  `x` (H, W, C) -> (H/2, W/2, C); H, W even.
pub fn maxpool2x2(x: &[f32], h: usize, w: usize, c: usize) -> Vec<f32> {
    assert!(h % 2 == 0 && w % 2 == 0);
    assert_eq!(x.len(), h * w * c);
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![f32::NEG_INFINITY; oh * ow * c];
    for oy in 0..oh {
        for ox in 0..ow {
            let dst = (oy * ow + ox) * c;
            for (dy, dx) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
                let src = ((oy * 2 + dy) * w + (ox * 2 + dx)) * c;
                for ch in 0..c {
                    let v = x[src + ch];
                    if v > out[dst + ch] {
                        out[dst + ch] = v;
                    }
                }
            }
        }
    }
    out
}

/// Packed OR pool.  `words` (H, W, NW) u32 -> (H/2, W/2, NW).
pub fn orpool2x2(words: &[u32], h: usize, w: usize, nw: usize) -> Vec<u32> {
    assert!(h % 2 == 0 && w % 2 == 0);
    assert_eq!(words.len(), h * w * nw);
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![0u32; oh * ow * nw];
    for oy in 0..oh {
        for ox in 0..ow {
            let dst = (oy * ow + ox) * nw;
            let r0 = ((oy * 2) * w + ox * 2) * nw;
            let r1 = ((oy * 2 + 1) * w + ox * 2) * nw;
            for wi in 0..nw {
                out[dst + wi] =
                    words[r0 + wi] | words[r0 + nw + wi] | words[r1 + wi] | words[r1 + nw + wi];
            }
        }
    }
    out
}

/// Float max-pool on ±1 data followed by channel packing — the unfused
/// ordering the paper uses (pool floats, binarize later).  For the E8
/// ablation bench.
pub fn maxpool_pm1_then_pack(x: &[f32], h: usize, w: usize, c: usize) -> Vec<u32> {
    assert!(c <= 32);
    let pooled = maxpool2x2(x, h, w, c);
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![0u32; oh * ow];
    for px in 0..oh * ow {
        let mut word = 0u32;
        for ch in 0..c {
            word |= u32::from(pooled[px * c + ch] > 0.0) << (31 - ch);
        }
        out[px] = word;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::packing::pack_channels32;
    use crate::util::prop::{self, ensure_eq};

    #[test]
    fn maxpool_basic() {
        // 2x2 single channel
        let out = maxpool2x2(&[1.0, 4.0, 3.0, 2.0], 2, 2, 1);
        assert_eq!(out, vec![4.0]);
    }

    #[test]
    fn maxpool_multichannel_independent() {
        // 2x2, C=2: channels pool independently
        #[rustfmt::skip]
        let x = vec![
            1.0, 10.0,  2.0, -10.0,
            3.0, -1.0,  0.0, 5.0,
        ];
        let out = maxpool2x2(&x, 2, 2, 2);
        assert_eq!(out, vec![3.0, 10.0]);
    }

    #[test]
    fn maxpool_handles_all_negative() {
        let x = vec![-5.0, -3.0, -9.0, -4.0];
        assert_eq!(maxpool2x2(&x, 2, 2, 1), vec![-3.0]);
    }

    #[test]
    fn orpool_is_bitwise_or() {
        let words = vec![0b0001, 0b0010, 0b0100, 0b1000];
        assert_eq!(orpool2x2(&words, 2, 2, 1), vec![0b1111]);
    }

    #[test]
    fn or_of_signs_equals_sign_of_max() {
        prop::check(64, |g| {
            let h = 2 * g.usize_in(1, 4);
            let w = 2 * g.usize_in(1, 4);
            let c = g.usize_in(1, 32);
            let x = g.pm1(h * w * c);
            // path A: float max-pool then channel-pack
            let packed_after = maxpool_pm1_then_pack(&x, h, w, c);
            // path B: channel-pack then OR-pool
            let mut words = Vec::with_capacity(h * w);
            for px in 0..h * w {
                words.push(pack_channels32(
                    x[px * c..(px + 1) * c].iter().map(|&v| u32::from(v > 0.0)),
                ));
            }
            let packed_before = orpool2x2(&words, h, w, 1);
            ensure_eq(packed_before, packed_after, "sign(max) == or(sign)")
        });
    }

    #[test]
    fn orpool_shapes() {
        let out = orpool2x2(&vec![1u32; 8 * 6 * 3], 8, 6, 3);
        assert_eq!(out.len(), 4 * 3 * 3);
    }
}
