//! 2x2/stride-2 pooling: float max-pool (the paper's layer) and the
//! packed-domain OR-pool (our binary-domain optimization, ablation E8):
//! sign is monotone, so `sign(max(x)) == or(sign(x))` bit-wise — 32
//! channels pooled per OR instruction.

/// Pool-shape violation — recoverable so a serving worker can answer a
/// malformed artifact or request with a protocol error instead of
/// aborting its thread (the bare `maxpool2x2`/`orpool2x2` wrappers keep
/// the assert semantics for bench/test code).
#[derive(Debug, PartialEq, Eq)]
pub struct PoolError {
    pub what: &'static str,
    pub h: usize,
    pub w: usize,
    pub got: usize,
    pub want: usize,
}

impl std::fmt::Display for PoolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}: H={} W={} (len {} vs expected {})",
            self.what, self.h, self.w, self.got, self.want
        )
    }
}

impl std::error::Error for PoolError {}

fn check_pool_shape(
    what: &'static str,
    len: usize,
    h: usize,
    w: usize,
    c: usize,
) -> Result<(), PoolError> {
    if h % 2 != 0 || w % 2 != 0 || len != h * w * c {
        Err(PoolError { what, h, w, got: len, want: h * w * c })
    } else {
        Ok(())
    }
}

/// Float 2x2 max pool.  `x` (H, W, C) -> (H/2, W/2, C); H, W even.
pub fn maxpool2x2(x: &[f32], h: usize, w: usize, c: usize) -> Vec<f32> {
    maxpool2x2_checked(x, h, w, c).expect("maxpool2x2 shape")
}

/// Fallible max pool for serving-reachable paths.
pub fn maxpool2x2_checked(
    x: &[f32],
    h: usize,
    w: usize,
    c: usize,
) -> Result<Vec<f32>, PoolError> {
    check_pool_shape("maxpool2x2: odd extent or length mismatch", x.len(), h, w, c)?;
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![f32::NEG_INFINITY; oh * ow * c];
    maxpool2x2_image_into(x, h, w, c, &mut out);
    Ok(out)
}

/// Pool one image into a pre-sized output slice (`out` must be
/// `NEG_INFINITY`-initialized, (H/2)*(W/2)*C long).
fn maxpool2x2_image_into(x: &[f32], h: usize, w: usize, c: usize, out: &mut [f32]) {
    let (oh, ow) = (h / 2, w / 2);
    for oy in 0..oh {
        for ox in 0..ow {
            let dst = (oy * ow + ox) * c;
            for (dy, dx) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
                let src = ((oy * 2 + dy) * w + (ox * 2 + dx)) * c;
                for ch in 0..c {
                    let v = x[src + ch];
                    if v > out[dst + ch] {
                        out[dst + ch] = v;
                    }
                }
            }
        }
    }
}

/// Batched max pool over `n` contiguous (H, W, C) images.
/// Bit-identical per image to `maxpool2x2` on each slice.
pub fn maxpool2x2_batch(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
) -> Result<Vec<f32>, PoolError> {
    let mut out = Vec::new();
    maxpool2x2_batch_into(x, n, h, w, c, &mut out)?;
    Ok(out)
}

/// `maxpool2x2_batch` into a caller-owned buffer (resized + fully
/// re-initialized every call, so cross-batch reuse cannot leak state;
/// capacity grows monotonically).
///
/// Write coverage: resizes `out` to exactly N·(H/2)·(W/2)·C and
/// re-initializes every element (`NEG_INFINITY` fill, then max-reduced);
/// prior contents are never read.
pub fn maxpool2x2_batch_into(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    out: &mut Vec<f32>,
) -> Result<(), PoolError> {
    check_pool_shape("maxpool2x2_batch: odd extent or length mismatch", x.len(), h, w, n * c)?;
    let (img_in, img_out) = (h * w * c, (h / 2) * (w / 2) * c);
    out.clear();
    out.resize(n * img_out, f32::NEG_INFINITY);
    for i in 0..n {
        maxpool2x2_image_into(
            &x[i * img_in..(i + 1) * img_in],
            h,
            w,
            c,
            &mut out[i * img_out..(i + 1) * img_out],
        );
    }
    Ok(())
}

/// Packed OR pool.  `words` (H, W, NW) u32 -> (H/2, W/2, NW).
pub fn orpool2x2(words: &[u32], h: usize, w: usize, nw: usize) -> Vec<u32> {
    orpool2x2_checked(words, h, w, nw).expect("orpool2x2 shape")
}

/// Fallible OR pool for serving-reachable paths.
pub fn orpool2x2_checked(
    words: &[u32],
    h: usize,
    w: usize,
    nw: usize,
) -> Result<Vec<u32>, PoolError> {
    check_pool_shape("orpool2x2: odd extent or length mismatch", words.len(), h, w, nw)?;
    let mut out = vec![0u32; (h / 2) * (w / 2) * nw];
    orpool2x2_image_into(words, h, w, nw, &mut out);
    Ok(out)
}

/// OR-pool one image into a pre-sized output slice.  Assigns every
/// output word (never OR-accumulates), so the slice may arrive dirty —
/// the reused-arena path relies on this.
fn orpool2x2_image_into(words: &[u32], h: usize, w: usize, nw: usize, out: &mut [u32]) {
    let (oh, ow) = (h / 2, w / 2);
    for oy in 0..oh {
        for ox in 0..ow {
            let dst = (oy * ow + ox) * nw;
            let r0 = ((oy * 2) * w + ox * 2) * nw;
            let r1 = ((oy * 2 + 1) * w + ox * 2) * nw;
            for wi in 0..nw {
                out[dst + wi] =
                    words[r0 + wi] | words[r0 + nw + wi] | words[r1 + wi] | words[r1 + nw + wi];
            }
        }
    }
}

/// Batched OR pool over `n` contiguous (H, W, NW) packed images.
/// Bit-identical per image to `orpool2x2` on each slice.
pub fn orpool2x2_batch(
    words: &[u32],
    n: usize,
    h: usize,
    w: usize,
    nw: usize,
) -> Result<Vec<u32>, PoolError> {
    let mut out = Vec::new();
    orpool2x2_batch_into(words, n, h, w, nw, &mut out)?;
    Ok(out)
}

/// `orpool2x2_batch` into a caller-owned buffer (capacity grows
/// monotonically; no pre-zeroing — `orpool2x2_image_into` assigns every
/// output word, it never ORs into existing contents).
///
/// Write coverage: resizes `out` to exactly N·(H/2)·(W/2)·NW and assigns
/// every word exactly once; a dirty buffer comes out identical to a
/// fresh allocation.
pub fn orpool2x2_batch_into(
    words: &[u32],
    n: usize,
    h: usize,
    w: usize,
    nw: usize,
    out: &mut Vec<u32>,
) -> Result<(), PoolError> {
    check_pool_shape("orpool2x2_batch: odd extent or length mismatch", words.len(), h, w, n * nw)?;
    let (img_in, img_out) = (h * w * nw, (h / 2) * (w / 2) * nw);
    out.resize(n * img_out, 0);
    for i in 0..n {
        orpool2x2_image_into(
            &words[i * img_in..(i + 1) * img_in],
            h,
            w,
            nw,
            &mut out[i * img_out..(i + 1) * img_out],
        );
    }
    Ok(())
}

/// Float max-pool on ±1 data followed by channel packing — the unfused
/// ordering the paper uses (pool floats, binarize later).  For the E8
/// ablation bench.
pub fn maxpool_pm1_then_pack(x: &[f32], h: usize, w: usize, c: usize) -> Vec<u32> {
    assert!(c <= 32);
    let pooled = maxpool2x2(x, h, w, c);
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![0u32; oh * ow];
    for px in 0..oh * ow {
        let mut word = 0u32;
        for ch in 0..c {
            word |= u32::from(pooled[px * c + ch] > 0.0) << (31 - ch);
        }
        out[px] = word;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::packing::pack_channels32;
    use crate::util::prop::{self, ensure_eq};

    #[test]
    fn maxpool_basic() {
        // 2x2 single channel
        let out = maxpool2x2(&[1.0, 4.0, 3.0, 2.0], 2, 2, 1);
        assert_eq!(out, vec![4.0]);
    }

    #[test]
    fn maxpool_multichannel_independent() {
        // 2x2, C=2: channels pool independently
        #[rustfmt::skip]
        let x = vec![
            1.0, 10.0,  2.0, -10.0,
            3.0, -1.0,  0.0, 5.0,
        ];
        let out = maxpool2x2(&x, 2, 2, 2);
        assert_eq!(out, vec![3.0, 10.0]);
    }

    #[test]
    fn maxpool_handles_all_negative() {
        let x = vec![-5.0, -3.0, -9.0, -4.0];
        assert_eq!(maxpool2x2(&x, 2, 2, 1), vec![-3.0]);
    }

    #[test]
    fn orpool_is_bitwise_or() {
        let words = vec![0b0001, 0b0010, 0b0100, 0b1000];
        assert_eq!(orpool2x2(&words, 2, 2, 1), vec![0b1111]);
    }

    #[test]
    fn or_of_signs_equals_sign_of_max() {
        prop::check(64, |g| {
            let h = 2 * g.usize_in(1, 4);
            let w = 2 * g.usize_in(1, 4);
            let c = g.usize_in(1, 32);
            let x = g.pm1(h * w * c);
            // path A: float max-pool then channel-pack
            let packed_after = maxpool_pm1_then_pack(&x, h, w, c);
            // path B: channel-pack then OR-pool
            let mut words = Vec::with_capacity(h * w);
            for px in 0..h * w {
                words.push(pack_channels32(
                    x[px * c..(px + 1) * c].iter().map(|&v| u32::from(v > 0.0)),
                ));
            }
            let packed_before = orpool2x2(&words, h, w, 1);
            ensure_eq(packed_before, packed_after, "sign(max) == or(sign)")
        });
    }

    #[test]
    fn orpool_shapes() {
        let out = orpool2x2(&[1u32; 8 * 6 * 3], 8, 6, 3);
        assert_eq!(out.len(), 4 * 3 * 3);
    }

    #[test]
    fn checked_variants_reject_bad_shapes() {
        // odd extent
        assert!(maxpool2x2_checked(&[0.0; 3 * 2], 3, 2, 1).is_err());
        assert!(orpool2x2_checked(&[0u32; 2 * 3], 2, 3, 1).is_err());
        // length mismatch
        assert!(maxpool2x2_checked(&[0.0; 5], 2, 2, 1).is_err());
        assert!(orpool2x2_checked(&[0u32; 5], 2, 2, 1).is_err());
        // errors are printable and name the offender
        let e = orpool2x2_checked(&[0u32; 5], 2, 2, 1).unwrap_err();
        assert!(e.to_string().contains("orpool2x2"));
    }

    #[test]
    fn batch_pools_match_per_image() {
        prop::check(32, |g| {
            let n = g.usize_in(1, 5);
            let h = 2 * g.usize_in(1, 4);
            let w = 2 * g.usize_in(1, 4);
            let c = g.usize_in(1, 4);
            let xs = g.normals(n * h * w * c);
            let words = g.words(n * h * w * c);
            let fb = maxpool2x2_batch(&xs, n, h, w, c).unwrap();
            let ob = orpool2x2_batch(&words, n, h, w, c).unwrap();
            let (img_in, img_out) = (h * w * c, (h / 2) * (w / 2) * c);
            for i in 0..n {
                ensure_eq(
                    fb[i * img_out..(i + 1) * img_out].to_vec(),
                    maxpool2x2(&xs[i * img_in..(i + 1) * img_in], h, w, c),
                    "maxpool batch == single",
                )?;
                ensure_eq(
                    ob[i * img_out..(i + 1) * img_out].to_vec(),
                    orpool2x2(&words[i * img_in..(i + 1) * img_in], h, w, c),
                    "orpool batch == single",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn batch_pools_reject_bad_shapes() {
        assert!(maxpool2x2_batch(&[0.0; 8], 3, 2, 2, 1).is_err());
        assert!(orpool2x2_batch(&[0u32; 9], 1, 3, 3, 1).is_err());
    }

    #[test]
    fn reused_into_buffers_never_leak_between_calls() {
        let mut mbuf = Vec::new();
        let mut obuf = Vec::new();
        prop::check(24, |g| {
            let n = g.usize_in(1, 4);
            let h = 2 * g.usize_in(1, 4);
            let w = 2 * g.usize_in(1, 4);
            let c = g.usize_in(1, 3);
            let xs = g.normals(n * h * w * c);
            let words = g.words(n * h * w * c);
            maxpool2x2_batch_into(&xs, n, h, w, c, &mut mbuf).unwrap();
            ensure_eq(mbuf.clone(), maxpool2x2_batch(&xs, n, h, w, c).unwrap(), "max reuse")?;
            orpool2x2_batch_into(&words, n, h, w, c, &mut obuf).unwrap();
            ensure_eq(obuf.clone(), orpool2x2_batch(&words, n, h, w, c).unwrap(), "or reuse")?;
            Ok(())
        });
    }
}
