//! Implicit-GEMM binarized convolution (the paper's stated future work,
//! Section 5: "extend this work to alternative convolution algorithms
//! such as implicit GEMM, which can be faster than explicit GEMM").
//!
//! Instead of materializing the (H·W, K·K·NW) patch matrix and calling
//! `bgemm`, the window walk happens inline per output pixel: each
//! (dy, dx) contributes `popcount(words[iy, ix] ^ w[o, dy, dx])`, and
//! out-of-bounds taps contribute `popcount(w)` (pad word 0 == all −1,
//! identical semantics to the explicit path's zero-word gather — tested
//! bit-exact against it).
//!
//! Operates in the channel-packed domain (the conv2 layout: NW words of
//! 32 channel bits per pixel).

/// Direct packed 'same' convolution.
///
/// `words`: (H, W, NW) u32; `wt`: (O, K*K*NW) u32 channel-packed weight
/// rows; returns (H*W, O) i32 counts — identical to
/// `bgemm(im2col_words(words), wt)`.
pub fn conv_packed_direct(
    words: &[u32],
    h: usize,
    w: usize,
    nw: usize,
    wt: &[u32],
    o: usize,
    k: usize,
    d_real: usize,
) -> Vec<i32> {
    assert_eq!(words.len(), h * w * nw);
    let kkn = k * k * nw;
    assert_eq!(wt.len(), o * kkn);
    let r = (k - 1) / 2;
    let d = d_real as i32;
    // interior rows ride the dispatched word-popcount microkernel
    // (resolved once per call); the border path stays scalar — its
    // per-tap runs are NW words long, below any SIMD break-even
    let kind = crate::platform::dispatch::current();
    // per-tap weight popcounts: the padding contribution of tap j for
    // output channel oc (hoisted so border pixels stay cheap)
    let mut pad_pc = vec![0u32; o * k * k];
    for oc in 0..o {
        for j in 0..k * k {
            let mut pc = 0u32;
            for wi in 0..nw {
                pc += wt[oc * kkn + j * nw + wi].count_ones();
            }
            pad_pc[oc * k * k + j] = pc;
        }
    }
    // cumulative pad popcount per channel (all taps) minus interior taps
    // is handled per-pixel below; interior pixels take the fast path.
    let mut out = vec![0i32; h * w * o];
    for oy in 0..h {
        for ox in 0..w {
            let interior =
                oy >= r && oy + r < h && ox >= r && ox + r < w;
            let orow = &mut out[(oy * w + ox) * o..(oy * w + ox + 1) * o];
            if interior {
                // fast path: every tap valid; each dy contributes one
                // contiguous k*nw run in both operands, so the xor+
                // popcount rides the u64-widened helper
                let y0 = oy - r;
                let x0 = ox - r;
                for oc in 0..o {
                    let wrow = &wt[oc * kkn..(oc + 1) * kkn];
                    let mut pc = 0u32;
                    for dy in 0..k {
                        let base = ((y0 + dy) * w + x0) * nw;
                        pc += crate::bnn::microkernel::xorpop_words(
                            kind,
                            &words[base..base + k * nw],
                            &wrow[dy * k * nw..(dy + 1) * k * nw],
                        );
                    }
                    orow[oc] = d - 2 * pc as i32;
                }
            } else {
                for oc in 0..o {
                    let wrow = &wt[oc * kkn..(oc + 1) * kkn];
                    let pads = &pad_pc[oc * k * k..(oc + 1) * k * k];
                    let mut pc = 0u32;
                    for dy in 0..k {
                        let iy = oy as isize + dy as isize - r as isize;
                        for dx in 0..k {
                            let ix = ox as isize + dx as isize - r as isize;
                            let j = dy * k + dx;
                            if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                                let src = ((iy as usize) * w + ix as usize) * nw;
                                for wi in 0..nw {
                                    pc += (words[src + wi] ^ wrow[j * nw + wi]).count_ones();
                                }
                            } else {
                                pc += pads[j]; // xor with zero pad word
                            }
                        }
                    }
                    orow[oc] = d - 2 * pc as i32;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::{bgemm, im2col};
    use crate::util::prop::{self, ensure_eq};

    #[test]
    fn matches_explicit_gemm_path() {
        prop::check(24, |g| {
            let h = g.usize_in(2, 10);
            let w = g.usize_in(2, 10);
            let nw = g.usize_in(1, 2);
            let o = g.usize_in(1, 8);
            let k = *g.pick(&[1usize, 3, 5]);
            let d = k * k * nw * 32;
            let words = g.words(h * w * nw);
            let wt = g.words(o * k * k * nw);
            let explicit = {
                let cols = im2col::im2col_words(&words, h, w, nw, k);
                bgemm::bgemm(&cols, &wt, h * w, o, k * k * nw, d)
            };
            let implicit = conv_packed_direct(&words, h, w, nw, &wt, o, k, d);
            ensure_eq(implicit, explicit, "implicit == explicit GEMM")
        });
    }

    #[test]
    fn conv2_paper_shape() {
        let mut rng = crate::util::rng::Xoshiro256::new(2);
        let words: Vec<u32> = (0..48 * 48).map(|_| rng.next_u32()).collect();
        let wt: Vec<u32> = (0..32 * 25).map(|_| rng.next_u32()).collect();
        let implicit = conv_packed_direct(&words, 48, 48, 1, &wt, 32, 5, 800);
        let cols = im2col::im2col_words(&words, 48, 48, 1, 5);
        let explicit = bgemm::bgemm(&cols, &wt, 48 * 48, 32, 25, 800);
        assert_eq!(implicit, explicit);
    }

    #[test]
    fn k1_is_pointwise() {
        // K=1: conv == per-pixel packed dot
        let words = vec![0xF0F0_F0F0u32, 0x0F0F_0F0Fu32];
        let wt = vec![0xFFFF_FFFFu32];
        let out = conv_packed_direct(&words, 1, 2, 1, &wt, 1, 1, 32);
        assert_eq!(out[0], 32 - 2 * 16);
        assert_eq!(out[1], 32 - 2 * 16);
    }
}
