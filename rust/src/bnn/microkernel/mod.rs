//! Runtime-dispatched XNOR-popcount microkernels — the tier every
//! popcount consumer (`bgemm_prewidened`, the fused
//! `bgemm_threshold_into` epilogue, the packed FC dots, and
//! `conv_direct`'s interior walk) routes through.
//!
//! Four tiers above the seed scalar kernels, selected per call by
//! [`crate::platform::dispatch::current`]:
//!
//! * **scalar** — the seed rowwise kernels, unchanged (the reference
//!   every other tier is property-tested bit-identical against);
//! * **tiled** ([`tiled`]) — MR=4 register tiling: each weight row
//!   streamed once per four patch rows;
//! * **swar** ([`swar`]) — Harley–Seal carry-save popcount for long-K
//!   rows (~1 `count_ones` retired per 8 u64 lanes);
//! * **avx2 / neon** ([`simd`]) — `std::arch` vector popcounts, the one
//!   audited `unsafe` module in the crate.
//!
//! Bit-identity is by construction, not by luck: every tier computes
//! the same exact integer `popcount(a ^ w)` sums, only grouped
//! differently, so no accumulation order can change an output.  That
//! invariant is what lets a runtime kernel choice sit *under* the
//! proof-carrying plan machinery without touching it — the verifier and
//! equivalence checker reason about counts, and the counts are
//! identical on every path.  The forced-dispatch suite below pins this
//! for all kernels × lane widths (L=1/2/13/dyn) × all four consumers.

pub mod simd;
pub mod swar;
pub mod tiled;

use crate::bnn::bgemm::{lanes, widen_row};
use crate::bnn::packing::threshold_bit;
use crate::platform::dispatch::KernelKind;

/// Lanes a rowwise driver holds on the stack before spilling to heap
/// scratch (16 covers every layer of this network: L=1/2/13).
pub(crate) const STACK_LANES: usize = 16;

/// Scratch selection: the stack buffer when it fits, else the heap
/// vector resized to `need` (zero-filled only on growth — callers
/// overwrite every lane they read, see `widen_row`'s contract).
#[inline]
pub(crate) fn lane_scratch<'s>(
    stack: &'s mut [u64],
    heap: &'s mut Vec<u64>,
    need: usize,
) -> &'s mut [u64] {
    if need <= stack.len() {
        &mut stack[..need]
    } else {
        heap.resize(need, 0);
        &mut heap[..need]
    }
}

/// Dispatched `popcount(a ^ b)` over u64 lane rows.  SIMD kinds on the
/// wrong architecture fall back to scalar (the dispatcher never routes
/// them there; this keeps the match total without `unreachable!`).
#[inline]
pub fn xorpop_lanes(kind: KernelKind, a: &[u64], b: &[u64]) -> u32 {
    match kind {
        KernelKind::Swar => swar::xorpop_csa(a, b),
        #[cfg(target_arch = "x86_64")]
        KernelKind::Avx2 => simd::xorpop_u64_avx2(a, b),
        #[cfg(target_arch = "aarch64")]
        KernelKind::Neon => simd::xorpop_u64_neon(a, b),
        _ => a.iter().zip(b).map(|(x, y)| (x ^ y).count_ones()).sum(),
    }
}

/// Dispatched `popcount(a ^ b)` over u32 word rows (the FC dot and
/// `conv_direct` operand shape).  Scalar/tiled take the seed
/// `xor_popcount` fuse-pair walk.
#[inline]
pub fn xorpop_words(kind: KernelKind, a: &[u32], b: &[u32]) -> u32 {
    match kind {
        KernelKind::Swar => swar::xorpop_words_csa(a, b),
        #[cfg(target_arch = "x86_64")]
        KernelKind::Avx2 => simd::xorpop_u32_avx2(a, b),
        #[cfg(target_arch = "aarch64")]
        KernelKind::Neon => simd::xorpop_u32_neon(a, b),
        _ => crate::bnn::packing::xor_popcount(a, b),
    }
}

/// `bgemm_prewidened` under an explicit kernel choice: (M, KW) packed
/// rows × pre-widened (N, L) weights → (M, N) i32 counts.
///
/// Write coverage: assigns every element of `out` (len M·N) exactly
/// once on every kernel path; prior contents are never read.
pub fn bgemm_with(
    kind: KernelKind,
    a: &[u32],
    w64: &[u64],
    m: usize,
    n: usize,
    kw: usize,
    d_real: usize,
    out: &mut [i32],
) {
    assert_eq!(a.len(), m * kw);
    let l = lanes(kw);
    assert_eq!(w64.len(), n * l);
    assert_eq!(out.len(), m * n);
    let d = d_real as i32;
    match kind {
        KernelKind::Scalar => crate::bnn::bgemm::bgemm_scalar(a, w64, m, n, kw, d, out),
        KernelKind::Tiled => tiled::bgemm_fill(a, w64, m, n, kw, d, out),
        _ => bgemm_rowwise(kind, a, w64, m, n, kw, d, out),
    }
}

/// Rowwise GEMM driver over the dispatched lane popcount (the SWAR and
/// SIMD tiers keep the seed loop structure and swap the reduction).
fn bgemm_rowwise(
    kind: KernelKind,
    a: &[u32],
    w64: &[u64],
    m: usize,
    n: usize,
    kw: usize,
    d: i32,
    out: &mut [i32],
) {
    let l = lanes(kw);
    let mut stack = [0u64; STACK_LANES];
    let mut heap = Vec::new();
    let arow = lane_scratch(&mut stack, &mut heap, l);
    for mi in 0..m {
        widen_row(&a[mi * kw..(mi + 1) * kw], arow);
        let orow = &mut out[mi * n..(mi + 1) * n];
        for ni in 0..n {
            let pc = xorpop_lanes(kind, arow, &w64[ni * l..(ni + 1) * l]);
            orow[ni] = d - 2 * pc as i32;
        }
    }
}

/// `bgemm_threshold_into` under an explicit kernel choice: fused GEMM +
/// per-channel threshold epilogue, channel bits packed MSB-first into
/// one u32 word per patch row.
///
/// Write coverage: resizes `out` to exactly M and assigns every word;
/// resizes `counts` (when present) to exactly M·N and assigns every
/// element; prior contents are never read, on every kernel path.
#[allow(clippy::too_many_arguments)]
pub fn bgemm_threshold_with(
    kind: KernelKind,
    a: &[u32],
    w64: &[u64],
    m: usize,
    n: usize,
    kw: usize,
    d_real: usize,
    theta: &[f32],
    flip: &[u32],
    cmp_bias: i32,
    out: &mut Vec<u32>,
    mut counts: Option<&mut Vec<i32>>,
) {
    assert_eq!(a.len(), m * kw);
    let l = lanes(kw);
    assert_eq!(w64.len(), n * l);
    assert!(n <= 32, "fused epilogue packs all channels into one word");
    assert_eq!(theta.len(), n);
    assert_eq!(flip.len(), n);
    out.resize(m, 0);
    if let Some(c) = counts.as_deref_mut() {
        c.resize(m * n, 0);
    }
    let d = d_real as i32;
    let counts = counts.map(Vec::as_mut_slice);
    match kind {
        KernelKind::Tiled => {
            tiled::threshold_fill(a, w64, m, n, kw, d, theta, flip, cmp_bias, out, counts);
        }
        _ => threshold_rowwise(kind, a, w64, m, n, kw, d, theta, flip, cmp_bias, out, counts),
    }
}

/// Rowwise fused-threshold driver over the dispatched lane popcount
/// (scalar kind reproduces the seed epilogue loop exactly).
#[allow(clippy::too_many_arguments)]
fn threshold_rowwise(
    kind: KernelKind,
    a: &[u32],
    w64: &[u64],
    m: usize,
    n: usize,
    kw: usize,
    d: i32,
    theta: &[f32],
    flip: &[u32],
    cmp_bias: i32,
    out: &mut [u32],
    mut counts: Option<&mut [i32]>,
) {
    let l = lanes(kw);
    let mut stack = [0u64; STACK_LANES];
    let mut heap = Vec::new();
    let arow = lane_scratch(&mut stack, &mut heap, l);
    for mi in 0..m {
        widen_row(&a[mi * kw..(mi + 1) * kw], arow);
        let mut word = 0u32;
        for ni in 0..n {
            let pc = xorpop_lanes(kind, arow, &w64[ni * l..(ni + 1) * l]);
            let count = d - 2 * pc as i32;
            if let Some(c) = counts.as_deref_mut() {
                c[mi * n + ni] = count;
            }
            word |= threshold_bit((count + cmp_bias) as f32, theta[ni], flip[ni]) << (31 - ni);
        }
        out[mi] = word;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::bgemm::{bgemm_prewidened, bgemm_threshold_into, widen_weights};
    use crate::platform::dispatch::{self, kernel_env_guard, KERNEL_ENV};
    use crate::util::prop::{self, ensure_eq};

    /// Every kernel that can run on this machine (the others are pinned
    /// on their own architectures; the dispatcher never selects them
    /// here).
    fn runnable() -> Vec<KernelKind> {
        KernelKind::ALL.into_iter().filter(|k| k.available()).collect()
    }

    // KW word widths covering every lane class: L=1 (gray conv1), L=2
    // (rgb conv1), L=13 (conv2), L=4 dyn, L=20 (> STACK_LANES: heap
    // scratch + multi-block Harley-Seal)
    const KWS: [usize; 5] = [1, 3, 25, 7, 40];

    #[test]
    fn every_kernel_matches_the_scalar_reference_gemm() {
        prop::check(24, |g| {
            for kw in KWS {
                let d = kw * 32;
                let m = g.usize_in(1, 9);
                let n = g.usize_in(1, 8);
                let a = g.words(m * kw);
                let w = g.words(n * kw);
                let w64 = widen_weights(&w, n, kw);
                let mut want = vec![0i32; m * n];
                bgemm_with(KernelKind::Scalar, &a, &w64, m, n, kw, d, &mut want);
                for kind in runnable() {
                    let mut got = vec![i32::MIN; m * n]; // dirty
                    bgemm_with(kind, &a, &w64, m, n, kw, d, &mut got);
                    ensure_eq(
                        got,
                        want.clone(),
                        &format!("{} == scalar, kw={kw}", kind.name()),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn every_kernel_matches_the_fused_threshold_epilogue() {
        prop::check(16, |g| {
            for kw in KWS {
                let d = kw * 32;
                let m = g.usize_in(1, 9);
                let n = g.usize_in(1, 32);
                let a = g.words(m * kw);
                let w = g.words(n * kw);
                let theta = g.normals(n);
                let flip = g.bits(n);
                let bias = *g.pick(&[0i32, 1, -3]);
                let w64 = widen_weights(&w, n, kw);
                let mut want_w = Vec::new();
                let mut want_c = Vec::new();
                bgemm_threshold_with(
                    KernelKind::Scalar,
                    &a,
                    &w64,
                    m,
                    n,
                    kw,
                    d,
                    &theta,
                    &flip,
                    bias,
                    &mut want_w,
                    Some(&mut want_c),
                );
                for kind in runnable() {
                    // dirty + wrongly-sized buffers: the driver must
                    // resize and fully overwrite on every path
                    let mut got_w = vec![9u32; 3];
                    let mut got_c = vec![7i32; 1];
                    bgemm_threshold_with(
                        kind,
                        &a,
                        &w64,
                        m,
                        n,
                        kw,
                        d,
                        &theta,
                        &flip,
                        bias,
                        &mut got_w,
                        Some(&mut got_c),
                    );
                    ensure_eq(
                        got_w.clone(),
                        want_w.clone(),
                        &format!("{} threshold words, kw={kw}", kind.name()),
                    )?;
                    ensure_eq(
                        got_c,
                        want_c.clone(),
                        &format!("{} threshold counts, kw={kw}", kind.name()),
                    )?;
                    // elided counts never change the words
                    let mut elided = Vec::new();
                    bgemm_threshold_with(
                        kind, &a, &w64, m, n, kw, d, &theta, &flip, bias, &mut elided, None,
                    );
                    ensure_eq(
                        elided,
                        got_w,
                        &format!("{} elided == staged, kw={kw}", kind.name()),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn popcount_primitives_match_scalar_for_every_length() {
        prop::check(32, |g| {
            let n = g.usize_in(0, 45);
            let a64: Vec<u64> = (0..n).map(|_| g.u64()).collect();
            let b64: Vec<u64> = (0..n).map(|_| g.u64()).collect();
            let want64 = xorpop_lanes(KernelKind::Scalar, &a64, &b64);
            let aw = g.words(n);
            let bw = g.words(n);
            let wantw = xorpop_words(KernelKind::Scalar, &aw, &bw);
            for kind in runnable() {
                ensure_eq(
                    xorpop_lanes(kind, &a64, &b64),
                    want64,
                    &format!("{} lanes n={n}", kind.name()),
                )?;
                ensure_eq(
                    xorpop_words(kind, &aw, &bw),
                    wantw,
                    &format!("{} words n={n}", kind.name()),
                )?;
            }
            Ok(())
        });
    }

    /// The satellite forced-dispatch suite: `BCNN_KERNEL` steers all
    /// four consumers — `bgemm_prewidened`, `bgemm_threshold_into`,
    /// `fc_packed_batch`, `conv_packed_direct` — and every forced
    /// kernel is bit-identical to the forced-scalar baseline.  Env
    /// mutation is serialized through the shared kernel-env guard
    /// (same pattern as the corrupt-plan loader hooks).
    #[test]
    fn forced_dispatch_is_bit_identical_across_all_consumers() {
        use crate::bnn::{conv_direct, fc, im2col};
        let env = kernel_env_guard();
        let mut g = crate::util::rng::Xoshiro256::new(0xD15);
        // conv-shaped problem reused across kernels: H=6, W=5, NW=2, K=3
        let (h, w_, nw, o, k) = (6usize, 5usize, 2usize, 8usize, 3usize);
        let d_conv = k * k * nw * 32;
        let words: Vec<u32> = (0..h * w_ * nw).map(|_| g.next_u32()).collect();
        let wt: Vec<u32> = (0..o * k * k * nw).map(|_| g.next_u32()).collect();
        let cols = im2col::im2col_words(&words, h, w_, nw, k);
        let kw = k * k * nw;
        let w64 = widen_weights(&wt, o, kw);
        let theta: Vec<f32> = (0..o).map(|i| i as f32 - 3.5).collect();
        let flip: Vec<u32> = (0..o as u32).map(|i| i & 1).collect();
        // FC-shaped problem: N=3 images, L=5 rows, KW=17 (odd tail)
        let (fn_, fl, fkw) = (3usize, 5usize, 17usize);
        let xs: Vec<u32> = (0..fn_ * fkw).map(|_| g.next_u32()).collect();
        let fwt: Vec<u32> = (0..fl * fkw).map(|_| g.next_u32()).collect();

        let run = |kernel: &str| {
            std::env::set_var(KERNEL_ENV, kernel);
            let mut gemm = vec![0i32; h * w_ * o];
            bgemm_prewidened(&cols, &w64, h * w_, o, kw, d_conv, &mut gemm);
            let mut thr = Vec::new();
            let mut cnt = Vec::new();
            bgemm_threshold_into(
                &cols, &w64, h * w_, o, kw, d_conv, &theta, &flip, 0, &mut thr, Some(&mut cnt),
            );
            let fc_out = fc::fc_packed_batch(&xs, &fwt, fn_, fl, fkw, fkw * 32);
            let direct = conv_direct::conv_packed_direct(&words, h, w_, nw, &wt, o, k, d_conv);
            std::env::remove_var(KERNEL_ENV);
            (gemm, thr, cnt, fc_out, direct)
        };

        let baseline = run("scalar");
        for kind in KernelKind::ALL {
            if !kind.available() {
                continue;
            }
            let got = run(kind.name());
            assert_eq!(got, baseline, "BCNN_KERNEL={} vs scalar", kind.name());
        }
        // an unavailable override must serve detection's choice, still
        // bit-identical (never an error, never a wrong count)
        let fallback = run("no-such-kernel");
        assert_eq!(fallback, baseline, "unknown override falls back");
        assert_eq!(dispatch::current(), dispatch::detect());
        drop(env);
    }
}
