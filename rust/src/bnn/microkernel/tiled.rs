//! MR=4 register-tiled XNOR-GEMM — the weight-reuse tier.
//!
//! The seed rowwise kernel streams every weight row from L1 once per
//! patch row: N·L u64 loads per row, M·N·L total.  Tiling MR=4 widened
//! A-rows at a time cuts the weight traffic by MR — each `w64` row is
//! loaded once per *tile* and xor'd against four resident A-rows on
//! four independent accumulators (the same ILP structure the FC dot
//! uses, here across rows instead of lanes).  This is the CPU
//! translation of the paper's shared-memory tiling: operand reuse moved
//! up one level of the memory hierarchy, arithmetic untouched — counts
//! are exact integer popcount sums, so the tiled walk is bit-identical
//! to the rowwise walk by construction.
//!
//! ```text
//!          w64 row ni (L lanes, loaded once per tile)
//!             │
//!   a row 0 ──xor─pop──► acc0 ──► out[mi+0, ni]
//!   a row 1 ──xor─pop──► acc1 ──► out[mi+1, ni]
//!   a row 2 ──xor─pop──► acc2 ──► out[mi+2, ni]
//!   a row 3 ──xor─pop──► acc3 ──► out[mi+3, ni]
//! ```
//!
//! Tail rows (M % 4) fall back to the rowwise walk.  NR is effectively
//! N (all 32 output channels of this network fit the pass); the MR
//! knob is the one that moves weight traffic.

use crate::bnn::bgemm::{lanes, widen_row};
use crate::bnn::packing::threshold_bit;

/// A-rows held widened per tile.
pub const MR: usize = 4;

/// Register-tiled `bgemm_prewidened` body: (M, KW) packed rows against
/// pre-widened (N, L) weights into (M, N) counts.  Caller has checked
/// the shape invariants.
pub(super) fn bgemm_fill(
    a: &[u32],
    w64: &[u64],
    m: usize,
    n: usize,
    kw: usize,
    d: i32,
    out: &mut [i32],
) {
    let l = lanes(kw);
    let mut stack = [0u64; MR * super::STACK_LANES];
    let mut heap = Vec::new();
    let arows = super::lane_scratch(&mut stack, &mut heap, MR * l);
    let mut mi = 0;
    while mi + MR <= m {
        for r in 0..MR {
            widen_row(&a[(mi + r) * kw..(mi + r + 1) * kw], &mut arows[r * l..(r + 1) * l]);
        }
        for ni in 0..n {
            let wrow = &w64[ni * l..(ni + 1) * l];
            let (mut p0, mut p1, mut p2, mut p3) = (0u32, 0u32, 0u32, 0u32);
            for (i, &wv) in wrow.iter().enumerate() {
                p0 += (arows[i] ^ wv).count_ones();
                p1 += (arows[l + i] ^ wv).count_ones();
                p2 += (arows[2 * l + i] ^ wv).count_ones();
                p3 += (arows[3 * l + i] ^ wv).count_ones();
            }
            out[mi * n + ni] = d - 2 * p0 as i32;
            out[(mi + 1) * n + ni] = d - 2 * p1 as i32;
            out[(mi + 2) * n + ni] = d - 2 * p2 as i32;
            out[(mi + 3) * n + ni] = d - 2 * p3 as i32;
        }
        mi += MR;
    }
    for r in mi..m {
        widen_row(&a[r * kw..(r + 1) * kw], &mut arows[..l]);
        let orow = &mut out[r * n..(r + 1) * n];
        for ni in 0..n {
            let wrow = &w64[ni * l..(ni + 1) * l];
            let mut pc = 0u32;
            for (x, y) in arows[..l].iter().zip(wrow) {
                pc += (x ^ y).count_ones();
            }
            orow[ni] = d - 2 * pc as i32;
        }
    }
}

/// Register-tiled fused GEMM + threshold epilogue body: four channel
/// words build up in registers across the ni loop, one per resident
/// A-row.  Caller has checked shapes and sized `out`/`counts`.
#[allow(clippy::too_many_arguments)]
pub(super) fn threshold_fill(
    a: &[u32],
    w64: &[u64],
    m: usize,
    n: usize,
    kw: usize,
    d: i32,
    theta: &[f32],
    flip: &[u32],
    cmp_bias: i32,
    out: &mut [u32],
    mut counts: Option<&mut [i32]>,
) {
    let l = lanes(kw);
    let mut stack = [0u64; MR * super::STACK_LANES];
    let mut heap = Vec::new();
    let arows = super::lane_scratch(&mut stack, &mut heap, MR * l);
    let mut mi = 0;
    while mi + MR <= m {
        for r in 0..MR {
            widen_row(&a[(mi + r) * kw..(mi + r + 1) * kw], &mut arows[r * l..(r + 1) * l]);
        }
        let mut words = [0u32; MR];
        for ni in 0..n {
            let wrow = &w64[ni * l..(ni + 1) * l];
            let (mut p0, mut p1, mut p2, mut p3) = (0u32, 0u32, 0u32, 0u32);
            for (i, &wv) in wrow.iter().enumerate() {
                p0 += (arows[i] ^ wv).count_ones();
                p1 += (arows[l + i] ^ wv).count_ones();
                p2 += (arows[2 * l + i] ^ wv).count_ones();
                p3 += (arows[3 * l + i] ^ wv).count_ones();
            }
            for (r, &pc) in [p0, p1, p2, p3].iter().enumerate() {
                let count = d - 2 * pc as i32;
                if let Some(c) = counts.as_deref_mut() {
                    c[(mi + r) * n + ni] = count;
                }
                words[r] |=
                    threshold_bit((count + cmp_bias) as f32, theta[ni], flip[ni]) << (31 - ni);
            }
        }
        out[mi..mi + MR].copy_from_slice(&words);
        mi += MR;
    }
    for r in mi..m {
        widen_row(&a[r * kw..(r + 1) * kw], &mut arows[..l]);
        let mut word = 0u32;
        for ni in 0..n {
            let wrow = &w64[ni * l..(ni + 1) * l];
            let mut pc = 0u32;
            for (x, y) in arows[..l].iter().zip(wrow) {
                pc += (x ^ y).count_ones();
            }
            let count = d - 2 * pc as i32;
            if let Some(c) = counts.as_deref_mut() {
                c[r * n + ni] = count;
            }
            word |= threshold_bit((count + cmp_bias) as f32, theta[ni], flip[ni]) << (31 - ni);
        }
        out[r] = word;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, ensure_eq};

    #[test]
    fn tiled_tail_rows_match_rowwise() {
        // every M % 4 residue, both scratch classes (L <= 16 stack,
        // L > 16 heap), against the scalar reference
        prop::check(32, |g| {
            for kw in [3usize, 25, 40] {
                let m = g.usize_in(1, 9); // residues 0..=3 with tiles
                let n = g.usize_in(1, 8);
                let d = kw * 32;
                let a = g.words(m * kw);
                let w = g.words(n * kw);
                let w64 = crate::bnn::bgemm::widen_weights(&w, n, kw);
                let mut got = vec![i32::MIN; m * n]; // dirty
                bgemm_fill(&a, &w64, m, n, kw, d as i32, &mut got);
                let mut want = vec![0i32; m * n];
                crate::bnn::bgemm::bgemm_scalar(&a, &w64, m, n, kw, d as i32, &mut want);
                ensure_eq(got, want, "tiled == scalar (incl. tail rows)")?;
            }
            Ok(())
        });
    }
}
