//! `std::arch` SIMD popcounts — the ONE audited `unsafe` module.
//!
//! The crate root carries `#![deny(unsafe_code)]`; this module is the
//! single argued exemption (see `lib.rs`), and lint rule F in
//! `scripts/check_invariants.py` mechanically rejects `allow(unsafe_code)`
//! anywhere else in the tree.  The audit boundary is kept narrow on
//! purpose: every `unsafe` here is one of exactly two shapes, each with
//! a local `// SAFETY:` argument —
//!
//! 1. **Calling a `#[target_feature]` fn.**  Sound iff the CPU has the
//!    feature.  Every such fn is private and reachable only through a
//!    safe wrapper that proves the feature first via
//!    `is_x86_feature_detected!` / `is_aarch64_feature_detected!` and
//!    panics otherwise (the dispatcher never routes here without the
//!    feature — the assert is defense in depth, not control flow).
//! 2. **Unaligned vector loads from a slice.**  Sound iff the read
//!    stays in bounds.  Every load pointer derives from a slice whose
//!    length the loop bound has already checked; no pointer survives
//!    the loop, no aliasing is created (loads only), and alignment is
//!    irrelevant by construction (`loadu`/`vld1q` are unaligned ops).
//!
//! The kernels themselves: AVX2 has no vector popcount, so the x86
//! path is the Muła lookup — split each byte into nibbles, table the
//! per-nibble popcount with `_mm256_shuffle_epi8`, horizontal-sum with
//! `_mm256_sad_epu8` into four u64 lanes that accumulate without
//! overflow for any slice this crate can address.  NEON has `vcntq_u8`
//! (per-byte popcount) natively; widening pairwise adds
//! (`vpaddlq_u8/u16/u32`) fold it to u64 lanes.  Both paths finish
//! short tails scalar, so results are bit-identical to the scalar tier
//! for every length — each `#[target_feature]` fn is pinned to the
//! scalar reference by name in the test region below (lint rule F
//! refuses an untested kernel).
#![allow(unsafe_code)]

#[cfg(target_arch = "x86_64")]
pub use x86::{xorpop_u32_avx2, xorpop_u64_avx2};

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::{
        __m256i, _mm256_add_epi64, _mm256_add_epi8, _mm256_and_si256, _mm256_loadu_si256,
        _mm256_sad_epu8, _mm256_set1_epi8, _mm256_setr_epi8, _mm256_setzero_si256,
        _mm256_shuffle_epi8, _mm256_srli_epi32, _mm256_storeu_si256, _mm256_xor_si256,
    };

    /// Muła nibble-popcount of one 256-bit xor'd vector, accumulated
    /// into four per-lane u64 byte-sums.
    ///
    /// # Safety
    /// Caller must have AVX2 enabled (inherited `#[target_feature]`
    /// obligation from the callers below).
    #[inline]
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn accum_popcount_256(acc: __m256i, x: __m256i) -> __m256i {
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, // per-nibble popcounts …
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, // … repeated per 128-bit half
        );
        let low = _mm256_set1_epi8(0x0f);
        let lo = _mm256_and_si256(x, low);
        // srli crosses byte bounds within each 32-bit lane; the mask
        // keeps exactly the original high nibble of every byte
        let hi = _mm256_and_si256(_mm256_srli_epi32(x, 4), low);
        let cnt = _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
        _mm256_add_epi64(acc, _mm256_sad_epu8(cnt, _mm256_setzero_si256()))
    }

    /// Horizontal sum of the four u64 accumulator lanes.
    ///
    /// # Safety
    /// Caller must have AVX2 enabled (inherited obligation).
    #[inline]
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn hsum_u64x4(acc: __m256i) -> u32 {
        let mut lanes = [0u64; 4];
        // SAFETY: storeu writes exactly 32 bytes into the 32-byte array
        unsafe { _mm256_storeu_si256(lanes.as_mut_ptr().cast(), acc) };
        (lanes[0] + lanes[1] + lanes[2] + lanes[3]) as u32
    }

    /// `popcount(a ^ b)` over u64 lanes, AVX2 lookup popcount.
    ///
    /// Safe wrapper: proves AVX2 before entering the
    /// `#[target_feature]` kernel (shape 1 of the module contract).
    pub fn xorpop_u64_avx2(a: &[u64], b: &[u64]) -> u32 {
        assert!(
            std::is_x86_feature_detected!("avx2"),
            "avx2 kernel dispatched on a cpu without avx2"
        );
        // SAFETY: AVX2 presence proven on the line above; the kernel
        // reads only within the slice bounds it checks.
        unsafe { xorpop_u64_avx2_impl(a, b) }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn xorpop_u64_avx2_impl(a: &[u64], b: &[u64]) -> u32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len().min(b.len());
        let mut acc = _mm256_setzero_si256();
        let mut i = 0;
        while i + 4 <= n {
            // SAFETY: i+4 <= n <= len of both slices, so each load
            // reads 32 in-bounds bytes; loadu has no alignment demand
            let (va, vb) = unsafe {
                (
                    _mm256_loadu_si256(a.as_ptr().add(i).cast()),
                    _mm256_loadu_si256(b.as_ptr().add(i).cast()),
                )
            };
            acc = accum_popcount_256(acc, _mm256_xor_si256(va, vb));
            i += 4;
        }
        let mut total = hsum_u64x4(acc);
        while i < n {
            total += (a[i] ^ b[i]).count_ones();
            i += 1;
        }
        total
    }

    /// `popcount(a ^ b)` over u32 words, AVX2 lookup popcount (eight
    /// words per vector).
    ///
    /// Safe wrapper: proves AVX2 before entering the
    /// `#[target_feature]` kernel (shape 1 of the module contract).
    pub fn xorpop_u32_avx2(a: &[u32], b: &[u32]) -> u32 {
        assert!(
            std::is_x86_feature_detected!("avx2"),
            "avx2 kernel dispatched on a cpu without avx2"
        );
        // SAFETY: AVX2 presence proven on the line above; the kernel
        // reads only within the slice bounds it checks.
        unsafe { xorpop_u32_avx2_impl(a, b) }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn xorpop_u32_avx2_impl(a: &[u32], b: &[u32]) -> u32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len().min(b.len());
        let mut acc = _mm256_setzero_si256();
        let mut i = 0;
        while i + 8 <= n {
            // SAFETY: i+8 <= n <= len of both slices, so each load
            // reads 32 in-bounds bytes; loadu has no alignment demand
            let (va, vb) = unsafe {
                (
                    _mm256_loadu_si256(a.as_ptr().add(i).cast()),
                    _mm256_loadu_si256(b.as_ptr().add(i).cast()),
                )
            };
            acc = accum_popcount_256(acc, _mm256_xor_si256(va, vb));
            i += 8;
        }
        let mut total = hsum_u64x4(acc);
        while i < n {
            total += (a[i] ^ b[i]).count_ones();
            i += 1;
        }
        total
    }
}

#[cfg(target_arch = "aarch64")]
pub use arm::{xorpop_u32_neon, xorpop_u64_neon};

#[cfg(target_arch = "aarch64")]
mod arm {
    use std::arch::aarch64::{
        vaddq_u64, vcntq_u8, vdupq_n_u64, veorq_u32, veorq_u64, vgetq_lane_u64, vld1q_u32,
        vld1q_u64, vpaddlq_u16, vpaddlq_u32, vpaddlq_u8, vreinterpretq_u8_u32,
        vreinterpretq_u8_u64,
    };

    /// `popcount(a ^ b)` over u64 lanes, NEON `vcntq_u8`.
    ///
    /// Safe wrapper: proves NEON before entering the
    /// `#[target_feature]` kernel (shape 1 of the module contract;
    /// NEON is baseline on aarch64 — the probe is defense in depth).
    pub fn xorpop_u64_neon(a: &[u64], b: &[u64]) -> u32 {
        assert!(
            std::arch::is_aarch64_feature_detected!("neon"),
            "neon kernel dispatched on a cpu without neon"
        );
        // SAFETY: NEON presence proven on the line above; the kernel
        // reads only within the slice bounds it checks.
        unsafe { xorpop_u64_neon_impl(a, b) }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn xorpop_u64_neon_impl(a: &[u64], b: &[u64]) -> u32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len().min(b.len());
        let mut acc = vdupq_n_u64(0);
        let mut i = 0;
        while i + 2 <= n {
            // SAFETY: i+2 <= n <= len of both slices, so each load
            // reads 16 in-bounds bytes; vld1q has no alignment demand
            let (va, vb) = unsafe { (vld1q_u64(a.as_ptr().add(i)), vld1q_u64(b.as_ptr().add(i))) };
            let bytes = vcntq_u8(vreinterpretq_u8_u64(veorq_u64(va, vb)));
            acc = vaddq_u64(acc, vpaddlq_u32(vpaddlq_u16(vpaddlq_u8(bytes))));
            i += 2;
        }
        let mut total = (vgetq_lane_u64::<0>(acc) + vgetq_lane_u64::<1>(acc)) as u32;
        while i < n {
            total += (a[i] ^ b[i]).count_ones();
            i += 1;
        }
        total
    }

    /// `popcount(a ^ b)` over u32 words, NEON `vcntq_u8` (four words
    /// per vector).
    ///
    /// Safe wrapper: proves NEON before entering the
    /// `#[target_feature]` kernel (shape 1 of the module contract).
    pub fn xorpop_u32_neon(a: &[u32], b: &[u32]) -> u32 {
        assert!(
            std::arch::is_aarch64_feature_detected!("neon"),
            "neon kernel dispatched on a cpu without neon"
        );
        // SAFETY: NEON presence proven on the line above; the kernel
        // reads only within the slice bounds it checks.
        unsafe { xorpop_u32_neon_impl(a, b) }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn xorpop_u32_neon_impl(a: &[u32], b: &[u32]) -> u32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len().min(b.len());
        let mut acc = vdupq_n_u64(0);
        let mut i = 0;
        while i + 4 <= n {
            // SAFETY: i+4 <= n <= len of both slices, so each load
            // reads 16 in-bounds bytes; vld1q has no alignment demand
            let (va, vb) = unsafe { (vld1q_u32(a.as_ptr().add(i)), vld1q_u32(b.as_ptr().add(i))) };
            let bytes = vcntq_u8(vreinterpretq_u8_u32(veorq_u32(va, vb)));
            acc = vaddq_u64(acc, vpaddlq_u32(vpaddlq_u16(vpaddlq_u8(bytes))));
            i += 4;
        }
        let mut total = (vgetq_lane_u64::<0>(acc) + vgetq_lane_u64::<1>(acc)) as u32;
        while i < n {
            total += (a[i] ^ b[i]).count_ones();
            i += 1;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    // Bit-identity pins for every `#[target_feature]` kernel, by name
    // (lint rule F keys on these): each `_impl` is driven directly in
    // an unsafe block AND through its safe wrapper, against the scalar
    // reference, across vector-width boundaries and scalar tails.
    #[allow(unused_imports)]
    use crate::util::prop::{self, ensure_eq};

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_kernels_are_bit_identical_to_scalar() {
        use super::x86::{xorpop_u32_avx2, xorpop_u64_avx2};
        if !std::is_x86_feature_detected!("avx2") {
            return; // nothing to pin on this machine; CI hosts have AVX2
        }
        prop::check(48, |g| {
            let n = g.usize_in(0, 37); // crosses 0/partial/multiple vectors
            let a64: Vec<u64> = (0..n).map(|_| g.u64()).collect();
            let b64: Vec<u64> = (0..n).map(|_| g.u64()).collect();
            let want64: u32 = a64.iter().zip(&b64).map(|(x, y)| (x ^ y).count_ones()).sum();
            ensure_eq(xorpop_u64_avx2(&a64, &b64), want64, "u64 wrapper")?;
            // SAFETY: avx2 proven above; direct call pins xorpop_u64_avx2_impl
            let direct = unsafe { super::x86::xorpop_u64_avx2_impl(&a64, &b64) };
            ensure_eq(direct, want64, "xorpop_u64_avx2_impl")?;
            // one full vector through the two `#[target_feature]`
            // helpers: accum_popcount_256 then hsum_u64x4 must equal
            // the scalar popcount of the four lanes
            let v = [g.u64(), g.u64(), g.u64(), g.u64()];
            let want_v: u32 = v.iter().map(|x| x.count_ones()).sum();
            // SAFETY: avx2 proven above; loadu reads the 32-byte array
            let got_v = unsafe {
                use std::arch::x86_64::{_mm256_loadu_si256, _mm256_setzero_si256};
                let x = _mm256_loadu_si256(v.as_ptr().cast());
                super::x86::hsum_u64x4(super::x86::accum_popcount_256(
                    _mm256_setzero_si256(),
                    x,
                ))
            };
            ensure_eq(got_v, want_v, "accum_popcount_256 + hsum_u64x4")?;
            let aw = g.words(2 * n + 1);
            let bw = g.words(2 * n + 1);
            let wantw = crate::bnn::packing::xor_popcount(&aw, &bw);
            ensure_eq(xorpop_u32_avx2(&aw, &bw), wantw, "u32 wrapper")?;
            // SAFETY: avx2 proven above; direct call pins xorpop_u32_avx2_impl
            let directw = unsafe { super::x86::xorpop_u32_avx2_impl(&aw, &bw) };
            ensure_eq(directw, wantw, "xorpop_u32_avx2_impl")
        });
    }

    #[cfg(target_arch = "aarch64")]
    #[test]
    fn neon_kernels_are_bit_identical_to_scalar() {
        use super::arm::{xorpop_u32_neon, xorpop_u64_neon};
        if !std::arch::is_aarch64_feature_detected!("neon") {
            return;
        }
        prop::check(48, |g| {
            let n = g.usize_in(0, 37);
            let a64: Vec<u64> = (0..n).map(|_| g.u64()).collect();
            let b64: Vec<u64> = (0..n).map(|_| g.u64()).collect();
            let want64: u32 = a64.iter().zip(&b64).map(|(x, y)| (x ^ y).count_ones()).sum();
            ensure_eq(xorpop_u64_neon(&a64, &b64), want64, "u64 wrapper")?;
            // SAFETY: neon proven above; direct call pins xorpop_u64_neon_impl
            let direct = unsafe { super::arm::xorpop_u64_neon_impl(&a64, &b64) };
            ensure_eq(direct, want64, "xorpop_u64_neon_impl")?;
            let aw = g.words(2 * n + 1);
            let bw = g.words(2 * n + 1);
            let wantw = crate::bnn::packing::xor_popcount(&aw, &bw);
            ensure_eq(xorpop_u32_neon(&aw, &bw), wantw, "u32 wrapper")?;
            // SAFETY: neon proven above; direct call pins xorpop_u32_neon_impl
            let directw = unsafe { super::arm::xorpop_u32_neon_impl(&aw, &bw) };
            ensure_eq(directw, wantw, "xorpop_u32_neon_impl")
        });
    }
}
