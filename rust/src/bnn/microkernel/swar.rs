//! SWAR Harley–Seal carry-save popcount — the long-K tier.
//!
//! The rowwise kernels retire one `count_ones` per u64 lane; for long-K
//! layers (conv2's L=13, the FC's 288 fused lanes) the popcount itself
//! becomes the bottleneck.  A carry-save adder (CSA) tree defers it:
//! three one-bit-per-position partial sums combine into a (sum, carry)
//! pair with five logic ops, so eight xor'd words collapse into running
//! `ones/twos/fours` accumulators plus one `eights` word whose popcount
//! is taken per 8-word block — ~1 hardware popcount per 8 lanes instead
//! of 8.  The final flush weights the accumulators by their bit value:
//!
//! ```text
//!   x0 x1   x2 x3            (xor'd input words, 8 per block)
//!    \ /     \ /
//!    CSA     CSA    ones ─┐        total += 8·pop(eights)  per block
//!      \     /            │
//!       \   /             ▼
//!        CSA ──── twos ─► CSA ─── fours ─► CSA ─► eights
//! ...
//!   flush: total += pop(ones) + 2·pop(twos) + 4·pop(fours)
//! ```
//!
//! Exactness: every step is integer bit bookkeeping — the block form
//! and the naive per-word form count the same multiset of set bits, so
//! results are bit-identical to the scalar tier for every input (the
//! property tests below drive lengths across block boundaries, carry
//! flushes, and odd tails).

use crate::bnn::packing::fuse64;

/// One carry-save adder step: `(sum, carry)` of three 1-bit-per-lane
/// partial sums, five ops, no popcount.
#[inline]
fn csa(a: u64, b: u64, c: u64) -> (u64, u64) {
    let u = a ^ b;
    (u ^ c, (a & b) | (u & c))
}

/// Harley–Seal popcount of `x(0) ^ ... ^ x(n-1)`-style streams: `x(i)`
/// yields the i-th 64-bit word to count.  Blocks of 8; tail scalar.
#[inline]
fn harley_seal(n: usize, mut x: impl FnMut(usize) -> u64) -> u32 {
    let (mut ones, mut twos, mut fours) = (0u64, 0u64, 0u64);
    let mut total = 0u32;
    let mut i = 0;
    while i + 8 <= n {
        let (o1, ta) = csa(ones, x(i), x(i + 1));
        let (o2, tb) = csa(o1, x(i + 2), x(i + 3));
        let (t1, fa) = csa(twos, ta, tb);
        let (o3, tc) = csa(o2, x(i + 4), x(i + 5));
        let (o4, td) = csa(o3, x(i + 6), x(i + 7));
        let (t2, fb) = csa(t1, tc, td);
        let (f1, eights) = csa(fours, fa, fb);
        ones = o4;
        twos = t2;
        fours = f1;
        total += 8 * eights.count_ones();
        i += 8;
    }
    total += 4 * fours.count_ones() + 2 * twos.count_ones() + ones.count_ones();
    while i < n {
        total += x(i).count_ones();
        i += 1;
    }
    total
}

/// `popcount(a ^ b)` over u64 lane rows via Harley–Seal.
pub fn xorpop_csa(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    harley_seal(a.len(), |i| a[i] ^ b[i])
}

/// `popcount(a ^ b)` over u32 word rows: pairs fused to u64 on the fly
/// (`fuse64` positional pairing, same as the scalar tier), odd final
/// word counted scalar.
pub fn xorpop_words_csa(a: &[u32], b: &[u32]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut total = harley_seal(n / 2, |i| {
        fuse64(a[2 * i], a[2 * i + 1]) ^ fuse64(b[2 * i], b[2 * i + 1])
    });
    if n % 2 == 1 {
        total += (a[n - 1] ^ b[n - 1]).count_ones();
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, ensure_eq};

    #[test]
    fn csa_counts_three_partial_sums_exactly() {
        // per bit position: pop(sum) + 2*pop(carry) == pop(a)+pop(b)+pop(c)
        prop::check(64, |g| {
            let (a, b, c) = (g.u64(), g.u64(), g.u64());
            let (s, cy) = csa(a, b, c);
            ensure_eq(
                s.count_ones() + 2 * cy.count_ones(),
                a.count_ones() + b.count_ones() + c.count_ones(),
                "csa bit bookkeeping",
            )
        });
    }

    #[test]
    fn lane_csa_matches_naive_across_block_boundaries() {
        // lengths 0..=40 cross 0, 1, and 5 full 8-word blocks plus every
        // tail size; 17+ exercises a carry surviving into the flush
        prop::check(48, |g| {
            let n = g.usize_in(0, 40);
            let a: Vec<u64> = (0..n).map(|_| g.u64()).collect();
            let b: Vec<u64> = (0..n).map(|_| g.u64()).collect();
            let naive: u32 = a.iter().zip(&b).map(|(x, y)| (x ^ y).count_ones()).sum();
            ensure_eq(xorpop_csa(&a, &b), naive, "harley-seal == naive")
        });
    }

    #[test]
    fn word_csa_matches_scalar_xor_popcount() {
        prop::check(48, |g| {
            let n = g.usize_in(0, 81); // odd cap: exercises the odd tail
            let a = g.words(n);
            let b = g.words(n);
            ensure_eq(
                xorpop_words_csa(&a, &b),
                crate::bnn::packing::xor_popcount(&a, &b),
                "word harley-seal == scalar",
            )
        });
    }

    #[test]
    fn all_ones_saturates_every_accumulator() {
        // 24 words of all-ones against zero: every csa carry path is
        // exercised and the count is exactly 24*64
        let a = vec![u64::MAX; 24];
        let b = vec![0u64; 24];
        assert_eq!(xorpop_csa(&a, &b), 24 * 64);
    }
}
