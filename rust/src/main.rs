//! `repro` — CLI entrypoint for the binarized-CNN serving system.
//!
//! Subcommands:
//!   serve      start the TCP serving loop (engine or PJRT backend)
//!   classify   classify one image (PPM file or synthetic index)
//!   evaluate   test-set accuracy for one or all variants (Table 3)
//!   inspect    print the artifact manifest summary
//!   gen-data   render SynthVehicles samples to PPM files
//!   platforms  print the analytical platform model (Table 1 projection)

use std::process::ExitCode;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use bcnn::bnn::network::{BcnnNetwork, FloatNetwork, CLASSES};
use bcnn::coordinator::{BatchPolicy, EngineBackend, InferBackend, RuntimeBackend};
use bcnn::dataset::synth;
use bcnn::dataset::testset::TestSet;
use bcnn::input::binarize::Scheme;
use bcnn::input::image;
use bcnn::registry::{parse_model_ref, ModelRegistry};
use bcnn::runtime::{Artifacts, RegistryManifest};
use bcnn::server::Server;
use bcnn::util::cli::{Args, CliError};
use bcnn::util::error::AppResult;
use bcnn::{app_bail, app_ensure, app_err};
use bcnn::util::threadpool::default_threads;

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        eprintln!("{USAGE}");
        return ExitCode::from(2);
    };
    let rest = &argv[1..];
    let result = match cmd.as_str() {
        "serve" => cmd_serve(rest),
        "classify" => cmd_classify(rest),
        "evaluate" => cmd_evaluate(rest),
        "inspect" => cmd_inspect(rest),
        "gen-data" => cmd_gen_data(rest),
        "platforms" => cmd_platforms(rest),
        "-h" | "--help" | "help" => {
            println!("{USAGE}");
            return ExitCode::SUCCESS;
        }
        other => {
            eprintln!("unknown command {other:?}\n{USAGE}");
            return ExitCode::from(2);
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            if matches!(e.downcast_ref::<CliError>(), Some(CliError::Help)) {
                return ExitCode::SUCCESS;
            }
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "repro — binarized CNN inference (Khan et al. 2018 reproduction)

usage: repro <command> [options]

commands:
  serve       start the TCP serving loop
  classify    classify one image (PPM file or --synth index)
  evaluate    test-set accuracy per variant (Table 3)
  inspect     summarize artifacts/manifest.json
  gen-data    render SynthVehicles samples to PPM
  platforms   print the analytical platform projections (Table 1)

run `repro <command> --help` for options";

/// Build an engine backend for a scheme (or float) from the artifacts dir.
fn engine_backend(artifacts_dir: &str, variant: &str, threads: usize) -> AppResult<Arc<dyn InferBackend>> {
    if variant == "float" {
        let net = FloatNetwork::load(format!("{artifacts_dir}/weights_float.bcnt"))?;
        return Ok(Arc::new(EngineBackend::float(net, threads)));
    }
    let scheme = Scheme::parse(variant)
        .ok_or_else(|| app_err!("unknown variant {variant:?} (float|none|rgb|gray|lbp)"))?;
    let net = BcnnNetwork::load(
        format!("{artifacts_dir}/weights_bcnn_{}.bcnt", scheme.name()),
        scheme,
    )?;
    Ok(Arc::new(EngineBackend::bcnn(net, threads)))
}

fn cmd_serve(raw: &[String]) -> AppResult<()> {
    let a = Args::new("repro serve", "start the TCP serving loop")
        .opt("artifacts", "artifacts", "artifacts directory (classic --variants mode)")
        .opt("models", "", "model-registry dir (registry.json + weights); enables load_model")
        .opt("default", "", "default model, name or name@version (requests naming none)")
        .opt("addr", "127.0.0.1:7878", "bind address")
        .opt("variants", "rgb,none,float", "variants to load (ignored with --models)")
        .opt("backend", "engine", "engine | pjrt (classic mode)")
        .opt("max-batch", "1", "dynamic batcher max batch")
        .opt("batch-window-us", "200", "batch window in microseconds")
        .opt("queue-cap", "1024", "admission queue capacity")
        .opt("threads", "0", "engine worker threads (0 = all cores)")
        .opt("executors", "0", "batched workers per lane (0 = auto from host profile)")
        .opt("write-timeout-ms", "10000", "per-session write deadline in ms (0 = disabled)")
        .opt(
            "trace-sample",
            "0",
            "trace 1 in N classify requests into the trace_dump ring (0 = off; \
             per-request \"trace\": true always captures)",
        )
        .opt(
            "admin-token",
            "",
            "require this token on load_model/unload_model/set_default (empty = ops stay \
             open; the startup banner names the posture — check it when passing a shell var)",
        )
        .parse(raw)?;
    let threads = match a.get_usize("threads")? {
        0 => default_threads(),
        n => n,
    };
    let models_dir = a.get_nonempty("models");
    // parse the manifest once; the same snapshot sizes the executor
    // pools AND drives the startup loads below, so they can't diverge
    let manifest = match &models_dir {
        Some(dir) => Some(RegistryManifest::load(dir)?),
        None => None,
    };
    // what the registry starts with: manifest entries (registry mode)
    // or the classic --variants list — also sizes the executor pools
    let initial_lanes = match &manifest {
        Some(m) => m.entries.len(),
        None => a.get("variants").split(',').filter(|v| !v.is_empty()).count(),
    };
    // auto-size from the operator's core budget: `threads` is
    // default_threads() unless --threads capped it, and the cap must
    // bound executor spawning too
    let executors = match a.get_usize_in("executors", 0, 64)? {
        0 => bcnn::platform::profiles::recommended_executors(threads, initial_lanes.max(1)),
        n => n,
    };
    let policy = BatchPolicy {
        max_batch: a.get_usize("max-batch")?,
        max_wait: std::time::Duration::from_micros(a.get_u64("batch-window-us")?),
        executors,
    };
    let mut builder = ModelRegistry::builder()
        .policy(policy)
        .queue_capacity(a.get_usize("queue-cap")?)
        .engine_threads(threads);
    if let Some(dir) = &models_dir {
        builder = builder.models_dir(dir);
    }
    let registry = builder.build();

    let backend_kind = a.get("backend");
    if let Some(manifest) = manifest {
        // registry mode: load + validate + publish every manifest entry
        // (checksums verified, smoke-inferred) via the background loader
        app_ensure!(
            !manifest.entries.is_empty(),
            "registry manifest in {} lists no models",
            manifest.dir.display()
        );
        for entry in &manifest.entries {
            let key = registry
                .load_model(&entry.name, entry.version)
                .map_err(|e| app_err!("loading {}: {e}", entry.key()))?;
            println!("loaded {key} ({} / {})", entry.kind, entry.scheme);
        }
        // --default wins over the manifest's default; first entry otherwise
        let default_ref = a
            .get_nonempty("default")
            .or(manifest.default_model)
            .unwrap_or_else(|| manifest.entries[0].name.clone());
        let (name, version) = parse_model_ref(&default_ref).map_err(|e| app_err!("{e}"))?;
        registry.set_default(&name, version).map_err(|e| app_err!("{e}"))?;
    } else {
        // classic mode: each --variants entry becomes version 1 of a
        // same-named registry entry
        let artifacts = Arc::new(Artifacts::load(a.get("artifacts"))?);
        let dir = a.get("artifacts");
        for variant in a.get("variants").split(',').filter(|v| !v.is_empty()) {
            let (kind, backend): (&str, Arc<dyn InferBackend>) = match backend_kind.as_str() {
                "engine" => {
                    let kind = if variant == "float" { "float" } else { "bcnn" };
                    (kind, engine_backend(&dir, variant, threads)?)
                }
                "pjrt" => {
                    let names: Vec<(usize, String)> = artifacts
                        .models
                        .iter()
                        .filter(|m| {
                            if variant == "float" {
                                m.kind == "float"
                            } else {
                                m.scheme == variant && m.kind == "bcnn_ref"
                            }
                        })
                        .map(|m| (m.batch, m.name.clone()))
                        .collect();
                    app_ensure!(!names.is_empty(), "no artifacts for variant {variant}");
                    (
                        "pjrt",
                        Arc::new(RuntimeBackend::spawn(
                            Arc::clone(&artifacts),
                            names,
                            format!("pjrt/{variant}"),
                        )?),
                    )
                }
                other => app_bail!("unknown backend {other:?}"),
            };
            registry
                .publish_backend(variant, 1, kind, variant, None, backend)
                .map_err(|e| app_err!("publishing {variant}: {e}"))?;
        }
        if let Some(default_ref) = a.get_nonempty("default") {
            let (name, version) = parse_model_ref(&default_ref).map_err(|e| app_err!("{e}"))?;
            registry.set_default(&name, version).map_err(|e| app_err!("{e}"))?;
        }
    }

    let write_timeout = match a.get_u64("write-timeout-ms")? {
        0 => None,
        ms => Some(std::time::Duration::from_millis(ms)),
    };
    let admin_token = a.get_nonempty("admin-token");
    let admin_gated = admin_token.is_some();
    let trace_sample = a.get_u64("trace-sample")?;
    let server = Arc::new(
        Server::new(Arc::clone(&registry), CLASSES.iter().map(|s| s.to_string()).collect())
            .with_write_timeout(write_timeout)
            .with_admin_token(admin_token)
            .with_trace_sample(trace_sample),
    );
    let stop = Arc::new(AtomicBool::new(false));
    let addr = server.serve(&a.get("addr"), threads.max(2), stop)?;
    println!(
        "serving on {addr} (default={}, max_batch={}, executors={}/lane, write_timeout={:?})",
        registry.default_key(),
        policy.max_batch,
        policy.executors,
        write_timeout,
    );
    println!("protocol: line JSON, e.g. {{\"op\":\"classify_synth\",\"index\":0}}");
    println!(
        "admin ops: load_model / unload_model / set_default ({}) / list_models",
        if admin_gated { "token-gated" } else { "open — pass --admin-token to gate" },
    );
    println!(
        "observability: metrics / trace_dump (sampling {})",
        if trace_sample == 0 {
            "off — pass --trace-sample N for 1-in-N".to_string()
        } else {
            format!("1-in-{trace_sample}")
        },
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_classify(raw: &[String]) -> AppResult<()> {
    let a = Args::new("repro classify", "classify one image")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("variant", "rgb", "model variant (float|none|rgb|gray|lbp)")
        .opt("synth", "-1", "render synthetic sample <n> instead of reading a file")
        .opt("threads", "1", "engine threads")
        .parse(raw)?;
    let dir = a.get("artifacts");
    let variant = a.get("variant");
    let backend = engine_backend(&dir, &variant, a.get_usize("threads")?)?;
    let synth_idx: i64 = a.get("synth").parse().unwrap_or(-1);
    let (img, truth) = if synth_idx >= 0 {
        let s = synth::render_vehicle(synth_idx as usize, synth::DEFAULT_SEED);
        (s.image, Some(s.label))
    } else {
        let pos = a.positional();
        app_ensure!(!pos.is_empty(), "pass a PPM path or --synth <n>");
        let (px, h, w) = image::read_ppm(&pos[0])?;
        app_ensure!(h == 96 && w == 96, "image must be 96x96 (got {h}x{w})");
        (px, None)
    };
    let start = std::time::Instant::now();
    let logits = backend.infer_batch(&img).map_err(|e| app_err!("{e}"))?;
    let took = start.elapsed();
    let class = bcnn::bnn::network::argmax(&logits);
    println!("class: {} ({})", class, CLASSES[class]);
    println!("logits: {logits:?}");
    println!("latency: {:.1} µs", took.as_nanos() as f64 / 1_000.0);
    if let Some(t) = truth {
        println!("truth: {} ({}) -> {}", t, CLASSES[t], if t == class { "CORRECT" } else { "WRONG" });
    }
    Ok(())
}

fn cmd_evaluate(raw: &[String]) -> AppResult<()> {
    let a = Args::new("repro evaluate", "test-set accuracy per variant (Table 3)")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("variants", "float,none,rgb,gray,lbp", "variants to evaluate")
        .opt("threads", "0", "engine threads (0 = all cores)")
        .opt("limit", "0", "evaluate only the first N test images (0 = all)")
        .parse(raw)?;
    let dir = a.get("artifacts");
    let threads = match a.get_usize("threads")? {
        0 => default_threads(),
        n => n,
    };
    let artifacts = Artifacts::load(&dir)?;
    let ts_path = artifacts
        .testset_path()
        .ok_or_else(|| app_err!("manifest has no testset — rerun make artifacts"))?;
    let ts = TestSet::load(ts_path)?;
    let limit = match a.get_usize("limit")? {
        0 => ts.len(),
        n => n.min(ts.len()),
    };
    println!("evaluating {limit} test images (trained flags: {:?})", artifacts.trained);
    println!("{:<24}{:>10}", "variant", "accuracy");
    for variant in a.get("variants").split(',').filter(|v| !v.is_empty()) {
        let backend = engine_backend(&dir, variant, threads)?;
        let correct: usize = bcnn::util::threadpool::scoped_map(limit, threads, |i| {
            let logits = backend.infer_batch(ts.image(i)).expect("infer");
            usize::from(bcnn::bnn::network::argmax(&logits) as i32 == ts.labels[i])
        })
        .into_iter()
        .sum();
        println!("{:<24}{:>9.2}%", variant, 100.0 * correct as f64 / limit as f64);
    }
    Ok(())
}

fn cmd_inspect(raw: &[String]) -> AppResult<()> {
    let a = Args::new("repro inspect", "summarize artifacts/manifest.json")
        .opt("artifacts", "artifacts", "artifacts directory")
        .parse(raw)?;
    let artifacts = Artifacts::load(a.get("artifacts"))?;
    println!("classes: {:?}", artifacts.classes);
    println!("trained: {:?}", artifacts.trained);
    println!("\n{} models:", artifacts.models.len());
    for m in &artifacts.models {
        println!(
            "  {:<32} kind={:<12} scheme={:<6} batch={:<3} weights={}",
            m.name, m.kind, m.scheme, m.batch, m.weights_file
        );
    }
    println!("\n{} layer kernels:", artifacts.layers.len());
    for l in &artifacts.layers {
        let shapes: Vec<String> = l.args.iter().map(|s| format!("{:?}", s.shape)).collect();
        println!("  {:<32} args={}", l.name, shapes.join(" x "));
    }
    Ok(())
}

fn cmd_gen_data(raw: &[String]) -> AppResult<()> {
    let a = Args::new("repro gen-data", "render SynthVehicles samples to PPM")
        .opt("count", "8", "how many samples")
        .opt("start", "0", "first sample index")
        .opt("out", "out/synth", "output directory")
        .parse(raw)?;
    let out = a.get("out");
    std::fs::create_dir_all(&out)?;
    let start = a.get_usize("start")?;
    for i in start..start + a.get_usize("count")? {
        let s = synth::render_vehicle(i, synth::DEFAULT_SEED);
        let path = format!("{out}/sample_{i:04}_{}.ppm", CLASSES[s.label]);
        image::write_ppm(&path, &s.image, 96, 96)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_platforms(raw: &[String]) -> AppResult<()> {
    let _a = Args::new("repro platforms", "analytical platform projections")
        .parse(raw)?;
    bcnn::platform::print_table1_projection();
    Ok(())
}
