//! Input binarization schemes (paper Section 2.3) — Rust ports of
//! `python/compile/binarize_input.py`, bit-identical on the same input.
//!
//! All map a (96,96,3) float image in [0,1] to a ±1 image the first
//! binarized conv layer consumes.

/// Luma weights (ITU-R BT.601), matching the Python `_LUMA` constant.
pub const LUMA: [f32; 3] = [0.299, 0.587, 0.114];

/// Neighbour offsets at radius 1, clockwise from the top-left corner.
const NEIGHBOURS: [(isize, isize); 8] =
    [(-1, -1), (-1, 0), (-1, 1), (0, 1), (1, 1), (1, 0), (1, -1), (0, -1)];

/// Paper: "3 pixels at a clockwise stride of 3 in the neighbourhood".
const LBP_SELECT: [usize; 3] = [0, 3, 6];

/// Eq. 1: sign into ±1 (sign(0) = -1).
#[inline]
fn sign(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else {
        -1.0
    }
}

/// `sign(X + T)` with a per-channel threshold `t` (len 3).
/// In/out layout: (H, W, 3) row-major.
pub fn threshold_rgb(x: &[f32], t: &[f32; 3]) -> Vec<f32> {
    let mut out = vec![0f32; x.len()];
    threshold_rgb_into(x, t, &mut out);
    out
}

/// `threshold_rgb` into a caller-provided buffer (len = `x.len()`,
/// fully overwritten — the ROADMAP-flagged zero-copy variant used by the
/// scratch-arena forward path).
pub fn threshold_rgb_into(x: &[f32], t: &[f32; 3], out: &mut [f32]) {
    assert_eq!(out.len(), x.len());
    for (px, o) in x.chunks_exact(3).zip(out.chunks_exact_mut(3)) {
        o[0] = sign(px[0] + t[0]);
        o[1] = sign(px[1] + t[1]);
        o[2] = sign(px[2] + t[2]);
    }
}

/// Grayscale threshold: `sign(luma(X) + t)`, output (H, W, 1).
pub fn threshold_gray(x: &[f32], t: f32) -> Vec<f32> {
    let mut out = vec![0f32; x.len() / 3];
    threshold_gray_into(x, t, &mut out);
    out
}

/// `threshold_gray` into a caller-provided buffer (len = `x.len() / 3`,
/// fully overwritten).
pub fn threshold_gray_into(x: &[f32], t: f32, out: &mut [f32]) {
    assert_eq!(out.len(), x.len() / 3);
    for (px, o) in x.chunks_exact(3).zip(out.iter_mut()) {
        *o = sign(px[0] * LUMA[0] + px[1] * LUMA[1] + px[2] * LUMA[2] + t);
    }
}

/// Grayscale conversion helper (shared with the LBP path and Figure 1).
pub fn to_gray(x: &[f32], h: usize, w: usize) -> Vec<f32> {
    let mut out = vec![0f32; h * w];
    to_gray_into(x, h, w, &mut out);
    out
}

/// `to_gray` into a caller-provided buffer (len = `h * w`, fully
/// overwritten).
pub fn to_gray_into(x: &[f32], h: usize, w: usize, out: &mut [f32]) {
    assert_eq!(x.len(), h * w * 3);
    assert_eq!(out.len(), h * w);
    for (px, o) in x.chunks_exact(3).zip(out.iter_mut()) {
        *o = px[0] * LUMA[0] + px[1] * LUMA[1] + px[2] * LUMA[2];
    }
}

/// Modified LBP (paper Section 2.3): 3 binary channels, channel k set to
/// +1 where neighbour `LBP_SELECT[k]` (radius 1) exceeds the center pixel
/// of the grayscale image; borders read neighbour value 0.
/// Output layout: (H, W, 3).
pub fn lbp(x: &[f32], h: usize, w: usize) -> Vec<f32> {
    let mut gray = vec![0f32; h * w];
    let mut out = vec![0f32; h * w * 3];
    lbp_into(x, h, w, &mut gray, &mut out);
    out
}

/// `lbp` into caller-provided buffers: `gray` is an (H*W) grayscale
/// scratch, `out` the (H, W, 3) result.  Both are fully overwritten.
pub fn lbp_into(x: &[f32], h: usize, w: usize, gray: &mut [f32], out: &mut [f32]) {
    assert_eq!(out.len(), h * w * 3);
    to_gray_into(x, h, w, gray);
    for y in 0..h {
        for xx in 0..w {
            let center = gray[y * w + xx];
            for (ch, &sel) in LBP_SELECT.iter().enumerate() {
                let (dy, dx) = NEIGHBOURS[sel];
                let ny = y as isize + dy;
                let nx = xx as isize + dx;
                let neigh = if ny >= 0 && nx >= 0 && (ny as usize) < h && (nx as usize) < w {
                    gray[ny as usize * w + nx as usize]
                } else {
                    0.0
                };
                out[(y * w + xx) * 3 + ch] = if neigh > center { 1.0 } else { -1.0 };
            }
        }
    }
}

/// Scheme dispatch matching `binarize_input.apply_scheme`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// First layer stays full-precision on the raw input.
    None,
    /// sign(X + T) per RGB channel (the paper's deployed choice).
    Rgb,
    /// Grayscale threshold.
    Gray,
    /// Modified local binary patterns.
    Lbp,
}

impl Scheme {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "none" => Scheme::None,
            "rgb" => Scheme::Rgb,
            "gray" => Scheme::Gray,
            "lbp" => Scheme::Lbp,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::None => "none",
            Scheme::Rgb => "rgb",
            Scheme::Gray => "gray",
            Scheme::Lbp => "lbp",
        }
    }

    /// Channels conv1 sees under this scheme.
    pub fn input_channels(&self) -> usize {
        match self {
            Scheme::None | Scheme::Rgb | Scheme::Lbp => 3,
            Scheme::Gray => 1,
        }
    }

    pub const ALL: [Scheme; 4] = [Scheme::None, Scheme::Rgb, Scheme::Gray, Scheme::Lbp];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_rgb_splits_range() {
        let x = [0.2, 0.5, 0.8, 0.6, 0.4, 0.1];
        let t = [-0.5, -0.5, -0.5];
        let out = threshold_rgb(&x, &t);
        assert_eq!(out, vec![-1.0, -1.0, 1.0, 1.0, -1.0, -1.0]);
    }

    #[test]
    fn threshold_at_exact_zero_is_minus_one() {
        let out = threshold_rgb(&[0.5, 0.5, 0.5], &[-0.5, -0.5, -0.5]);
        assert_eq!(out, vec![-1.0, -1.0, -1.0]);
    }

    #[test]
    fn gray_uses_luma() {
        // pure green is brighter than pure blue in luma
        let g = to_gray(&[0.0, 1.0, 0.0, 0.0, 0.0, 1.0], 1, 2);
        assert!((g[0] - 0.587).abs() < 1e-6);
        assert!((g[1] - 0.114).abs() < 1e-6);
    }

    #[test]
    fn lbp_flat_image_is_all_minus_one() {
        // constant image: no neighbour exceeds the center (borders read 0
        // which is < 0.5 too)
        let x = vec![0.5f32; 4 * 4 * 3];
        let out = lbp(&x, 4, 4);
        assert!(out.iter().all(|&v| v == -1.0));
    }

    #[test]
    fn lbp_detects_bright_neighbour() {
        // 3x3 grayscale ramp: the bottom-right pixel is brightest.
        // neighbour 4 = (+1,+1); center (1,1) should fire channel 1
        // (select index 3 -> neighbour (0,+1)) when right neighbour brighter.
        let mut x = vec![0.0f32; 9 * 3];
        for i in 0..9 {
            let v = i as f32 / 10.0;
            x[i * 3] = v;
            x[i * 3 + 1] = v;
            x[i * 3 + 2] = v;
        }
        let out = lbp(&x, 3, 3);
        // center pixel (1,1): neighbour (0,+1) = pixel (1,2), brighter -> +1
        assert_eq!(out[(1 * 3 + 1) * 3 + 1], 1.0);
        // channel 0 neighbour (-1,-1) = pixel (0,0), darker -> -1
        assert_eq!(out[(1 * 3 + 1) * 3], -1.0);
    }

    #[test]
    fn into_variants_match_alloc_on_dirty_buffers() {
        use crate::util::prop::{self, ensure_eq};
        prop::check(24, |g| {
            let h = g.usize_in(1, 8);
            let w = g.usize_in(1, 8);
            let x: Vec<f32> = (0..h * w * 3).map(|_| g.f32_in(0.0, 1.0)).collect();
            let t = [g.f32_in(-1.0, 0.0), g.f32_in(-1.0, 0.0), g.f32_in(-1.0, 0.0)];
            let mut rgb = vec![f32::NAN; h * w * 3];
            threshold_rgb_into(&x, &t, &mut rgb);
            ensure_eq(rgb, threshold_rgb(&x, &t), "rgb into")?;
            let mut gr = vec![f32::NAN; h * w];
            threshold_gray_into(&x, t[0], &mut gr);
            ensure_eq(gr, threshold_gray(&x, t[0]), "gray into")?;
            let mut gray = vec![f32::NAN; h * w];
            let mut lb = vec![f32::NAN; h * w * 3];
            lbp_into(&x, h, w, &mut gray, &mut lb);
            ensure_eq(lb, lbp(&x, h, w), "lbp into")?;
            ensure_eq(gray, to_gray(&x, h, w), "gray scratch filled")?;
            Ok(())
        });
    }

    #[test]
    fn scheme_parse_roundtrip() {
        for s in Scheme::ALL {
            assert_eq!(Scheme::parse(s.name()), Some(s));
        }
        assert_eq!(Scheme::parse("bogus"), None);
    }

    #[test]
    fn scheme_channels() {
        assert_eq!(Scheme::Gray.input_channels(), 1);
        assert_eq!(Scheme::Rgb.input_channels(), 3);
        assert_eq!(Scheme::Lbp.input_channels(), 3);
    }
}
