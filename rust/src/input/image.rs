//! Minimal image IO: binary PPM (P6) / PGM (P5) writers and a PPM reader.
//! Used by the Figure-1 demo (`examples/binarize_demo.rs`) and the
//! `repro classify` CLI path.

use std::io::Write;
use std::path::Path;

#[derive(Debug)]
pub enum ImageError {
    Io(std::io::Error),
    Parse(String),
}

crate::error_enum_impls!(ImageError {
    ImageError::Io(e) => ("image io: {e}"),
    ImageError::Parse(msg) => ("image parse: {msg}"),
}
source { ImageError::Io(e) => e }
from { std::io::Error => ImageError::Io });

fn clamp_u8(v: f32) -> u8 {
    (v.clamp(0.0, 1.0) * 255.0).round() as u8
}

/// Write an (H, W, 3) float image in [0,1] as binary PPM.
pub fn write_ppm(path: impl AsRef<Path>, x: &[f32], h: usize, w: usize) -> Result<(), ImageError> {
    assert_eq!(x.len(), h * w * 3);
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write!(f, "P6\n{w} {h}\n255\n")?;
    let bytes: Vec<u8> = x.iter().map(|&v| clamp_u8(v)).collect();
    f.write_all(&bytes)?;
    Ok(())
}

/// Write an (H, W) float image in [0,1] as binary PGM.
pub fn write_pgm(path: impl AsRef<Path>, x: &[f32], h: usize, w: usize) -> Result<(), ImageError> {
    assert_eq!(x.len(), h * w);
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write!(f, "P5\n{w} {h}\n255\n")?;
    let bytes: Vec<u8> = x.iter().map(|&v| clamp_u8(v)).collect();
    f.write_all(&bytes)?;
    Ok(())
}

/// Map a ±1 image to [0,1] for visualization (-1 -> 0, +1 -> 1).
pub fn pm1_to_unit(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| if v > 0.0 { 1.0 } else { 0.0 }).collect()
}

/// Upper bound on parsed PPM extents.  The pipeline consumes (96, 96)
/// images; this leaves generous headroom while keeping `w * h * 3` far
/// from `usize` overflow on crafted headers.
pub const MAX_DIM: usize = 1 << 15;

/// Read a binary PPM (P6, maxval 255) into (H, W, 3) floats in [0,1].
pub fn read_ppm(path: impl AsRef<Path>) -> Result<(Vec<f32>, usize, usize), ImageError> {
    let data = std::fs::read(path)?;
    let mut pos = 0usize;
    let mut token = |data: &[u8]| -> Result<String, ImageError> {
        // skip whitespace and comments
        while pos < data.len() {
            match data[pos] {
                b' ' | b'\t' | b'\r' | b'\n' => pos += 1,
                b'#' => {
                    while pos < data.len() && data[pos] != b'\n' {
                        pos += 1;
                    }
                }
                _ => break,
            }
        }
        let start = pos;
        while pos < data.len() && !data[pos].is_ascii_whitespace() {
            pos += 1;
        }
        if start == pos {
            return Err(ImageError::Parse("unexpected EOF in header".into()));
        }
        Ok(String::from_utf8_lossy(&data[start..pos]).to_string())
    };
    let magic = token(&data)?;
    if magic != "P6" {
        return Err(ImageError::Parse(format!("unsupported magic {magic:?}")));
    }
    let w: usize = token(&data)?.parse().map_err(|_| ImageError::Parse("bad width".into()))?;
    let h: usize = token(&data)?.parse().map_err(|_| ImageError::Parse("bad height".into()))?;
    let maxval: usize =
        token(&data)?.parse().map_err(|_| ImageError::Parse("bad maxval".into()))?;
    if maxval != 255 {
        return Err(ImageError::Parse(format!("unsupported maxval {maxval}")));
    }
    pos += 1; // single whitespace after maxval
    // A crafted header ("P6\n<huge> <huge>\n255\n") must not wrap
    // `w * h * 3` (which bypassed the truncation check in release builds
    // and panicked in debug): cap the extents and multiply checked.
    if w == 0 || h == 0 || w > MAX_DIM || h > MAX_DIM {
        return Err(ImageError::Parse(format!("unreasonable dimensions {w}x{h}")));
    }
    let need = w
        .checked_mul(h)
        .and_then(|px| px.checked_mul(3))
        .ok_or_else(|| ImageError::Parse(format!("dimensions {w}x{h} overflow")))?;
    if data.len() < pos || data.len() - pos < need {
        return Err(ImageError::Parse("truncated pixel data".into()));
    }
    let px = data[pos..pos + need].iter().map(|&b| b as f32 / 255.0).collect();
    Ok((px, h, w))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("bcnn-image-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn ppm_roundtrip() {
        let x: Vec<f32> = (0..2 * 3 * 3).map(|i| (i as f32) / 17.0).collect();
        let p = tmp("rt.ppm");
        write_ppm(&p, &x, 2, 3).unwrap();
        let (y, h, w) = read_ppm(&p).unwrap();
        assert_eq!((h, w), (2, 3));
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1.0 / 255.0 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn pgm_writes_header_and_payload() {
        let p = tmp("g.pgm");
        write_pgm(&p, &[0.0, 1.0], 1, 2).unwrap();
        let data = std::fs::read(&p).unwrap();
        assert!(data.starts_with(b"P5\n2 1\n255\n"));
        assert_eq!(&data[data.len() - 2..], &[0u8, 255u8]);
    }

    #[test]
    fn pm1_maps_to_unit() {
        assert_eq!(pm1_to_unit(&[-1.0, 1.0, -1.0]), vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn read_rejects_bad_magic() {
        let p = tmp("bad.ppm");
        std::fs::write(&p, b"P5\n1 1\n255\n\0").unwrap();
        assert!(read_ppm(&p).is_err());
    }

    #[test]
    fn read_rejects_overflowing_header() {
        // 2^63 * 2 * 3 wraps usize to 0: the old code then read an empty
        // pixel payload as a "valid" 2^63-wide image in release builds
        // (and panicked on the multiply in debug).  Must be a Parse error.
        let p = tmp("overflow.ppm");
        std::fs::write(&p, b"P6\n9223372036854775808 2\n255\n\0\0\0").unwrap();
        match read_ppm(&p) {
            Err(ImageError::Parse(_)) => {}
            other => panic!("expected parse error, got {other:?}"),
        }
        // enormous-but-non-wrapping extents hit the dimension cap
        let p2 = tmp("huge.ppm");
        std::fs::write(&p2, b"P6\n1000000 1000000\n255\n\0\0\0").unwrap();
        assert!(matches!(read_ppm(&p2), Err(ImageError::Parse(_))));
        // zero extents are equally meaningless for a P6 payload
        let p3 = tmp("zero.ppm");
        std::fs::write(&p3, b"P6\n0 4\n255\n").unwrap();
        assert!(matches!(read_ppm(&p3), Err(ImageError::Parse(_))));
    }

    #[test]
    fn read_handles_comments() {
        let p = tmp("comment.ppm");
        let mut bytes = b"P6\n# a comment\n1 1\n255\n".to_vec();
        bytes.extend_from_slice(&[10, 20, 30]);
        std::fs::write(&p, &bytes).unwrap();
        let (px, h, w) = read_ppm(&p).unwrap();
        assert_eq!((h, w), (1, 1));
        assert!((px[0] - 10.0 / 255.0).abs() < 1e-6);
    }
}
