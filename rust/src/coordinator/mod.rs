//! Layer-3 coordinator: the serving system around the compiled models.
//!
//! Request flow (the paper's "real-time inference" use case, Section 2.2,
//! generalized to a serving loop):
//!
//! ```text
//!  client ──submit──▶ admission (bounded queue, backpressure)
//!                       │
//!                  batcher thread (size + deadline policy)
//!                       │ batches
//!                  backend: pure-Rust engine (parallel workers)
//!                           or PJRT executor thread (HLO artifacts)
//!                       │ logits
//!                  response channels + metrics (latency histograms)
//! ```
//!
//! The default policy is `max_batch = 1` — the paper's protocol feeds
//! images one at a time ("batch processing is not a suitable option for
//! real-time applications") — and the batching ablation (E6) raises it.

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod router;

pub use backend::{EngineBackend, InferBackend, RuntimeBackend};
pub use batcher::{plan_batches, BatchPolicy, Batcher};
pub use metrics::Metrics;
pub use queue::BoundedQueue;
pub use request::{InferRequest, InferResponse, RequestId};
pub use router::Router;
