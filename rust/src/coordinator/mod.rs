//! Layer-3 coordinator: the serving system around the compiled models.
//!
//! Request flow (the paper's "real-time inference" use case, Section 2.2,
//! generalized to a serving loop):
//!
//! ```text
//!  client ──submit──▶ admission (bounded MPMC queue, backpressure)
//!                       │            │
//!                  batcher-0 … batcher-N   (one lane = an executor pool;
//!                       │            │      size + deadline policy each)
//!                       │ batches    │ batches  — concurrently in flight
//!                  backend: pure-Rust engine (parallel workers,
//!                           pooled PlanScratch arenas w/ decay)
//!                           or PJRT executor thread (HLO artifacts)
//!                       │ logits
//!                  response channels + metrics (latency histograms)
//! ```
//!
//! The default policy is `max_batch = 1` — the paper's protocol feeds
//! images one at a time ("batch processing is not a suitable option for
//! real-time applications") — and the batching ablation (E6) raises it.
//!
//! When `max_batch > 1`, a drained batch is executed as ONE batched
//! backend call: `EngineBackend::infer_batch` forwards the whole payload
//! through `BcnnNetwork::infer_batch` / `FloatNetwork::infer_batch`
//! (M = batch × spatial GEMMs, one weight widening per batch, weight
//! rows L1-hot across images) instead of looping image-by-image, so the
//! batching policy is a real throughput lever rather than decorative
//! grouping.  Logits are bit-identical to the single-image path per
//! image, which is what lets the policy be changed freely in production.
//!
//! Batch-size/latency tradeoff: a request riding a batch of B waits up
//! to `BatchPolicy::max_wait` for peers plus the batched execution time;
//! in exchange, per-batch fixed costs amortize ~B-fold (see
//! `benches/ablation_batch_forward.rs` for the measured curve).  Clients
//! can opt whole groups of images in via the `classify_batch` protocol
//! op, which `Router::infer_blocking_batch` submits back-to-back so the
//! batcher can coalesce them.
//!
//! With `BatchPolicy::executors > 1` a lane runs several batched
//! workers against its queue, so batch formation overlaps execution and
//! multiple batches per variant are in flight concurrently (see
//! `benches/ablation_executors.rs`); requests may then complete out of
//! submission order.  Blocking entry points re-order by request id;
//! `Router::submit_group` exposes completion order on one shared
//! channel, which is what the server's `classify_batch_stream` op
//! streams to clients frame by frame.
//!
//! Lanes have a **runtime lifecycle**: the model registry
//! ([`crate::registry`]) spawns one lane per published `name@version`
//! entry ([`Router::add_lane`]) and retires lanes gracefully on unload
//! ([`Router::remove_lane`] → [`Batcher::retire`]: the queue closes,
//! admitted requests drain, threads reap in the background), so model
//! versions hot-swap without dropping a request and a batch can never
//! mix two versions' weights.  The full request lifecycle is
//! diagrammed in `docs/ARCHITECTURE.md`, the wire format in
//! `docs/PROTOCOL.md`.
//!
//! # Lock order
//!
//! The serving plane holds locks from three owners, and two paths
//! genuinely nest them: publication holds registry state while adding a
//! router lane and swapping the route snapshot, and `list_models` reads
//! lane metrics under registry state.  Deadlock freedom rests on one
//! rule — **locks are acquired in ascending rank only** — asserted in
//! debug builds by [`crate::util::lockorder`] witnesses at every
//! instrumented site:
//!
//! | rank | lock | owner | held where |
//! |------|------|-------|------------|
//! | 10 | `state` (Mutex) | `ModelRegistry` | admin ops; outermost |
//! | 20 | `lanes` (RwLock) | `Router` | resolution reads; publish/retire writes (nested under 10) |
//! | 30 | `routes` (RwLock) | `ModelRegistry` | snapshot swap (nested under 10); resolve reads |
//! | 40 | `counters` (Mutex) | `ModelRegistry` | leaf, admin side |
//! | 50 | `scratch_pool` (Mutex) | `EngineBackend` | leaf, serving side; only around a pop/push, never across a forward |
//!
//! Locks outside the table (`Router::default_variant`, each `Lane`'s
//! `batcher` mutex, queue/metrics internals, the per-step profile
//! histograms, and the trace-store/journal rings) are strict leaves:
//! no other lock is ever acquired while one of them is held, so they
//! need no rank — enforced by expression-scoping at their call sites.

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod router;

pub use backend::{EngineBackend, InferBackend, PoolStats, RuntimeBackend};
pub use batcher::{plan_batches, BatchPolicy, Batcher};
pub use metrics::Metrics;
pub use queue::BoundedQueue;
pub use request::{InferRequest, InferResponse, RequestId};
pub use router::{GroupSlot, GroupSubmission, Router};
