//! The router: the serving front door.  Owns one (queue, batcher,
//! backend, metrics) lane per registered model variant and routes
//! submissions by variant name.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use super::backend::{InferBackend, IMG_ELEMS};
use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::queue::{BoundedQueue, PushError};
use super::request::{InferRequest, InferResponse, RequestId};
use crate::util::json::{Json, JsonObj};

#[derive(Debug)]
pub enum RouteError {
    UnknownVariant(String, String),
    Rejected(PushError),
    BadPayload(usize),
    /// The lane's batcher died before answering (worker crash).
    BackendGone,
}

crate::error_enum_impls!(RouteError {
    RouteError::UnknownVariant(name, avail) =>
        ("unknown model variant {name:?} (available: {avail})"),
    RouteError::Rejected(e) => ("admission rejected: {e}"),
    RouteError::BadPayload(n) => ("image payload must be {IMG_ELEMS} floats, got {n}"),
    RouteError::BackendGone => ("backend dropped the response channel"),
}
source { RouteError::Rejected(e) => e }
from { PushError => RouteError::Rejected });

struct Lane {
    queue: Arc<BoundedQueue<InferRequest>>,
    metrics: Arc<Metrics>,
    _batcher: Batcher,
}

/// Multi-variant serving router.
pub struct Router {
    lanes: HashMap<String, Lane>,
    default_variant: String,
    next_id: AtomicU64,
}

impl Router {
    pub fn builder() -> RouterBuilder {
        RouterBuilder { lanes: Vec::new(), queue_capacity: 1024, policy: BatchPolicy::default() }
    }

    fn lane(&self, variant: &str) -> Result<&Lane, RouteError> {
        let key = if variant.is_empty() { &self.default_variant } else { variant };
        self.lanes.get(key).ok_or_else(|| {
            RouteError::UnknownVariant(
                key.to_string(),
                self.lanes.keys().cloned().collect::<Vec<_>>().join(", "),
            )
        })
    }

    fn alloc_id(&self) -> RequestId {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Submit one image; returns the request id and the response channel.
    pub fn submit(
        &self,
        variant: &str,
        image: Vec<f32>,
    ) -> Result<(RequestId, mpsc::Receiver<InferResponse>), RouteError> {
        self.submit_with_id(self.alloc_id(), variant, image)
    }

    /// Submission with a caller-assigned id, so the batch path can report
    /// a real request id even when admission itself fails.
    fn submit_with_id(
        &self,
        id: RequestId,
        variant: &str,
        image: Vec<f32>,
    ) -> Result<(RequestId, mpsc::Receiver<InferResponse>), RouteError> {
        if image.len() != IMG_ELEMS {
            return Err(RouteError::BadPayload(image.len()));
        }
        let lane = self.lane(variant)?;
        let (tx, rx) = mpsc::channel();
        lane.metrics.record_submit();
        let req = InferRequest { id, image, enqueued: Instant::now(), resp: tx };
        match lane.queue.try_push(req) {
            Ok(()) => Ok((id, rx)),
            Err(e) => {
                lane.metrics.record_reject();
                Err(RouteError::Rejected(e))
            }
        }
    }

    /// Submit and block for the response (convenience for CLI paths).
    /// A dead batcher surfaces as `BackendGone` instead of a panic so a
    /// serving thread can answer the client with a structured error.
    pub fn infer_blocking(
        &self,
        variant: &str,
        image: Vec<f32>,
    ) -> Result<InferResponse, RouteError> {
        let (_, rx) = self.submit(variant, image)?;
        rx.recv().map_err(|_| RouteError::BackendGone)
    }

    /// Submit a whole batch of images to one variant's lane back-to-back,
    /// then block for every response (in submission order).  Because the
    /// images hit the admission queue together, the dynamic batcher can
    /// drain them into a single backend call (up to `BatchPolicy::max_batch`)
    /// — this is the serving entry point for the batched forward path.
    ///
    /// Errors stay per-image (`InferResponse::failed`): a mid-batch
    /// admission rejection must not discard the results of images already
    /// submitted and executing.
    pub fn infer_blocking_batch(
        &self,
        variant: &str,
        images: Vec<Vec<f32>>,
    ) -> Vec<InferResponse> {
        // submit everything first so the batcher sees the whole group;
        // each image gets its id up front so a failed submission still
        // reports a real id (regression: failures used to answer id 0)
        let rxs: Vec<(RequestId, Result<mpsc::Receiver<InferResponse>, RouteError>)> = images
            .into_iter()
            .map(|img| {
                let id = self.alloc_id();
                (id, self.submit_with_id(id, variant, img).map(|(_, rx)| rx))
            })
            .collect();
        // ...then collect, mapping failures per-image
        rxs.into_iter()
            .map(|(id, r)| match r {
                Err(e) => InferResponse::failed(id, e.to_string()),
                Ok(rx) => rx
                    .recv()
                    .unwrap_or_else(|_| InferResponse::failed(id, RouteError::BackendGone.to_string())),
            })
            .collect()
    }

    pub fn variants(&self) -> Vec<String> {
        let mut v: Vec<String> = self.lanes.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn default_variant(&self) -> &str {
        &self.default_variant
    }

    pub fn metrics(&self, variant: &str) -> Result<Arc<Metrics>, RouteError> {
        Ok(Arc::clone(&self.lane(variant)?.metrics))
    }

    /// Aggregate stats across all lanes.
    pub fn stats(&self) -> Json {
        let mut obj = JsonObj::new();
        let mut names: Vec<&String> = self.lanes.keys().collect();
        names.sort();
        for name in names {
            obj.insert(name.clone(), self.lanes[name].metrics.snapshot());
        }
        Json::Obj(obj)
    }

    /// Close all queues (drains in-flight work; batchers exit).
    pub fn shutdown(&self) {
        for lane in self.lanes.values() {
            lane.queue.close();
        }
    }
}

/// Builder: register variants then `build`.
pub struct RouterBuilder {
    lanes: Vec<(String, Arc<dyn InferBackend>)>,
    queue_capacity: usize,
    policy: BatchPolicy,
}

impl RouterBuilder {
    pub fn queue_capacity(mut self, cap: usize) -> Self {
        self.queue_capacity = cap;
        self
    }

    pub fn policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn variant(mut self, name: impl Into<String>, backend: Arc<dyn InferBackend>) -> Self {
        self.lanes.push((name.into(), backend));
        self
    }

    pub fn build(self) -> Router {
        assert!(!self.lanes.is_empty(), "router needs at least one variant");
        let default_variant = self.lanes[0].0.clone();
        let mut lanes = HashMap::new();
        for (name, backend) in self.lanes {
            let queue = Arc::new(BoundedQueue::new(self.queue_capacity));
            let metrics = Arc::new(Metrics::new());
            let batcher = Batcher::spawn(
                Arc::clone(&queue),
                backend,
                self.policy,
                Arc::clone(&metrics),
            );
            lanes.insert(name, Lane { queue, metrics, _batcher: batcher });
        }
        Router { lanes, default_variant, next_id: AtomicU64::new(1) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::network::tests_support::synth_bcnn_network;
    use crate::coordinator::backend::EngineBackend;
    use crate::input::binarize::Scheme;
    use crate::util::rng::Xoshiro256;

    fn test_router(policy: BatchPolicy, capacity: usize) -> Router {
        let be: Arc<dyn InferBackend> =
            Arc::new(EngineBackend::bcnn(synth_bcnn_network(Scheme::Rgb, 1), 2));
        Router::builder()
            .policy(policy)
            .queue_capacity(capacity)
            .variant("bcnn_rgb", be)
            .build()
    }

    fn image(seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256::new(seed);
        (0..IMG_ELEMS).map(|_| rng.next_f32()).collect()
    }

    #[test]
    fn routes_and_answers() {
        let r = test_router(BatchPolicy::default(), 64);
        let resp = r.infer_blocking("bcnn_rgb", image(1)).unwrap();
        assert!(resp.error.is_none());
        assert_eq!(resp.logits.len(), 4);
        assert!(resp.class < 4);
        r.shutdown();
    }

    #[test]
    fn default_variant_used_for_empty_name() {
        let r = test_router(BatchPolicy::default(), 64);
        let resp = r.infer_blocking("", image(2)).unwrap();
        assert!(resp.error.is_none());
        r.shutdown();
    }

    #[test]
    fn unknown_variant_is_reported() {
        let r = test_router(BatchPolicy::default(), 64);
        let err = r.infer_blocking("nope", image(3)).unwrap_err();
        assert!(err.to_string().contains("bcnn_rgb"));
        r.shutdown();
    }

    #[test]
    fn bad_payload_rejected() {
        let r = test_router(BatchPolicy::default(), 64);
        assert!(matches!(
            r.infer_blocking("bcnn_rgb", vec![0.0; 10]),
            Err(RouteError::BadPayload(10))
        ));
        r.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_complete() {
        let r = Arc::new(test_router(
            BatchPolicy { max_batch: 8, max_wait: std::time::Duration::from_millis(2) },
            256,
        ));
        let mut handles = Vec::new();
        for t in 0..4 {
            let r2 = Arc::clone(&r);
            handles.push(std::thread::spawn(move || {
                for i in 0..16 {
                    let resp = r2.infer_blocking("bcnn_rgb", image(t * 100 + i)).unwrap();
                    assert!(resp.error.is_none());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.metrics("bcnn_rgb").unwrap().completed(), 64);
        r.shutdown();
    }

    #[test]
    fn batch_failures_carry_real_request_ids() {
        // regression: failed submissions used to answer with id 0
        let r = test_router(BatchPolicy::default(), 64);
        let resps =
            r.infer_blocking_batch("bcnn_rgb", vec![vec![0.0; 3], image(4), vec![0.0; 5]]);
        assert_eq!(resps.len(), 3);
        assert!(resps[0].error.is_some() && resps[2].error.is_some());
        assert!(resps[1].error.is_none());
        assert_ne!(resps[0].id, 0);
        assert_ne!(resps[2].id, 0);
        // ids follow submission order, distinct per image
        assert!(resps[0].id < resps[1].id && resps[1].id < resps[2].id);
        r.shutdown();
    }

    #[test]
    fn deterministic_same_image_same_class() {
        let r = test_router(BatchPolicy::default(), 64);
        let img = image(9);
        let a = r.infer_blocking("bcnn_rgb", img.clone()).unwrap();
        let b = r.infer_blocking("bcnn_rgb", img).unwrap();
        assert_eq!(a.logits, b.logits);
        r.shutdown();
    }
}
