//! The router: the serving front door.  Owns one (queue, batcher,
//! backend, metrics) lane per registered model variant and routes
//! submissions by variant name.
//!
//! Lanes are **dynamic**: [`Router::add_lane`] spawns a new lane at
//! runtime and [`Router::remove_lane`] retires one gracefully (the
//! queue closes so nothing new is admitted, the executors drain every
//! already-admitted request, and the threads are reaped in the
//! background).  This is the substrate the model registry
//! ([`crate::registry`]) drives: each published `name@version` entry
//! owns one lane, so a batch can never mix model versions, and in-flight
//! work finishes on the old version while new admissions route to the
//! new one.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::time::Instant;

use super::backend::{InferBackend, IMG_ELEMS};
use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::queue::{BoundedQueue, PushError};
use super::request::{InferRequest, InferResponse, RequestId};
use crate::util::json::{Json, JsonObj};
use crate::util::lockorder;
use crate::util::trace::Trace;

#[derive(Debug)]
pub enum RouteError {
    UnknownVariant(String, String),
    /// `add_lane` refused a duplicate lane name.
    LaneExists(String),
    Rejected(PushError),
    BadPayload(usize),
    /// The lane's batcher died before answering (worker crash).
    BackendGone,
}

crate::error_enum_impls!(RouteError {
    RouteError::UnknownVariant(name, avail) =>
        ("unknown model variant {name:?} (available: {avail})"),
    RouteError::LaneExists(name) => ("lane {name:?} already registered"),
    RouteError::Rejected(e) => ("admission rejected: {e}"),
    RouteError::BadPayload(n) => ("image payload must be {IMG_ELEMS} floats, got {n}"),
    RouteError::BackendGone => ("backend dropped the response channel"),
}
source { RouteError::Rejected(e) => e }
from { PushError => RouteError::Rejected });

struct Lane {
    queue: Arc<BoundedQueue<InferRequest>>,
    metrics: Arc<Metrics>,
    /// The backend serving this lane, kept alongside the batcher so the
    /// observability plane (per-model `"profile"`, scratch-pool gauges)
    /// can reach it without going through the queue.
    backend: Arc<dyn InferBackend>,
    /// Taken (and retired) by `remove_lane`; dropped with the router
    /// otherwise.  Behind a mutex because lanes are shared as `Arc`s
    /// with in-flight submitters while an admin thread retires them.
    batcher: Mutex<Option<Batcher>>,
}

impl Lane {
    fn spawn(queue_capacity: usize, policy: BatchPolicy, backend: Arc<dyn InferBackend>) -> Self {
        let queue = Arc::new(BoundedQueue::new(queue_capacity));
        let metrics = Arc::new(Metrics::new());
        let batcher =
            Batcher::spawn(Arc::clone(&queue), Arc::clone(&backend), policy, Arc::clone(&metrics));
        Self { queue, metrics, backend, batcher: Mutex::new(Some(batcher)) }
    }
}

/// One image's slot in a group submission.  Every slot owns a real,
/// distinct request id — including images that never reached the queue
/// (validation or admission failure), so failure frames can always name
/// the request they answer.
pub struct GroupSlot {
    pub id: RequestId,
    /// Set when the image never entered the lane (bad payload, parse
    /// rejection carried in by the caller, admission backpressure, …).
    /// `None` means a response for `id` will arrive on the group channel.
    pub error: Option<String>,
}

impl GroupSlot {
    /// Whether a response for this slot will arrive on the group channel.
    pub fn submitted(&self) -> bool {
        self.error.is_none()
    }
}

/// A whole group submitted onto one shared response channel.
///
/// `rx` yields responses in **completion order**, not submission order —
/// with multi-executor lanes a later image can finish first.  Match
/// responses back to slots by `InferResponse::id`.  Dropping `rx` is
/// safe at any point: executors send into a disconnected channel without
/// blocking or failing the lane.
pub struct GroupSubmission {
    pub slots: Vec<GroupSlot>,
    pub rx: mpsc::Receiver<InferResponse>,
}

impl GroupSubmission {
    /// How many responses the group channel will deliver (slots that
    /// were actually admitted).
    pub fn pending(&self) -> usize {
        self.slots.iter().filter(|s| s.submitted()).count()
    }
}

/// Multi-variant serving router with runtime lane lifecycle.
pub struct Router {
    lanes: RwLock<HashMap<String, Arc<Lane>>>,
    default_variant: RwLock<String>,
    next_id: AtomicU64,
    queue_capacity: usize,
    policy: BatchPolicy,
}

impl Router {
    pub fn builder() -> RouterBuilder {
        RouterBuilder { lanes: Vec::new(), queue_capacity: 1024, policy: BatchPolicy::default() }
    }

    /// An empty router whose lanes are managed entirely at runtime (the
    /// registry's constructor).  `add_lane` / `remove_lane` /
    /// `set_default` drive the lifecycle; every lane shares `policy`
    /// (including its `executors` pool size) and `queue_capacity`.
    pub fn new_dynamic(queue_capacity: usize, policy: BatchPolicy) -> Self {
        Self {
            lanes: RwLock::new(HashMap::new()),
            default_variant: RwLock::new(String::new()),
            next_id: AtomicU64::new(1),
            queue_capacity,
            policy,
        }
    }

    fn lane(&self, variant: &str) -> Result<Arc<Lane>, RouteError> {
        Ok(self.lane_resolved(variant)?.1)
    }

    /// Resolve `variant` (empty means the default route) to its lane
    /// key and lane — callers that stamp traces need the resolved
    /// `name@version`, not the possibly-empty alias they were given.
    fn lane_resolved(&self, variant: &str) -> Result<(String, Arc<Lane>), RouteError> {
        // never hold the default-variant and lane-map locks together
        // (add_lane takes them in sequence; nesting could deadlock)
        let key = if variant.is_empty() {
            self.default_variant.read().unwrap().clone()
        } else {
            variant.to_string()
        };
        let lanes = self.lanes.read().unwrap();
        let _ord = lockorder::acquired(lockorder::ROUTER_LANES, "router.lanes");
        match lanes.get(&key).cloned() {
            Some(lane) => Ok((key, lane)),
            None => Err(RouteError::UnknownVariant(
                key,
                lanes.keys().cloned().collect::<Vec<_>>().join(", "),
            )),
        }
    }

    /// Spawn a new lane for `backend` under `name`, using the router's
    /// shared policy and queue capacity.  The first lane ever added
    /// becomes the default variant (unless one was already set).
    pub fn add_lane(
        &self,
        name: impl Into<String>,
        backend: Arc<dyn InferBackend>,
    ) -> Result<(), RouteError> {
        self.add_lane_with_policy(name, backend, self.policy)
    }

    /// `add_lane` with a per-lane batch policy override — the registry's
    /// per-model `"batch"` manifest knob: one entry can run a deeper
    /// batcher or a wider executor pool than its neighbours without
    /// changing the router default every other lane inherits.
    pub fn add_lane_with_policy(
        &self,
        name: impl Into<String>,
        backend: Arc<dyn InferBackend>,
        policy: BatchPolicy,
    ) -> Result<(), RouteError> {
        let name = name.into();
        {
            let mut lanes = self.lanes.write().unwrap();
            let _ord = lockorder::acquired(lockorder::ROUTER_LANES, "router.lanes");
            if lanes.contains_key(&name) {
                return Err(RouteError::LaneExists(name));
            }
            let lane = Lane::spawn(self.queue_capacity, policy, backend);
            lanes.insert(name.clone(), Arc::new(lane));
        }
        let mut def = self.default_variant.write().unwrap();
        if def.is_empty() {
            *def = name;
        }
        Ok(())
    }

    /// The batch policy lanes inherit when spawned without an override.
    pub fn default_policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Executor-pool width of a lane (for the admin plane's effective
    /// policy report).
    pub fn lane_executors(&self, name: &str) -> Result<usize, RouteError> {
        let lane = self.lane(name)?;
        let executors = lane.batcher.lock().unwrap().as_ref().map(|b| b.executors());
        Ok(executors.unwrap_or(0))
    }

    /// Retire a lane: unregister it (new submissions fail with
    /// `UnknownVariant`, racing ones with a closed-queue rejection),
    /// then let its executors drain every already-admitted request
    /// before the threads are reaped in the background.  If the removed
    /// lane was the default variant, the default is cleared rather than
    /// left dangling — the empty-variant route then fails with a
    /// structured error until `set_default` (or the next first
    /// `add_lane`) re-points it.
    pub fn remove_lane(&self, name: &str) -> Result<(), RouteError> {
        let lane = {
            let mut lanes = self.lanes.write().unwrap();
            let _ord = lockorder::acquired(lockorder::ROUTER_LANES, "router.lanes");
            match lanes.remove(name) {
                Some(lane) => lane,
                None => {
                    return Err(RouteError::UnknownVariant(
                        name.to_string(),
                        lanes.keys().cloned().collect::<Vec<_>>().join(", "),
                    ))
                }
            }
        };
        {
            let mut def = self.default_variant.write().unwrap();
            if *def == name {
                def.clear();
            }
        }
        if let Some(batcher) = lane.batcher.lock().unwrap().take() {
            batcher.retire();
        }
        Ok(())
    }

    /// Re-point the empty-variant (`""`) route at `name`.
    pub fn set_default(&self, name: &str) -> Result<(), RouteError> {
        {
            let lanes = self.lanes.read().unwrap();
            let _ord = lockorder::acquired(lockorder::ROUTER_LANES, "router.lanes");
            if !lanes.contains_key(name) {
                return Err(RouteError::UnknownVariant(
                    name.to_string(),
                    lanes.keys().cloned().collect::<Vec<_>>().join(", "),
                ));
            }
        }
        *self.default_variant.write().unwrap() = name.to_string();
        Ok(())
    }

    pub fn has_lane(&self, name: &str) -> bool {
        self.lanes.read().unwrap().contains_key(name)
    }

    fn alloc_id(&self) -> RequestId {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Submit one image; returns the request id and the response channel.
    pub fn submit(
        &self,
        variant: &str,
        image: Vec<f32>,
    ) -> Result<(RequestId, mpsc::Receiver<InferResponse>), RouteError> {
        self.submit_with_id(self.alloc_id(), variant, image)
    }

    /// Submission with a caller-assigned id, so the batch path can report
    /// a real request id even when admission itself fails.
    fn submit_with_id(
        &self,
        id: RequestId,
        variant: &str,
        image: Vec<f32>,
    ) -> Result<(RequestId, mpsc::Receiver<InferResponse>), RouteError> {
        let (tx, rx) = mpsc::channel();
        self.submit_with_sender(id, variant, image, tx, None)?;
        Ok((id, rx))
    }

    /// Submission onto a caller-owned response channel — the group/stream
    /// path shares ONE channel across a whole request group, so responses
    /// arrive in **completion order** (a fast image's response is
    /// available before a slow peer finishes, even mid-group).
    fn submit_with_sender(
        &self,
        id: RequestId,
        variant: &str,
        image: Vec<f32>,
        resp: mpsc::Sender<InferResponse>,
        mut trace: Option<Box<Trace>>,
    ) -> Result<(), RouteError> {
        if image.len() != IMG_ELEMS {
            return Err(RouteError::BadPayload(image.len()));
        }
        let (key, lane) = self.lane_resolved(variant)?;
        if let Some(t) = trace.as_deref_mut() {
            t.id = id;
            t.model = key;
            t.mark("admitted");
        }
        lane.metrics.record_submit();
        if let Some(t) = trace.as_deref_mut() {
            t.mark("enqueued");
        }
        let req = InferRequest { id, image, enqueued: Instant::now(), resp, trace };
        lane.queue.try_push(req).map_err(|e| {
            lane.metrics.record_reject();
            RouteError::Rejected(e)
        })
    }

    /// Submit and block for the response (convenience for CLI paths).
    /// A dead batcher surfaces as `BackendGone` instead of a panic so a
    /// serving thread can answer the client with a structured error.
    pub fn infer_blocking(
        &self,
        variant: &str,
        image: Vec<f32>,
    ) -> Result<InferResponse, RouteError> {
        self.infer_blocking_traced(variant, image, None)
    }

    /// [`Router::infer_blocking`] carrying an optional span trace: the
    /// trace rides the [`InferRequest`] through the lane (admission and
    /// queue stages stamped here, batch/exec stages in the batcher) and
    /// comes back on the [`InferResponse`].  `None` is the steady-state
    /// path and behaves exactly like `infer_blocking`.
    pub fn infer_blocking_traced(
        &self,
        variant: &str,
        image: Vec<f32>,
        trace: Option<Box<Trace>>,
    ) -> Result<InferResponse, RouteError> {
        let id = self.alloc_id();
        let (tx, rx) = mpsc::channel();
        self.submit_with_sender(id, variant, image, tx, trace)?;
        rx.recv().map_err(|_| RouteError::BackendGone)
    }

    /// Submit a whole group of images to one variant's lane back-to-back
    /// onto ONE shared response channel.  Because the images hit the
    /// admission queue together, the dynamic batcher can drain them into
    /// batched backend calls (up to `BatchPolicy::max_batch`), and with
    /// multi-executor lanes several of those batches execute
    /// concurrently.  This is the entry point for both `classify_batch`
    /// (which blocks for the whole group) and `classify_batch_stream`
    /// (which forwards each response as it completes).
    ///
    /// `images` entries may carry an upstream per-image error (e.g. a
    /// non-finite pixel caught at protocol parse); those get a real
    /// request id and an errored slot without touching the lane.
    /// Errors stay per-image: a mid-group rejection must not discard the
    /// results of images already submitted and executing.
    pub fn submit_group(
        &self,
        variant: &str,
        images: Vec<Result<Vec<f32>, String>>,
    ) -> GroupSubmission {
        let (tx, rx) = mpsc::channel();
        // submit everything first so the batcher sees the whole group;
        // each image gets its id up front so a failed submission still
        // reports a real id (regression: failures used to answer id 0)
        let slots = images
            .into_iter()
            .map(|img| {
                let id = self.alloc_id();
                let error = match img {
                    Err(reason) => Some(reason),
                    Ok(image) => self
                        .submit_with_sender(id, variant, image, tx.clone(), None)
                        .err()
                        .map(|e| e.to_string()),
                };
                GroupSlot { id, error }
            })
            .collect();
        GroupSubmission { slots, rx }
    }

    /// Submit a whole batch of images to one variant's lane, then block
    /// for every response and return them in **submission order** (the
    /// `classify_batch` contract; responses are matched back to slots by
    /// id, so out-of-order completion under multi-executor lanes is
    /// invisible here).
    pub fn infer_blocking_batch(
        &self,
        variant: &str,
        images: Vec<Vec<f32>>,
    ) -> Vec<InferResponse> {
        let group = self.submit_group(variant, images.into_iter().map(Ok).collect());
        let mut by_id: HashMap<RequestId, InferResponse> = HashMap::new();
        for _ in 0..group.pending() {
            match group.rx.recv() {
                Ok(resp) => {
                    by_id.insert(resp.id, resp);
                }
                Err(_) => break, // lane died; remaining slots fail below
            }
        }
        group
            .slots
            .into_iter()
            .map(|slot| match slot.error {
                Some(e) => InferResponse::failed(slot.id, e),
                None => by_id.remove(&slot.id).unwrap_or_else(|| {
                    InferResponse::failed(slot.id, RouteError::BackendGone.to_string())
                }),
            })
            .collect()
    }

    pub fn variants(&self) -> Vec<String> {
        let mut v: Vec<String> = self.lanes.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    pub fn default_variant(&self) -> String {
        self.default_variant.read().unwrap().clone()
    }

    pub fn metrics(&self, variant: &str) -> Result<Arc<Metrics>, RouteError> {
        Ok(Arc::clone(&self.lane(variant)?.metrics))
    }

    /// Queue occupancy of a lane: `(depth, capacity)` — the
    /// backpressure gauges in the metrics exposition.
    pub fn queue_depth(&self, variant: &str) -> Result<(usize, usize), RouteError> {
        let lane = self.lane(variant)?;
        Ok((lane.queue.len(), lane.queue.capacity()))
    }

    /// The backend serving a lane, for per-model observability
    /// (`"profile"` in `list_models`, scratch-pool gauges).
    pub fn lane_backend(&self, variant: &str) -> Result<Arc<dyn InferBackend>, RouteError> {
        Ok(Arc::clone(&self.lane(variant)?.backend))
    }

    /// Aggregate stats across all lanes.
    pub fn stats(&self) -> Json {
        let lanes = self.lanes.read().unwrap();
        let _ord = lockorder::acquired(lockorder::ROUTER_LANES, "router.lanes");
        let mut obj = JsonObj::new();
        let mut names: Vec<&String> = lanes.keys().collect();
        names.sort();
        for name in names {
            obj.insert(name.clone(), lanes[name].metrics.snapshot());
        }
        Json::Obj(obj)
    }

    /// Close all queues (drains in-flight work; batchers exit).
    pub fn shutdown(&self) {
        let lanes = self.lanes.read().unwrap();
        let _ord = lockorder::acquired(lockorder::ROUTER_LANES, "router.lanes");
        for lane in lanes.values() {
            lane.queue.close();
        }
    }
}

/// Builder: register variants then `build`.
pub struct RouterBuilder {
    lanes: Vec<(String, Arc<dyn InferBackend>)>,
    queue_capacity: usize,
    policy: BatchPolicy,
}

impl RouterBuilder {
    pub fn queue_capacity(mut self, cap: usize) -> Self {
        self.queue_capacity = cap;
        self
    }

    pub fn policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn variant(mut self, name: impl Into<String>, backend: Arc<dyn InferBackend>) -> Self {
        self.lanes.push((name.into(), backend));
        self
    }

    pub fn build(self) -> Router {
        assert!(!self.lanes.is_empty(), "router needs at least one variant");
        let router = Router::new_dynamic(self.queue_capacity, self.policy);
        for (name, backend) in self.lanes {
            router.add_lane(name, backend).expect("duplicate variant registered");
        }
        router
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::network::tests_support::synth_bcnn_network;
    use crate::coordinator::backend::EngineBackend;
    use crate::input::binarize::Scheme;
    use crate::util::rng::Xoshiro256;

    fn test_router(policy: BatchPolicy, capacity: usize) -> Router {
        let be: Arc<dyn InferBackend> =
            Arc::new(EngineBackend::bcnn(synth_bcnn_network(Scheme::Rgb, 1), 2));
        Router::builder()
            .policy(policy)
            .queue_capacity(capacity)
            .variant("bcnn_rgb", be)
            .build()
    }

    fn image(seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256::new(seed);
        (0..IMG_ELEMS).map(|_| rng.next_f32()).collect()
    }

    #[test]
    fn routes_and_answers() {
        let r = test_router(BatchPolicy::default(), 64);
        let resp = r.infer_blocking("bcnn_rgb", image(1)).unwrap();
        assert!(resp.error.is_none());
        assert_eq!(resp.logits.len(), 4);
        assert!(resp.class < 4);
        r.shutdown();
    }

    #[test]
    fn default_variant_used_for_empty_name() {
        let r = test_router(BatchPolicy::default(), 64);
        let resp = r.infer_blocking("", image(2)).unwrap();
        assert!(resp.error.is_none());
        r.shutdown();
    }

    #[test]
    fn unknown_variant_is_reported() {
        let r = test_router(BatchPolicy::default(), 64);
        let err = r.infer_blocking("nope", image(3)).unwrap_err();
        assert!(err.to_string().contains("bcnn_rgb"));
        r.shutdown();
    }

    #[test]
    fn bad_payload_rejected() {
        let r = test_router(BatchPolicy::default(), 64);
        assert!(matches!(
            r.infer_blocking("bcnn_rgb", vec![0.0; 10]),
            Err(RouteError::BadPayload(10))
        ));
        r.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_complete() {
        let r = Arc::new(test_router(
            BatchPolicy {
                max_batch: 8,
                max_wait: std::time::Duration::from_millis(2),
                ..BatchPolicy::default()
            },
            256,
        ));
        let mut handles = Vec::new();
        for t in 0..4 {
            let r2 = Arc::clone(&r);
            handles.push(std::thread::spawn(move || {
                for i in 0..16 {
                    let resp = r2.infer_blocking("bcnn_rgb", image(t * 100 + i)).unwrap();
                    assert!(resp.error.is_none());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.metrics("bcnn_rgb").unwrap().completed(), 64);
        r.shutdown();
    }

    #[test]
    fn batch_failures_carry_real_request_ids() {
        // regression: failed submissions used to answer with id 0
        let r = test_router(BatchPolicy::default(), 64);
        let resps =
            r.infer_blocking_batch("bcnn_rgb", vec![vec![0.0; 3], image(4), vec![0.0; 5]]);
        assert_eq!(resps.len(), 3);
        assert!(resps[0].error.is_some() && resps[2].error.is_some());
        assert!(resps[1].error.is_none());
        assert_ne!(resps[0].id, 0);
        assert_ne!(resps[2].id, 0);
        // ids follow submission order, distinct per image
        assert!(resps[0].id < resps[1].id && resps[1].id < resps[2].id);
        r.shutdown();
    }

    #[test]
    fn submit_group_slots_carry_upstream_errors_and_real_ids() {
        let r = test_router(BatchPolicy::default(), 64);
        let group = r.submit_group(
            "bcnn_rgb",
            vec![
                Ok(image(11)),
                Err("non-finite pixel".to_string()), // parse-layer reject
                Ok(vec![0.0; 9]),                    // bad payload
                Ok(image(12)),
            ],
        );
        assert_eq!(group.slots.len(), 4);
        assert_eq!(group.pending(), 2);
        assert!(group.slots[0].submitted() && group.slots[3].submitted());
        assert_eq!(group.slots[1].error.as_deref(), Some("non-finite pixel"));
        assert!(group.slots[2].error.as_ref().unwrap().contains("payload"));
        // every slot owns a real, distinct, ascending id — failures too
        for w in group.slots.windows(2) {
            assert!(w[0].id < w[1].id);
        }
        // the shared channel delivers exactly the admitted responses,
        // ids matching the submitted slots
        let mut got = vec![group.rx.recv().unwrap(), group.rx.recv().unwrap()];
        got.sort_by_key(|resp| resp.id);
        assert_eq!(got[0].id, group.slots[0].id);
        assert_eq!(got[1].id, group.slots[3].id);
        assert!(got.iter().all(|resp| resp.error.is_none()));
        r.shutdown();
    }

    #[test]
    fn dynamic_lane_lifecycle_add_default_remove() {
        let r = Router::new_dynamic(64, BatchPolicy::default());
        assert!(r.variants().is_empty());
        assert!(matches!(r.infer_blocking("", image(1)), Err(RouteError::UnknownVariant(..))));

        let be_a: Arc<dyn InferBackend> =
            Arc::new(EngineBackend::bcnn(synth_bcnn_network(Scheme::Rgb, 1), 1));
        let be_b: Arc<dyn InferBackend> =
            Arc::new(EngineBackend::bcnn(synth_bcnn_network(Scheme::Rgb, 2), 1));
        r.add_lane("m@1", Arc::clone(&be_a)).unwrap();
        // first lane becomes the default route
        assert_eq!(r.default_variant(), "m@1");
        assert!(r.infer_blocking("", image(2)).unwrap().error.is_none());

        // duplicates are refused
        assert!(matches!(r.add_lane("m@1", be_a), Err(RouteError::LaneExists(_))));

        r.add_lane("m@2", be_b).unwrap();
        assert_eq!(r.variants(), vec!["m@1", "m@2"]);
        r.set_default("m@2").unwrap();
        assert_eq!(r.default_variant(), "m@2");
        assert!(matches!(r.set_default("nope"), Err(RouteError::UnknownVariant(..))));

        // retire the old version: it disappears from routing...
        r.remove_lane("m@1").unwrap();
        assert!(!r.has_lane("m@1"));
        assert!(matches!(r.infer_blocking("m@1", image(3)), Err(RouteError::UnknownVariant(..))));
        assert!(matches!(r.remove_lane("m@1"), Err(RouteError::UnknownVariant(..))));
        // ...while the new default keeps serving
        assert!(r.infer_blocking("", image(4)).unwrap().error.is_none());
        // removing the default lane clears the default instead of
        // leaving it dangling at a dead name
        r.remove_lane("m@2").unwrap();
        assert_eq!(r.default_variant(), "");
        assert!(matches!(r.infer_blocking("", image(5)), Err(RouteError::UnknownVariant(..))));
        r.shutdown();
    }

    #[test]
    fn deterministic_same_image_same_class() {
        let r = test_router(BatchPolicy::default(), 64);
        let img = image(9);
        let a = r.infer_blocking("bcnn_rgb", img.clone()).unwrap();
        let b = r.infer_blocking("bcnn_rgb", img).unwrap();
        assert_eq!(a.logits, b.logits);
        r.shutdown();
    }

    #[test]
    fn traced_requests_carry_a_monotone_stage_timeline() {
        let r = test_router(BatchPolicy::default(), 64);
        let mut trace = Box::new(crate::util::trace::Trace::begin());
        trace.mark("parsed");
        // default-route submission: the trace must name the RESOLVED lane
        let resp = r.infer_blocking_traced("", image(21), Some(trace)).unwrap();
        assert!(resp.error.is_none());
        let t = resp.trace.expect("traced request returns its trace");
        assert_eq!(t.model, "bcnn_rgb");
        assert_eq!(t.id, resp.id);
        let labels: Vec<&str> = t.spans().iter().map(|(l, _)| l.as_str()).collect();
        assert_eq!(&labels[..4], &["parsed", "admitted", "enqueued", "batch_formed"]);
        assert_eq!(labels[labels.len() - 1], "logits");
        let exec_spans = labels.iter().filter(|l| l.starts_with("exec:")).count();
        assert!(exec_spans >= 1, "per-step exec spans present: {labels:?}");
        for w in t.spans().windows(2) {
            assert!(w[0].1 <= w[1].1, "offsets monotone: {:?}", t.spans());
        }
        // traced and untraced logits are bit-identical
        let plain = r.infer_blocking("bcnn_rgb", image(21)).unwrap();
        assert_eq!(plain.logits, resp.logits);
        assert!(plain.trace.is_none(), "untraced requests carry no trace");
        r.shutdown();
    }
}
