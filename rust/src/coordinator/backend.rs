//! Inference backends: the pure-Rust engine and the PJRT runtime.
//!
//! Both expose `infer_batch(images) -> logits`; the batcher is agnostic.
//! The PJRT client is not `Send`, so `RuntimeBackend` owns a dedicated
//! executor thread and proxies batches over channels.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::bnn::graph::CompiledNetwork;
use crate::bnn::network::{BcnnNetwork, FloatNetwork};
use crate::bnn::scratch::PlanScratch;
use crate::runtime::{Artifacts, ModelRuntime, RuntimeError};
use crate::util::json::Json;
use crate::util::lockorder;
use crate::util::threadpool::scoped_map;

pub const IMG_ELEMS: usize = 96 * 96 * 3;

/// Scratch-arena pool observability snapshot (`None` for backends
/// without a pool, e.g. the PJRT runtime).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    /// Idle arenas currently parked in the pool.
    pub arenas: usize,
    /// Peak capacity in bytes across pooled arenas, per slot class
    /// (`[f32, u32, i32]` — all 4-byte elements).
    pub peak_bytes: [usize; 3],
}

/// A model backend the batcher can drive.
pub trait InferBackend: Send + Sync {
    /// Human-readable backend name (for metrics / CLI).
    fn name(&self) -> String;

    /// Batch sizes the backend can execute natively, ascending.
    /// The engine accepts anything (`vec![usize::MAX]` sentinel).
    fn supported_batches(&self) -> Vec<usize>;

    /// Per-step serving profile (`list_models` `"profile"` field);
    /// `None` when the backend has no per-step instrumentation.
    fn profile_json(&self) -> Option<Json> {
        None
    }

    /// Scratch-pool gauges for the metrics exposition; `None` when the
    /// backend owns no arena pool.
    fn pool_stats(&self) -> Option<PoolStats> {
        None
    }

    /// Run `n` images (flattened, `n * IMG_ELEMS` floats); returns
    /// `n * classes` logits, where `classes` is the served model's
    /// declared head width (4 for the legacy networks).
    fn infer_batch(&self, images: &[f32]) -> Result<Vec<f32>, String>;

    /// Gather-free batch entry: one slice per request (each `IMG_ELEMS`
    /// floats) plus the padded batch size `exec` to execute.  The
    /// default gathers the slices into `gather` (reused across calls by
    /// the batcher, so steady state allocates nothing) and runs
    /// [`InferBackend::infer_batch`]; backends that can consume a
    /// request's buffer in place override it — [`EngineBackend`] skips
    /// the copy entirely on the B=1 path.  Must be bit-identical to the
    /// gathered path (property-tested in this module).
    fn infer_slices(
        &self,
        images: &[&[f32]],
        exec: usize,
        gather: &mut Vec<f32>,
    ) -> Result<Vec<f32>, String> {
        gather_padded(images, exec, gather);
        self.infer_batch(gather)
    }

    /// [`InferBackend::infer_slices`] plus per-plan-step wall times
    /// appended to `steps` as `(label, ns)` pairs — the traced-batch
    /// path.  The default cannot time steps and leaves `steps` empty;
    /// results must be identical to the untimed path either way.
    fn infer_slices_timed(
        &self,
        images: &[&[f32]],
        exec: usize,
        gather: &mut Vec<f32>,
        steps: &mut Vec<(String, u64)>,
    ) -> Result<Vec<f32>, String> {
        let _ = steps;
        self.infer_slices(images, exec, gather)
    }
}

/// Assemble per-request image slices into one contiguous payload of
/// `exec * IMG_ELEMS` floats (tail zero-padded).  Cleared and re-zeroed
/// every call, so padding lanes never carry a previous batch's pixels.
pub fn gather_padded(images: &[&[f32]], exec: usize, out: &mut Vec<f32>) {
    out.clear();
    out.resize(exec * IMG_ELEMS, 0.0);
    for (i, img) in images.iter().enumerate() {
        out[i * IMG_ELEMS..(i + 1) * IMG_ELEMS].copy_from_slice(img);
    }
}

// ---------------------------------------------------------------------------
// pure-Rust engine backend
// ---------------------------------------------------------------------------

/// CPU engine backend; data-parallel across a scoped thread pool.
///
/// The engine runs a [`CompiledNetwork`] — a layer-graph plan with
/// weights bound — so ANY topology the plan compiler accepts serves
/// through the same backend; the legacy `BcnnNetwork`/`FloatNetwork`
/// constructors below just unwrap their compiled plan.
pub struct EngineBackend {
    model: CompiledNetwork,
    threads: usize,
    label: String,
    /// Checked-out-and-returned planned arenas, one per concurrent
    /// worker: a worker pops one for the duration of its chunk and pushes
    /// it back, so steady-state inference allocates no intermediate
    /// tensors (the pool grows to at most `threads × executors` arenas,
    /// each sized by this backend's plan — the pool is keyed by the
    /// backend, hence by its plan; slots are role-less, so even an arena
    /// that once served a deeper plan stays valid).  Arenas carry the
    /// serving decay policy: every
    /// [`PlanScratch::SERVING_DECAY_BATCHES`] batches an arena shrinks
    /// back to the window's high-water mark, so a worker that once served
    /// a B=64 burst stops pinning that memory under steady B=1 traffic.
    scratch_pool: Mutex<Vec<PlanScratch>>,
}

impl EngineBackend {
    /// A backend around an arbitrary compiled plan (the registry loader
    /// uses this for manifest-declared `arch` graphs).
    pub fn compiled(model: CompiledNetwork, threads: usize, label: impl Into<String>) -> Self {
        Self {
            model,
            threads: threads.max(1),
            label: label.into(),
            scratch_pool: Mutex::new(Vec::new()),
        }
    }

    pub fn bcnn(net: BcnnNetwork, threads: usize) -> Self {
        let label = format!("engine/bcnn_{}", net.scheme.name());
        Self::compiled(net.into_compiled(), threads, label)
    }

    pub fn float(net: FloatNetwork, threads: usize) -> Self {
        Self::compiled(net.into_compiled(), threads, "engine/float")
    }
}

impl InferBackend for EngineBackend {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn supported_batches(&self) -> Vec<usize> {
        vec![usize::MAX] // any size
    }

    fn infer_batch(&self, images: &[f32]) -> Result<Vec<f32>, String> {
        if images.len() % IMG_ELEMS != 0 {
            return Err(format!("batch payload {} not a multiple of {IMG_ELEMS}", images.len()));
        }
        let n = images.len() / IMG_ELEMS;
        if n == 0 {
            return Ok(Vec::new());
        }
        // The whole batch flows through the networks' batched forward
        // (one A-operand repack per conv layer, not per image).  With
        // several worker threads the batch is split into contiguous
        // sub-batches — still batched within each chunk, and bit-identical
        // per image either way.  Each worker checks a forward arena out of
        // the pool, so steady-state serving allocates no intermediate
        // tensors.
        let run = |lo: usize, hi: usize| -> Result<Vec<f32>, String> {
            let xs = &images[lo * IMG_ELEMS..hi * IMG_ELEMS];
            // the pool mutex is the highest-ranked lock in the stack
            // (held only around a pop/push, never across the forward)
            let mut scratch = {
                let mut pool = self.scratch_pool.lock().unwrap();
                let _ord = lockorder::acquired(lockorder::SCRATCH_POOL, "backend.scratch_pool");
                pool.pop()
            }
            .unwrap_or_else(|| PlanScratch::with_decay(PlanScratch::SERVING_DECAY_BATCHES));
            let result =
                self.model.infer_batch_with(xs, &mut scratch).map_err(|e| e.to_string());
            {
                let mut pool = self.scratch_pool.lock().unwrap();
                let _ord = lockorder::acquired(lockorder::SCRATCH_POOL, "backend.scratch_pool");
                pool.push(scratch);
            }
            result
        };
        let per = n.div_ceil(self.threads.min(n));
        let chunks = n.div_ceil(per);
        let results: Vec<Result<Vec<f32>, String>> = if chunks == 1 {
            vec![run(0, n)]
        } else {
            scoped_map(chunks, chunks, |i| run(i * per, ((i + 1) * per).min(n)))
        };
        let mut out = Vec::with_capacity(n * self.model.num_classes());
        for chunk in results {
            out.extend_from_slice(&chunk?);
        }
        Ok(out)
    }

    /// The engine runs any batch size, so a single unpadded request can
    /// be forwarded straight from its own buffer — the dominant serving
    /// shape under the paper's real-time protocol (`max_batch = 1`)
    /// never copies pixels into a staging payload at all.
    fn infer_slices(
        &self,
        images: &[&[f32]],
        exec: usize,
        gather: &mut Vec<f32>,
    ) -> Result<Vec<f32>, String> {
        if let [only] = images {
            if exec == 1 {
                return self.infer_batch(only);
            }
        }
        gather_padded(images, exec, gather);
        self.infer_batch(gather)
    }

    /// Traced batches run single-chunk through the plan's timed forward
    /// (no worker split — per-step times for a split batch would
    /// interleave).  Bit-identical to the untimed path: chunking never
    /// changes per-image results (property-tested in this module).
    fn infer_slices_timed(
        &self,
        images: &[&[f32]],
        exec: usize,
        gather: &mut Vec<f32>,
        steps: &mut Vec<(String, u64)>,
    ) -> Result<Vec<f32>, String> {
        let mut scratch = {
            let mut pool = self.scratch_pool.lock().unwrap();
            let _ord = lockorder::acquired(lockorder::SCRATCH_POOL, "backend.scratch_pool");
            pool.pop()
        }
        .unwrap_or_else(|| PlanScratch::with_decay(PlanScratch::SERVING_DECAY_BATCHES));
        let single = matches!(images, [_] if exec == 1);
        let result = if single {
            self.model.infer_batch_timed(images[0], &mut scratch)
        } else {
            gather_padded(images, exec, gather);
            self.model.infer_batch_timed(gather, &mut scratch)
        };
        {
            let mut pool = self.scratch_pool.lock().unwrap();
            let _ord = lockorder::acquired(lockorder::SCRATCH_POOL, "backend.scratch_pool");
            pool.push(scratch);
        }
        match result {
            Ok((logits, times)) => {
                steps.extend(times.into_iter().map(|(label, d)| (label, d.as_nanos() as u64)));
                Ok(logits)
            }
            Err(e) => Err(e.to_string()),
        }
    }

    fn profile_json(&self) -> Option<Json> {
        Some(self.model.profile_json())
    }

    fn pool_stats(&self) -> Option<PoolStats> {
        let pool = self.scratch_pool.lock().unwrap();
        let _ord = lockorder::acquired(lockorder::SCRATCH_POOL, "backend.scratch_pool");
        let mut peak = [0usize; 3];
        for arena in pool.iter() {
            let caps = arena.class_capacity_bytes();
            for (p, c) in peak.iter_mut().zip(caps) {
                *p = (*p).max(c);
            }
        }
        Some(PoolStats { arenas: pool.len(), peak_bytes: peak })
    }
}

// ---------------------------------------------------------------------------
// PJRT runtime backend (dedicated executor thread)
// ---------------------------------------------------------------------------

enum RtMsg {
    Infer { images: Vec<f32>, resp: mpsc::Sender<Result<Vec<f32>, String>> },
    Shutdown,
}

/// Backend executing AOT HLO artifacts on a dedicated PJRT thread.
///
/// Loads every batch variant of a model family (e.g.
/// `model_bcnn_rgb_ref_b{1,4,16,64}`) and dispatches each batch to the
/// matching executable.
pub struct RuntimeBackend {
    tx: mpsc::Sender<RtMsg>,
    batches: Vec<usize>,
    label: String,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl RuntimeBackend {
    /// `model_names`: artifact names keyed by their batch size.
    pub fn spawn(
        artifacts: Arc<Artifacts>,
        model_names: Vec<(usize, String)>,
        label: impl Into<String>,
    ) -> Result<Self, RuntimeError> {
        let (tx, rx) = mpsc::channel::<RtMsg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<Vec<usize>, String>>();
        let names = model_names.clone();
        let handle = std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || {
                // All PJRT state lives on this thread (client is !Send).
                let client = match crate::runtime::client::cpu_client() {
                    Ok(c) => c,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e.to_string()));
                        return;
                    }
                };
                let mut models: Vec<(usize, ModelRuntime)> = Vec::new();
                for (bs, name) in &names {
                    match ModelRuntime::load(&client, &artifacts, name) {
                        Ok(m) => models.push((*bs, m)),
                        Err(e) => {
                            let _ = ready_tx.send(Err(format!("{name}: {e}")));
                            return;
                        }
                    }
                }
                models.sort_by_key(|(bs, _)| *bs);
                let _ = ready_tx.send(Ok(models.iter().map(|(bs, _)| *bs).collect()));
                while let Ok(msg) = rx.recv() {
                    match msg {
                        RtMsg::Shutdown => break,
                        RtMsg::Infer { images, resp } => {
                            let n = images.len() / IMG_ELEMS;
                            let result = models
                                .iter()
                                .find(|(bs, _)| *bs == n)
                                .ok_or_else(|| format!("no executable for batch {n}"))
                                .and_then(|(_, m)| m.infer(&images).map_err(|e| e.to_string()));
                            let _ = resp.send(result);
                        }
                    }
                }
            })
            .expect("spawn pjrt executor");
        let batches = ready_rx
            .recv()
            .map_err(|_| RuntimeError::Xla("executor thread died during init".into()))?
            .map_err(RuntimeError::Xla)?;
        Ok(Self { tx, batches, label: label.into(), handle: Some(handle) })
    }
}

impl InferBackend for RuntimeBackend {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn supported_batches(&self) -> Vec<usize> {
        self.batches.clone()
    }

    fn infer_batch(&self, images: &[f32]) -> Result<Vec<f32>, String> {
        let (resp_tx, resp_rx) = mpsc::channel();
        self.tx
            .send(RtMsg::Infer { images: images.to_vec(), resp: resp_tx })
            .map_err(|_| "pjrt executor gone".to_string())?;
        resp_rx.recv().map_err(|_| "pjrt executor dropped response".to_string())?
    }
}

impl Drop for RuntimeBackend {
    fn drop(&mut self) {
        let _ = self.tx.send(RtMsg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::network::tests_support::synth_bcnn_network;
    use crate::input::binarize::Scheme;

    #[test]
    fn engine_backend_single_and_batch_agree() {
        let net = synth_bcnn_network(Scheme::Rgb, 11);
        let be = EngineBackend::bcnn(net, 4);
        let mut rng = crate::util::rng::Xoshiro256::new(5);
        let imgs: Vec<f32> = (0..3 * IMG_ELEMS).map(|_| rng.next_f32()).collect();
        let batched = be.infer_batch(&imgs).unwrap();
        for i in 0..3 {
            let single = be.infer_batch(&imgs[i * IMG_ELEMS..(i + 1) * IMG_ELEMS]).unwrap();
            assert_eq!(&batched[i * 4..(i + 1) * 4], &single[..]);
        }
    }

    #[test]
    fn engine_backend_scratch_pool_reuses_arenas() {
        let net = synth_bcnn_network(Scheme::Gray, 12);
        let be = EngineBackend::bcnn(net, 2);
        let mut rng = crate::util::rng::Xoshiro256::new(9);
        let imgs: Vec<f32> = (0..4 * IMG_ELEMS).map(|_| rng.next_f32()).collect();
        let first = be.infer_batch(&imgs).unwrap();
        // repeated and differently-sized payloads flow through the same
        // pooled arenas and stay bit-identical
        for _ in 0..3 {
            assert_eq!(be.infer_batch(&imgs).unwrap(), first);
        }
        let small = be.infer_batch(&imgs[..IMG_ELEMS]).unwrap();
        assert_eq!(&first[..4], &small[..]);
        // the pool never grows beyond the worker count
        assert!(be.scratch_pool.lock().unwrap().len() <= 2);
    }

    #[test]
    fn engine_backend_rejects_ragged_payload() {
        let net = synth_bcnn_network(Scheme::Lbp, 3);
        let be = EngineBackend::bcnn(net, 1);
        assert!(be.infer_batch(&[0.0; 100]).is_err());
    }

    #[test]
    fn gather_padded_zeroes_padding_lanes() {
        let a = vec![1.0f32; IMG_ELEMS];
        let mut buf = vec![9.0f32; 7]; // stale garbage must vanish
        gather_padded(&[&a], 4, &mut buf);
        assert_eq!(buf.len(), 4 * IMG_ELEMS);
        assert!(buf[..IMG_ELEMS].iter().all(|&v| v == 1.0));
        assert!(buf[IMG_ELEMS..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn engine_single_slice_skips_the_gather_buffer() {
        // the B=1 path must run straight off the request's own buffer:
        // the (empty) gather buffer stays untouched, and the logits are
        // bit-identical to the contiguous path
        let net = synth_bcnn_network(Scheme::Rgb, 17);
        let be = EngineBackend::bcnn(net, 1);
        let mut rng = crate::util::rng::Xoshiro256::new(3);
        let img: Vec<f32> = (0..IMG_ELEMS).map(|_| rng.next_f32()).collect();
        let mut gather = Vec::new();
        let via_slices = be.infer_slices(&[&img[..]], 1, &mut gather).unwrap();
        assert!(gather.is_empty(), "B=1 must not gather");
        assert_eq!(via_slices, be.infer_batch(&img).unwrap());
    }

    #[test]
    fn infer_slices_bit_identical_to_gathered_batches() {
        use crate::util::prop::{self, ensure_eq};
        let net = synth_bcnn_network(Scheme::Gray, 23);
        let be = EngineBackend::bcnn(net, 2);
        prop::check(6, |g| {
            let n = g.usize_in(1, 4);
            let exec = n + g.usize_in(0, 2); // sometimes padded
            let seed = g.u64();
            let images: Vec<Vec<f32>> = (0..n)
                .map(|i| {
                    let mut rng = crate::util::rng::Xoshiro256::new(seed.wrapping_add(i as u64));
                    (0..IMG_ELEMS).map(|_| rng.next_f32()).collect()
                })
                .collect();
            let slices: Vec<&[f32]> = images.iter().map(|v| v.as_slice()).collect();
            let mut gather = Vec::new();
            let via_slices = be.infer_slices(&slices, exec, &mut gather).unwrap();
            let mut contiguous = Vec::new();
            gather_padded(&slices, exec, &mut contiguous);
            let direct = be.infer_batch(&contiguous).unwrap();
            ensure_eq(via_slices, direct, "slices == gathered (bitwise)")
        });
    }

    #[test]
    fn infer_slices_timed_is_bit_identical_and_reports_plan_steps() {
        let net = synth_bcnn_network(Scheme::Rgb, 31);
        let be = EngineBackend::bcnn(net, 2);
        let mut rng = crate::util::rng::Xoshiro256::new(8);
        let imgs: Vec<Vec<f32>> =
            (0..2).map(|_| (0..IMG_ELEMS).map(|_| rng.next_f32()).collect()).collect();
        let slices: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
        let (mut gather, mut gather2) = (Vec::new(), Vec::new());
        let mut steps = Vec::new();
        let timed = be.infer_slices_timed(&slices, 2, &mut gather, &mut steps).unwrap();
        let plain = be.infer_slices(&slices, 2, &mut gather2).unwrap();
        assert_eq!(timed, plain, "timed path must not change logits");
        let labels: Vec<String> = steps.iter().map(|(l, _)| l.clone()).collect();
        assert_eq!(labels, be.model.plan().step_names(), "one span per plan step label");
        // B=1 timed path skips the gather too
        let mut g3 = Vec::new();
        let mut s3 = Vec::new();
        let one = be.infer_slices_timed(&slices[..1], 1, &mut g3, &mut s3).unwrap();
        assert!(g3.is_empty(), "B=1 timed must not gather");
        assert_eq!(one, be.infer_batch(&imgs[0]).unwrap());
    }

    #[test]
    fn pool_stats_report_parked_arenas_and_peak_bytes() {
        let net = synth_bcnn_network(Scheme::Gray, 21);
        let be = EngineBackend::bcnn(net, 2);
        let empty = be.pool_stats().unwrap();
        assert_eq!(empty, PoolStats { arenas: 0, peak_bytes: [0; 3] });
        let mut rng = crate::util::rng::Xoshiro256::new(4);
        let imgs: Vec<f32> = (0..2 * IMG_ELEMS).map(|_| rng.next_f32()).collect();
        be.infer_batch(&imgs).unwrap();
        let stats = be.pool_stats().unwrap();
        assert!(stats.arenas >= 1);
        assert!(stats.peak_bytes[0] > 0, "f32 class carried the activations");
        // profile surfaced through the trait: one row per plan step
        let profile = be.profile_json().unwrap();
        let rows = profile.as_arr().unwrap();
        assert_eq!(rows.len(), be.model.plan().steps.len());
        assert!(rows.iter().all(|r| r.get("count").unwrap().as_f64().unwrap() >= 1.0));
    }
}
