//! Bounded MPMC queue with blocking pop and batch draining — the
//! admission-control stage (backpressure: `try_push` fails when full).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Bounded FIFO; producers use `try_push` (admission) and consumers
/// `pop_wait` / `drain_batch`.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    capacity: usize,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Why a push was refused.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError {
    Full,
    Closed,
}

crate::error_enum_impls!(PushError {
    PushError::Full => ("queue full (capacity reached) — backpressure"),
    PushError::Closed => ("queue closed"),
});

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Admission: enqueue or fail fast (the caller surfaces 429-style
    /// rejection to the client).
    pub fn try_push(&self, item: T) -> Result<(), PushError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed);
        }
        if g.items.len() >= self.capacity {
            return Err(PushError::Full);
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop; `None` when the queue is closed and drained.
    pub fn pop_wait(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Pop one item (blocking), then keep draining until either
    /// `max_batch` items are collected or `window` elapses.
    /// Returns an empty vec only when closed.
    pub fn drain_batch(&self, max_batch: usize, window: Duration) -> Vec<T> {
        let mut batch = Vec::new();
        match self.pop_wait() {
            Some(first) => batch.push(first),
            None => return batch,
        }
        if max_batch <= 1 {
            return batch;
        }
        let deadline = Instant::now() + window;
        let mut g = self.inner.lock().unwrap();
        loop {
            while batch.len() < max_batch {
                match g.items.pop_front() {
                    Some(it) => batch.push(it),
                    None => break,
                }
            }
            if batch.len() >= max_batch || g.closed {
                return batch;
            }
            let now = Instant::now();
            if now >= deadline {
                return batch;
            }
            let (ng, timeout) = self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = ng;
            if timeout.timed_out() && g.items.is_empty() {
                return batch;
            }
        }
    }

    /// Close the queue: producers fail, consumers drain whatever is left.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(10);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.pop_wait(), Some(1));
        assert_eq!(q.pop_wait(), Some(2));
    }

    #[test]
    fn backpressure_when_full() {
        let q = BoundedQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_push(3), Err(PushError::Full));
        assert_eq!(q.pop_wait(), Some(1));
        q.try_push(3).unwrap();
    }

    #[test]
    fn closed_queue_rejects_and_drains() {
        let q = BoundedQueue::new(4);
        q.try_push(1).unwrap();
        q.close();
        assert_eq!(q.try_push(2), Err(PushError::Closed));
        assert_eq!(q.pop_wait(), Some(1));
        assert_eq!(q.pop_wait(), None);
    }

    #[test]
    fn drain_batch_respects_max() {
        let q = BoundedQueue::new(16);
        for i in 0..10 {
            q.try_push(i).unwrap();
        }
        let b = q.drain_batch(4, Duration::from_millis(1));
        assert_eq!(b, vec![0, 1, 2, 3]);
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn drain_batch_returns_partial_after_window() {
        let q = BoundedQueue::new(16);
        q.try_push(42).unwrap();
        let start = Instant::now();
        let b = q.drain_batch(8, Duration::from_millis(20));
        assert_eq!(b, vec![42]);
        assert!(start.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn drain_batch_collects_across_threads() {
        let q = Arc::new(BoundedQueue::new(16));
        let q2 = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            for i in 0..4 {
                std::thread::sleep(Duration::from_millis(2));
                q2.try_push(i).unwrap();
            }
        });
        let b = q.drain_batch(4, Duration::from_millis(200));
        producer.join().unwrap();
        assert_eq!(b.len(), 4);
    }

    #[test]
    fn concurrent_drain_partitions_items_exactly_once() {
        // the multi-executor contract: several workers draining the same
        // queue receive disjoint batches that together cover every item
        let q = Arc::new(BoundedQueue::new(256));
        for i in 0..96 {
            q.try_push(i).unwrap();
        }
        q.close(); // drained workers exit instead of blocking
        let mut handles = Vec::new();
        for _ in 0..4 {
            let q2 = Arc::clone(&q);
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                loop {
                    let b = q2.drain_batch(8, Duration::from_millis(1));
                    if b.is_empty() {
                        return got;
                    }
                    got.extend(b);
                }
            }));
        }
        let mut all: Vec<i32> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..96).collect::<Vec<_>>());
    }

    #[test]
    fn pop_wait_blocks_until_push() {
        let q = Arc::new(BoundedQueue::new(4));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop_wait());
        std::thread::sleep(Duration::from_millis(10));
        q.try_push(99).unwrap();
        assert_eq!(h.join().unwrap(), Some(99));
    }
}
