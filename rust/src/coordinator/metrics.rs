//! Serving metrics: latency histograms + counters, snapshot as JSON.

use std::sync::Mutex;
use std::time::Duration;

use crate::util::histogram::Histogram;
use crate::util::json::{Json, JsonObj};

#[derive(Default)]
struct Counters {
    submitted: u64,
    rejected: u64,
    completed: u64,
    failed: u64,
    batches: u64,
    batched_requests: u64,
    /// `classify_batch_stream` sessions opened against this lane.
    streams: u64,
    /// Per-image frames emitted by those sessions (success + failure,
    /// excluding the terminal summary frame).
    stream_frames: u64,
}

/// Thread-safe metrics hub shared by admission, batcher, and server.
pub struct Metrics {
    queue_hist: Mutex<Histogram>,
    exec_hist: Mutex<Histogram>,
    e2e_hist: Mutex<Histogram>,
    counters: Mutex<Counters>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            queue_hist: Mutex::new(Histogram::new()),
            exec_hist: Mutex::new(Histogram::new()),
            e2e_hist: Mutex::new(Histogram::new()),
            counters: Mutex::new(Counters::default()),
        }
    }

    pub fn record_submit(&self) {
        self.counters.lock().unwrap().submitted += 1;
    }

    pub fn record_reject(&self) {
        self.counters.lock().unwrap().rejected += 1;
    }

    /// Called per request completing in a batch.
    pub fn record_request(&self, queue_time: Duration, exec_time: Duration) {
        self.queue_hist.lock().unwrap().record(queue_time.as_nanos() as u64);
        self.exec_hist.lock().unwrap().record(exec_time.as_nanos() as u64);
        self.e2e_hist
            .lock()
            .unwrap()
            .record((queue_time + exec_time).as_nanos() as u64);
        self.counters.lock().unwrap().completed += 1;
    }

    /// Called once per executed batch.
    pub fn record_batch(&self, size: usize, _exec: Duration) {
        let mut c = self.counters.lock().unwrap();
        c.batches += 1;
        c.batched_requests += size as u64;
    }

    pub fn record_failure(&self, size: usize) {
        self.counters.lock().unwrap().failed += size as u64;
    }

    /// Called once per `classify_batch_stream` request against this lane.
    pub fn record_stream(&self) {
        self.counters.lock().unwrap().streams += 1;
    }

    /// Called per per-image frame a stream session emits.
    pub fn record_stream_frame(&self) {
        self.counters.lock().unwrap().stream_frames += 1;
    }

    pub fn completed(&self) -> u64 {
        self.counters.lock().unwrap().completed
    }

    pub fn failed(&self) -> u64 {
        self.counters.lock().unwrap().failed
    }

    pub fn submitted(&self) -> u64 {
        self.counters.lock().unwrap().submitted
    }

    pub fn rejected(&self) -> u64 {
        self.counters.lock().unwrap().rejected
    }

    /// JSON snapshot (served by the `stats` op and printed by the CLI).
    pub fn snapshot(&self) -> Json {
        let c = self.counters.lock().unwrap();
        let mut obj = JsonObj::new();
        obj.insert("submitted", Json::from(c.submitted as usize));
        obj.insert("rejected", Json::from(c.rejected as usize));
        obj.insert("completed", Json::from(c.completed as usize));
        obj.insert("failed", Json::from(c.failed as usize));
        obj.insert("batches", Json::from(c.batches as usize));
        let mean_batch = if c.batches > 0 {
            c.batched_requests as f64 / c.batches as f64
        } else {
            0.0
        };
        obj.insert("mean_batch_size", Json::from(mean_batch));
        obj.insert("streams", Json::from(c.streams as usize));
        obj.insert("stream_frames", Json::from(c.stream_frames as usize));
        drop(c);
        for (name, hist) in [
            ("queue_us", &self.queue_hist),
            ("exec_us", &self.exec_hist),
            ("e2e_us", &self.e2e_hist),
        ] {
            let h = hist.lock().unwrap();
            let mut stats = JsonObj::new();
            stats.insert("count", Json::from(h.count() as usize));
            stats.insert("mean", Json::from(h.mean_ns() / 1_000.0));
            stats.insert("p50", Json::from(h.quantile_ns(0.5) / 1_000.0));
            stats.insert("p95", Json::from(h.quantile_ns(0.95) / 1_000.0));
            stats.insert("p99", Json::from(h.quantile_ns(0.99) / 1_000.0));
            stats.insert("max", Json::from(h.max_ns() as f64 / 1_000.0));
            obj.insert(name, Json::Obj(stats));
        }
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_counts() {
        let m = Metrics::new();
        m.record_submit();
        m.record_submit();
        m.record_reject();
        m.record_request(Duration::from_micros(50), Duration::from_micros(150));
        m.record_batch(1, Duration::from_micros(150));
        let snap = m.snapshot();
        assert_eq!(snap.get("submitted").unwrap().as_usize().unwrap(), 2);
        assert_eq!(snap.get("rejected").unwrap().as_usize().unwrap(), 1);
        assert_eq!(snap.get("completed").unwrap().as_usize().unwrap(), 1);
        let e2e = snap.get("e2e_us").unwrap();
        assert_eq!(e2e.get("count").unwrap().as_usize().unwrap(), 1);
        let mean = e2e.get("mean").unwrap().as_f64().unwrap();
        assert!((mean - 200.0).abs() < 1.0, "mean {mean}");
    }

    #[test]
    fn stream_counters_surface_in_snapshot() {
        let m = Metrics::new();
        m.record_stream();
        m.record_stream_frame();
        m.record_stream_frame();
        let snap = m.snapshot();
        assert_eq!(snap.get("streams").unwrap().as_usize().unwrap(), 1);
        assert_eq!(snap.get("stream_frames").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn mean_batch_size() {
        let m = Metrics::new();
        m.record_batch(4, Duration::ZERO);
        m.record_batch(8, Duration::ZERO);
        let snap = m.snapshot();
        let mb = snap.get("mean_batch_size").unwrap().as_f64().unwrap();
        assert!((mb - 6.0).abs() < 1e-9);
    }
}
