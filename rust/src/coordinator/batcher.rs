//! Dynamic batcher: drains the admission queue under a size+deadline
//! policy, plans backend-executable batch sizes, runs the backend, and
//! fans responses back out.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::backend::{InferBackend, IMG_ELEMS};
use super::metrics::Metrics;
use super::queue::BoundedQueue;
use super::request::{InferRequest, InferResponse};
use crate::bnn::network::{argmax, NUM_CLASSES};

/// Batch formation policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Maximum requests per batch (1 = the paper's real-time protocol).
    pub max_batch: usize,
    /// How long to hold an open batch waiting for more requests.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 1, max_wait: Duration::from_micros(200) }
    }
}

/// Split `n` pending requests into backend-supported chunk sizes.
///
/// Greedy largest-first; the remainder uses the smallest supported size
/// that covers it (the tail gets zero-padded by the caller, padded
/// outputs discarded).  `supported` must be ascending; `usize::MAX`
/// means "any size" (pure-Rust engine).
pub fn plan_batches(n: usize, supported: &[usize]) -> Vec<(usize, usize)> {
    assert!(!supported.is_empty());
    if supported.contains(&usize::MAX) {
        return if n == 0 { vec![] } else { vec![(n, n)] };
    }
    let mut plan = Vec::new();
    let mut left = n;
    while left > 0 {
        // largest supported <= left, else smallest supported >= left
        let exec = match supported.iter().rev().find(|&&b| b <= left) {
            Some(&b) => b,
            None => *supported.first().unwrap(),
        };
        let real = exec.min(left);
        plan.push((real, exec));
        left -= real;
    }
    plan
}

/// The batcher thread bundle.
pub struct Batcher {
    handle: Option<std::thread::JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    /// Kept so `drop` can close the queue and wake a blocked `pop_wait`
    /// (otherwise joining the thread would deadlock).
    queue: Arc<BoundedQueue<InferRequest>>,
}

impl Batcher {
    /// Start a batcher draining `queue` into `backend`.
    pub fn spawn(
        queue: Arc<BoundedQueue<InferRequest>>,
        backend: Arc<dyn InferBackend>,
        policy: BatchPolicy,
        metrics: Arc<Metrics>,
    ) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let queue2 = Arc::clone(&queue);
        let handle = std::thread::Builder::new()
            .name("batcher".into())
            .spawn(move || {
                let supported = backend.supported_batches();
                // the lane's padded-payload buffer, reused across batches
                // (grows to the largest executed batch, then stays put)
                let mut payload: Vec<f32> = Vec::new();
                while !stop2.load(Ordering::Relaxed) {
                    let batch = queue2.drain_batch(policy.max_batch, policy.max_wait);
                    if batch.is_empty() {
                        break; // queue closed and drained
                    }
                    Self::run_batch(batch, &*backend, &supported, &metrics, &mut payload);
                }
            })
            .expect("spawn batcher");
        Self { handle: Some(handle), stop, queue }
    }

    fn run_batch(
        mut reqs: Vec<InferRequest>,
        backend: &dyn InferBackend,
        supported: &[usize],
        metrics: &Metrics,
        payload: &mut Vec<f32>,
    ) {
        let plan = plan_batches(reqs.len(), supported);
        for (real, exec) in plan {
            let chunk: Vec<InferRequest> = reqs.drain(..real).collect();
            // assemble the padded payload in the lane's reused buffer —
            // cleared and re-zeroed every time, so padding lanes never
            // carry a previous batch's pixels
            payload.clear();
            payload.resize(exec * IMG_ELEMS, 0.0);
            for (i, r) in chunk.iter().enumerate() {
                payload[i * IMG_ELEMS..(i + 1) * IMG_ELEMS].copy_from_slice(&r.image);
            }
            let started = Instant::now();
            let result = backend.infer_batch(payload);
            let exec_time = started.elapsed();
            match result {
                Ok(logits) => {
                    metrics.record_batch(real, exec_time);
                    for (i, r) in chunk.into_iter().enumerate() {
                        let l = logits[i * NUM_CLASSES..(i + 1) * NUM_CLASSES].to_vec();
                        let queue_time = started.duration_since(r.enqueued);
                        // Non-finite logits mean the image poisoned the
                        // forward pass (inf/NaN pixels); argmax over NaNs
                        // would silently answer class 0 — fail the image
                        // with a structured per-image error instead, and
                        // count it as a failure (not a completion) so the
                        // stats op reflects the incident.
                        if l.iter().any(|v| !v.is_finite()) {
                            metrics.record_failure(1);
                            let _ = r.resp.send(InferResponse::failed(
                                r.id,
                                "non-finite logits (input pixels out of range?)".to_string(),
                            ));
                            continue;
                        }
                        metrics.record_request(queue_time, exec_time);
                        let resp = InferResponse {
                            id: r.id,
                            class: argmax(&l),
                            logits: l,
                            queue_time,
                            exec_time,
                            batch_size: real,
                            error: None,
                        };
                        let _ = r.resp.send(resp);
                    }
                }
                Err(msg) => {
                    metrics.record_failure(real);
                    for r in chunk {
                        let _ = r.resp.send(InferResponse::failed(r.id, msg.clone()));
                    }
                }
            }
        }
    }

    /// Signal the thread and wait for it to drain.
    pub fn join(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.queue.close(); // wakes a blocked pop_wait
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, ensure};

    #[test]
    fn plan_exact_fit() {
        assert_eq!(plan_batches(8, &[1, 4, 16]), vec![(4, 4), (4, 4)]);
        assert_eq!(plan_batches(16, &[1, 4, 16]), vec![(16, 16)]);
    }

    #[test]
    fn plan_remainder_pads_up() {
        // 5 = 4 + 1
        assert_eq!(plan_batches(5, &[1, 4, 16]), vec![(4, 4), (1, 1)]);
        // 3 with only {4,16} available -> one padded 4-batch
        assert_eq!(plan_batches(3, &[4, 16]), vec![(3, 4)]);
    }

    #[test]
    fn plan_any_size_engine() {
        assert_eq!(plan_batches(7, &[usize::MAX]), vec![(7, 7)]);
        assert_eq!(plan_batches(0, &[usize::MAX]), vec![]);
    }

    #[test]
    fn plan_properties() {
        prop::check(256, |g| {
            let n = g.usize_in(0, 200);
            let supported: Vec<usize> = match g.usize_in(0, 2) {
                0 => vec![1],
                1 => vec![1, 4, 16, 64],
                _ => vec![4, 16],
            };
            let plan = plan_batches(n, &supported);
            let total: usize = plan.iter().map(|(real, _)| real).sum();
            ensure(total == n, format!("covers all: {total} != {n}"))?;
            for (real, exec) in &plan {
                ensure(real <= exec, "real <= exec")?;
                ensure(supported.contains(exec), format!("exec {exec} supported"))?;
            }
            // padding waste is bounded by the smallest supported size
            let waste: usize = plan.iter().map(|(r, e)| e - r).sum();
            ensure(
                waste < *supported.first().unwrap(),
                format!("waste {waste} < min supported"),
            )
        });
    }
}
