//! Dynamic batcher: drains the admission queue under a size+deadline
//! policy, plans backend-executable batch sizes, runs the backend, and
//! fans responses back out.
//!
//! A lane is a **multi-executor pool**: `BatchPolicy::executors` worker
//! threads drain the same admission queue concurrently, so batch
//! formation overlaps with execution and several batches for the same
//! model variant can be in flight at once (the coordinator-level
//! serialization FINN frames as the real scaling problem for BNN
//! inference).  The queue is MPMC, so each drained request lands in
//! exactly one executor's batch; per-request response channels make
//! fan-out order-independent, and per-image logits are bit-identical
//! regardless of which executor (or batch) a request rides in
//! (integration-tested against the serial lane).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::backend::InferBackend;
use super::metrics::Metrics;
use super::queue::BoundedQueue;
use super::request::{InferRequest, InferResponse};
use crate::bnn::network::argmax;

/// Batch formation policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Maximum requests per batch (1 = the paper's real-time protocol).
    pub max_batch: usize,
    /// How long to hold an open batch waiting for more requests.
    pub max_wait: Duration,
    /// Batched workers per lane (clamped to ≥ 1).  With N > 1, batch
    /// formation overlaps with execution: while one executor runs a
    /// batch, the others keep draining the queue, so a long batch never
    /// stalls admission.  Requests may then complete out of submission
    /// order — ids and per-request channels keep the fan-out correct,
    /// and `classify_batch_stream` exposes the reordering to clients.
    pub executors: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 1, max_wait: Duration::from_micros(200), executors: 1 }
    }
}

/// Split `n` pending requests into backend-supported chunk sizes.
///
/// Greedy largest-first; the remainder uses the smallest supported size
/// that covers it (the tail gets zero-padded by the caller, padded
/// outputs discarded).  `supported` must be ascending; `usize::MAX`
/// means "any size" (pure-Rust engine).
pub fn plan_batches(n: usize, supported: &[usize]) -> Vec<(usize, usize)> {
    assert!(!supported.is_empty());
    if supported.contains(&usize::MAX) {
        return if n == 0 { vec![] } else { vec![(n, n)] };
    }
    let mut plan = Vec::new();
    let mut left = n;
    while left > 0 {
        // largest supported <= left, else smallest supported >= left
        let exec = match supported.iter().rev().find(|&&b| b <= left) {
            Some(&b) => b,
            None => *supported.first().expect("plan_batches: asserted non-empty above"),
        };
        let real = exec.min(left);
        plan.push((real, exec));
        left -= real;
    }
    plan
}

/// The batcher executor pool for one lane.
pub struct Batcher {
    handles: Vec<std::thread::JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    /// Kept so `drop` can close the queue and wake blocked `pop_wait`s
    /// (otherwise joining the threads would deadlock).
    queue: Arc<BoundedQueue<InferRequest>>,
    /// Set by [`Batcher::retire`]: drop must NOT raise the stop flag, so
    /// executors drain every already-admitted request before exiting.
    retired: bool,
}

impl Batcher {
    /// Start `policy.executors` batched workers draining `queue` into
    /// `backend`.  Each executor owns its padded-payload buffer; the
    /// shared MPMC queue hands every request to exactly one of them.
    pub fn spawn(
        queue: Arc<BoundedQueue<InferRequest>>,
        backend: Arc<dyn InferBackend>,
        policy: BatchPolicy,
        metrics: Arc<Metrics>,
    ) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let executors = policy.executors.max(1);
        let mut handles = Vec::with_capacity(executors);
        for e in 0..executors {
            let stop2 = Arc::clone(&stop);
            let queue2 = Arc::clone(&queue);
            let backend2 = Arc::clone(&backend);
            let metrics2 = Arc::clone(&metrics);
            let handle = std::thread::Builder::new()
                .name(format!("batcher-{e}"))
                .spawn(move || {
                    let supported = backend2.supported_batches();
                    // this executor's padded-payload buffer, reused across
                    // batches (grows to the largest executed batch, then
                    // stays put)
                    let mut payload: Vec<f32> = Vec::new();
                    while !stop2.load(Ordering::Relaxed) {
                        let batch = queue2.drain_batch(policy.max_batch, policy.max_wait);
                        if batch.is_empty() {
                            break; // queue closed and drained
                        }
                        Self::run_batch(batch, &*backend2, &supported, &metrics2, &mut payload);
                    }
                })
                .expect("spawn batcher");
            handles.push(handle);
        }
        Self { handles, stop, queue, retired: false }
    }

    fn run_batch(
        mut reqs: Vec<InferRequest>,
        backend: &dyn InferBackend,
        supported: &[usize],
        metrics: &Metrics,
        payload: &mut Vec<f32>,
    ) {
        let plan = plan_batches(reqs.len(), supported);
        for (real, exec) in plan {
            let mut chunk: Vec<InferRequest> = reqs.drain(..real).collect();
            // traced requests close their queue-wait span here; a chunk
            // with ANY traced request runs the timed backend path so its
            // per-plan-step spans can be synthesized (logits stay
            // bit-identical either way — property-tested in `backend`)
            let any_trace = chunk.iter().any(|r| r.trace.is_some());
            if any_trace {
                for r in chunk.iter_mut() {
                    if let Some(t) = r.trace.as_deref_mut() {
                        t.mark("batch_formed");
                    }
                }
            }
            // hand the backend each request's own pixel buffer: padding
            // and gathering (when needed at all) happen behind
            // `InferBackend::infer_slices`, which reuses this executor's
            // `payload` buffer — and the engine's B=1 path runs with no
            // copy at all
            let slices: Vec<&[f32]> = chunk.iter().map(|r| r.image.as_slice()).collect();
            let started = Instant::now();
            let mut step_times: Vec<(String, u64)> = Vec::new();
            let result = if any_trace {
                backend.infer_slices_timed(&slices, exec, payload, &mut step_times)
            } else {
                backend.infer_slices(&slices, exec, payload)
            };
            let exec_time = started.elapsed();
            match result {
                Ok(logits) => {
                    metrics.record_batch(real, exec_time);
                    // the row width comes from the batch itself: the
                    // backend executed `exec` rows of whatever head the
                    // served plan declares (4 for the legacy networks)
                    let classes = logits.len() / exec.max(1);
                    for (i, mut r) in chunk.into_iter().enumerate() {
                        let l = logits[i * classes..(i + 1) * classes].to_vec();
                        let queue_time = started.duration_since(r.enqueued);
                        // Non-finite logits mean the image poisoned the
                        // forward pass (inf/NaN pixels); argmax over NaNs
                        // would silently answer class 0 — fail the image
                        // with a structured per-image error instead, and
                        // count it as a failure (not a completion) so the
                        // stats op reflects the incident.
                        if l.iter().any(|v| !v.is_finite()) {
                            metrics.record_failure(1);
                            let _ = r.resp.send(InferResponse::failed(
                                r.id,
                                "non-finite logits (input pixels out of range?)".to_string(),
                            ));
                            continue;
                        }
                        metrics.record_request(queue_time, exec_time);
                        let trace = r.trace.take().map(|mut t| {
                            // per-step exec spans, laid end-to-end from
                            // the instant the backend call began (the
                            // whole batch shares one backend run)
                            let mut acc = t.offset_ns(started);
                            for (label, ns) in &step_times {
                                acc += ns;
                                t.push(format!("exec:{label}"), acc);
                            }
                            t.mark("logits");
                            t
                        });
                        let resp = InferResponse {
                            id: r.id,
                            class: argmax(&l),
                            logits: l,
                            queue_time,
                            exec_time,
                            batch_size: real,
                            error: None,
                            trace,
                        };
                        let _ = r.resp.send(resp);
                    }
                }
                Err(msg) => {
                    metrics.record_failure(real);
                    for r in chunk {
                        let _ = r.resp.send(InferResponse::failed(r.id, msg.clone()));
                    }
                }
            }
        }
    }

    /// Signal every executor and wait for them to drain.
    pub fn join(mut self) {
        self.shutdown();
    }

    /// Graceful lane retirement (the registry's unpublish path): close
    /// the queue so no new request can be admitted, but do **not** raise
    /// the stop flag — the executors keep draining until every
    /// already-admitted request has been answered, then exit on the
    /// closed-and-empty queue.  Joining happens on a detached reaper
    /// thread so the admin caller isn't blocked behind in-flight
    /// batches.
    pub fn retire(mut self) {
        self.retired = true;
        self.queue.close();
        let handles: Vec<_> = self.handles.drain(..).collect();
        if handles.is_empty() {
            return;
        }
        // if the reaper can't spawn the threads still drain and exit on
        // their own; they just go unjoined
        let _ = std::thread::Builder::new().name("lane-reaper".into()).spawn(move || {
            for h in handles {
                let _ = h.join();
            }
        });
    }

    /// Number of executor threads in this lane's pool.
    pub fn executors(&self) -> usize {
        self.handles.len()
    }

    fn shutdown(&mut self) {
        if !self.retired {
            // retired lanes must finish their admitted work; everything
            // else stops after the batch in progress
            self.stop.store(true, Ordering::Relaxed);
        }
        self.queue.close(); // wakes every blocked pop_wait
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::network::NUM_CLASSES;
    use crate::coordinator::backend::IMG_ELEMS;
    use crate::util::prop::{self, ensure};

    /// Echoes each image's first pixel into logit 0, so a response can be
    /// matched back to the request that produced it regardless of which
    /// executor or batch it rode in.
    struct EchoBackend;

    impl InferBackend for EchoBackend {
        fn name(&self) -> String {
            "echo".into()
        }
        fn supported_batches(&self) -> Vec<usize> {
            vec![usize::MAX]
        }
        fn infer_batch(&self, images: &[f32]) -> Result<Vec<f32>, String> {
            let n = images.len() / IMG_ELEMS;
            let mut out = vec![0.0; n * NUM_CLASSES];
            for i in 0..n {
                out[i * NUM_CLASSES] = images[i * IMG_ELEMS];
            }
            Ok(out)
        }
    }

    #[test]
    fn multi_executor_pool_answers_every_request_exactly_once() {
        let queue = Arc::new(BoundedQueue::new(256));
        let metrics = Arc::new(Metrics::new());
        let policy = BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_micros(100),
            executors: 4,
        };
        let batcher = Batcher::spawn(
            Arc::clone(&queue),
            Arc::new(EchoBackend),
            policy,
            Arc::clone(&metrics),
        );
        assert_eq!(batcher.executors(), 4);
        let mut rxs = Vec::new();
        for i in 0..48u64 {
            let (tx, rx) = std::sync::mpsc::channel();
            let mut image = vec![0.0f32; IMG_ELEMS];
            image[0] = i as f32;
            queue
                .try_push(InferRequest {
                    id: i,
                    image,
                    enqueued: Instant::now(),
                    resp: tx,
                    trace: None,
                })
                .unwrap();
            rxs.push((i, rx));
        }
        // every request is answered on its own channel with its own
        // payload, no matter which of the 4 executors ran it
        for (i, rx) in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.error.is_none());
            assert_eq!(resp.id, i);
            assert_eq!(resp.logits[0], i as f32);
        }
        assert_eq!(metrics.completed(), 48);
        batcher.join();
    }

    #[test]
    fn retire_answers_every_admitted_request() {
        // the hot-swap guarantee: a retired lane drains everything that
        // was admitted before the queue closed — nothing is dropped
        let queue = Arc::new(BoundedQueue::new(256));
        let batcher = Batcher::spawn(
            Arc::clone(&queue),
            Arc::new(EchoBackend),
            BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(50), executors: 2 },
            Arc::new(Metrics::new()),
        );
        let mut rxs = Vec::new();
        for i in 0..32u64 {
            let (tx, rx) = std::sync::mpsc::channel();
            let mut image = vec![0.0f32; IMG_ELEMS];
            image[0] = i as f32;
            queue
                .try_push(InferRequest {
                    id: i,
                    image,
                    enqueued: Instant::now(),
                    resp: tx,
                    trace: None,
                })
                .unwrap();
            rxs.push((i, rx));
        }
        batcher.retire();
        // post-retire admissions are refused...
        assert!(queue
            .try_push(InferRequest {
                id: 999,
                image: vec![0.0; IMG_ELEMS],
                enqueued: Instant::now(),
                resp: std::sync::mpsc::channel().0,
                trace: None,
            })
            .is_err());
        // ...but every admitted request is still answered
        for (i, rx) in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(resp.id, i);
            assert_eq!(resp.logits[0], i as f32);
        }
    }

    #[test]
    fn zero_executors_clamps_to_one() {
        let queue = Arc::new(BoundedQueue::new(4));
        let policy = BatchPolicy { executors: 0, ..BatchPolicy::default() };
        let batcher = Batcher::spawn(
            Arc::clone(&queue),
            Arc::new(EchoBackend),
            policy,
            Arc::new(Metrics::new()),
        );
        assert_eq!(batcher.executors(), 1);
        batcher.join();
    }

    #[test]
    fn plan_exact_fit() {
        assert_eq!(plan_batches(8, &[1, 4, 16]), vec![(4, 4), (4, 4)]);
        assert_eq!(plan_batches(16, &[1, 4, 16]), vec![(16, 16)]);
    }

    #[test]
    fn plan_remainder_pads_up() {
        // 5 = 4 + 1
        assert_eq!(plan_batches(5, &[1, 4, 16]), vec![(4, 4), (1, 1)]);
        // 3 with only {4,16} available -> one padded 4-batch
        assert_eq!(plan_batches(3, &[4, 16]), vec![(3, 4)]);
    }

    #[test]
    fn plan_any_size_engine() {
        assert_eq!(plan_batches(7, &[usize::MAX]), vec![(7, 7)]);
        assert_eq!(plan_batches(0, &[usize::MAX]), vec![]);
    }

    #[test]
    fn plan_properties() {
        prop::check(256, |g| {
            let n = g.usize_in(0, 200);
            let supported: Vec<usize> = match g.usize_in(0, 2) {
                0 => vec![1],
                1 => vec![1, 4, 16, 64],
                _ => vec![4, 16],
            };
            let plan = plan_batches(n, &supported);
            let total: usize = plan.iter().map(|(real, _)| real).sum();
            ensure(total == n, format!("covers all: {total} != {n}"))?;
            for (real, exec) in &plan {
                ensure(real <= exec, "real <= exec")?;
                ensure(supported.contains(exec), format!("exec {exec} supported"))?;
            }
            // padding waste is bounded by the smallest supported size
            let waste: usize = plan.iter().map(|(r, e)| e - r).sum();
            ensure(
                waste < *supported.first().unwrap(),
                format!("waste {waste} < min supported"),
            )
        });
    }
}
