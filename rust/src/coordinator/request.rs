//! Request/response types flowing through the coordinator.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::util::trace::Trace;

pub type RequestId = u64;

/// A single inference request: one (96,96,3) image.
pub struct InferRequest {
    pub id: RequestId,
    pub image: Vec<f32>,
    pub enqueued: Instant,
    /// Response channel (one-shot).
    pub resp: mpsc::Sender<InferResponse>,
    /// Span timeline, only for sampled/forced-trace requests — `None`
    /// on the steady-state path so untraced requests allocate nothing
    /// for tracing.
    pub trace: Option<Box<Trace>>,
}

/// The served result.
#[derive(Debug, Clone)]
pub struct InferResponse {
    pub id: RequestId,
    pub logits: Vec<f32>,
    /// argmax class index.
    pub class: usize,
    /// Time spent waiting in the queue + batch window.
    pub queue_time: Duration,
    /// Backend execution time for the batch this request rode in.
    pub exec_time: Duration,
    /// Size of that batch.
    pub batch_size: usize,
    /// Set when the backend failed; logits empty in that case.
    pub error: Option<String>,
    /// The request's span timeline, carried back only when it was
    /// traced (the batcher moves it from the [`InferRequest`]).
    pub trace: Option<Box<Trace>>,
}

impl InferResponse {
    pub fn failed(id: RequestId, msg: String) -> Self {
        Self {
            id,
            logits: Vec::new(),
            class: usize::MAX,
            queue_time: Duration::ZERO,
            exec_time: Duration::ZERO,
            batch_size: 0,
            error: Some(msg),
            trace: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failed_response_is_marked() {
        let r = InferResponse::failed(7, "boom".into());
        assert_eq!(r.id, 7);
        assert!(r.error.is_some());
        assert!(r.logits.is_empty());
    }
}
