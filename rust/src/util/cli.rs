//! Tiny declarative CLI argument parser (no clap in the vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! subcommands (handled by `main.rs`), `-h/--help` text generation, and
//! typed accessors with defaults.

use std::collections::HashMap;
use std::fmt::Write as _;

/// Declared option.
#[derive(Debug, Clone)]
struct OptSpec {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative parser for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    cmd: String,
    about: &'static str,
    specs: Vec<OptSpec>,
    values: HashMap<String, String>,
    flags: HashMap<String, bool>,
    positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    Invalid(String, String, String),
    Help,
}

crate::error_enum_impls!(CliError {
    CliError::Unknown(n) => ("unknown option --{n} (try --help)"),
    CliError::MissingValue(n) => ("option --{n} requires a value"),
    CliError::Invalid(n, v, why) => ("invalid value for --{n}: {v:?} ({why})"),
    CliError::Help => ("help requested"),
});

impl Args {
    pub fn new(cmd: &str, about: &'static str) -> Self {
        Self { cmd: cmd.to_string(), about, ..Default::default() }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, default: Some(default.to_string()), is_flag: false });
        self
    }

    /// Declare a required `--name <value>` (no default).
    pub fn opt_req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, default: None, is_flag: false });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    /// Parse raw arguments (excluding program/subcommand names).
    pub fn parse(mut self, raw: &[String]) -> Result<Self, CliError> {
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if a == "-h" || a == "--help" {
                eprintln!("{}", self.help_text());
                return Err(CliError::Help);
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| CliError::Unknown(name.clone()))?
                    .clone();
                if spec.is_flag {
                    self.flags.insert(name, true);
                } else {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            raw.get(i).cloned().ok_or_else(|| CliError::MissingValue(name.clone()))?
                        }
                    };
                    self.values.insert(name, value);
                }
            } else {
                self.positional.push(a.clone());
            }
            i += 1;
        }
        // check required options
        for spec in &self.specs {
            if !spec.is_flag && spec.default.is_none() && !self.values.contains_key(spec.name) {
                return Err(CliError::MissingValue(spec.name.to_string()));
            }
        }
        Ok(self)
    }

    pub fn help_text(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.cmd, self.about);
        let _ = writeln!(s, "options:");
        for spec in &self.specs {
            let head = if spec.is_flag {
                format!("  --{}", spec.name)
            } else {
                format!("  --{} <v>", spec.name)
            };
            let def = match &spec.default {
                Some(d) if !spec.is_flag => format!(" [default: {d}]"),
                _ => String::new(),
            };
            let _ = writeln!(s, "{head:<24} {}{def}", spec.help);
        }
        s
    }

    fn raw(&self, name: &str) -> Option<String> {
        self.values.get(name).cloned().or_else(|| {
            self.specs
                .iter()
                .find(|s| s.name == name && !s.is_flag)
                .and_then(|s| s.default.clone())
        })
    }

    pub fn get(&self, name: &str) -> String {
        self.raw(name).unwrap_or_else(|| panic!("undeclared option --{name}"))
    }

    /// `get` for options whose empty-string default means "absent"
    /// (e.g. `serve --models`, `serve --default`).
    pub fn get_nonempty(&self, name: &str) -> Option<String> {
        let v = self.get(name);
        if v.is_empty() {
            None
        } else {
            Some(v)
        }
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        let v = self.get(name);
        v.parse().map_err(|e: std::num::ParseIntError| {
            CliError::Invalid(name.to_string(), v.clone(), e.to_string())
        })
    }

    /// `get_usize` with an inclusive range check — serving options like
    /// `--executors` reject nonsense (e.g. 10_000 worker threads) at
    /// startup with a structured error instead of spawning it.
    pub fn get_usize_in(&self, name: &str, lo: usize, hi: usize) -> Result<usize, CliError> {
        let v = self.get_usize(name)?;
        if v < lo || v > hi {
            return Err(CliError::Invalid(
                name.to_string(),
                v.to_string(),
                format!("must be in {lo}..={hi}"),
            ));
        }
        Ok(v)
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, CliError> {
        let v = self.get(name);
        v.parse().map_err(|e: std::num::ParseIntError| {
            CliError::Invalid(name.to_string(), v.clone(), e.to_string())
        })
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        let v = self.get(name);
        v.parse().map_err(|e: std::num::ParseFloatError| {
            CliError::Invalid(name.to_string(), v.clone(), e.to_string())
        })
    }

    pub fn get_flag(&self, name: &str) -> bool {
        *self.flags.get(name).unwrap_or(&false)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    fn demo() -> Args {
        Args::new("demo", "test command")
            .opt("model", "bcnn_rgb", "model variant")
            .opt("iters", "100", "iterations")
            .flag("verbose", "chatty output")
    }

    #[test]
    fn defaults_apply() {
        let a = demo().parse(&raw(&[])).unwrap();
        assert_eq!(a.get("model"), "bcnn_rgb");
        assert_eq!(a.get_usize("iters").unwrap(), 100);
        assert!(!a.get_flag("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = demo().parse(&raw(&["--model", "float", "--iters=7", "--verbose"])).unwrap();
        assert_eq!(a.get("model"), "float");
        assert_eq!(a.get_usize("iters").unwrap(), 7);
        assert!(a.get_flag("verbose"));
    }

    #[test]
    fn nonempty_treats_empty_default_as_absent() {
        let p = Args::new("x", "y").opt("dir", "", "optional dir");
        assert_eq!(p.parse(&raw(&[])).unwrap().get_nonempty("dir"), None);
        let p = Args::new("x", "y").opt("dir", "", "optional dir");
        assert_eq!(
            p.parse(&raw(&["--dir", "/tmp"])).unwrap().get_nonempty("dir"),
            Some("/tmp".to_string())
        );
    }

    #[test]
    fn positional_collected() {
        let a = demo().parse(&raw(&["input.ppm", "--iters", "3", "more"])).unwrap();
        assert_eq!(a.positional(), &["input.ppm".to_string(), "more".to_string()]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(matches!(demo().parse(&raw(&["--nope"])), Err(CliError::Unknown(_))));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(demo().parse(&raw(&["--model"])), Err(CliError::MissingValue(_))));
    }

    #[test]
    fn required_option_enforced() {
        let p = Args::new("x", "y").opt_req("path", "required path");
        assert!(matches!(p.parse(&raw(&[])), Err(CliError::MissingValue(_))));
        let p = Args::new("x", "y").opt_req("path", "required path");
        assert_eq!(p.parse(&raw(&["--path", "/tmp"])).unwrap().get("path"), "/tmp");
    }

    #[test]
    fn invalid_number_reports() {
        let a = demo().parse(&raw(&["--iters", "abc"])).unwrap();
        assert!(matches!(a.get_usize("iters"), Err(CliError::Invalid(..))));
    }

    #[test]
    fn bounded_usize_enforces_range() {
        let a = demo().parse(&raw(&["--iters", "7"])).unwrap();
        assert_eq!(a.get_usize_in("iters", 0, 64).unwrap(), 7);
        assert_eq!(a.get_usize_in("iters", 7, 7).unwrap(), 7);
        match a.get_usize_in("iters", 8, 64) {
            Err(CliError::Invalid(name, v, why)) => {
                assert_eq!(name, "iters");
                assert_eq!(v, "7");
                assert!(why.contains("8..=64"));
            }
            other => panic!("{other:?}"),
        }
        assert!(a.get_usize_in("iters", 0, 6).is_err());
    }

    #[test]
    fn help_text_lists_options() {
        let h = demo().help_text();
        assert!(h.contains("--model"));
        assert!(h.contains("--verbose"));
        assert!(h.contains("[default: bcnn_rgb]"));
    }
}
