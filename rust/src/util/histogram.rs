//! Log-bucketed latency histogram for coordinator metrics.
//!
//! Fixed memory, lock-free-friendly (plain u64 counters behind a mutex in
//! `coordinator::metrics`), ~4% relative error per bucket — plenty for
//! p50/p95/p99 serving statistics.

/// Histogram over nanosecond values with logarithmic buckets.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// bucket i covers [base * growth^i, base * growth^(i+1))
    counts: Vec<u64>,
    total: u64,
    sum_ns: f64,
    min_ns: u64,
    max_ns: u64,
    base: f64,
    growth: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Buckets spanning 100ns .. ~1000s with 8% growth (~290 buckets).
    pub fn new() -> Self {
        Self::with_params(100.0, 1.08, 300)
    }

    pub fn with_params(base: f64, growth: f64, buckets: usize) -> Self {
        Self {
            counts: vec![0; buckets],
            total: 0,
            sum_ns: 0.0,
            min_ns: u64::MAX,
            max_ns: 0,
            base,
            growth,
        }
    }

    fn bucket_for(&self, ns: u64) -> usize {
        if (ns as f64) < self.base {
            return 0;
        }
        let idx = ((ns as f64 / self.base).ln() / self.growth.ln()) as usize;
        idx.min(self.counts.len() - 1)
    }

    /// Lower edge of bucket `i` in ns.
    fn bucket_edge(&self, i: usize) -> f64 {
        self.base * self.growth.powi(i as i32)
    }

    pub fn record(&mut self, ns: u64) {
        let b = self.bucket_for(ns);
        self.counts[b] += 1;
        self.total += 1;
        self.sum_ns += ns as f64;
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Sum of all recorded values in ns (exact, not bucketed).
    pub fn sum_ns(&self) -> f64 {
        self.sum_ns
    }

    pub fn mean_ns(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_ns / self.total as f64
        }
    }

    pub fn min_ns(&self) -> u64 {
        if self.total == 0 { 0 } else { self.min_ns }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Quantile estimate (0.0..=1.0); returns the bucket's geometric
    /// midpoint, clamped to observed min/max.
    pub fn quantile_ns(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                let mid = self.bucket_edge(i) * self.growth.sqrt();
                return mid.clamp(self.min_ns as f64, self.max_ns as f64);
            }
        }
        self.max_ns as f64
    }

    /// Fold `other` into `self`.  Histograms with identical bucket
    /// parameters merge bucket-by-bucket; anything else is rebucketed
    /// through `self`'s geometry (each foreign bucket lands at its
    /// geometric midpoint, saturating into `self`'s edge buckets when it
    /// falls outside the covered range).  Merging an empty histogram is
    /// always a no-op — never a panic, whatever the parameters.
    pub fn merge(&mut self, other: &Histogram) {
        if other.total == 0 {
            return;
        }
        let same_shape = self.counts.len() == other.counts.len()
            && self.base == other.base
            && self.growth == other.growth;
        if same_shape {
            for (a, b) in self.counts.iter_mut().zip(&other.counts) {
                *a += b;
            }
        } else {
            for (i, &c) in other.counts.iter().enumerate() {
                if c == 0 {
                    continue;
                }
                let mid = other.bucket_edge(i) * other.growth.sqrt();
                let ns = if mid.is_finite() && mid >= 0.0 { mid as u64 } else { u64::MAX };
                // bucket_for clamps, so out-of-range mass saturates into
                // self's first/last bucket instead of being dropped
                let b = self.bucket_for(ns);
                self.counts[b] += c;
            }
        }
        self.total += other.total;
        self.sum_ns += other.sum_ns;
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    pub fn reset(&mut self) {
        self.counts.fill(0);
        self.total = 0;
        self.sum_ns = 0.0;
        self.min_ns = u64::MAX;
        self.max_ns = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_ns(), 0.0);
        assert_eq!(h.quantile_ns(0.5), 0.0);
    }

    #[test]
    fn mean_is_exact() {
        let mut h = Histogram::new();
        for v in [1_000u64, 2_000, 3_000] {
            h.record(v);
        }
        assert!((h.mean_ns() - 2_000.0).abs() < 1e-9);
        assert_eq!(h.min_ns(), 1_000);
        assert_eq!(h.max_ns(), 3_000);
    }

    #[test]
    fn quantiles_within_bucket_error() {
        let mut h = Histogram::new();
        for i in 1..=10_000u64 {
            h.record(i * 1_000); // 1µs .. 10ms uniform
        }
        let p50 = h.quantile_ns(0.50);
        let p99 = h.quantile_ns(0.99);
        // log buckets with 8% growth: allow 10% relative error
        assert!((p50 - 5_000_000.0).abs() / 5_000_000.0 < 0.10, "p50={p50}");
        assert!((p99 - 9_900_000.0).abs() / 9_900_000.0 < 0.10, "p99={p99}");
    }

    #[test]
    fn merge_combines() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(1_000);
        b.record(9_000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min_ns(), 1_000);
        assert_eq!(a.max_ns(), 9_000);
    }

    #[test]
    fn merging_an_empty_histogram_is_a_noop_even_across_params() {
        let mut a = Histogram::new();
        a.record(5_000);
        let empty = Histogram::with_params(1.0, 2.0, 8);
        a.merge(&empty); // must not panic, must not disturb a
        assert_eq!(a.count(), 1);
        assert_eq!(a.min_ns(), 5_000);
        assert_eq!(a.max_ns(), 5_000);

        let mut b = Histogram::with_params(1.0, 2.0, 8);
        let mut filled = Histogram::new();
        filled.record(40);
        b.merge(&filled); // empty self absorbing a foreign-shaped other
        assert_eq!(b.count(), 1);
    }

    #[test]
    fn merging_differently_parameterized_histograms_rebuckets() {
        let mut a = Histogram::new();
        a.record(1_000);
        let mut b = Histogram::with_params(10.0, 1.5, 40);
        for v in [2_000u64, 4_000, 8_000] {
            b.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), 4);
        assert_eq!(a.min_ns(), 1_000);
        assert_eq!(a.max_ns(), 8_000);
        // quantiles stay plausible after rebucketing: the median of
        // {1k, 2k, 4k, 8k} under ~50% bucket error is well inside 1k..8k
        let p50 = a.quantile_ns(0.5);
        assert!((1_000.0..=8_000.0).contains(&p50), "p50={p50}");
    }

    #[test]
    fn merge_saturates_foreign_mass_into_edge_buckets() {
        // a covers 100ns..~215ns in 10 buckets; b's values land far
        // outside on both sides and must saturate, never panic or drop
        let mut a = Histogram::with_params(100.0, 1.08, 10);
        let mut b = Histogram::new();
        b.record(1);
        b.record(10_000_000_000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min_ns(), 1);
        assert_eq!(a.max_ns(), 10_000_000_000);
    }

    #[test]
    fn extreme_values_clamp_to_edge_buckets() {
        let mut h = Histogram::new();
        h.record(1); // below base
        h.record(u64::MAX / 2); // beyond last bucket
        assert_eq!(h.count(), 2);
        assert!(h.quantile_ns(0.0) >= 1.0);
    }

    #[test]
    fn reset_clears() {
        let mut h = Histogram::new();
        h.record(5_000);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.max_ns(), 0);
    }
}
