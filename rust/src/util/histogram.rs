//! Log-bucketed latency histogram for coordinator metrics.
//!
//! Fixed memory, lock-free-friendly (plain u64 counters behind a mutex in
//! `coordinator::metrics`), ~4% relative error per bucket — plenty for
//! p50/p95/p99 serving statistics.

/// Histogram over nanosecond values with logarithmic buckets.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// bucket i covers [base * growth^i, base * growth^(i+1))
    counts: Vec<u64>,
    total: u64,
    sum_ns: f64,
    min_ns: u64,
    max_ns: u64,
    base: f64,
    growth: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Buckets spanning 100ns .. ~1000s with 8% growth (~290 buckets).
    pub fn new() -> Self {
        Self::with_params(100.0, 1.08, 300)
    }

    pub fn with_params(base: f64, growth: f64, buckets: usize) -> Self {
        Self {
            counts: vec![0; buckets],
            total: 0,
            sum_ns: 0.0,
            min_ns: u64::MAX,
            max_ns: 0,
            base,
            growth,
        }
    }

    fn bucket_for(&self, ns: u64) -> usize {
        if (ns as f64) < self.base {
            return 0;
        }
        let idx = ((ns as f64 / self.base).ln() / self.growth.ln()) as usize;
        idx.min(self.counts.len() - 1)
    }

    /// Lower edge of bucket `i` in ns.
    fn bucket_edge(&self, i: usize) -> f64 {
        self.base * self.growth.powi(i as i32)
    }

    pub fn record(&mut self, ns: u64) {
        let b = self.bucket_for(ns);
        self.counts[b] += 1;
        self.total += 1;
        self.sum_ns += ns as f64;
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_ns(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_ns / self.total as f64
        }
    }

    pub fn min_ns(&self) -> u64 {
        if self.total == 0 { 0 } else { self.min_ns }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Quantile estimate (0.0..=1.0); returns the bucket's geometric
    /// midpoint, clamped to observed min/max.
    pub fn quantile_ns(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                let mid = self.bucket_edge(i) * self.growth.sqrt();
                return mid.clamp(self.min_ns as f64, self.max_ns as f64);
            }
        }
        self.max_ns as f64
    }

    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.counts.len(), other.counts.len());
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_ns += other.sum_ns;
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    pub fn reset(&mut self) {
        self.counts.fill(0);
        self.total = 0;
        self.sum_ns = 0.0;
        self.min_ns = u64::MAX;
        self.max_ns = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_ns(), 0.0);
        assert_eq!(h.quantile_ns(0.5), 0.0);
    }

    #[test]
    fn mean_is_exact() {
        let mut h = Histogram::new();
        for v in [1_000u64, 2_000, 3_000] {
            h.record(v);
        }
        assert!((h.mean_ns() - 2_000.0).abs() < 1e-9);
        assert_eq!(h.min_ns(), 1_000);
        assert_eq!(h.max_ns(), 3_000);
    }

    #[test]
    fn quantiles_within_bucket_error() {
        let mut h = Histogram::new();
        for i in 1..=10_000u64 {
            h.record(i * 1_000); // 1µs .. 10ms uniform
        }
        let p50 = h.quantile_ns(0.50);
        let p99 = h.quantile_ns(0.99);
        // log buckets with 8% growth: allow 10% relative error
        assert!((p50 - 5_000_000.0).abs() / 5_000_000.0 < 0.10, "p50={p50}");
        assert!((p99 - 9_900_000.0).abs() / 9_900_000.0 < 0.10, "p99={p99}");
    }

    #[test]
    fn merge_combines() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(1_000);
        b.record(9_000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min_ns(), 1_000);
        assert_eq!(a.max_ns(), 9_000);
    }

    #[test]
    fn extreme_values_clamp_to_edge_buckets() {
        let mut h = Histogram::new();
        h.record(1); // below base
        h.record(u64::MAX / 2); // beyond last bucket
        assert_eq!(h.count(), 2);
        assert!(h.quantile_ns(0.0) >= 1.0);
    }

    #[test]
    fn reset_clears() {
        let mut h = Histogram::new();
        h.record(5_000);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.max_ns(), 0);
    }
}
