//! Crate-local application-error plumbing (no anyhow in the offline
//! vendor set): a boxed error alias plus `app_err!` / `app_bail!` /
//! `app_ensure!` macros used by the CLI binary and the examples.

/// Boxed dynamic error, thread-safe so it can cross worker threads.
pub type BoxError = Box<dyn std::error::Error + Send + Sync + 'static>;

/// `Result` alias for application entry points (`main`, examples).
pub type AppResult<T> = std::result::Result<T, BoxError>;

/// Build a [`BoxError`] from format arguments.
#[macro_export]
macro_rules! app_err {
    ($($t:tt)*) => {
        $crate::util::error::BoxError::from(format!($($t)*))
    };
}

/// Return early with a formatted [`BoxError`].
#[macro_export]
macro_rules! app_bail {
    ($($t:tt)*) => {
        return Err($crate::app_err!($($t)*).into())
    };
}

/// Return early with a formatted [`BoxError`] unless `cond` holds.
#[macro_export]
macro_rules! app_ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::app_err!($($t)*).into());
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> AppResult<()> {
        app_bail!("bad {}", 7);
    }

    fn guarded(x: i32) -> AppResult<i32> {
        app_ensure!(x > 0, "x must be positive, got {x}");
        Ok(x * 2)
    }

    #[test]
    fn bail_formats() {
        assert_eq!(fails().unwrap_err().to_string(), "bad 7");
    }

    #[test]
    fn ensure_passes_and_fails() {
        assert_eq!(guarded(3).unwrap(), 6);
        assert!(guarded(-1).unwrap_err().to_string().contains("positive"));
    }

    #[test]
    fn io_error_converts() {
        fn f() -> AppResult<()> {
            Err(std::io::Error::new(std::io::ErrorKind::Other, "io boom"))?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("io boom"));
    }
}
