//! Crate-local application-error plumbing (no anyhow in the offline
//! vendor set): a boxed error alias plus `app_err!` / `app_bail!` /
//! `app_ensure!` macros used by the CLI binary and the examples.

/// Boxed dynamic error, thread-safe so it can cross worker threads.
pub type BoxError = Box<dyn std::error::Error + Send + Sync + 'static>;

/// `Result` alias for application entry points (`main`, examples).
pub type AppResult<T> = std::result::Result<T, BoxError>;

/// Build a [`BoxError`] from format arguments.
#[macro_export]
macro_rules! app_err {
    ($($t:tt)*) => {
        $crate::util::error::BoxError::from(format!($($t)*))
    };
}

/// Return early with a formatted [`BoxError`].
#[macro_export]
macro_rules! app_bail {
    ($($t:tt)*) => {
        return Err($crate::app_err!($($t)*).into())
    };
}

/// Return early with a formatted [`BoxError`] unless `cond` holds.
#[macro_export]
macro_rules! app_ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::app_err!($($t)*).into());
        }
    };
}

/// Implement `Display`, `std::error::Error` (with optional `source`),
/// and optional `From` conversions for an error enum in one declaration
/// — replaces the hand-rolled three-impl blocks that every typed error
/// in the crate used to carry.
///
/// ```text
///     crate::error_enum_impls!(MyError {
///         MyError::Io(e) => ("my io: {e}"),
///         MyError::Bad { what, n } => ("bad {what}: {n}"),
///     }
///     source { MyError::Io(e) => e }
///     from { std::io::Error => MyError::Io });
/// ```
///
/// * every Display arm is `pattern => (format args...)`;
/// * `source { pattern => expr }` arms return `Some(expr)`, everything
///   else `None` (omit the block for source-less enums);
/// * `from { Type => constructor }` emits `impl From<Type>`; the
///   constructor is any callable expression (a variant path or a
///   closure), invoked as `(ctor)(e)`.
#[macro_export]
macro_rules! error_enum_impls {
    (
        $ty:ident {
            $( $pat:pat => ( $($fmt:tt)+ ) ),+ $(,)?
        }
        $( source { $( $spat:pat => $src:expr ),+ $(,)? } )?
        $( from { $( $fty:ty => $ctor:expr ),+ $(,)? } )?
    ) => {
        impl std::fmt::Display for $ty {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                match self {
                    $( $pat => write!(f, $($fmt)+) ),+
                }
            }
        }

        impl std::error::Error for $ty {
            fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
                $(
                    match self {
                        $( $spat => return Some($src), )+
                        #[allow(unreachable_patterns)]
                        _ => {}
                    }
                )?
                None
            }
        }

        $( $(
            impl From<$fty> for $ty {
                fn from(e: $fty) -> Self {
                    ($ctor)(e)
                }
            }
        )+ )?
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> AppResult<()> {
        app_bail!("bad {}", 7);
    }

    fn guarded(x: i32) -> AppResult<i32> {
        app_ensure!(x > 0, "x must be positive, got {x}");
        Ok(x * 2)
    }

    #[test]
    fn bail_formats() {
        assert_eq!(fails().unwrap_err().to_string(), "bad 7");
    }

    #[test]
    fn ensure_passes_and_fails() {
        assert_eq!(guarded(3).unwrap(), 6);
        assert!(guarded(-1).unwrap_err().to_string().contains("positive"));
    }

    #[test]
    fn io_error_converts() {
        fn f() -> AppResult<()> {
            Err(std::io::Error::new(std::io::ErrorKind::Other, "io boom"))?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("io boom"));
    }

    #[derive(Debug)]
    enum DemoError {
        Io(std::io::Error),
        Plain(String),
        Coded { code: u32 },
    }

    crate::error_enum_impls!(DemoError {
        DemoError::Io(e) => ("demo io: {e}"),
        DemoError::Plain(msg) => ("demo: {msg}"),
        DemoError::Coded { code } => ("demo code {code}"),
    }
    source { DemoError::Io(e) => e }
    from { std::io::Error => DemoError::Io });

    #[test]
    fn error_enum_macro_generates_display_source_from() {
        let e: DemoError = std::io::Error::new(std::io::ErrorKind::Other, "boom").into();
        assert_eq!(e.to_string(), "demo io: boom");
        assert!(std::error::Error::source(&e).is_some());
        let p = DemoError::Plain("x".into());
        assert_eq!(p.to_string(), "demo: x");
        assert!(std::error::Error::source(&p).is_none());
        assert_eq!(DemoError::Coded { code: 7 }.to_string(), "demo code 7");
    }
}
