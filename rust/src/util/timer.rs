//! Monotonic timing helpers shared by the bench harness, the per-layer
//! instrumentation in `bnn::network`, and the coordinator metrics.

use std::time::{Duration, Instant};

/// Measure a closure's wall time.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed())
}

/// Benchmark protocol used throughout (mirrors the paper's Section 2.2:
/// warmup, then many single-sample runs, report the mean over samples).
///
/// Returns per-iteration statistics in nanoseconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub std_ns: f64,
}

impl BenchStats {
    pub fn from_samples(mut ns: Vec<f64>) -> Self {
        assert!(!ns.is_empty());
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ns.len();
        let mean = ns.iter().sum::<f64>() / n as f64;
        let var = ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Self {
            iters: n,
            mean_ns: mean,
            median_ns: ns[n / 2],
            p95_ns: ns[(n as f64 * 0.95) as usize % n],
            min_ns: ns[0],
            max_ns: ns[n - 1],
            std_ns: var.sqrt(),
        }
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1_000.0
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1_000_000.0
    }
}

/// Run `f` with `warmup` unmeasured iterations then `iters` measured ones.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let start = Instant::now();
        std::hint::black_box(f());
        samples.push(start.elapsed().as_nanos() as f64);
    }
    BenchStats::from_samples(samples)
}

/// Adaptive variant: runs until `min_time` has elapsed (at least
/// `min_iters` iterations), so fast kernels get enough samples.
pub fn bench_for<T>(min_time: Duration, min_iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    // warmup ~10% of budget
    let warm_deadline = Instant::now() + min_time / 10;
    while Instant::now() < warm_deadline {
        std::hint::black_box(f());
    }
    let mut samples = Vec::new();
    let deadline = Instant::now() + min_time;
    while Instant::now() < deadline || samples.len() < min_iters {
        let start = Instant::now();
        std::hint::black_box(f());
        samples.push(start.elapsed().as_nanos() as f64);
        if samples.len() > 5_000_000 {
            break; // safety valve for sub-ns closures
        }
    }
    BenchStats::from_samples(samples)
}

/// Human-friendly duration formatting for bench tables.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.2} s", ns / 1_000_000_000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_on_known_samples() {
        let s = BenchStats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.iters, 5);
        assert!((s.mean_ns - 3.0).abs() < 1e-9);
        assert_eq!(s.median_ns, 3.0);
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.max_ns, 5.0);
    }

    #[test]
    fn bench_runs_requested_iters() {
        let mut count = 0usize;
        let s = bench(2, 10, || count += 1);
        assert_eq!(s.iters, 10);
        assert_eq!(count, 12);
    }

    #[test]
    fn fmt_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1_500.0), "1.50 µs");
        assert_eq!(fmt_ns(2_500_000.0), "2.50 ms");
        assert_eq!(fmt_ns(3_000_000_000.0), "3.00 s");
    }

    #[test]
    fn time_it_returns_value() {
        let (v, d) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }
}
