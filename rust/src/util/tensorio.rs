//! Reader/writer for the BCNT named-tensor container produced by
//! `python/compile/tensorio.py` (see that file for the layout).

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"BCNT";
const VERSION: u32 = 1;

/// Element type codes (must match tensorio.py `_DTYPES`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32 = 0,
    I32 = 1,
    U32 = 2,
    U8 = 3,
    I8 = 4,
}

impl DType {
    fn from_code(c: u32) -> Result<Self, TensorIoError> {
        Ok(match c {
            0 => DType::F32,
            1 => DType::I32,
            2 => DType::U32,
            3 => DType::U8,
            4 => DType::I8,
            _ => return Err(TensorIoError::BadDType(c)),
        })
    }

    pub fn size(&self) -> usize {
        match self {
            DType::F32 | DType::I32 | DType::U32 => 4,
            DType::U8 | DType::I8 => 1,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
            DType::U32 => "u32",
            DType::U8 => "u8",
            DType::I8 => "i8",
        }
    }
}

/// A named tensor: raw little-endian bytes + shape + dtype.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

#[derive(Debug)]
pub enum TensorIoError {
    Io(std::io::Error),
    BadMagic,
    BadVersion(u32),
    BadDType(u32),
    NotFound(String),
    DTypeMismatch { name: String, got: &'static str, want: &'static str },
    Truncated(String),
}

crate::error_enum_impls!(TensorIoError {
    TensorIoError::Io(e) => ("tensor io: {e}"),
    TensorIoError::BadMagic => ("tensor io: bad magic"),
    TensorIoError::BadVersion(v) => ("tensor io: unsupported version {v}"),
    TensorIoError::BadDType(c) => ("tensor io: unknown dtype code {c}"),
    TensorIoError::NotFound(n) => ("tensor io: tensor {n:?} not found"),
    TensorIoError::DTypeMismatch { name, got, want } =>
        ("tensor io: {name:?} has dtype {got}, expected {want}"),
    TensorIoError::Truncated(n) => ("tensor io: truncated payload for {n:?}"),
}
source { TensorIoError::Io(e) => e }
from { std::io::Error => TensorIoError::Io });

impl Tensor {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(if self.shape.is_empty() { 1 } else { 0 })
    }

    pub fn from_f32(shape: Vec<usize>, values: &[f32]) -> Self {
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Self { dtype: DType::F32, shape, data }
    }

    pub fn from_u32(shape: Vec<usize>, values: &[u32]) -> Self {
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Self { dtype: DType::U32, shape, data }
    }

    pub fn from_i32(shape: Vec<usize>, values: &[i32]) -> Self {
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Self { dtype: DType::I32, shape, data }
    }

    pub fn to_f32(&self, name: &str) -> Result<Vec<f32>, TensorIoError> {
        if self.dtype != DType::F32 {
            return Err(TensorIoError::DTypeMismatch {
                name: name.to_string(),
                got: self.dtype.name(),
                want: "f32",
            });
        }
        Ok(self.data.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn to_u32(&self, name: &str) -> Result<Vec<u32>, TensorIoError> {
        if self.dtype != DType::U32 {
            return Err(TensorIoError::DTypeMismatch {
                name: name.to_string(),
                got: self.dtype.name(),
                want: "u32",
            });
        }
        Ok(self.data.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn to_i32(&self, name: &str) -> Result<Vec<i32>, TensorIoError> {
        if self.dtype != DType::I32 {
            return Err(TensorIoError::DTypeMismatch {
                name: name.to_string(),
                got: self.dtype.name(),
                want: "i32",
            });
        }
        Ok(self.data.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

/// Ordered collection of named tensors.
#[derive(Debug, Default, Clone)]
pub struct TensorFile {
    names: Vec<String>,
    tensors: HashMap<String, Tensor>,
}

impl TensorFile {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, t: Tensor) {
        let name = name.into();
        if !self.tensors.contains_key(&name) {
            self.names.push(name.clone());
        }
        self.tensors.insert(name, t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor, TensorIoError> {
        self.tensors.get(name).ok_or_else(|| TensorIoError::NotFound(name.to_string()))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.tensors.contains_key(name)
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn f32(&self, name: &str) -> Result<Vec<f32>, TensorIoError> {
        self.get(name)?.to_f32(name)
    }

    pub fn u32(&self, name: &str) -> Result<Vec<u32>, TensorIoError> {
        self.get(name)?.to_u32(name)
    }

    pub fn i32(&self, name: &str) -> Result<Vec<i32>, TensorIoError> {
        self.get(name)?.to_i32(name)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self, TensorIoError> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(TensorIoError::BadMagic);
        }
        let version = read_u32(&mut f)?;
        if version != VERSION {
            return Err(TensorIoError::BadVersion(version));
        }
        let count = read_u32(&mut f)?;
        let mut out = Self::new();
        for _ in 0..count {
            let name_len = read_u32(&mut f)? as usize;
            let mut name_bytes = vec![0u8; name_len];
            f.read_exact(&mut name_bytes)?;
            let name = String::from_utf8_lossy(&name_bytes).to_string();
            let dtype = DType::from_code(read_u32(&mut f)?)?;
            let ndim = read_u32(&mut f)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u64(&mut f)? as usize);
            }
            let n: usize = shape.iter().product::<usize>().max(usize::from(shape.is_empty()));
            let mut data = vec![0u8; n * dtype.size()];
            f.read_exact(&mut data).map_err(|_| TensorIoError::Truncated(name.clone()))?;
            out.insert(name, Tensor { dtype, shape, data });
        }
        Ok(out)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), TensorIoError> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&(self.names.len() as u32).to_le_bytes())?;
        for name in &self.names {
            let t = &self.tensors[name];
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            f.write_all(&(t.dtype as u32).to_le_bytes())?;
            f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for d in &t.shape {
                f.write_all(&(*d as u64).to_le_bytes())?;
            }
            f.write_all(&t.data)?;
        }
        Ok(())
    }
}

fn read_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("bcnn-tensorio-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_f32_u32_i32() {
        let mut tf = TensorFile::new();
        tf.insert("a", Tensor::from_f32(vec![2, 3], &[1.0, -2.5, 3.0, 4.0, 5.5, -6.0]));
        tf.insert("b", Tensor::from_u32(vec![4], &[0, 1, u32::MAX, 42]));
        tf.insert("c", Tensor::from_i32(vec![2], &[-7, 7]));
        let path = tmpfile("roundtrip.bcnt");
        tf.save(&path).unwrap();
        let rt = TensorFile::load(&path).unwrap();
        assert_eq!(rt.names(), tf.names());
        assert_eq!(rt.f32("a").unwrap(), vec![1.0, -2.5, 3.0, 4.0, 5.5, -6.0]);
        assert_eq!(rt.get("a").unwrap().shape, vec![2, 3]);
        assert_eq!(rt.u32("b").unwrap(), vec![0, 1, u32::MAX, 42]);
        assert_eq!(rt.i32("c").unwrap(), vec![-7, 7]);
    }

    #[test]
    fn missing_tensor_and_dtype_mismatch() {
        let mut tf = TensorFile::new();
        tf.insert("x", Tensor::from_f32(vec![1], &[1.0]));
        assert!(matches!(tf.get("y"), Err(TensorIoError::NotFound(_))));
        assert!(matches!(tf.u32("x"), Err(TensorIoError::DTypeMismatch { .. })));
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmpfile("badmagic.bcnt");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(matches!(TensorFile::load(&path), Err(TensorIoError::BadMagic)));
    }

    #[test]
    fn python_compatibility_layout() {
        // Hand-build the byte layout tensorio.py writes for a known tensor
        // and check we parse it identically.
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(b"BCNT");
        bytes.extend_from_slice(&1u32.to_le_bytes()); // version
        bytes.extend_from_slice(&1u32.to_le_bytes()); // count
        bytes.extend_from_slice(&3u32.to_le_bytes()); // name len
        bytes.extend_from_slice(b"abc");
        bytes.extend_from_slice(&2u32.to_le_bytes()); // dtype u32
        bytes.extend_from_slice(&1u32.to_le_bytes()); // ndim
        bytes.extend_from_slice(&2u64.to_le_bytes()); // dim 0 = 2
        bytes.extend_from_slice(&0xDEADBEEFu32.to_le_bytes());
        bytes.extend_from_slice(&7u32.to_le_bytes());
        let path = tmpfile("pycompat.bcnt");
        std::fs::write(&path, &bytes).unwrap();
        let tf = TensorFile::load(&path).unwrap();
        assert_eq!(tf.u32("abc").unwrap(), vec![0xDEADBEEF, 7]);
    }

    #[test]
    fn scalar_tensor_roundtrip() {
        let mut tf = TensorFile::new();
        tf.insert("s", Tensor::from_f32(vec![], &[3.25]));
        let path = tmpfile("scalar.bcnt");
        tf.save(&path).unwrap();
        let rt = TensorFile::load(&path).unwrap();
        assert_eq!(rt.f32("s").unwrap(), vec![3.25]);
        assert!(rt.get("s").unwrap().shape.is_empty());
    }
}
