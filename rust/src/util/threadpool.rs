//! Fixed-size worker thread pool (no tokio in the offline vendor set).
//!
//! The coordinator uses this for inference workers; the bench harness and
//! dataset generator use [`scoped_map`] for data-parallel loops.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads executing queued jobs.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    active: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize, name: &str) -> Self {
        assert!(threads > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let active = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let active = Arc::clone(&active);
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                active.fetch_add(1, Ordering::SeqCst);
                                // keep the pool alive across panicking jobs
                                let _ = catch_unwind(AssertUnwindSafe(job));
                                active.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { tx: Some(tx), workers, active }
    }

    /// Queue a job. Panics if the pool is shut down.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("worker channel closed");
    }

    /// Number of jobs currently executing (approximate).
    pub fn active_jobs(&self) -> usize {
        self.active.load(Ordering::SeqCst)
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Data-parallel map over indices `0..n` using scoped threads.
///
/// `f(i)` must be `Sync`-callable; results come back in index order.
pub fn scoped_map<T: Send>(n: usize, threads: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    assert!(threads > 0);
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.min(n);
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots: Vec<Mutex<&mut Option<T>>> = out.iter_mut().map(Mutex::new).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                **slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    drop(slots);
    out.into_iter().map(|o| o.expect("slot filled")).collect()
}

/// Default parallelism: physical cores as reported by the OS.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_executes_all_jobs() {
        let pool = ThreadPool::new(4, "test");
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join workers
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_survives_panicking_job() {
        let pool = ThreadPool::new(2, "panic-test");
        let counter = Arc::new(AtomicU64::new(0));
        pool.execute(|| panic!("boom"));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn scoped_map_ordered_results() {
        let out = scoped_map(100, 8, |i| i * i);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn scoped_map_handles_small_n() {
        assert_eq!(scoped_map(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(scoped_map(1, 4, |i| i + 7), vec![7]);
    }

    #[test]
    fn threads_reported() {
        let pool = ThreadPool::new(3, "t");
        assert_eq!(pool.threads(), 3);
    }
}
