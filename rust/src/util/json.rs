//! Minimal JSON parser/serializer (the offline vendor set has no serde).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null).  Numbers are kept as `f64`, which is exact
//! for every integer the manifest contains (< 2^53).  Object key order is
//! preserved (insertion order) so round-trips are stable.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Objects preserve key order via the side vector of keys.
    Obj(JsonObj),
}

/// Insertion-ordered string map.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct JsonObj {
    keys: Vec<String>,
    map: BTreeMap<String, Json>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, value: Json) {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.keys.push(key.clone());
        }
        self.map.insert(key, value);
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.map.get(key)
    }

    pub fn contains(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.keys.iter()
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Json)> {
        self.keys.iter().map(move |k| (k, &self.map[k]))
    }
}

/// Parse or access error.
#[derive(Debug)]
pub enum JsonError {
    Parse { pos: usize, msg: String },
    Type { expected: &'static str, found: &'static str },
    MissingKey(String),
}

crate::error_enum_impls!(JsonError {
    JsonError::Parse { pos, msg } => ("json parse error at byte {pos}: {msg}"),
    JsonError::Type { expected, found } => ("json: expected {expected}, found {found}"),
    JsonError::MissingKey(k) => ("json: missing key {k:?}"),
});

impl Json {
    // ------------------------------------------------------------------
    // typed accessors
    // ------------------------------------------------------------------

    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(JsonError::Type { expected: "string", found: other.kind() }),
        }
    }

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(JsonError::Type { expected: "number", found: other.kind() }),
        }
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_i64(&self) -> Result<i64, JsonError> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(JsonError::Type { expected: "bool", found: other.kind() }),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(JsonError::Type { expected: "array", found: other.kind() }),
        }
    }

    pub fn as_obj(&self) -> Result<&JsonObj, JsonError> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(JsonError::Type { expected: "object", found: other.kind() }),
        }
    }

    /// `obj[key]` with a descriptive error.
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::MissingKey(key.to_string()))
    }

    /// Optional key access: `Ok(None)` when absent.
    pub fn get_opt(&self, key: &str) -> Result<Option<&Json>, JsonError> {
        Ok(self.as_obj()?.get(key))
    }

    // ------------------------------------------------------------------
    // parsing
    // ------------------------------------------------------------------

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: input.as_bytes(), pos: 0, depth: 0, nodes: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ------------------------------------------------------------------
    // serialization
    // ------------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(0));
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(lvl) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(lvl + 1));
                        v.write(out, Some(lvl + 1));
                    } else {
                        v.write(out, None);
                    }
                }
                if let (Some(lvl), false) = (indent, a.is_empty()) {
                    out.push('\n');
                    out.push_str(&"  ".repeat(lvl));
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(lvl) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(lvl + 1));
                        write_escaped(out, k);
                        out.push_str(": ");
                        v.write(out, Some(lvl + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if let (Some(lvl), false) = (indent, o.is_empty()) {
                    out.push('\n');
                    out.push_str(&"  ".repeat(lvl));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    /// Compact (single-line) serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Nesting cap for the recursive-descent parser: hostile input like a
/// megabyte of `[` must yield a parse error, not a worker-stack overflow
/// (which aborts the whole process).  Honest documents nest < 10 deep.
const MAX_DEPTH: usize = 128;

/// Cap on total parsed values per document.  Bounds the ~16x heap
/// amplification of a maximal protocol line BEFORE any protocol-level
/// check can run: the largest legitimate request (`classify_batch`, 64 ×
/// 27648 pixel numbers) is ~1.8M nodes; a 64 MiB line of 1-byte numerals
/// would be ~33M nodes (≈1 GB of `Json` values) without this cap.
const MAX_NODES: usize = 8_000_000;

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
    depth: usize,
    nodes: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {lit}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.nodes += 1;
        if self.nodes > MAX_NODES {
            return Err(self.err("document exceeds the value-count limit"));
        }
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting exceeds the depth limit"));
        }
        Ok(())
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            obj.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(obj));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: only BMP escapes are emitted by
                            // our own writer; accept lone surrogates as U+FFFD.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let rest = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// Convenience constructors used by the server/metrics code.
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x");
        let inner = &j.get("a").unwrap().as_arr().unwrap()[2];
        assert_eq!(inner.get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_string_escapes() {
        let j = Json::parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\n\t\"\\A");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let j = Json::parse("\"caf\u{e9} \u{1F697}\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "café 🚗");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn deep_nesting_is_an_error_not_a_stack_overflow() {
        // a hostile line of brackets must parse-error, not abort the process
        let hostile = "[".repeat(100_000);
        let err = Json::parse(&hostile).unwrap_err();
        assert!(err.to_string().contains("depth"), "{err}");
        // same for objects
        let hostile = "{\"a\":".repeat(100_000);
        assert!(Json::parse(&hostile).is_err());
        // well under the limit still parses
        let ok = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn node_budget_caps_parsed_values() {
        // MAX_NODES bounds heap amplification; a small doc is nowhere near it
        let j = Json::parse("[1,2,3]").unwrap();
        assert_eq!(j.as_arr().unwrap().len(), 3);
        // the limit itself is exercised cheaply via a tiny synthetic parser
        let mut p = Parser { b: b"1", pos: 0, depth: 0, nodes: MAX_NODES };
        assert!(p.value().unwrap_err().to_string().contains("value-count"));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"bcnn","nums":[1,2.5,-3],"flag":true,"none":null,"nested":{"k":"v"}}"#;
        let j = Json::parse(src).unwrap();
        let compact = j.to_string();
        assert_eq!(Json::parse(&compact).unwrap(), j);
        let pretty = j.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn object_preserves_insertion_order() {
        let j = Json::parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<_> = j.as_obj().unwrap().keys().cloned().collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }

    #[test]
    fn typed_accessor_errors() {
        let j = Json::parse("[1]").unwrap();
        assert!(matches!(j.as_obj(), Err(JsonError::Type { .. })));
        let o = Json::parse("{}").unwrap();
        assert!(matches!(o.get("k"), Err(JsonError::MissingKey(_))));
    }
}
