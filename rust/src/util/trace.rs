//! Per-request span tracing, the sampled-trace ring buffer, and the
//! bounded structured event journal.
//!
//! A [`Trace`] is a monotone timeline of named stage spans for one
//! request (`parsed → admitted → enqueued → batch_formed →
//! exec:<step> → logits → written`).  Each span records the offset in
//! nanoseconds from the trace's start at which that stage *ended*, so
//! the gap between consecutive offsets is the stage's duration and the
//! timeline is gap-accounted by construction.
//!
//! Tracing is opt-in per request: the server carries traces as
//! `Option<Box<Trace>>` through the coordinator, so the unsampled
//! steady-state path stays `None` end to end and allocates nothing.
//! Sampling is deterministic 1-in-N ([`TraceSampler`]); captured traces
//! land in a fixed-capacity ring ([`TraceStore`]) drained by the
//! `trace_dump` protocol op.
//!
//! All mutexes in this module are leaves: nothing else is ever locked
//! while one is held, so they sit outside the `util::lockorder` ranks.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::{Json, JsonObj};

/// One request's stage timeline: `(label, end-offset-ns)` pairs,
/// monotone in offset.
#[derive(Debug, Clone)]
pub struct Trace {
    start: Instant,
    /// Coordinator request id (0 until the router assigns one).
    pub id: u64,
    /// Resolved lane key (`name@version`), set at admission.
    pub model: String,
    spans: Vec<(String, u64)>,
}

impl Trace {
    /// Start a trace whose zero point is `start` (capture the instant
    /// *before* parsing so the `parsed` span covers parse time).
    pub fn begin_at(start: Instant) -> Self {
        Self { start, id: 0, model: String::new(), spans: Vec::new() }
    }

    /// Start a trace at the current instant.
    pub fn begin() -> Self {
        Self::begin_at(Instant::now())
    }

    /// Nanoseconds from the trace start to `at` (saturating at 0).
    pub fn offset_ns(&self, at: Instant) -> u64 {
        at.saturating_duration_since(self.start).as_nanos() as u64
    }

    /// Close the span `label` at offset `off_ns`, clamped so offsets
    /// never run backwards.
    pub fn push(&mut self, label: impl Into<String>, off_ns: u64) {
        let floor = self.spans.last().map(|(_, o)| *o).unwrap_or(0);
        self.spans.push((label.into(), off_ns.max(floor)));
    }

    /// Close the span `label` now.
    pub fn mark(&mut self, label: impl Into<String>) {
        let off = self.offset_ns(Instant::now());
        self.push(label, off);
    }

    /// The recorded `(label, end-offset-ns)` spans, in order.
    pub fn spans(&self) -> &[(String, u64)] {
        &self.spans
    }

    /// End offset of the last span (the traced total), in ns.
    pub fn total_ns(&self) -> u64 {
        self.spans.last().map(|(_, o)| *o).unwrap_or(0)
    }

    /// Render as `{"id", "model", "total_us", "spans": [{"label",
    /// "us"}...]}` — offsets in microseconds to match the wire's
    /// `queue_us`/`exec_us` convention.
    pub fn to_json(&self) -> Json {
        let mut obj = JsonObj::new();
        obj.insert("id", Json::Num(self.id as f64));
        obj.insert("model", Json::from(self.model.as_str()));
        obj.insert("total_us", Json::Num(self.total_ns() as f64 / 1_000.0));
        let spans = self
            .spans
            .iter()
            .map(|(label, off)| {
                let mut s = JsonObj::new();
                s.insert("label", Json::from(label.as_str()));
                s.insert("us", Json::Num(*off as f64 / 1_000.0));
                Json::Obj(s)
            })
            .collect();
        obj.insert("spans", Json::Arr(spans));
        Json::Obj(obj)
    }
}

/// Deterministic 1-in-N request sampler.  `every == 0` disables
/// sampling entirely (the steady-state default); `every == 1` traces
/// every request.  The first request is always sampled when enabled,
/// so `--trace-sample N` yields requests `0, N, 2N, ...`.
#[derive(Debug)]
pub struct TraceSampler {
    every: u64,
    counter: AtomicU64,
}

impl TraceSampler {
    pub fn new(every: u64) -> Self {
        Self { every, counter: AtomicU64::new(0) }
    }

    /// Whether sampling is enabled at all (cheap pre-check: when this
    /// is false, callers skip even the counter increment).
    pub fn enabled(&self) -> bool {
        self.every != 0
    }

    /// Count one eligible request and decide whether to trace it.
    pub fn sample(&self) -> bool {
        if self.every == 0 {
            return false;
        }
        self.counter.fetch_add(1, Ordering::Relaxed) % self.every == 0
    }
}

/// Fixed-capacity ring buffer of completed traces.  Pushing beyond
/// capacity evicts the oldest trace and counts it as dropped.
#[derive(Debug)]
pub struct TraceStore {
    inner: Mutex<StoreInner>,
    cap: usize,
}

#[derive(Debug)]
struct StoreInner {
    traces: VecDeque<Trace>,
    dropped: u64,
}

impl TraceStore {
    pub const DEFAULT_CAPACITY: usize = 256;

    pub fn new(cap: usize) -> Self {
        Self {
            inner: Mutex::new(StoreInner { traces: VecDeque::new(), dropped: 0 }),
            cap: cap.max(1),
        }
    }

    pub fn push(&self, trace: Trace) {
        let mut inner = self.inner.lock().unwrap();
        if inner.traces.len() == self.cap {
            inner.traces.pop_front();
            inner.dropped += 1;
        }
        inner.traces.push_back(trace);
    }

    /// Number of traces currently buffered.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().traces.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Traces evicted by ring overflow since startup.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Drain buffered traces (all of them, or only those whose model
    /// matches `filter`), oldest first.  Drained traces leave the ring.
    pub fn drain(&self, filter: Option<&str>) -> Vec<Trace> {
        let mut inner = self.inner.lock().unwrap();
        match filter {
            None => inner.traces.drain(..).collect(),
            Some(model) => {
                let mut kept = VecDeque::new();
                let mut out = Vec::new();
                for t in inner.traces.drain(..) {
                    if t.model == model {
                        out.push(t);
                    } else {
                        kept.push_back(t);
                    }
                }
                inner.traces = kept;
                out
            }
        }
    }
}

/// Journal event kinds — a closed set so operators can filter on them.
pub mod event {
    pub const MODEL_LOAD: &str = "model_load";
    pub const MODEL_LOAD_FAILED: &str = "model_load_failed";
    pub const MODEL_RETIRE: &str = "model_retire";
    pub const VERIFY_FAILED: &str = "verify_failed";
    pub const REWRITE_FALLBACK: &str = "rewrite_fallback";
    pub const ROUTE_SWAP: &str = "route_swap";
    pub const WRITE_TIMEOUT: &str = "write_timeout";
    /// Logged once at server construction with the XNOR microkernel
    /// `platform::dispatch` selected for this process (detail = kernel
    /// name), so perf envelopes in the journal correlate with the
    /// kernel that produced them.
    pub const KERNEL_DISPATCH: &str = "kernel_dispatch";
}

/// Bounded structured event journal with monotonic sequence numbers.
/// Old events are evicted (and counted) when the ring fills; `next_seq`
/// never resets, so gaps in drained sequences are detectable.
#[derive(Debug)]
pub struct Journal {
    inner: Mutex<JournalInner>,
    cap: usize,
}

#[derive(Debug)]
struct JournalInner {
    events: VecDeque<(u64, String, String)>,
    next_seq: u64,
    dropped: u64,
}

impl Journal {
    pub const DEFAULT_CAPACITY: usize = 256;

    pub fn new(cap: usize) -> Self {
        Self {
            inner: Mutex::new(JournalInner {
                events: VecDeque::new(),
                next_seq: 0,
                dropped: 0,
            }),
            cap: cap.max(1),
        }
    }

    /// Append an event, returning its sequence number.
    pub fn log(&self, kind: &str, detail: impl Into<String>) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        let seq = inner.next_seq;
        inner.next_seq += 1;
        if inner.events.len() == self.cap {
            inner.events.pop_front();
            inner.dropped += 1;
        }
        inner.events.push_back((seq, kind.to_string(), detail.into()));
        seq
    }

    /// Events currently buffered.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total events ever logged (== the next sequence number).
    pub fn total(&self) -> u64 {
        self.inner.lock().unwrap().next_seq
    }

    /// Events evicted by ring overflow.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Render as `{"next_seq", "dropped", "events": [{"seq", "kind",
    /// "detail"}...]}`, oldest first.
    pub fn to_json(&self) -> Json {
        let inner = self.inner.lock().unwrap();
        let mut obj = JsonObj::new();
        obj.insert("next_seq", Json::Num(inner.next_seq as f64));
        obj.insert("dropped", Json::Num(inner.dropped as f64));
        let events = inner
            .events
            .iter()
            .map(|(seq, kind, detail)| {
                let mut e = JsonObj::new();
                e.insert("seq", Json::Num(*seq as f64));
                e.insert("kind", Json::from(kind.as_str()));
                e.insert("detail", Json::from(detail.as_str()));
                Json::Obj(e)
            })
            .collect();
        obj.insert("events", Json::Arr(events));
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn spans_are_monotone_even_with_stale_offsets() {
        let mut t = Trace::begin();
        t.push("a", 100);
        t.push("b", 50); // clamped up to 100
        t.push("c", 300);
        let offs: Vec<u64> = t.spans().iter().map(|(_, o)| *o).collect();
        assert_eq!(offs, vec![100, 100, 300]);
        assert_eq!(t.total_ns(), 300);
    }

    #[test]
    fn trace_json_carries_labels_and_microsecond_offsets() {
        let mut t = Trace::begin();
        t.id = 7;
        t.model = "rgb@1".to_string();
        t.push("parsed", 2_000);
        t.push("logits", 10_000);
        let j = t.to_json();
        assert_eq!(j.get("model").unwrap().as_str().unwrap(), "rgb@1");
        assert_eq!(j.get("total_us").unwrap().as_f64().unwrap(), 10.0);
        let spans = j.get("spans").unwrap().as_arr().unwrap();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].get("label").unwrap().as_str().unwrap(), "parsed");
        assert_eq!(spans[0].get("us").unwrap().as_f64().unwrap(), 2.0);
    }

    #[test]
    fn sampler_is_deterministic_one_in_n() {
        let s = TraceSampler::new(3);
        let picks: Vec<bool> = (0..9).map(|_| s.sample()).collect();
        assert_eq!(picks, vec![true, false, false, true, false, false, true, false, false]);
    }

    #[test]
    fn sampler_zero_never_samples() {
        let off = TraceSampler::new(0);
        assert!(!off.enabled());
        prop::check(200, |_g| {
            prop::ensure(!off.sample(), "sampler with N=0 must never sample")
        });
    }

    #[test]
    fn store_ring_evicts_oldest_and_counts_drops() {
        let store = TraceStore::new(3);
        for i in 0..5u64 {
            let mut t = Trace::begin();
            t.id = i;
            store.push(t);
        }
        assert_eq!(store.len(), 3);
        assert_eq!(store.dropped(), 2);
        let drained = store.drain(None);
        let ids: Vec<u64> = drained.iter().map(|t| t.id).collect();
        assert_eq!(ids, vec![2, 3, 4]); // oldest two evicted
        assert!(store.is_empty());
        assert_eq!(store.dropped(), 2, "draining is not dropping");
    }

    #[test]
    fn store_drain_filters_by_model_and_keeps_the_rest() {
        let store = TraceStore::new(8);
        for (i, model) in ["rgb@1", "lbp@1", "rgb@1"].iter().enumerate() {
            let mut t = Trace::begin();
            t.id = i as u64;
            t.model = model.to_string();
            store.push(t);
        }
        let rgb = store.drain(Some("rgb@1"));
        assert_eq!(rgb.len(), 2);
        assert!(rgb.iter().all(|t| t.model == "rgb@1"));
        assert_eq!(store.len(), 1, "non-matching traces stay buffered");
        let rest = store.drain(None);
        assert_eq!(rest[0].model, "lbp@1");
    }

    #[test]
    fn journal_sequences_are_monotonic_across_eviction() {
        let j = Journal::new(2);
        for i in 0..5 {
            let seq = j.log(event::MODEL_LOAD, format!("m@{i}"));
            assert_eq!(seq, i);
        }
        assert_eq!(j.len(), 2);
        assert_eq!(j.total(), 5);
        assert_eq!(j.dropped(), 3);
        let json = j.to_json();
        let events = json.get("events").unwrap().as_arr().unwrap();
        let seqs: Vec<f64> =
            events.iter().map(|e| e.get("seq").unwrap().as_f64().unwrap()).collect();
        assert_eq!(seqs, vec![3.0, 4.0]);
        assert_eq!(json.get("next_seq").unwrap().as_f64().unwrap(), 5.0);
    }
}
