//! Mini property-based testing harness (no proptest in the vendor set).
//!
//! Usage pattern:
//!
//! ```text
//!     prop::check(256, |g| {
//!         let d = g.usize_in(1, 4096);
//!         let bits = g.bits(d);
//!         // ... assert an invariant, return Ok(()) or Err(msg)
//!         prop::ensure(cond, "message")
//!     });
//! ```
//!
//! On failure the harness retries with the recorded seed and reports it so
//! the case can be replayed (`PROP_SEED=<n> cargo test`).  Generation is
//! seeded deterministically per test unless `PROP_SEED` overrides it.

use super::rng::Xoshiro256;

/// Value generator handed to property closures.
pub struct Gen {
    rng: Xoshiro256,
    /// Human-readable trace of generated values (printed on failure).
    pub trace: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self { rng: Xoshiro256::new(seed), trace: Vec::new() }
    }

    pub fn u64(&mut self) -> u64 {
        let v = self.rng.next_u64();
        self.trace.push(format!("u64 = {v}"));
        v
    }

    pub fn u32(&mut self) -> u32 {
        let v = self.rng.next_u32();
        self.trace.push(format!("u32 = {v:#010x}"));
        v
    }

    /// Inclusive range.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let v = lo + self.rng.below((hi - lo + 1) as u64) as usize;
        self.trace.push(format!("usize in [{lo},{hi}] = {v}"));
        v
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        let v = lo + self.rng.next_f32() * (hi - lo);
        self.trace.push(format!("f32 in [{lo},{hi}] = {v}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.next_u64() & 1 == 1;
        self.trace.push(format!("bool = {v}"));
        v
    }

    /// Vector of `n` random {0,1} bits.
    pub fn bits(&mut self, n: usize) -> Vec<u32> {
        let v: Vec<u32> = (0..n).map(|_| (self.rng.next_u64() & 1) as u32).collect();
        self.trace.push(format!("bits[{n}]"));
        v
    }

    /// Vector of `n` random {-1.0, +1.0} values.
    pub fn pm1(&mut self, n: usize) -> Vec<f32> {
        let v: Vec<f32> = (0..n).map(|_| self.rng.next_pm1()).collect();
        self.trace.push(format!("pm1[{n}]"));
        v
    }

    /// Vector of `n` standard-normal f32 values.
    pub fn normals(&mut self, n: usize) -> Vec<f32> {
        let v: Vec<f32> = (0..n).map(|_| self.rng.next_normal_f32()).collect();
        self.trace.push(format!("normals[{n}]"));
        v
    }

    /// Vector of `n` random u32 words.
    pub fn words(&mut self, n: usize) -> Vec<u32> {
        let v: Vec<u32> = (0..n).map(|_| self.rng.next_u32()).collect();
        self.trace.push(format!("words[{n}]"));
        v
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.below(xs.len() as u64) as usize;
        self.trace.push(format!("pick #{i} of {}", xs.len()));
        &xs[i]
    }
}

/// Property result: Ok or a failure message.
pub type PropResult = Result<(), String>;

/// Assertion helper for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Equality helper with value reporting.
pub fn ensure_eq<T: PartialEq + std::fmt::Debug>(a: T, b: T, ctx: &str) -> PropResult {
    if a == b {
        Ok(())
    } else {
        Err(format!("{ctx}: {a:?} != {b:?}"))
    }
}

/// Run `cases` random cases of the property; panic with seed + trace on
/// the first failure.  `FnMut` so properties can thread mutable state
/// (e.g. a reused scratch arena) across cases.
pub fn check(cases: usize, mut prop: impl FnMut(&mut Gen) -> PropResult) {
    let base_seed = match std::env::var("PROP_SEED") {
        Ok(s) => s.parse::<u64>().expect("PROP_SEED must be u64"),
        Err(_) => 0xBC44_2026,
    };
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed on case {case} (replay: PROP_SEED={base_seed})\n  {msg}\n  trace:\n    {}",
                g.trace.join("\n    ")
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check(64, |g| {
            let n = g.usize_in(1, 100);
            ensure(n >= 1 && n <= 100, "range respected")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_reports_failures() {
        check(64, |g| {
            let n = g.usize_in(0, 10);
            ensure(n < 10, "will eventually fail")
        });
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::new(7);
        let mut b = Gen::new(7);
        assert_eq!(a.u64(), b.u64());
        assert_eq!(a.bits(16), b.bits(16));
    }

    #[test]
    fn pm1_values_are_pm1() {
        let mut g = Gen::new(3);
        for v in g.pm1(100) {
            assert!(v == 1.0 || v == -1.0);
        }
    }

    #[test]
    fn ensure_eq_formats_context() {
        let err = ensure_eq(1, 2, "demo").unwrap_err();
        assert!(err.contains("demo"));
        assert!(err.contains("1"));
        assert!(err.contains("2"));
    }
}
