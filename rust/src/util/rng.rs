//! Deterministic PRNGs (no `rand` crate in the offline vendor set).
//!
//! [`SplitMix64`] matches the Python generator in `python/compile/data.py`
//! bit-for-bit (used by the synthetic dataset and by tests that need
//! cross-language reproducibility).  [`Xoshiro256`] (xoshiro256**) is the
//! general-purpose generator for workloads and property tests.

/// SplitMix64 (Steele, Lea, Flood 2014).  Matches data.py `_splitmix64_stream`.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1) with 53-bit precision — matches data.py `_unit_floats`.
    #[inline]
    pub fn next_unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }
}

/// xoshiro256** 1.0 (Blackman & Vigna) — fast general-purpose PRNG.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as the xoshiro authors recommend.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in `[0, bound)` (Lemire's method, unbiased enough
    /// for workload generation; exact rejection for property tests).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // rejection sampling for exactness
        let zone = u64::MAX - (u64::MAX % bound);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % bound;
            }
        }
    }

    /// Standard normal via Box-Muller.
    pub fn next_normal_f32(&mut self) -> f32 {
        let u1 = (self.next_unit_f64().max(1e-300)) as f64;
        let u2 = self.next_unit_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Random {-1.0, +1.0} value.
    #[inline]
    pub fn next_pm1(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            -1.0
        } else {
            1.0
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // First three outputs from seed 0 (published reference values).
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(r.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn unit_floats_in_range() {
        let mut r = SplitMix64::new(123);
        for _ in 0..1000 {
            let f = r.next_unit_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_seeds() {
        let a: Vec<u64> = { let mut r = Xoshiro256::new(1); (0..8).map(|_| r.next_u64()).collect() };
        let b: Vec<u64> = { let mut r = Xoshiro256::new(1); (0..8).map(|_| r.next_u64()).collect() };
        let c: Vec<u64> = { let mut r = Xoshiro256::new(2); (0..8).map(|_| r.next_u64()).collect() };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn below_is_in_bounds_and_covers() {
        let mut r = Xoshiro256::new(42);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "astronomically unlikely identity");
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Xoshiro256::new(5);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.next_normal_f32()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
