//! Debug-build lock-order witness for the serving plane.
//!
//! The coordinator stack holds locks from three owners — the registry's
//! admin mutexes, the router's lane table, and each backend's scratch
//! arena pool — and some admin paths genuinely nest them (publication
//! adds a lane while holding registry state).  Deadlock freedom rests
//! on one global rule: **locks are only ever acquired in ascending rank
//! order** (see the rank constants below and the table in
//! [`crate::coordinator`]).  This module makes that rule checkable: a
//! thread-local stack of held ranks, asserted on every acquisition in
//! debug builds and compiled to nothing in release.
//!
//! Usage — construct the witness immediately after taking the lock and
//! bind it to a named `_`-prefixed variable so it lives as long as the
//! guard (a bare `let _ = ...` would drop it on the same line):
//!
//! ```ignore
//! let st = self.state.lock().unwrap();
//! let _ord = lockorder::acquired(lockorder::REGISTRY_STATE, "registry.state");
//! ```

use std::cell::RefCell;

/// `ModelRegistry::state` — admin-plane entry mutex; outermost because
/// publication/eviction nest every other lock under it.
pub const REGISTRY_STATE: u8 = 10;
/// `Router`'s lane-table `RwLock` (read by every request resolution,
/// written while registry state is held during publish/retire).
pub const ROUTER_LANES: u8 = 20;
/// `ModelRegistry::routes` — the route-snapshot `RwLock`, swapped while
/// registry state is held.
pub const REGISTRY_ROUTES: u8 = 30;
/// `ModelRegistry::counters` — lifecycle counter mutex (leaf on the
/// admin side).
pub const REGISTRY_COUNTERS: u8 = 40;
/// `EngineBackend`'s scratch-arena pool mutex (leaf on the serving
/// side; held only around a pop/push, never across an inference).
pub const SCRATCH_POOL: u8 = 50;

#[cfg(debug_assertions)]
thread_local! {
    /// Ranks (with names, for the panic message) of locks this thread
    /// currently holds, in acquisition order.
    static HELD: RefCell<Vec<(u8, &'static str)>> = const { RefCell::new(Vec::new()) };
}

/// RAII witness that this thread holds the lock ranked `rank`.  Panics
/// (debug builds only) when `rank` does not exceed the rank of every
/// lock the thread already holds.
#[must_use = "bind as `let _ord = ...`; dropping immediately unregisters the lock"]
pub struct OrderGuard {
    #[cfg(debug_assertions)]
    rank: u8,
}

/// Register an acquisition.  Call immediately after the lock call
/// succeeds; drop the returned witness when the lock guard drops.
pub fn acquired(rank: u8, name: &'static str) -> OrderGuard {
    #[cfg(debug_assertions)]
    {
        HELD.with(|h| {
            let mut held = h.borrow_mut();
            if let Some(&(top, top_name)) = held.last() {
                assert!(
                    rank > top,
                    "lock-order inversion: acquiring {name} (rank {rank}) while \
                     holding {top_name} (rank {top})"
                );
            }
            held.push((rank, name));
        });
        OrderGuard { rank }
    }
    #[cfg(not(debug_assertions))]
    {
        let _ = (rank, name);
        OrderGuard {}
    }
}

impl Drop for OrderGuard {
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        HELD.with(|h| {
            let mut held = h.borrow_mut();
            // rposition: same-rank reacquisition on sibling locks (two
            // backends' pools) releases the most recent entry
            if let Some(i) = held.iter().rposition(|&(r, _)| r == self.rank) {
                held.remove(i);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascending_acquisition_is_clean() {
        let _a = acquired(REGISTRY_STATE, "registry.state");
        let _b = acquired(ROUTER_LANES, "router.lanes");
        let _c = acquired(SCRATCH_POOL, "backend.scratch_pool");
    }

    #[test]
    fn reacquisition_after_release_is_clean() {
        // admin flows repeatedly take low-ranked locks after releasing
        // higher-ranked ones; only SIMULTANEOUS holding is ordered
        {
            let _c = acquired(REGISTRY_COUNTERS, "registry.counters");
        }
        {
            let _s = acquired(REGISTRY_STATE, "registry.state");
            let _r = acquired(REGISTRY_ROUTES, "registry.routes");
        }
        let _c = acquired(REGISTRY_COUNTERS, "registry.counters");
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore)]
    #[should_panic(expected = "lock-order inversion")]
    fn descending_acquisition_panics() {
        let _pool = acquired(SCRATCH_POOL, "backend.scratch_pool");
        let _state = acquired(REGISTRY_STATE, "registry.state");
    }

    #[test]
    fn threads_track_independently() {
        let _a = acquired(SCRATCH_POOL, "backend.scratch_pool");
        // another thread holding nothing may take a low rank freely
        std::thread::spawn(|| {
            let _b = acquired(REGISTRY_STATE, "registry.state");
        })
        .join()
        .unwrap();
    }
}
