//! # bcnn — Binarized CNN inference on a Rust + JAX/Pallas stack
//!
//! Reproduction of *"Binarized Convolutional Neural Networks for
//! Efficient Inference on GPUs"* (Khan, Huttunen, Boutellier, 2018).
//!
//! Three layers (see DESIGN.md):
//! * **L1** Pallas kernels (`python/compile/kernels/`) — packed
//!   xnor-popcount GEMM, fused im2col+pack, OR-pool, packed FC;
//! * **L2** JAX model (`python/compile/model.py`) — AOT-lowered to HLO
//!   text artifacts at build time;
//! * **L3** this crate — the serving coordinator (`coordinator`), the
//!   hot-swappable versioned model store and admin plane (`registry`),
//!   the TCP front end (`server`), the PJRT runtime that executes the
//!   artifacts (`runtime`), a pure-Rust engine implementing the same
//!   kernels for the CPU hot path (`bnn`), and every substrate the
//!   system needs (`util`, `input`, `dataset`, `platform`).
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/*.hlo.txt` + weight/test containers once, and the `repro`
//! binary serves from them.

// The engine is safe Rust with ONE argued exemption: the `std::arch`
// SIMD popcounts in `bnn::microkernel::simd`, which carries its own
// module-level `#![allow(unsafe_code)]`, documents a two-shape safety
// contract (feature-gated `#[target_feature]` calls behind detecting
// wrappers; bounds-checked unaligned loads), and is pinned
// bit-identical to the scalar kernels per `#[target_feature]` fn.
// Lint rule F (scripts/check_invariants.py) mechanically refuses
// `allow(unsafe_code)` in any other module — a new exemption must
// argue itself there and here.
#![deny(unsafe_code)]

pub mod bnn {
    //! Pure-Rust binarized inference engine (the paper's CUDA kernels,
    //! re-expressed for CPU: u64 xnor+popcount, cache-blocked GEMM).
    pub mod bgemm;
    pub mod conv_direct;
    pub mod fc;
    pub mod float_ops;
    pub mod graph;
    pub mod im2col;
    pub mod maxpool;
    pub mod microkernel;
    pub mod network;
    pub mod packing;
    pub mod scratch;
}

pub mod coordinator;

pub mod dataset {
    //! SynthVehicles renderer (Rust port) + canonical test-split loader.
    pub mod synth;
    pub mod testset;
}

pub mod input {
    //! Input binarization schemes (paper Section 2.3) + image IO.
    pub mod binarize;
    pub mod image;
}

pub mod platform;

pub mod registry;

pub mod runtime;

pub mod server;

pub mod util {
    //! Substrates the offline vendor set lacks: JSON, CLI, RNG, thread
    //! pool, histogram, property testing, timing, tracing, tensor IO.
    pub mod cli;
    pub mod error;
    pub mod histogram;
    pub mod json;
    pub mod lockorder;
    pub mod prop;
    pub mod rng;
    pub mod tensorio;
    pub mod threadpool;
    pub mod timer;
    pub mod trace;
}
