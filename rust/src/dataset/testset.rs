//! Loader for the canonical test split dumped by `python/compile/aot.py`
//! (`artifacts/testset.bcnt`) — the images Table 3 accuracy is measured
//! on, plus the expected-logits file used for cross-validation.

use std::path::Path;

use crate::util::tensorio::{TensorFile, TensorIoError};

pub const IMG_ELEMS: usize = 96 * 96 * 3;

/// The dumped test split.
pub struct TestSet {
    /// (N, 96, 96, 3) row-major.
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
}

impl TestSet {
    pub fn load(path: impl AsRef<Path>) -> Result<Self, TensorIoError> {
        let tf = TensorFile::load(path)?;
        let images = tf.f32("images")?;
        let labels = tf.i32("labels")?;
        assert_eq!(images.len(), labels.len() * IMG_ELEMS, "testset shape mismatch");
        Ok(Self { images, labels })
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Image `i` as a slice.
    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * IMG_ELEMS..(i + 1) * IMG_ELEMS]
    }
}

/// Expected logits for cross-validating Rust vs JAX (first N test images).
pub struct ExpectedLogits {
    pub x: Vec<f32>, // (N, 96, 96, 3)
    pub n: usize,
    tf: TensorFile,
}

impl ExpectedLogits {
    pub fn load(path: impl AsRef<Path>) -> Result<Self, TensorIoError> {
        let tf = TensorFile::load(path)?;
        let x = tf.f32("x")?;
        let n = x.len() / IMG_ELEMS;
        Ok(Self { x, n, tf })
    }

    pub fn image(&self, i: usize) -> &[f32] {
        &self.x[i * IMG_ELEMS..(i + 1) * IMG_ELEMS]
    }

    /// Logits tensor for a model key, e.g. "logits_bcnn_rgb" or
    /// "logits_float"; rows of 4.
    pub fn logits(&self, key: &str) -> Result<Vec<f32>, TensorIoError> {
        self.tf.f32(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tensorio::Tensor;

    #[test]
    fn loads_synthetic_testset() {
        let dir = std::env::temp_dir().join("bcnn-testset-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ts.bcnt");
        let mut tf = TensorFile::new();
        let images = vec![0.5f32; 2 * IMG_ELEMS];
        tf.insert("images", Tensor::from_f32(vec![2, 96, 96, 3], &images));
        tf.insert("labels", Tensor::from_i32(vec![2], &[1, 3]));
        tf.save(&path).unwrap();
        let ts = TestSet::load(&path).unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts.labels, vec![1, 3]);
        assert_eq!(ts.image(1).len(), IMG_ELEMS);
    }
}
