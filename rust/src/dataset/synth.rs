//! SynthVehicles — Rust port of the procedural vehicle renderer in
//! `python/compile/data.py` (same SplitMix64 streams, same geometry).
//!
//! Used for load generation (`repro serve`, benches) and demos; the
//! Python side renders the canonical train/test splits that get dumped to
//! `artifacts/testset.bcnt`, so cross-language bit-parity is not required
//! here — distributional parity is (same classes, same jitter ranges).

use crate::util::rng::SplitMix64;

pub const CLASSES: [&str; 4] = ["bus", "normal", "truck", "van"];
pub const NUM_CLASSES: usize = 4;
pub const IMG_H: usize = 96;
pub const IMG_W: usize = 96;
pub const IMG_C: usize = 3;
pub const DATASET_SIZE: usize = 6555;
pub const DEFAULT_SEED: u64 = 0xB0C4;

/// One rendered sample.
pub struct Sample {
    /// (96, 96, 3) row-major floats in [0, 1].
    pub image: Vec<f32>,
    pub label: usize,
}

fn unit_floats(seed: u64, n: usize) -> Vec<f64> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| rng.next_unit_f64()).collect()
}

struct Canvas {
    px: Vec<f32>, // (H, W, 3)
}

impl Canvas {
    fn paint_rect(&mut self, x0: f64, y0: f64, x1: f64, y1: f64, color: [f32; 3]) {
        let ys = (y0.max(0.0) as usize).min(IMG_H);
        let ye = (y1.max(0.0) as usize).min(IMG_H);
        let xs = (x0.max(0.0) as usize).min(IMG_W);
        let xe = (x1.max(0.0) as usize).min(IMG_W);
        for y in ys..ye {
            for x in xs..xe {
                let i = (y * IMG_W + x) * 3;
                self.px[i..i + 3].copy_from_slice(&color);
            }
        }
    }

    fn paint_disc(&mut self, cx: f64, cy: f64, r: f64, color: [f32; 3]) {
        let ys = ((cy - r).max(0.0) as usize).min(IMG_H);
        let ye = ((cy + r + 1.0).max(0.0) as usize).min(IMG_H);
        let xs = ((cx - r).max(0.0) as usize).min(IMG_W);
        let xe = ((cx + r + 1.0).max(0.0) as usize).min(IMG_W);
        for y in ys..ye {
            for x in xs..xe {
                let dx = x as f64 - cx;
                let dy = y as f64 - cy;
                if dx * dx + dy * dy <= r * r {
                    let i = (y * IMG_W + x) * 3;
                    self.px[i..i + 3].copy_from_slice(&color);
                }
            }
        }
    }
}

/// Render dataset image `index` deterministically (label = index % 4).
pub fn render_vehicle(index: usize, seed: u64) -> Sample {
    let label = index % NUM_CLASSES;
    let u = unit_floats(
        (seed << 20) ^ ((index as u64).wrapping_mul(0x9E37).wrapping_add(0x1234_5678)),
        32,
    );

    let mut canvas = Canvas { px: vec![0f32; IMG_H * IMG_W * 3] };

    // --- background ---------------------------------------------------
    let horizon = 52 + (u[0] * 10.0) as usize;
    let sky = [
        0.45 + 0.2 * u[1] as f32,
        0.55 + 0.2 * u[2] as f32,
        0.75 + 0.2 * u[3] as f32,
    ];
    let road = 0.25 + 0.15 * u[4] as f32;
    for y in 0..IMG_H {
        if y >= horizon {
            for x in 0..IMG_W {
                let i = (y * IMG_W + x) * 3;
                canvas.px[i] = road;
                canvas.px[i + 1] = road;
                canvas.px[i + 2] = road * 1.02;
            }
        } else {
            let t = (y as f32 / horizon.max(1) as f32).min(1.0);
            let shade = 1.0 - 0.35 * t;
            for x in 0..IMG_W {
                let i = (y * IMG_W + x) * 3;
                canvas.px[i] = sky[0] * shade;
                canvas.px[i + 1] = sky[1] * shade;
                canvas.px[i + 2] = sky[2] * shade;
            }
        }
    }
    // background clutter
    for b in 0..2 {
        let bx = u[5 + b] * IMG_W as f64;
        let bw = 8.0 + u[7 + b] * 16.0;
        let bh = 6.0 + u[9 + b] * 12.0;
        let shade = 0.35 + 0.3 * u[11 + b] as f32;
        canvas.paint_rect(
            bx,
            horizon as f64 - bh,
            bx + bw,
            horizon as f64,
            [shade, shade * 0.95, shade * 0.9],
        );
    }

    // --- vehicle --------------------------------------------------------
    let scale = 0.75 + 0.4 * u[13];
    let cx = 48.0 + (u[14] - 0.5) * 16.0;
    let ground = horizon as f64 + 14.0 + (u[15] - 0.5) * 8.0;
    let body = [
        0.15 + 0.75 * u[16] as f32,
        0.15 + 0.75 * u[17] as f32,
        0.15 + 0.75 * u[18] as f32,
    ];
    let winb = 0.7 + 0.3 * u[19] as f32;
    let win = [0.65 * winb, 0.8 * winb, 0.9 * winb];
    let dark = [0.06, 0.06, 0.07];
    let px = |v: f64| v * scale;
    let wheel_r = px(5.0);
    let wy = ground - wheel_r * 0.6;
    let dim = |c: [f32; 3], f: f32| [c[0] * f, c[1] * f, c[2] * f];

    let mut wheels: Vec<f64> = Vec::new();
    match label {
        0 => {
            // bus
            let (half_len, height) = (px(34.0), px(26.0));
            let (x0, x1) = (cx - half_len, cx + half_len);
            let y1 = ground - px(3.0);
            let y0 = y1 - height;
            canvas.paint_rect(x0, y0, x1, y1, body);
            let wn = 5;
            let wgap = (2.0 * half_len) / (wn as f64 + 1.0);
            for wdw in 0..wn {
                let wx0 = x0 + wgap * (wdw as f64 + 0.6);
                canvas.paint_rect(wx0, y0 + px(4.0), wx0 + wgap * 0.6, y0 + px(11.0), win);
            }
            wheels.extend([x0 + px(8.0), x1 - px(8.0)]);
        }
        1 => {
            // normal car
            let (half_len, height) = (px(24.0), px(10.0));
            let (x0, x1) = (cx - half_len, cx + half_len);
            let y1 = ground - px(2.0);
            let y0 = y1 - height;
            canvas.paint_rect(x0, y0, x1, y1, body);
            let (cx0, cx1) = (cx - half_len * 0.45, cx + half_len * 0.45);
            let cy0 = y0 - px(9.0);
            canvas.paint_rect(cx0, cy0, cx1, y0, dim(body, 0.92));
            canvas.paint_rect(cx0 + px(2.0), cy0 + px(2.0), cx - px(1.0), y0 - px(1.0), win);
            canvas.paint_rect(cx + px(1.0), cy0 + px(2.0), cx1 - px(2.0), y0 - px(1.0), win);
            wheels.extend([x0 + px(7.0), x1 - px(7.0)]);
        }
        2 => {
            // truck: cab + separate cargo box
            let (cab_len, cab_h) = (px(12.0), px(16.0));
            let (box_len, box_h) = (px(30.0), px(24.0));
            let gap = px(3.0);
            let x_cab1 = cx + cab_len + box_len / 2.0 + gap;
            let x_cab0 = x_cab1 - cab_len;
            let xb0 = x_cab0 - gap - box_len;
            let xb1 = x_cab0 - gap;
            let y1 = ground - px(3.0);
            canvas.paint_rect(xb0, y1 - box_h, xb1, y1, body);
            canvas.paint_rect(x_cab0, y1 - cab_h, x_cab1, y1, dim(body, 0.85));
            canvas.paint_rect(
                x_cab0 + px(2.0),
                y1 - cab_h + px(2.0),
                x_cab1 - px(2.0),
                y1 - cab_h + px(8.0),
                win,
            );
            wheels.extend([xb0 + px(6.0), xb1 - px(6.0), x_cab1 - px(5.0)]);
        }
        _ => {
            // van
            let (half_len, height) = (px(26.0), px(22.0));
            let (x0, x1) = (cx - half_len, cx + half_len);
            let y1 = ground - px(2.0);
            let y0 = y1 - height;
            canvas.paint_rect(x0, y0, x1, y1, body);
            canvas.paint_rect(x1, y1 - px(8.0), x1 + px(6.0), y1, dim(body, 0.95));
            canvas.paint_rect(x1 - px(10.0), y0 + px(3.0), x1 - px(2.0), y0 + px(11.0), win);
            wheels.extend([x0 + px(7.0), x1 - px(7.0)]);
        }
    }
    for &wx in &wheels {
        canvas.paint_disc(wx, wy, wheel_r, dark);
        canvas.paint_disc(wx, wy, wheel_r * 0.45, [0.5, 0.5, 0.52]);
    }

    // --- noise + illumination jitter ------------------------------------
    let gain = 0.85 + 0.3 * u[20] as f32;
    let noise = unit_floats(
        (seed << 21) ^ ((index as u64).wrapping_mul(0x85EB).wrapping_add(77)),
        IMG_H * IMG_W,
    );
    for p in 0..IMG_H * IMG_W {
        let n = (noise[p] as f32 - 0.5) * 0.06;
        for ch in 0..3 {
            let i = p * 3 + ch;
            canvas.px[i] = (canvas.px[i] * gain + n).clamp(0.0, 1.0);
        }
    }
    Sample { image: canvas.px, label }
}

/// Render a batch of images starting at `start` (for load generation).
pub fn render_batch(start: usize, count: usize, seed: u64) -> Vec<Sample> {
    (start..start + count).map(|i| render_vehicle(i, seed)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_are_deterministic() {
        let a = render_vehicle(17, DEFAULT_SEED);
        let b = render_vehicle(17, DEFAULT_SEED);
        assert_eq!(a.image, b.image);
        assert_eq!(a.label, 1);
    }

    #[test]
    fn labels_are_balanced() {
        let samples = render_batch(0, 16, DEFAULT_SEED);
        for (i, s) in samples.iter().enumerate() {
            assert_eq!(s.label, i % 4);
        }
    }

    #[test]
    fn pixels_in_unit_range() {
        for i in 0..8 {
            let s = render_vehicle(i, DEFAULT_SEED);
            assert_eq!(s.image.len(), IMG_H * IMG_W * 3);
            assert!(s.image.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn different_indices_differ() {
        let a = render_vehicle(0, DEFAULT_SEED);
        let b = render_vehicle(4, DEFAULT_SEED); // same class, different jitter
        assert_eq!(a.label, b.label);
        assert_ne!(a.image, b.image);
    }

    #[test]
    fn classes_are_visually_distinct_in_mean_coverage() {
        // trucks+buses cover more dark-wheel/body area than cars on average;
        // sanity-check the renderer produces class-dependent statistics.
        let mean_of = |label: usize| -> f32 {
            let mut acc = 0f32;
            let mut n = 0;
            for i in 0..40 {
                if i % 4 == label {
                    let s = render_vehicle(i, DEFAULT_SEED);
                    acc += s.image.iter().sum::<f32>() / s.image.len() as f32;
                    n += 1;
                }
            }
            acc / n as f32
        };
        let truck = mean_of(2);
        let car = mean_of(1);
        // a truck's dark cargo box covers far more area than a car body;
        // the class means must differ measurably
        assert!((truck - car).abs() > 0.01, "truck {truck} vs car {car}");
    }
}
