//! Hot-swappable model registry: the versioned store behind the
//! serving plane.
//!
//! The paper's central trade — a binarized network giving up 4.4%
//! accuracy for a 7.4× speedup over its float twin — means a real
//! deployment wants *several* accuracy/latency points resident at once
//! (the multi-variant posture FINN argues for, and XNOR-Net's
//! binary-vs-float accuracy ladder motivates), and wants to move
//! between them without dropping a connection.  This module provides
//! that lifecycle:
//!
//! * **Versioned entries.**  Every published model is a `name@version`
//!   key ([`ModelKey`]) owning its *own* coordinator lane (queue +
//!   executor pool + metrics, via [`Router::add_lane`]) — a batch can
//!   structurally never mix two versions' weights.
//! * **Atomic publication.**  Clients resolve model references through
//!   an immutable route-table snapshot behind an `Arc` swap: a
//!   request group resolves once, rides its resolved lane to
//!   completion, and concurrent `load_model` / `set_default` /
//!   `unload_model` calls swap the snapshot without ever invalidating
//!   an in-flight resolution.  In-flight batches finish on the old
//!   version while new admissions see the new one.
//! * **Validated loads off the hot path.**  A background loader thread
//!   (`loader.rs`) re-reads `registry.json`, checksums the weight file
//!   (FNV-1a 64), parses and shape-checks the container, statically
//!   verifies the compiled plan ([`crate::bnn::graph::verify_plan`]:
//!   aliasing, dataflow, extents, weight bindings), runs the
//!   proof-carrying fusion rewriter (a rewrite refused by
//!   [`crate::bnn::graph::check_equiv`] or re-verification falls back
//!   to the unoptimized plan, counted in `registry.rewrite_fallbacks`
//!   and reported per entry by `list_models`), and smoke-infers one
//!   synthetic image — only then is the entry published.  Serving
//!   threads never parse artifacts, and a plan that fails verification
//!   never serves (counted in `registry.verify_failures`).
//! * **Graceful retirement.**  Unloading removes the entry from the
//!   snapshot first, then retires its lane: the queue closes, the
//!   executors drain every already-admitted request, and the threads
//!   are reaped in the background ([`crate::coordinator::Batcher::retire`]).
//!   No admitted request is ever dropped by a swap.
//!
//! Wire-level admin (`load_model`, `unload_model`, `set_default`,
//! `list_models`) lives in [`crate::server::protocol`]; lifecycle
//! documentation in `docs/ARCHITECTURE.md`.

mod loader;

pub use loader::{fnv1a64, format_checksum, parse_checksum};
#[cfg(test)]
pub(crate) use loader::corrupt_env_guard;

use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::bnn::graph::VerifyReport;
use crate::coordinator::{BatchPolicy, InferBackend, Router};
use crate::runtime::RegistryBatchSpec;
use crate::util::json::{Json, JsonObj};
use crate::util::lockorder;
use crate::util::threadpool::default_threads;
use crate::util::trace::{event, Journal};

#[derive(Debug)]
pub enum RegistryError {
    BadName(String),
    Exists(String),
    Unknown(String, String),
    ServingDefault(String),
    NoModelsDir,
    LoaderGone,
    Load(String),
    /// The compiled plan failed static verification
    /// ([`crate::bnn::graph::verify_plan`]); the entry is never published.
    Verify(String),
}

crate::error_enum_impls!(RegistryError {
    RegistryError::BadName(n) =>
        ("invalid model name {n:?} (must be non-empty, no '@' or whitespace)"),
    RegistryError::Exists(k) => ("model {k} is already loaded"),
    RegistryError::Unknown(k, avail) => ("unknown model {k:?} (loaded: {avail})"),
    RegistryError::ServingDefault(k) =>
        ("model {k} serves the default route; set_default to another entry before unloading"),
    RegistryError::NoModelsDir => ("server started without --models; load_model is unavailable"),
    RegistryError::LoaderGone => ("model loader thread is gone"),
    RegistryError::Load(msg) => ("model load failed: {msg}"),
    RegistryError::Verify(msg) => ("plan verification failed: {msg}"),
});

/// Identity of one published model version.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelKey {
    pub name: String,
    pub version: u32,
}

impl ModelKey {
    /// The lane key this entry serves under (`name@version`).
    pub fn lane(&self) -> String {
        format!("{}@{}", self.name, self.version)
    }
}

impl std::fmt::Display for ModelKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}@{}", self.name, self.version)
    }
}

/// Parse a client-facing model reference: `"name"` → `(name, None)`,
/// `"name@version"` → `(name, Some(version))`.
pub fn parse_model_ref(s: &str) -> Result<(String, Option<u32>), RegistryError> {
    match s.split_once('@') {
        None => {
            validate_name(s)?;
            Ok((s.to_string(), None))
        }
        Some((name, version)) => {
            validate_name(name)?;
            let v: u32 = version
                .parse()
                .map_err(|_| RegistryError::BadName(s.to_string()))?;
            Ok((name.to_string(), Some(v)))
        }
    }
}

fn validate_name(name: &str) -> Result<(), RegistryError> {
    if name.is_empty() || name.contains('@') || name.contains(char::is_whitespace) {
        return Err(RegistryError::BadName(name.to_string()));
    }
    Ok(())
}

/// Published-entry metadata (immutable once published).
#[derive(Debug, Clone)]
pub struct EntryMeta {
    pub key: ModelKey,
    /// `"bcnn"` | `"float"` | `"pjrt"` (programmatic publishers may
    /// extend this).
    pub kind: String,
    /// Input-binarization scheme label (`none|rgb|gray|lbp|float`).
    pub scheme: String,
    /// FNV-1a 64 of the weight container; `None` for programmatic
    /// (non-file) publications.
    pub checksum: Option<u64>,
    /// The EFFECTIVE batch policy this entry's lane was spawned with:
    /// the registry default merged with the entry's `"batch"` manifest
    /// overrides.  Reported per model by `list_models`.
    pub policy: BatchPolicy,
    /// Static-verification report for file loads (the loader runs
    /// [`crate::bnn::graph::verify_plan`] on the compiled plan before
    /// publication); `None` for programmatic publications, which hand
    /// the registry an opaque backend rather than a plan.
    pub verify: Option<VerifyReport>,
    /// Rewrite status for file loads: the fusion pass list the entry
    /// serves with, or `fallback:<err>` when the equivalence gauntlet
    /// refused the rewrite and the unoptimized plan serves.  `None` for
    /// programmatic publications (no plan, nothing to rewrite).
    pub rewrite: Option<String>,
}

/// Mutable registry state, guarded by one mutex and only ever touched
/// by admin operations.
struct State {
    /// name → version → metadata.
    entries: BTreeMap<String, BTreeMap<u32, EntryMeta>>,
    /// name → the version currently serving the bare-`name` alias.
    serving: BTreeMap<String, u32>,
    /// Model *name* the empty model reference routes to.
    default_name: String,
}

impl State {
    fn available(&self) -> String {
        let mut keys = Vec::new();
        for (name, versions) in &self.entries {
            for v in versions.keys() {
                keys.push(format!("{name}@{v}"));
            }
        }
        keys.join(", ")
    }
}

/// Immutable resolution snapshot.  Rebuilt and `Arc`-swapped on every
/// publication event; readers resolve against a consistent table
/// without taking the state mutex.
struct RouteTable {
    /// Every acceptable model reference → the lane that serves it:
    /// `name@version` maps to itself, bare `name` to its serving
    /// version.
    aliases: HashMap<String, String>,
    /// Lane serving the empty model reference (empty string = none).
    default_key: String,
}

impl RouteTable {
    fn available(&self) -> String {
        let mut v: Vec<&str> = self.aliases.keys().map(String::as_str).collect();
        v.sort_unstable();
        v.join(", ")
    }
}

#[derive(Default)]
struct Counters {
    loads: u64,
    load_failures: u64,
    /// Loads refused because the compiled plan failed static
    /// verification (a subset of `load_failures`).
    verify_failures: u64,
    /// Successful loads whose fusion rewrite was refused by the
    /// equivalence/verification gauntlet — the entry serves the
    /// unoptimized plan instead (NOT a load failure; the model is up,
    /// just unfused).
    rewrite_fallbacks: u64,
    swaps: u64,
    evictions: u64,
}

/// The registry: versioned model store + route snapshot + admin plane.
pub struct ModelRegistry {
    router: Arc<Router>,
    state: Mutex<State>,
    routes: RwLock<Arc<RouteTable>>,
    counters: Mutex<Counters>,
    loader: Option<loader::Loader>,
    /// Monotonic route-snapshot version: bumped on every
    /// [`ModelRegistry::rebuild_routes`] swap.  A metrics scraper that
    /// sees the gauge move knows the serving topology changed between
    /// two scrapes even if the model list looks identical.
    route_version: AtomicU64,
    /// Bounded structured event journal (model lifecycle, verify/rewrite
    /// fallbacks; the server appends write-timeout events too).  A strict
    /// leaf lock — every `log` call sits after the admin-state mutex is
    /// released.
    journal: Arc<Journal>,
}

impl ModelRegistry {
    pub fn builder() -> RegistryBuilder {
        RegistryBuilder {
            policy: BatchPolicy::default(),
            queue_capacity: 1024,
            engine_threads: 0,
            models_dir: None,
        }
    }

    /// The router whose lanes this registry manages.  Callers resolve a
    /// model reference first ([`ModelRegistry::resolve`]) and submit to
    /// the returned lane key.
    pub fn router(&self) -> &Arc<Router> {
        &self.router
    }

    /// The registry's structured event journal (shared with the server,
    /// which appends wire-side events like write timeouts).
    pub fn journal(&self) -> &Arc<Journal> {
        &self.journal
    }

    /// Current route-snapshot version (0 before the first publication;
    /// bumped on every snapshot swap).
    pub fn route_version(&self) -> u64 {
        self.route_version.load(Ordering::Relaxed)
    }

    /// Resolve a client-facing model reference (`""` = default, bare
    /// name, or `name@version`) to the lane key that serves it, against
    /// the current snapshot.
    pub fn resolve(&self, model: &str) -> Result<String, RegistryError> {
        let routes = Arc::clone(&self.routes.read().unwrap());
        let wanted = if model.is_empty() { routes.default_key.as_str() } else { model };
        if wanted.is_empty() {
            return Err(RegistryError::Unknown("<default>".to_string(), routes.available()));
        }
        routes
            .aliases
            .get(wanted)
            .cloned()
            .ok_or_else(|| RegistryError::Unknown(wanted.to_string(), routes.available()))
    }

    /// Publish an already-constructed backend under `name@version`
    /// (programmatic path: `serve --variants`, PJRT backends, tests).
    /// Runs the same smoke gate as file loads; the first published name
    /// becomes the default.
    pub fn publish_backend(
        &self,
        name: &str,
        version: u32,
        kind: &str,
        scheme: &str,
        checksum: Option<u64>,
        backend: Arc<dyn InferBackend>,
    ) -> Result<String, RegistryError> {
        validate_name(name)?;
        loader::smoke_test_any_width(&*backend)?;
        let policy = self.router.default_policy();
        self.publish_validated(
            EntryMeta {
                key: ModelKey { name: name.to_string(), version },
                kind: kind.to_string(),
                scheme: scheme.to_string(),
                checksum,
                policy,
                verify: None,
                rewrite: None,
            },
            backend,
        )
    }

    /// Load `name@version` from the models directory via the background
    /// loader (checksum + parse + smoke validation) and publish it.
    /// Serving traffic continues on the existing lanes throughout.
    pub fn load_model(&self, name: &str, version: u32) -> Result<String, RegistryError> {
        validate_name(name)?;
        let loader = self.loader.as_ref().ok_or(RegistryError::NoModelsDir)?;
        {
            let st = self.state.lock().unwrap();
            let _ord = lockorder::acquired(lockorder::REGISTRY_STATE, "registry.state");
            if st.entries.get(name).is_some_and(|vs| vs.contains_key(&version)) {
                return Err(RegistryError::Exists(format!("{name}@{version}")));
            }
        }
        match loader.load(name, version) {
            Ok(loaded) => {
                let key = self.publish_validated(
                    EntryMeta {
                        key: ModelKey { name: name.to_string(), version },
                        kind: loaded.kind,
                        scheme: loaded.scheme,
                        checksum: Some(loaded.checksum),
                        policy: effective_policy(self.router.default_policy(), loaded.batch),
                        verify: Some(loaded.report),
                        rewrite: Some(loaded.rewrite),
                    },
                    loaded.backend,
                )?;
                {
                    let mut c = self.counters.lock().unwrap();
                    let _ord =
                        lockorder::acquired(lockorder::REGISTRY_COUNTERS, "registry.counters");
                    c.loads += 1;
                    if loaded.rewrite_fallback {
                        c.rewrite_fallbacks += 1;
                    }
                }
                if loaded.rewrite_fallback {
                    self.journal.log(event::REWRITE_FALLBACK, &key);
                }
                Ok(key)
            }
            Err(e) => {
                {
                    let mut c = self.counters.lock().unwrap();
                    let _ord =
                        lockorder::acquired(lockorder::REGISTRY_COUNTERS, "registry.counters");
                    c.load_failures += 1;
                    if matches!(e, RegistryError::Verify(_)) {
                        c.verify_failures += 1;
                    }
                }
                let detail = format!("{name}@{version}: {e}");
                self.journal.log(event::MODEL_LOAD_FAILED, &detail);
                if matches!(e, RegistryError::Verify(_)) {
                    self.journal.log(event::VERIFY_FAILED, &detail);
                }
                Err(e)
            }
        }
    }

    fn publish_validated(
        &self,
        meta: EntryMeta,
        backend: Arc<dyn InferBackend>,
    ) -> Result<String, RegistryError> {
        let lane_key = meta.key.lane();
        let mut st = self.state.lock().unwrap();
        let _ord = lockorder::acquired(lockorder::REGISTRY_STATE, "registry.state");
        if st
            .entries
            .get(&meta.key.name)
            .is_some_and(|vs| vs.contains_key(&meta.key.version))
        {
            return Err(RegistryError::Exists(lane_key));
        }
        self.router
            .add_lane_with_policy(lane_key.clone(), backend, meta.policy)
            .map_err(|e| RegistryError::Load(e.to_string()))?;
        let name = meta.key.name.clone();
        let version = meta.key.version;
        st.entries.entry(name.clone()).or_default().insert(version, meta);
        // a name's first version starts serving its bare alias; later
        // versions wait for an explicit set_default (hot swaps are
        // admin-driven, never implicit)
        st.serving.entry(name.clone()).or_insert(version);
        if st.default_name.is_empty() {
            st.default_name = name;
        }
        self.rebuild_routes(&st);
        drop(st);
        // journal AFTER the state mutex is released: its ring mutex is a
        // strict leaf, never nested under an admin lock
        self.journal.log(event::MODEL_LOAD, &lane_key);
        Ok(lane_key)
    }

    /// Point the serving alias for `name` at `version`, atomically (one
    /// snapshot swap: every request line parsed after the swap resolves
    /// to the new version; groups already resolved finish on the old
    /// one).  Two intents, split by the `version` argument:
    ///
    /// * `Some(v)` — **pin** `name`'s serving version.  The registry
    ///   default follows only if `name` already *is* the default model,
    ///   so upgrading a secondary model never hijacks default-route
    ///   traffic.
    /// * `None` — make `name` the **default model** (serving its
    ///   highest loaded version).
    pub fn set_default(&self, name: &str, version: Option<u32>) -> Result<String, RegistryError> {
        let mut st = self.state.lock().unwrap();
        let _ord = lockorder::acquired(lockorder::REGISTRY_STATE, "registry.state");
        let Some(versions) = st.entries.get(name) else {
            let avail = st.available();
            return Err(RegistryError::Unknown(name.to_string(), avail));
        };
        let pinned = version;
        let version = match version {
            Some(v) => {
                if !versions.contains_key(&v) {
                    let avail = st.available();
                    return Err(RegistryError::Unknown(format!("{name}@{v}"), avail));
                }
                v
            }
            None => *versions.keys().next_back().expect("published name has >= 1 version"),
        };
        let serving_changed = st.serving.insert(name.to_string(), version) != Some(version);
        let adopt_default =
            pinned.is_none() || st.default_name.is_empty() || st.default_name == name;
        let default_changed = adopt_default && st.default_name != name;
        if adopt_default {
            st.default_name = name.to_string();
        }
        self.rebuild_routes(&st);
        drop(st);
        if serving_changed || default_changed {
            self.counters.lock().unwrap().swaps += 1;
            self.journal.log(event::ROUTE_SWAP, &format!("{name}@{version}"));
        }
        Ok(format!("{name}@{version}"))
    }

    /// Evict `name@version`.  The entry leaves the route snapshot
    /// first, then its lane retires gracefully (admitted requests
    /// drain; threads reap in the background).  The entry serving the
    /// registry default is protected — repoint the default first.
    pub fn unload_model(&self, name: &str, version: u32) -> Result<String, RegistryError> {
        let lane_key = format!("{name}@{version}");
        let mut st = self.state.lock().unwrap();
        let _ord = lockorder::acquired(lockorder::REGISTRY_STATE, "registry.state");
        if !st.entries.get(name).is_some_and(|vs| vs.contains_key(&version)) {
            let avail = st.available();
            return Err(RegistryError::Unknown(lane_key, avail));
        }
        if st.default_name == name && st.serving.get(name) == Some(&version) {
            return Err(RegistryError::ServingDefault(lane_key));
        }
        let versions = st.entries.get_mut(name).expect("checked above");
        versions.remove(&version);
        let remaining_highest = versions.keys().next_back().copied();
        if versions.is_empty() {
            st.entries.remove(name);
        }
        // re-point (or drop) the bare-name alias if it tracked this one
        if st.serving.get(name) == Some(&version) {
            match remaining_highest {
                Some(v) => {
                    st.serving.insert(name.to_string(), v);
                }
                None => {
                    st.serving.remove(name);
                }
            }
        }
        self.rebuild_routes(&st);
        drop(st);
        // retire AFTER the snapshot swap: no new resolution reaches the
        // lane, and its executors drain everything already admitted
        self.router
            .remove_lane(&lane_key)
            .map_err(|e| RegistryError::Load(e.to_string()))?;
        self.counters.lock().unwrap().evictions += 1;
        self.journal.log(event::MODEL_RETIRE, &lane_key);
        Ok(lane_key)
    }

    /// Swap the route snapshot.  Runs while `state` is held (rank 10 →
    /// rank 30, ascending — the one admin-side nesting the lock-order
    /// table in [`crate::coordinator`] pins down).
    fn rebuild_routes(&self, st: &State) {
        let mut aliases = HashMap::new();
        for (name, versions) in &st.entries {
            for v in versions.keys() {
                let key = format!("{name}@{v}");
                aliases.insert(key.clone(), key);
            }
            if let Some(v) = st.serving.get(name) {
                aliases.insert(name.clone(), format!("{name}@{v}"));
            }
        }
        let default_key = st
            .serving
            .get(&st.default_name)
            .map(|v| format!("{}@{v}", st.default_name))
            .unwrap_or_default();
        let mut routes = self.routes.write().unwrap();
        let _ord = lockorder::acquired(lockorder::REGISTRY_ROUTES, "registry.routes");
        *routes = Arc::new(RouteTable { aliases, default_key });
        self.route_version.fetch_add(1, Ordering::Relaxed);
    }

    /// The lane key currently serving the empty model reference
    /// (empty when nothing is published).
    pub fn default_key(&self) -> String {
        self.routes.read().unwrap().default_key.clone()
    }

    /// One JSON row per resident entry — identity, serving role, and
    /// its lane's traffic counters (the `list_models` admin op body).
    pub fn list_models(&self) -> Json {
        let st = self.state.lock().unwrap();
        let _ord = lockorder::acquired(lockorder::REGISTRY_STATE, "registry.state");
        let mut rows = Vec::new();
        for (name, versions) in &st.entries {
            for (version, meta) in versions {
                let lane_key = format!("{name}@{version}");
                let mut row = JsonObj::new();
                row.insert("model", Json::from(lane_key.as_str()));
                row.insert("name", Json::from(name.as_str()));
                row.insert("version", Json::from(*version as usize));
                row.insert("kind", Json::from(meta.kind.as_str()));
                row.insert("scheme", Json::from(meta.scheme.as_str()));
                row.insert(
                    "checksum",
                    match meta.checksum {
                        Some(c) => Json::from(format_checksum(c)),
                        None => Json::Null,
                    },
                );
                let serving = st.serving.get(name) == Some(version);
                row.insert("serving", Json::Bool(serving));
                row.insert("default", Json::Bool(st.default_name == *name && serving));
                // the EFFECTIVE batch policy this entry's lane runs with
                // (registry default merged with its manifest overrides)
                let mut batch = JsonObj::new();
                batch.insert("max_images", Json::from(meta.policy.max_batch));
                batch.insert("executors", Json::from(meta.policy.executors));
                row.insert("batch", Json::Obj(batch));
                // static-verification envelope for file-loaded entries
                // (slot counts, interval count, peak arena bytes)
                row.insert(
                    "verify",
                    match &meta.verify {
                        Some(report) => report.to_json(),
                        None => Json::Null,
                    },
                );
                // fusion-rewrite status: the pass list the entry serves
                // with, or `fallback:<err>` when the proof gauntlet
                // refused the rewrite (file loads only)
                row.insert(
                    "rewrite",
                    match &meta.rewrite {
                        Some(status) => Json::from(status.as_str()),
                        None => Json::Null,
                    },
                );
                if let Ok(m) = self.router.metrics(&lane_key) {
                    row.insert("submitted", Json::from(m.submitted() as usize));
                    row.insert("completed", Json::from(m.completed() as usize));
                    row.insert("failed", Json::from(m.failed() as usize));
                    row.insert("rejected", Json::from(m.rejected() as usize));
                }
                // per-plan-step execution profile (p50/p95/share per
                // step, accumulated over every batch the lane has run);
                // Null for backends that don't expose one
                row.insert(
                    "profile",
                    match self.router.lane_backend(&lane_key) {
                        Ok(backend) => backend.profile_json().unwrap_or(Json::Null),
                        Err(_) => Json::Null,
                    },
                );
                rows.push(Json::Obj(row));
            }
        }
        Json::Arr(rows)
    }

    /// Registry lifecycle counters (the `stats` op's `registry`
    /// section and part of every `list_models` reply).
    pub fn counters_json(&self) -> Json {
        let c = self.counters.lock().unwrap();
        let _ord = lockorder::acquired(lockorder::REGISTRY_COUNTERS, "registry.counters");
        let mut obj = JsonObj::new();
        obj.insert("loads", Json::from(c.loads as usize));
        obj.insert("load_failures", Json::from(c.load_failures as usize));
        obj.insert("verify_failures", Json::from(c.verify_failures as usize));
        obj.insert("rewrite_fallbacks", Json::from(c.rewrite_fallbacks as usize));
        obj.insert("swaps", Json::from(c.swaps as usize));
        obj.insert("evictions", Json::from(c.evictions as usize));
        Json::Obj(obj)
    }

    /// Close every lane queue (drains in-flight work; executors exit).
    pub fn shutdown(&self) {
        self.router.shutdown();
    }
}

/// Merge a manifest entry's `"batch"` overrides into the registry's
/// shared policy (absent fields inherit; `max_wait` is never
/// per-model).
fn effective_policy(base: BatchPolicy, over: Option<RegistryBatchSpec>) -> BatchPolicy {
    let mut policy = base;
    if let Some(over) = over {
        if let Some(max_images) = over.max_images {
            policy.max_batch = max_images;
        }
        if let Some(executors) = over.executors {
            policy.executors = executors;
        }
    }
    policy
}

/// Builder for [`ModelRegistry`].
pub struct RegistryBuilder {
    policy: BatchPolicy,
    queue_capacity: usize,
    engine_threads: usize,
    models_dir: Option<PathBuf>,
}

impl RegistryBuilder {
    /// Batch policy shared by every lane the registry spawns
    /// (including `BatchPolicy::executors`, the per-lane worker pool).
    pub fn policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn queue_capacity(mut self, cap: usize) -> Self {
        self.queue_capacity = cap;
        self
    }

    /// Engine worker threads for backends the loader constructs
    /// (`0` = all cores).
    pub fn engine_threads(mut self, threads: usize) -> Self {
        self.engine_threads = threads;
        self
    }

    /// Directory holding `registry.json` + weight containers; enables
    /// the `load_model` admin op (and the background loader thread).
    pub fn models_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.models_dir = Some(dir.into());
        self
    }

    pub fn build(self) -> Arc<ModelRegistry> {
        let threads = match self.engine_threads {
            0 => default_threads(),
            n => n,
        };
        let loader = self.models_dir.map(|dir| loader::Loader::spawn(dir, threads));
        Arc::new(ModelRegistry {
            router: Arc::new(Router::new_dynamic(self.queue_capacity, self.policy)),
            state: Mutex::new(State {
                entries: BTreeMap::new(),
                serving: BTreeMap::new(),
                default_name: String::new(),
            }),
            routes: RwLock::new(Arc::new(RouteTable {
                aliases: HashMap::new(),
                default_key: String::new(),
            })),
            counters: Mutex::new(Counters::default()),
            loader,
            route_version: AtomicU64::new(0),
            journal: Arc::new(Journal::new(Journal::DEFAULT_CAPACITY)),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::network::tests_support::{synth_bcnn_network, synth_bcnn_tf, synth_image};
    use crate::coordinator::EngineBackend;
    use crate::input::binarize::Scheme;

    fn backend(seed: u64) -> Arc<dyn InferBackend> {
        Arc::new(EngineBackend::bcnn(synth_bcnn_network(Scheme::Rgb, seed), 1))
    }

    fn registry() -> Arc<ModelRegistry> {
        ModelRegistry::builder().queue_capacity(64).build()
    }

    #[test]
    fn parse_model_ref_shapes() {
        assert_eq!(parse_model_ref("bcnn").unwrap(), ("bcnn".to_string(), None));
        assert_eq!(parse_model_ref("bcnn@3").unwrap(), ("bcnn".to_string(), Some(3)));
        assert!(parse_model_ref("").is_err());
        assert!(parse_model_ref("a@b").is_err());
        assert!(parse_model_ref("a b").is_err());
    }

    #[test]
    fn publish_resolve_and_default_flow() {
        let r = registry();
        assert!(r.resolve("").is_err(), "empty registry has no default");
        let key = r.publish_backend("bcnn", 1, "bcnn", "rgb", None, backend(1)).unwrap();
        assert_eq!(key, "bcnn@1");
        // "" and "bcnn" and "bcnn@1" all resolve to the first entry
        assert_eq!(r.resolve("").unwrap(), "bcnn@1");
        assert_eq!(r.resolve("bcnn").unwrap(), "bcnn@1");
        assert_eq!(r.resolve("bcnn@1").unwrap(), "bcnn@1");
        assert!(r.resolve("bcnn@2").is_err());

        // a second version is resident but NOT serving until set_default
        r.publish_backend("bcnn", 2, "bcnn", "rgb", None, backend(2)).unwrap();
        assert_eq!(r.resolve("bcnn").unwrap(), "bcnn@1");
        assert_eq!(r.resolve("bcnn@2").unwrap(), "bcnn@2");
        assert_eq!(r.set_default("bcnn", None).unwrap(), "bcnn@2");
        assert_eq!(r.resolve("bcnn").unwrap(), "bcnn@2");
        assert_eq!(r.resolve("").unwrap(), "bcnn@2");
        // explicit version pin rolls back
        assert_eq!(r.set_default("bcnn", Some(1)).unwrap(), "bcnn@1");
        assert_eq!(r.default_key(), "bcnn@1");
        r.shutdown();
    }

    #[test]
    fn pinning_a_secondary_model_does_not_hijack_the_default_route() {
        let r = registry();
        r.publish_backend("bcnn", 1, "bcnn", "rgb", None, backend(20)).unwrap();
        r.publish_backend("float", 1, "bcnn", "rgb", None, backend(21)).unwrap();
        r.publish_backend("float", 2, "bcnn", "rgb", None, backend(22)).unwrap();
        assert_eq!(r.resolve("").unwrap(), "bcnn@1");
        // upgrading float's serving version leaves the default on bcnn
        assert_eq!(r.set_default("float", Some(2)).unwrap(), "float@2");
        assert_eq!(r.resolve("float").unwrap(), "float@2");
        assert_eq!(r.resolve("").unwrap(), "bcnn@1", "default must not move");
        // versionless set_default is the explicit default-model switch
        assert_eq!(r.set_default("float", None).unwrap(), "float@2");
        assert_eq!(r.resolve("").unwrap(), "float@2");
        r.shutdown();
    }

    #[test]
    fn duplicate_and_invalid_publications_refused() {
        let r = registry();
        r.publish_backend("m", 1, "bcnn", "rgb", None, backend(3)).unwrap();
        assert!(matches!(
            r.publish_backend("m", 1, "bcnn", "rgb", None, backend(3)),
            Err(RegistryError::Exists(_))
        ));
        assert!(matches!(
            r.publish_backend("m@x", 1, "bcnn", "rgb", None, backend(3)),
            Err(RegistryError::BadName(_))
        ));
        r.shutdown();
    }

    #[test]
    fn unload_protects_the_serving_default_and_repoints_aliases() {
        let r = registry();
        r.publish_backend("m", 1, "bcnn", "rgb", None, backend(4)).unwrap();
        r.publish_backend("m", 2, "bcnn", "rgb", None, backend(5)).unwrap();
        // v1 serves the default: refuse to unload it
        assert!(matches!(r.unload_model("m", 1), Err(RegistryError::ServingDefault(_))));
        // after the swap, v1 is evictable; the pinned alias dies with it
        r.set_default("m", Some(2)).unwrap();
        assert_eq!(r.unload_model("m", 1).unwrap(), "m@1");
        assert!(r.resolve("m@1").is_err());
        assert_eq!(r.resolve("m").unwrap(), "m@2");
        assert_eq!(r.resolve("").unwrap(), "m@2");
        assert!(matches!(r.unload_model("m", 1), Err(RegistryError::Unknown(..))));
        // the lane is gone from the router too
        assert!(!r.router().has_lane("m@1"));
        assert!(r.router().has_lane("m@2"));
        r.shutdown();
    }

    #[test]
    fn served_requests_flow_through_resolved_lanes() {
        let r = registry();
        r.publish_backend("a", 1, "bcnn", "rgb", None, backend(6)).unwrap();
        r.publish_backend("b", 1, "bcnn", "rgb", None, backend(7)).unwrap();
        let img = synth_image(1);
        let lane_a = r.resolve("a").unwrap();
        let lane_b = r.resolve("b@1").unwrap();
        let ra = r.router().infer_blocking(&lane_a, img.clone()).unwrap();
        let rb = r.router().infer_blocking(&lane_b, img).unwrap();
        assert!(ra.error.is_none() && rb.error.is_none());
        assert_ne!(ra.logits, rb.logits, "distinct weights, distinct lanes");
        r.shutdown();
    }

    #[test]
    fn counters_track_the_lifecycle() {
        let r = registry();
        r.publish_backend("m", 1, "bcnn", "rgb", None, backend(8)).unwrap();
        r.publish_backend("m", 2, "bcnn", "rgb", None, backend(9)).unwrap();
        r.set_default("m", Some(2)).unwrap();
        r.unload_model("m", 1).unwrap();
        let c = r.counters_json();
        assert_eq!(c.get("swaps").unwrap().as_usize().unwrap(), 1);
        assert_eq!(c.get("evictions").unwrap().as_usize().unwrap(), 1);
        // programmatic publications aren't "loads"
        assert_eq!(c.get("loads").unwrap().as_usize().unwrap(), 0);
        r.shutdown();
    }

    #[test]
    fn lifecycle_events_reach_the_journal_and_bump_the_route_version() {
        use crate::util::trace::event;
        let r = registry();
        assert_eq!(r.route_version(), 0);
        r.publish_backend("m", 1, "bcnn", "rgb", None, backend(30)).unwrap();
        r.publish_backend("m", 2, "bcnn", "rgb", None, backend(31)).unwrap();
        let after_publish = r.route_version();
        assert_eq!(after_publish, 2, "one snapshot swap per publication");
        r.set_default("m", Some(2)).unwrap();
        r.unload_model("m", 1).unwrap();
        assert!(r.route_version() > after_publish);
        let j = r.journal().to_json();
        let kinds: Vec<String> = j
            .get("events")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|e| e.get("kind").unwrap().as_str().unwrap().to_string())
            .collect();
        assert_eq!(
            kinds,
            vec![
                event::MODEL_LOAD,
                event::MODEL_LOAD,
                event::ROUTE_SWAP,
                event::MODEL_RETIRE
            ]
        );
        // sequence numbers are monotonic from zero and nothing was evicted
        assert_eq!(j.get("next_seq").unwrap().as_usize().unwrap(), 4);
        assert_eq!(j.get("dropped").unwrap().as_usize().unwrap(), 0);
        r.shutdown();
    }

    #[test]
    fn list_models_carries_a_per_step_profile_after_traffic() {
        let r = registry();
        r.publish_backend("m", 1, "bcnn", "rgb", None, backend(32)).unwrap();
        let lane = r.resolve("m").unwrap();
        // the publish-time smoke inference already primed the profile;
        // a served request adds another sample per step
        assert!(r.router().infer_blocking(&lane, synth_image(13)).unwrap().error.is_none());
        let rows = r.list_models();
        let rows = rows.as_arr().unwrap();
        let profile = rows[0].get("profile").unwrap().as_arr().unwrap();
        assert!(!profile.is_empty(), "engine backends expose a per-step profile");
        let mut share = 0.0;
        for step in profile {
            assert!(step.get("count").unwrap().as_usize().unwrap() >= 1);
            assert!(step.get("p50_us").unwrap().as_f64().unwrap() >= 0.0);
            share += step.get("share").unwrap().as_f64().unwrap();
        }
        assert!((share - 1.0).abs() < 1e-9, "step shares sum to 1, got {share}");
        r.shutdown();
    }

    #[test]
    fn list_models_reports_identity_roles_and_traffic() {
        let r = registry();
        r.publish_backend("m", 1, "bcnn", "rgb", Some(0xabcd), backend(10)).unwrap();
        r.publish_backend("m", 2, "bcnn", "rgb", None, backend(11)).unwrap();
        let lane = r.resolve("m").unwrap();
        assert!(r.router().infer_blocking(&lane, synth_image(2)).unwrap().error.is_none());
        let rows = r.list_models();
        let rows = rows.as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        let v1 = &rows[0];
        assert_eq!(v1.get("model").unwrap().as_str().unwrap(), "m@1");
        assert_eq!(v1.get("scheme").unwrap().as_str().unwrap(), "rgb");
        assert!(v1.get("serving").unwrap().as_bool().unwrap());
        assert!(v1.get("default").unwrap().as_bool().unwrap());
        assert_eq!(
            v1.get("checksum").unwrap().as_str().unwrap(),
            "fnv1a64:000000000000abcd"
        );
        assert_eq!(v1.get("completed").unwrap().as_usize().unwrap(), 1);
        // programmatic publications hand over an opaque backend — no
        // plan, so no verification envelope
        assert_eq!(v1.get("verify").unwrap(), &Json::Null);
        let v2 = &rows[1];
        assert!(!v2.get("serving").unwrap().as_bool().unwrap());
        assert_eq!(v2.get("checksum").unwrap(), &Json::Null);
        assert_eq!(v2.get("completed").unwrap().as_usize().unwrap(), 0);
        r.shutdown();
    }

    // -- directory/loader path ---------------------------------------------

    fn write_models_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("bcnn-registry-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let tf1 = synth_bcnn_tf(Scheme::Rgb, 100);
        tf1.save(dir.join("m_v1.bcnt")).unwrap();
        let tf2 = synth_bcnn_tf(Scheme::Gray, 200);
        tf2.save(dir.join("m_v2.bcnt")).unwrap();
        let sum = |f: &str| {
            format_checksum(fnv1a64(&std::fs::read(dir.join(f)).unwrap()))
        };
        let manifest = format!(
            r#"{{"version": 1, "default": "m", "models": [
  {{"name": "m", "version": 1, "kind": "bcnn", "scheme": "rgb",
    "weights_file": "m_v1.bcnt", "checksum": "{}"}},
  {{"name": "m", "version": 2, "kind": "bcnn", "scheme": "gray",
    "weights_file": "m_v2.bcnt", "checksum": "{}"}},
  {{"name": "corrupt", "version": 1, "kind": "bcnn", "scheme": "rgb",
    "weights_file": "m_v1.bcnt", "checksum": "fnv1a64:0000000000000000"}},
  {{"name": "mismatched", "version": 1, "kind": "bcnn", "scheme": "gray",
    "weights_file": "m_v1.bcnt", "checksum": "{}"}}
]}}"#,
            sum("m_v1.bcnt"),
            sum("m_v2.bcnt"),
            sum("m_v1.bcnt"),
        );
        std::fs::write(dir.join("registry.json"), manifest).unwrap();
        dir
    }

    #[test]
    fn load_model_from_dir_validates_and_publishes() {
        let dir = write_models_dir("load");
        let r = ModelRegistry::builder()
            .queue_capacity(64)
            .engine_threads(1)
            .models_dir(&dir)
            .build();
        assert_eq!(r.load_model("m", 1).unwrap(), "m@1");
        assert_eq!(r.load_model("m", 2).unwrap(), "m@2");
        // per-scheme metadata came from the manifest
        let rows = r.list_models();
        let rows = rows.as_arr().unwrap();
        assert_eq!(rows[0].get("scheme").unwrap().as_str().unwrap(), "rgb");
        assert_eq!(rows[1].get("scheme").unwrap().as_str().unwrap(), "gray");
        // both servable immediately
        for model in ["m@1", "m@2"] {
            let lane = r.resolve(model).unwrap();
            assert!(r.router().infer_blocking(&lane, synth_image(3)).unwrap().error.is_none());
        }
        // duplicates and unknown entries refuse cleanly
        assert!(matches!(r.load_model("m", 1), Err(RegistryError::Exists(_))));
        assert!(matches!(r.load_model("ghost", 1), Err(RegistryError::Load(_))));
        let c = r.counters_json();
        assert_eq!(c.get("loads").unwrap().as_usize().unwrap(), 2);
        assert_eq!(c.get("load_failures").unwrap().as_usize().unwrap(), 1);
        r.shutdown();
    }

    #[test]
    fn checksum_mismatch_and_scheme_mismatch_refuse_publication() {
        let dir = write_models_dir("corrupt");
        let r = ModelRegistry::builder()
            .queue_capacity(64)
            .engine_threads(1)
            .models_dir(&dir)
            .build();
        // declared checksum doesn't match the file bytes
        let err = r.load_model("corrupt", 1).unwrap_err();
        assert!(err.to_string().contains("checksum mismatch"), "{err}");
        // right bytes, wrong scheme: the shape check catches it before
        // publication (a gray network can't be built from rgb weights)
        let err = r.load_model("mismatched", 1).unwrap_err();
        assert!(matches!(err, RegistryError::Load(_)), "{err}");
        assert!(r.resolve("corrupt").is_err() && r.resolve("mismatched").is_err());
        assert_eq!(
            r.counters_json().get("load_failures").unwrap().as_usize().unwrap(),
            2
        );
        r.shutdown();
    }

    #[test]
    fn a_corrupted_plan_is_refused_before_publication() {
        // the loader's test-only fault hook corrupts one named model's
        // plan AFTER compilation — exactly the class of data bug a
        // hand-edited or rewritten plan could carry — and the verifier
        // must refuse it before it ever serves
        let dir = std::env::temp_dir()
            .join(format!("bcnn-registry-verify-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let tf = synth_bcnn_tf(Scheme::Rgb, 300);
        tf.save(dir.join("mutant.bcnt")).unwrap();
        let sum = format_checksum(fnv1a64(&std::fs::read(dir.join("mutant.bcnt")).unwrap()));
        let manifest = format!(
            r#"{{"models": [
  {{"name": "mutant", "version": 1, "kind": "bcnn", "scheme": "rgb",
    "weights_file": "mutant.bcnt", "checksum": "{sum}"}}
]}}"#
        );
        std::fs::write(dir.join("registry.json"), manifest).unwrap();
        let r = ModelRegistry::builder()
            .queue_capacity(64)
            .engine_threads(1)
            .models_dir(&dir)
            .build();
        let env = corrupt_env_guard();
        std::env::set_var("BCNN_TEST_CORRUPT_PLAN", "mutant:slot-merge");
        let err = r.load_model("mutant", 1).unwrap_err();
        std::env::remove_var("BCNN_TEST_CORRUPT_PLAN");
        drop(env);
        assert!(matches!(err, RegistryError::Verify(_)), "{err}");
        assert!(err.to_string().contains("aliased"), "{err}");
        assert!(r.resolve("mutant").is_err(), "refused entries must never serve");
        let c = r.counters_json();
        assert_eq!(c.get("verify_failures").unwrap().as_usize().unwrap(), 1);
        assert_eq!(c.get("load_failures").unwrap().as_usize().unwrap(), 1);
        // with the hook cleared the same artifact verifies clean and
        // publishes, carrying its report into list_models
        r.load_model("mutant", 1).unwrap();
        let rows = r.list_models();
        let rows = rows.as_arr().unwrap();
        let report = rows[0].get("verify").unwrap();
        assert!(report.get("steps").unwrap().as_usize().unwrap() > 0);
        assert!(report.get("intervals").unwrap().as_usize().unwrap() > 0);
        r.shutdown();
    }

    #[test]
    fn a_refused_rewrite_falls_back_to_the_unoptimized_plan() {
        // seed an unsound "optimizer" output via the loader's rewrite
        // fault hook: the equivalence checker must refuse it, but unlike
        // a corrupted plan this is NOT a load failure — the entry
        // publishes with the already-verified unoptimized plan, the
        // fallback is counted, and the lane serves requests end to end
        let dir = std::env::temp_dir()
            .join(format!("bcnn-registry-rwfall-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let tf = synth_bcnn_tf(Scheme::Rgb, 500);
        tf.save(dir.join("optim.bcnt")).unwrap();
        let sum = format_checksum(fnv1a64(&std::fs::read(dir.join("optim.bcnt")).unwrap()));
        let manifest = format!(
            r#"{{"models": [
  {{"name": "optim", "version": 1, "kind": "bcnn", "scheme": "rgb",
    "weights_file": "optim.bcnt", "checksum": "{sum}"}},
  {{"name": "optim", "version": 2, "kind": "bcnn", "scheme": "rgb",
    "weights_file": "optim.bcnt", "checksum": "{sum}"}}
]}}"#
        );
        std::fs::write(dir.join("registry.json"), manifest).unwrap();
        let r = ModelRegistry::builder()
            .queue_capacity(64)
            .engine_threads(1)
            .models_dir(&dir)
            .build();
        let env = corrupt_env_guard();
        std::env::set_var(
            "BCNN_TEST_CORRUPT_REWRITE",
            "optim:epilogue-threshold-off-by-one",
        );
        let key = r.load_model("optim", 1).unwrap();
        std::env::remove_var("BCNN_TEST_CORRUPT_REWRITE");
        drop(env);
        assert_eq!(key, "optim@1");
        let c = r.counters_json();
        assert_eq!(c.get("rewrite_fallbacks").unwrap().as_usize().unwrap(), 1);
        assert_eq!(c.get("load_failures").unwrap().as_usize().unwrap(), 0);
        assert_eq!(c.get("verify_failures").unwrap().as_usize().unwrap(), 0);
        let rows = r.list_models();
        let rows = rows.as_arr().unwrap();
        let status = rows[0].get("rewrite").unwrap().as_str().unwrap();
        assert!(status.starts_with("fallback:equiv:"), "{status}");
        assert!(status.contains("cmp_bias"), "{status}");
        // the fallback entry serves the unoptimized (but verified) plan
        let lane = r.resolve("optim").unwrap();
        for _ in 0..4 {
            assert!(r.router().infer_blocking(&lane, synth_image(5)).unwrap().error.is_none());
        }
        // with the hook cleared the same artifact rewrites clean: the
        // full pass list is reported, the envelope prices the rewritten
        // (shorter) plan, and the fallback counter does not move
        r.load_model("optim", 2).unwrap();
        let rows = r.list_models();
        let rows = rows.as_arr().unwrap();
        let clean = rows[1].get("rewrite").unwrap().as_str().unwrap();
        assert_eq!(clean, "fold-threshold+fuse-pack+elide-counts");
        let fb = rows[0].get("verify").unwrap().get("steps").unwrap().as_usize().unwrap();
        let rw = rows[1].get("verify").unwrap().get("steps").unwrap().as_usize().unwrap();
        assert!(rw < fb, "rewritten plan must have fewer steps ({rw} vs {fb})");
        let lane = r.resolve("optim@2").unwrap();
        assert!(r.router().infer_blocking(&lane, synth_image(6)).unwrap().error.is_none());
        assert_eq!(
            r.counters_json().get("rewrite_fallbacks").unwrap().as_usize().unwrap(),
            1
        );
        r.shutdown();
    }

    #[test]
    fn load_model_without_dir_is_a_structured_error() {
        let r = registry();
        assert!(matches!(r.load_model("m", 1), Err(RegistryError::NoModelsDir)));
    }

    #[test]
    fn per_model_batch_overrides_reach_the_lane_and_list_models() {
        // one entry overrides the batcher depth + executor pool; its
        // sibling inherits the registry default — both visible in
        // list_models and in the actually-spawned executor count
        let dir = std::env::temp_dir()
            .join(format!("bcnn-registry-batch-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let tf = synth_bcnn_tf(Scheme::Rgb, 400);
        tf.save(dir.join("m.bcnt")).unwrap();
        let sum = format_checksum(fnv1a64(&std::fs::read(dir.join("m.bcnt")).unwrap()));
        let manifest = format!(
            r#"{{"models": [
  {{"name": "hot", "version": 1, "kind": "bcnn", "scheme": "rgb",
    "weights_file": "m.bcnt", "checksum": "{sum}",
    "batch": {{"max_images": 8, "executors": 3}}}},
  {{"name": "plain", "version": 1, "kind": "bcnn", "scheme": "rgb",
    "weights_file": "m.bcnt", "checksum": "{sum}"}}
]}}"#
        );
        std::fs::write(dir.join("registry.json"), manifest).unwrap();
        let r = ModelRegistry::builder()
            .policy(BatchPolicy { max_batch: 2, executors: 1, ..BatchPolicy::default() })
            .queue_capacity(64)
            .engine_threads(1)
            .models_dir(&dir)
            .build();
        r.load_model("hot", 1).unwrap();
        r.load_model("plain", 1).unwrap();
        let rows = r.list_models();
        let rows = rows.as_arr().unwrap();
        let batch_of = |i: usize| rows[i].get("batch").unwrap().clone();
        // rows are name-sorted: hot@1 then plain@1
        assert_eq!(rows[0].get("model").unwrap().as_str().unwrap(), "hot@1");
        assert_eq!(batch_of(0).get("max_images").unwrap().as_usize().unwrap(), 8);
        assert_eq!(batch_of(0).get("executors").unwrap().as_usize().unwrap(), 3);
        assert_eq!(batch_of(1).get("max_images").unwrap().as_usize().unwrap(), 2);
        assert_eq!(batch_of(1).get("executors").unwrap().as_usize().unwrap(), 1);
        // the override actually spawned that many executors
        assert_eq!(r.router().lane_executors("hot@1").unwrap(), 3);
        assert_eq!(r.router().lane_executors("plain@1").unwrap(), 1);
        // and the overridden lane still serves correctly
        let lane = r.resolve("hot").unwrap();
        assert!(r.router().infer_blocking(&lane, synth_image(9)).unwrap().error.is_none());
        r.shutdown();
    }

    #[test]
    fn malformed_branch_archs_refuse_at_manifest_load() {
        // the third negative layer (after from_json parse and plan
        // compile unit tests): each malformed branch topology declared
        // in a registry.json `arch` must surface as a structured
        // RegistryError::Load from the loader thread — never a publish,
        // never a panic.  The shared weight file is a valid container;
        // every refusal here is the GRAPH's.
        use crate::bnn::network::tests_support::synth_tf_for_spec;
        use crate::bnn::graph::NetworkSpec;
        use crate::util::tensorio::Tensor;

        let dir = std::env::temp_dir()
            .join(format!("bcnn-registry-badarch-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // a compiling six-class split/scale/concat spec donates the
        // container all entries share (the bad archs never reach binding)
        let good_arch = r#"[
            {"op": "conv_float", "k": 5, "out": 8, "relu": true},
            {"op": "split", "parts": [3, 5]},
            {"op": "scale"},
            {"op": "concat", "with": [1, 1]},
            {"op": "maxpool"},
            {"op": "fc_float", "out": 6}
        ]"#;
        let spec = NetworkSpec::from_json(&Json::parse(good_arch).unwrap()).unwrap();
        synth_tf_for_spec(&spec, 710).save(dir.join("w.bcnt")).unwrap();
        let sum = format_checksum(fnv1a64(&std::fs::read(dir.join("w.bcnt")).unwrap()));
        // the same container with alpha1 truncated to 4 channels (the
        // scale op's input has 3): shape-checked binding must refuse it
        let mut lying = synth_tf_for_spec(&spec, 710);
        lying.insert("alpha1", Tensor::from_f32(vec![4], &[1.0, 1.0, 1.0, 1.0]));
        lying.save(dir.join("lying.bcnt")).unwrap();
        let lying_sum =
            format_checksum(fnv1a64(&std::fs::read(dir.join("lying.bcnt")).unwrap()));
        let cases: Vec<(&str, &str, &str, &str)> = vec![
            (
                "dangling",
                r#"[{"op": "conv_float", "k": 5, "out": 8},
                    {"op": "split", "parts": [4, 4]},
                    {"op": "maxpool"},
                    {"op": "fc_float", "out": 4}]"#,
                "w.bcnt",
                "dangling split output",
            ),
            (
                "addmismatch",
                r#"[{"op": "conv_float", "k": 5, "out": 8},
                    {"op": "conv_float", "k": 1, "out": 4},
                    {"op": "add", "with": 0},
                    {"op": "maxpool"},
                    {"op": "fc_float", "out": 4}]"#,
                "w.bcnt",
                "add operands must match",
            ),
            (
                "dtypemix",
                r#"[{"op": "binarize", "scheme": "rgb"},
                    {"op": "conv_bin", "k": 5, "out": 32},
                    {"op": "scale"},
                    {"op": "concat", "with": 1},
                    {"op": "maxpool"},
                    {"op": "fc_float", "out": 4}]"#,
                "w.bcnt",
                "share a value domain",
            ),
            (
                "cyclic",
                r#"[{"op": "conv_float", "k": 5, "out": 8},
                    {"op": "add", "with": 1},
                    {"op": "maxpool"},
                    {"op": "fc_float", "out": 4}]"#,
                "w.bcnt",
                "cyclic reference",
            ),
            ("badalpha", good_arch, "lying.bcnt", "alpha1"),
        ];
        let mut manifest = String::from(r#"{"models": ["#);
        for (i, (name, arch, file, _)) in cases.iter().enumerate() {
            let sum = if *file == "lying.bcnt" { &lying_sum } else { &sum };
            if i > 0 {
                manifest.push(',');
            }
            manifest.push_str(&format!(
                r#"{{"name": "{name}", "version": 1, "kind": "float", "scheme": "none",
                    "weights_file": "{file}", "checksum": "{sum}", "arch": {arch}}}"#
            ));
        }
        manifest.push_str("]}");
        std::fs::write(dir.join("registry.json"), manifest).unwrap();
        let r = ModelRegistry::builder()
            .queue_capacity(64)
            .engine_threads(1)
            .models_dir(&dir)
            .build();
        for (name, _, _, needle) in &cases {
            let err = r.load_model(name, 1).unwrap_err();
            assert!(matches!(err, RegistryError::Load(_)), "{name}: {err}");
            assert!(err.to_string().contains(needle), "{name}: {err}");
            assert!(r.resolve(name).is_err(), "{name} must never publish");
        }
        assert_eq!(
            r.counters_json().get("load_failures").unwrap().as_usize().unwrap(),
            cases.len()
        );
        r.shutdown();
    }

    #[test]
    fn a_branch_corruption_is_refused_through_the_loader_hook() {
        // the branch-shaped mutation classes bite end to end: a
        // manifest-declared residual arch whose compiled plan is
        // corrupted by the loader's fault hook (the skip edge's slot
        // reused before its second reader) must be refused by the
        // verifier as a RegistryError::Verify, and load clean once the
        // hook is cleared.
        use crate::bnn::network::tests_support::synth_tf_for_spec;
        use crate::bnn::graph::NetworkSpec;

        let arch = r#"[
            {"op": "binarize", "scheme": "rgb"},
            {"op": "conv_bin", "k": 5, "out": 32},
            {"op": "threshold"},
            {"op": "conv_bin", "k": 1, "out": 32},
            {"op": "add", "with": 1},
            {"op": "scale"},
            {"op": "maxpool"},
            {"op": "fc_float", "out": 4}
        ]"#;
        let spec = NetworkSpec::from_json(&Json::parse(arch).unwrap()).unwrap();
        let dir = std::env::temp_dir()
            .join(format!("bcnn-registry-branchmut-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        synth_tf_for_spec(&spec, 720).save(dir.join("resid.bcnt")).unwrap();
        let sum = format_checksum(fnv1a64(&std::fs::read(dir.join("resid.bcnt")).unwrap()));
        let manifest = format!(
            r#"{{"models": [
  {{"name": "resid", "version": 1, "kind": "bcnn", "scheme": "rgb",
    "weights_file": "resid.bcnt", "checksum": "{sum}", "arch": {arch}}}
]}}"#
        );
        std::fs::write(dir.join("registry.json"), manifest).unwrap();
        let r = ModelRegistry::builder()
            .queue_capacity(64)
            .engine_threads(1)
            .models_dir(&dir)
            .build();
        let env = corrupt_env_guard();
        std::env::set_var(
            "BCNN_TEST_CORRUPT_PLAN",
            "resid:skip-edge-clobbered-before-second-reader",
        );
        let err = r.load_model("resid", 1).unwrap_err();
        std::env::remove_var("BCNN_TEST_CORRUPT_PLAN");
        drop(env);
        assert!(matches!(err, RegistryError::Verify(_)), "{err}");
        assert!(r.resolve("resid").is_err(), "refused entries must never serve");
        assert_eq!(
            r.counters_json().get("verify_failures").unwrap().as_usize().unwrap(),
            1
        );
        // hook cleared: the same artifact verifies and serves
        r.load_model("resid", 1).unwrap();
        let lane = r.resolve("resid").unwrap();
        assert!(r.router().infer_blocking(&lane, synth_image(11)).unwrap().error.is_none());
        r.shutdown();
    }
}
