//! Background artifact loader: the registry's parse/validate pipeline,
//! run on a dedicated thread so weight-file IO and parsing never block
//! a serving or session thread.
//!
//! Every load runs the same gauntlet before an entry may be published:
//!
//! 1. manifest lookup (`registry.json`, re-read per load so entries
//!    dropped into the directory while the server runs are visible);
//! 2. FNV-1a 64 checksum over the raw weight-file bytes against the
//!    manifest's `fnv1a64:<hex>` declaration;
//! 3. graph-plan compilation (the manifest's `arch` or the synthesized
//!    legacy topology) + tensor-container parse;
//! 4. static plan verification ([`verify_plan`]): aliasing soundness of
//!    the scratch coloring, dataflow well-formedness, slot dtype/extent
//!    domination, and weight-binding totality are proven on the
//!    compiled plan *before* any weight is bound — a refusal here is
//!    [`RegistryError::Verify`], counted in `registry.verify_failures`;
//! 5. proof-carrying fusion rewrite: the optimizer
//!    ([`rewrite_plan`]) fuses thresholds into conv/FC epilogues,
//!    binarization into the patch gather, and elides the i32 counts
//!    buffer — then its output must survive
//!    [`check_equiv`](crate::bnn::graph::check_equiv) (the rewritten
//!    plan provably computes the same logit terms) and a fresh
//!    [`verify_plan`].  A refusal here is NOT fatal: the entry falls
//!    back to the unoptimized (already-verified) plan, the fallback is
//!    counted in `registry.rewrite_fallbacks`, and `list_models`
//!    reports `fallback:<err>` for the entry;
//! 6. weight binding (shape-checked by the plan) + smoke inference: one
//!    deterministic synthetic image must produce the plan's declared
//!    logit count, all finite.
//!
//! A failure at any other stage is a structured
//! [`RegistryError::Load`]; the registry never publishes a backend that
//! did not pass the gauntlet.

use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc};

use crate::bnn::graph::{
    check_equiv, pass_names, rewrite_plan, verify_plan, CompiledNetwork, NetworkSpec, Plan,
    RewritePass, VerifyReport,
};
use crate::coordinator::{EngineBackend, InferBackend};
use crate::dataset::synth;
use crate::input::binarize::Scheme;
use crate::runtime::{RegistryBatchSpec, RegistryManifest};
use crate::util::tensorio::TensorFile;

use super::RegistryError;

/// FNV-1a 64-bit hash — the registry's artifact checksum.  Chosen for
/// being dependency-free and fast over multi-megabyte weight files; it
/// guards against truncation, corruption, and copy-paste mixups, not
/// adversaries.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// Render a checksum the way the manifest declares it.
pub fn format_checksum(sum: u64) -> String {
    format!("fnv1a64:{sum:016x}")
}

/// Parse a manifest checksum declaration (`fnv1a64:<hex>`).
pub fn parse_checksum(s: &str) -> Result<u64, RegistryError> {
    let hex = s.strip_prefix("fnv1a64:").ok_or_else(|| {
        RegistryError::Load(format!("checksum {s:?} must start with \"fnv1a64:\""))
    })?;
    u64::from_str_radix(hex, 16)
        .map_err(|e| RegistryError::Load(format!("checksum {s:?}: {e}")))
}

/// A fully-validated model, ready for publication.
pub(crate) struct Loaded {
    pub kind: String,
    pub scheme: String,
    pub checksum: u64,
    pub backend: Arc<dyn InferBackend>,
    /// Per-model batch-policy overrides from the manifest entry.
    pub batch: Option<RegistryBatchSpec>,
    /// Static-verification envelope for the plan actually bound (the
    /// rewritten plan when the proof gauntlet accepted it, otherwise
    /// the original), surfaced per-entry by `list_models`.
    pub report: VerifyReport,
    /// Rewrite status for `list_models`: the enabled pass list
    /// (`"fold-threshold+fuse-pack+elide-counts"`) or `fallback:<err>`
    /// when the proof gauntlet refused the rewrite.
    pub rewrite: String,
    /// True when the rewrite was refused and the unoptimized plan
    /// serves (counted in `registry.rewrite_fallbacks`).
    pub rewrite_fallback: bool,
}

struct Job {
    name: String,
    version: u32,
    reply: mpsc::Sender<Result<Loaded, RegistryError>>,
}

/// Handle to the background loader thread.
pub(crate) struct Loader {
    /// `Some` for the loader's lifetime; dropped first in `drop` so the
    /// thread's `recv` loop ends before the join.
    tx: Option<mpsc::Sender<Job>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Loader {
    pub fn spawn(dir: PathBuf, engine_threads: usize) -> Self {
        let (tx, rx) = mpsc::channel::<Job>();
        let handle = std::thread::Builder::new()
            .name("model-loader".into())
            .spawn(move || {
                while let Ok(job) = rx.recv() {
                    let result = load_entry(&dir, &job.name, job.version, engine_threads);
                    let _ = job.reply.send(result);
                }
            })
            .expect("spawn model loader");
        Self { tx: Some(tx), handle: Some(handle) }
    }

    /// Run one load on the loader thread and wait for the outcome.  The
    /// calling (admin session) thread blocks; serving lanes never do.
    pub fn load(&self, name: &str, version: u32) -> Result<Loaded, RegistryError> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .as_ref()
            .ok_or(RegistryError::LoaderGone)?
            .send(Job { name: name.to_string(), version, reply })
            .map_err(|_| RegistryError::LoaderGone)?;
        rx.recv().map_err(|_| RegistryError::LoaderGone)?
    }
}

impl Drop for Loader {
    fn drop(&mut self) {
        self.tx = None; // close the channel; the thread drains and exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn load_err(e: impl std::fmt::Display) -> RegistryError {
    RegistryError::Load(e.to_string())
}

/// The full validation pipeline for one manifest entry (see the module
/// docs for the stages).
fn load_entry(
    dir: &Path,
    name: &str,
    version: u32,
    threads: usize,
) -> Result<Loaded, RegistryError> {
    let manifest = RegistryManifest::load(dir).map_err(load_err)?;
    let spec = manifest.entry(name, version).map_err(load_err)?.clone();
    let path = manifest.path_of(&spec.weights_file);
    let bytes =
        std::fs::read(&path).map_err(|e| RegistryError::Load(format!("{}: {e}", path.display())))?;
    let want = parse_checksum(&spec.checksum)?;
    let got = fnv1a64(&bytes);
    if got != want {
        return Err(RegistryError::Load(format!(
            "checksum mismatch for {}: manifest {}, file {}",
            spec.weights_file,
            format_checksum(want),
            format_checksum(got)
        )));
    }
    let tf = TensorFile::load(&path).map_err(load_err)?;
    // the graph spec: manifest-declared `arch`, or the synthesized
    // legacy topology for the entry's kind/scheme.  Compilation (shape
    // inference + liveness planning) and weight binding both happen
    // here, on the loader thread — serving threads only ever see the
    // finished CompiledNetwork.
    let graph_spec = match &spec.arch {
        Some(arch) => NetworkSpec::from_json(arch).map_err(load_err)?,
        None => match spec.kind.as_str() {
            "float" => NetworkSpec::legacy_float(),
            "bcnn" => {
                let scheme = Scheme::parse(&spec.scheme).ok_or_else(|| {
                    RegistryError::Load(format!(
                        "unknown scheme {:?} (none|rgb|gray|lbp)",
                        spec.scheme
                    ))
                })?;
                NetworkSpec::legacy_bcnn(scheme)
            }
            other => {
                return Err(RegistryError::Load(format!(
                    "unknown kind {other:?} (bcnn|float; or declare an \"arch\")"
                )))
            }
        },
    };
    let plan = graph_spec.plan().map_err(load_err)?;
    let plan = corrupt_plan_from_env(name, plan);
    // stage 4: the verifier independently re-proves what the compiler
    // constructed — scratch aliasing, dataflow, extents, weight
    // declarations — so a wrong plan is refused before it binds weights
    // or serves a single request
    let report =
        verify_plan(&plan).map_err(|e| RegistryError::Verify(format!("{name}@{version}: {e}")))?;
    // stage 5: the fusion optimizer's output is never trusted.  The
    // equivalence checker must prove the rewritten plan emits the same
    // logit terms as the verified original, and the verifier must
    // re-prove the rewritten plan's soundness on its own.  Either
    // refusal falls back to the unoptimized plan — slower, but proven —
    // and is surfaced via `rewrite_fallbacks` / `list_models`.
    let rewritten = corrupt_rewrite_from_env(name, rewrite_plan(&plan, &RewritePass::ALL));
    let (plan, report, rewrite, rewrite_fallback) = match check_equiv(&plan, &rewritten)
        .map_err(|e| format!("equiv: {e}"))
        .and_then(|_| verify_plan(&rewritten).map_err(|e| format!("verify: {e}")))
    {
        Ok(rw_report) => (rewritten, rw_report, pass_names(&RewritePass::ALL), false),
        Err(e) => (plan, report, format!("fallback:{e}"), true),
    };
    let compiled = CompiledNetwork::from_plan(plan, &tf).map_err(load_err)?;
    let classes = compiled.num_classes();
    let label = match spec.kind.as_str() {
        "float" => "engine/float".to_string(),
        kind => format!("engine/{kind}_{}", spec.scheme),
    };
    let backend: Arc<dyn InferBackend> =
        Arc::new(EngineBackend::compiled(compiled, threads, label));
    smoke_test(&*backend, classes)?;
    Ok(Loaded {
        kind: spec.kind,
        scheme: spec.scheme,
        checksum: got,
        backend,
        batch: spec.batch,
        report,
        rewrite,
        rewrite_fallback,
    })
}

/// Test-only fault injection: when `BCNN_TEST_CORRUPT_PLAN` is set to
/// `"<model-name>:<corruption-name>"` and `name` matches, the named
/// [`Corruption`](crate::bnn::graph::Corruption) is applied to the
/// freshly-compiled plan.  This is how the e2e suite proves the
/// verification stage actually gates publication — the compiler alone
/// cannot emit an unsound plan, so the corruption has to be injected
/// between compilation and verification, exactly where a future rewrite
/// pass would sit.  Scoped by model name so concurrent tests (and every
/// production load) are untouched.
fn corrupt_plan_from_env(name: &str, plan: Plan) -> Plan {
    if let Ok(spec) = std::env::var("BCNN_TEST_CORRUPT_PLAN") {
        if let Some((model, corruption)) = spec.split_once(':') {
            if model == name {
                if let Some(c) = crate::bnn::graph::Corruption::parse(corruption) {
                    return plan.corrupt_for_test(c);
                }
            }
        }
    }
    plan
}

/// Test-only fault injection for the REWRITE stage: when
/// `BCNN_TEST_CORRUPT_REWRITE` is set to `"<model-name>:<corruption>"`
/// and `name` matches, the named corruption is applied to the
/// freshly-REWRITTEN plan — simulating an unsound optimizer pass.  The
/// e2e suite uses this to prove the equivalence gauntlet actually gates
/// fused plans: the sound rewriter cannot emit the unsound shapes the
/// checker exists to refuse, so they have to be injected between
/// rewrite and `check_equiv`.  Scoped by model name, like
/// `corrupt_plan_from_env`.
/// Serializes tests that arm the env-var fault hooks above: env vars
/// are process-global, so two parallel tests setting
/// `BCNN_TEST_CORRUPT_REWRITE` would clobber each other's spec mid-load.
/// Hold the guard across set_var..remove_var.
#[cfg(test)]
pub(crate) fn corrupt_env_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

fn corrupt_rewrite_from_env(name: &str, plan: Plan) -> Plan {
    if let Ok(spec) = std::env::var("BCNN_TEST_CORRUPT_REWRITE") {
        if let Some((model, corruption)) = spec.split_once(':') {
            if model == name {
                if let Some(c) = crate::bnn::graph::Corruption::parse(corruption) {
                    return plan.corrupt_for_test(c);
                }
            }
        }
    }
    plan
}

/// One deterministic synthetic image through a freshly-built backend:
/// publication is refused unless it answers the PLAN's declared logit
/// count, all finite (for file loads `classes` comes from the compiled
/// plan — graphs declare their own head width, so a six-class manifest
/// must answer six logits here, not the legacy four).  Catches
/// weight/scheme mismatches and poisoned containers before any client
/// request can reach them.
pub(crate) fn smoke_test(backend: &dyn InferBackend, classes: usize) -> Result<(), RegistryError> {
    let img = synth::render_vehicle(0, synth::DEFAULT_SEED).image;
    let logits = backend
        .infer_batch(&img)
        .map_err(|e| RegistryError::Load(format!("smoke inference failed: {e}")))?;
    if logits.len() != classes || logits.iter().any(|v| !v.is_finite()) {
        return Err(RegistryError::Load(format!(
            "smoke inference produced {} logits (want {classes}, all finite)",
            logits.len()
        )));
    }
    Ok(())
}

/// Smoke gate for programmatic publishes ([`publish_backend`] hands us
/// an opaque backend with no plan in hand): the backend must answer one
/// image with a non-empty, all-finite logit row of ANY width — the
/// served head width is whatever the backend's model declares.
///
/// [`publish_backend`]: crate::registry::ModelRegistry::publish_backend
pub(crate) fn smoke_test_any_width(backend: &dyn InferBackend) -> Result<(), RegistryError> {
    let img = synth::render_vehicle(0, synth::DEFAULT_SEED).image;
    let logits = backend
        .infer_batch(&img)
        .map_err(|e| RegistryError::Load(format!("smoke inference failed: {e}")))?;
    if logits.is_empty() || logits.iter().any(|v| !v.is_finite()) {
        return Err(RegistryError::Load(format!(
            "smoke inference produced {} logits (want a non-empty, all-finite row)",
            logits.len()
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a64_reference_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn checksum_roundtrip_and_rejects() {
        let sum = fnv1a64(b"weights");
        assert_eq!(parse_checksum(&format_checksum(sum)).unwrap(), sum);
        assert!(parse_checksum("crc32:abcd").is_err());
        assert!(parse_checksum("fnv1a64:not-hex").is_err());
    }
}
