//! Runtime microkernel dispatch — which XNOR-popcount kernel serves
//! this process.
//!
//! The paper wins its 7.4× inside the bit-GEMM kernel; on CPU the same
//! headroom splits across three tiers above the seed scalar walk:
//! register tiling (amortize weight-row streaming), SWAR Harley–Seal
//! carry-save popcounts (retire ~1 `count_ones` per 8 lanes), and
//! `std::arch` SIMD popcounts (AVX2 lookup / NEON `vcntq_u8`).  All
//! tiers are bit-identical by construction — popcount sums are exact
//! integers, so grouping and accumulation order cannot change a single
//! output — which is what lets a *runtime* choice live safely under the
//! proof-carrying plan machinery: the verifier/equiv stack never sees
//! the kernel, only its (identical) results.
//!
//! Selection order: the `BCNN_KERNEL` env override when set to an
//! available kernel, else the best detected kernel for this CPU
//! (`avx2` on x86_64 with AVX2, `neon` on aarch64, else `tiled`).  An
//! unknown or unavailable override falls back to detection rather than
//! failing: the serving plane must come up, and the fallback is
//! observable — `stats`, `list_models`, the `bcnn_kernel_dispatch`
//! metric family, and the startup journal event all report the kernel
//! actually chosen, not the one asked for.
//!
//! The override is read per call (like the `BCNN_TEST_CORRUPT_PLAN`
//! loader hook) so the forced-dispatch test suites can steer every path
//! without process restarts; feature detection itself is cached by
//! `std`.

/// Env var naming the kernel to force: `scalar|tiled|swar|avx2|neon`.
pub const KERNEL_ENV: &str = "BCNN_KERNEL";

/// The XNOR-popcount microkernel families ([`crate::bnn::microkernel`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Seed scalar kernel: one A-row × one W-row, `count_ones` per lane.
    Scalar,
    /// MR=4 register-tiled scalar: each weight row streamed once per
    /// four patch rows.
    Tiled,
    /// Harley–Seal carry-save popcount (SWAR) over the tiled loop.
    Swar,
    /// AVX2 lookup popcount (`_mm256_shuffle_epi8` nibble LUT), x86_64.
    Avx2,
    /// NEON byte popcount (`vcntq_u8`), aarch64.
    Neon,
}

impl KernelKind {
    /// Every kind, in detection-preference order (best first).
    pub const ALL: [KernelKind; 5] = [
        KernelKind::Avx2,
        KernelKind::Neon,
        KernelKind::Swar,
        KernelKind::Tiled,
        KernelKind::Scalar,
    ];

    /// The wire/env name (`scalar|tiled|swar|avx2|neon`).
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Tiled => "tiled",
            KernelKind::Swar => "swar",
            KernelKind::Avx2 => "avx2",
            KernelKind::Neon => "neon",
        }
    }

    /// Parse an env/wire name; `None` for anything unrecognized.
    pub fn parse(s: &str) -> Option<KernelKind> {
        match s {
            "scalar" => Some(KernelKind::Scalar),
            "tiled" => Some(KernelKind::Tiled),
            "swar" => Some(KernelKind::Swar),
            "avx2" => Some(KernelKind::Avx2),
            "neon" => Some(KernelKind::Neon),
            _ => None,
        }
    }

    /// Whether this kernel can run on the current CPU.  The portable
    /// tiers are always available; the SIMD tiers require their arch
    /// and (on x86_64) a positive `is_x86_feature_detected!` probe.
    pub fn available(self) -> bool {
        match self {
            KernelKind::Scalar | KernelKind::Tiled | KernelKind::Swar => true,
            KernelKind::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::is_x86_feature_detected!("avx2")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            KernelKind::Neon => {
                #[cfg(target_arch = "aarch64")]
                {
                    std::arch::is_aarch64_feature_detected!("neon")
                }
                #[cfg(not(target_arch = "aarch64"))]
                {
                    false
                }
            }
        }
    }
}

/// Best available kernel for this CPU (no env override applied).
pub fn detect() -> KernelKind {
    if KernelKind::Avx2.available() {
        KernelKind::Avx2
    } else if KernelKind::Neon.available() {
        KernelKind::Neon
    } else {
        // portable best: tiling pays on every CPU, and the SWAR tier
        // only beats plain `count_ones` where hardware popcount is
        // slow/emulated — benchmark before promoting it (see
        // benches/ablation_microkernel.rs)
        KernelKind::Tiled
    }
}

/// Resolve an optional override string against availability: a known,
/// available kernel wins; anything else falls back to [`detect`].
pub fn resolve(over: Option<&str>) -> KernelKind {
    match over.and_then(KernelKind::parse) {
        Some(k) if k.available() => k,
        _ => detect(),
    }
}

/// The kernel serving this call: [`KERNEL_ENV`] override, else [`detect`].
pub fn current() -> KernelKind {
    resolve(std::env::var(KERNEL_ENV).ok().as_deref())
}

/// Serialize tests that set [`KERNEL_ENV`] (process-global state), in
/// the same shape as the loader's corrupt-plan env guard.  Poisoning is
/// ignored: a failed test already reported; later tests still need the
/// exclusion.
#[cfg(test)]
pub(crate) fn kernel_env_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip_and_unknowns_refuse() {
        for k in KernelKind::ALL {
            assert_eq!(KernelKind::parse(k.name()), Some(k));
        }
        for bad in ["", "AVX2", "sse", "scalar "] {
            assert_eq!(KernelKind::parse(bad), None, "{bad:?} must not parse");
        }
    }

    #[test]
    fn portable_kernels_are_always_available() {
        for k in [KernelKind::Scalar, KernelKind::Tiled, KernelKind::Swar] {
            assert!(k.available(), "{} must be available everywhere", k.name());
        }
    }

    #[test]
    fn detect_returns_an_available_kernel() {
        assert!(detect().available());
    }

    #[test]
    fn resolve_prefers_an_available_override_and_falls_back_otherwise() {
        // portable overrides always win
        assert_eq!(resolve(Some("scalar")), KernelKind::Scalar);
        assert_eq!(resolve(Some("swar")), KernelKind::Swar);
        // unknown / empty overrides fall back to detection
        assert_eq!(resolve(Some("turbo")), detect());
        assert_eq!(resolve(None), detect());
        // a SIMD override resolves to itself iff available, else detect()
        for k in [KernelKind::Avx2, KernelKind::Neon] {
            let want = if k.available() { k } else { detect() };
            assert_eq!(resolve(Some(k.name())), want);
        }
    }

    #[test]
    fn current_honours_the_env_override() {
        let env = kernel_env_guard();
        std::env::set_var(KERNEL_ENV, "scalar");
        assert_eq!(current(), KernelKind::Scalar);
        std::env::set_var(KERNEL_ENV, "not-a-kernel");
        assert_eq!(current(), detect());
        std::env::remove_var(KERNEL_ENV);
        assert_eq!(current(), detect());
        drop(env);
    }
}
