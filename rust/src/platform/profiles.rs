//! Platform profiles for the three GPUs of the paper's testbed.
//!
//! Numbers are public datasheet figures (peak fp32, memory bandwidth)
//! plus two modelled parameters: integer-op throughput (fp32 rate x the
//! architecture's int32 issue ratio) and on-chip-memory effectiveness
//! (1.0 where shared/local memory is real SRAM; 0.0 on Mali where OpenCL
//! local memory is allocated in system DRAM — the paper's explanation
//! for the small Mali speedup).

use super::Profile;

/// Nvidia GTX 1080 (Pascal GP104): 8.87 TFLOP/s fp32, 320 GB/s GDDR5X.
/// Pascal issues 32-bit integer logic at roughly the fp32 rate; popcount
/// runs on the SFU-adjacent path, modelled inside the 0.75 factor.
pub const GTX1080: Profile = Profile {
    name: "GTX 1080",
    fp32_gflops: 8870.0,
    int_gops: 8870.0 * 0.75,
    dram_gbps: 320.0,
    onchip_gbps: 6000.0,
    onchip_effectiveness: 1.0,
    launch_overhead_us: 3.0,
};

/// ARM Mali T860 MP4 (Midgard, 650 MHz): ~94 GFLOP/s fp32, ~10 GB/s LPDDR.
/// Crucially, OpenCL local memory is a region of global memory, so the
/// shared-memory tiling the kernels rely on buys nothing: effectiveness 0.
pub const MALI_T860: Profile = Profile {
    name: "Mali T860",
    fp32_gflops: 94.0,
    int_gops: 94.0 * 0.9, // Midgard SIMD issues int ops near fp rate
    dram_gbps: 10.0,
    onchip_gbps: 10.0, // "local" memory IS dram
    onchip_effectiveness: 0.0,
    launch_overhead_us: 40.0,
};

/// Nvidia Tegra X2 (Pascal, 2 SM @ 1.3 GHz): ~665 GFLOP/s fp32,
/// 58 GB/s LPDDR4 (shared with the CPU). Real on-chip shared memory.
pub const TEGRA_X2: Profile = Profile {
    name: "Tegra X2",
    fp32_gflops: 665.0,
    int_gops: 665.0 * 0.75,
    dram_gbps: 58.0,
    onchip_gbps: 1300.0,
    onchip_effectiveness: 1.0,
    launch_overhead_us: 8.0,
};

/// All paper platforms, in Table 1 column order.
pub const ALL: [Profile; 3] = [GTX1080, MALI_T860, TEGRA_X2];

// ---------------------------------------------------------------------------
// serving-host lane sizing
// ---------------------------------------------------------------------------

/// Hard cap on auto-selected executors per lane.  Past this point
/// executors stop overlapping batch formation with execution and start
/// fighting the engine's own data-parallel workers for cores (the
/// `benches/ablation_executors.rs` curve flattens well before 8 on
/// typical hosts).
pub const MAX_AUTO_EXECUTORS: usize = 8;

/// Recommended batched workers per lane for a serving host with `cores`
/// logical CPUs serving `lanes` model variants.
///
/// Rationale: one executor per lane serializes the coordinator — while a
/// batch executes, newly admitted requests just queue (the FINN
/// observation that BNN serving throughput is a dataflow/scheduling
/// problem, not only a kernel problem).  A second executor lets batch
/// formation overlap execution; beyond that, extra executors only help
/// while spare cores exist, because `EngineBackend` already
/// data-parallelizes each batch across its worker threads.  So: spend
/// about half the cores on cross-batch concurrency, split across lanes,
/// clamped to `1..=MAX_AUTO_EXECUTORS`.
///
/// Used by `repro serve --executors 0` (the auto default); any explicit
/// `--executors N` overrides it.
pub fn recommended_executors(cores: usize, lanes: usize) -> usize {
    (cores / (2 * lanes.max(1))).clamp(1, MAX_AUTO_EXECUTORS)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recommended_executors_is_sane() {
        // always at least one, even on tiny hosts or absurd lane counts
        assert_eq!(recommended_executors(1, 1), 1);
        assert_eq!(recommended_executors(4, 100), 1);
        // half the cores for a single lane, split across lanes
        assert_eq!(recommended_executors(8, 1), 4);
        assert_eq!(recommended_executors(8, 2), 2);
        assert_eq!(recommended_executors(16, 4), 2);
        // capped: a 128-core host doesn't get 64 executors on one lane
        assert_eq!(recommended_executors(128, 1), MAX_AUTO_EXECUTORS);
        // monotone in cores for a fixed lane count
        for lanes in 1..4 {
            let mut prev = 0;
            for cores in 1..64 {
                let e = recommended_executors(cores, lanes);
                assert!(e >= prev, "cores {cores} lanes {lanes}: {e} < {prev}");
                prev = e;
            }
        }
    }

    #[test]
    fn profiles_have_sane_orderings() {
        assert!(GTX1080.fp32_gflops > TEGRA_X2.fp32_gflops);
        assert!(TEGRA_X2.fp32_gflops > MALI_T860.fp32_gflops);
        assert!(GTX1080.dram_gbps > TEGRA_X2.dram_gbps);
        assert_eq!(MALI_T860.onchip_effectiveness, 0.0);
        assert_eq!(GTX1080.onchip_effectiveness, 1.0);
    }
}
