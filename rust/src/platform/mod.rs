//! Analytical GPU platform model — the substitution for the paper's
//! physical testbed (GTX 1080, Mali T860, Tegra X2; DESIGN.md §2).
//!
//! The paper's cross-platform claims are *ratios*: binarized vs
//! full-precision speedup per platform, and the observation that Mali
//! gains least because its "local memory" is just global memory.  We
//! model each kernel as the max of its compute time and memory time
//! (roofline) on a per-platform profile, with an on-chip-memory
//! effectiveness factor that captures exactly the Mali caveat.

pub mod dispatch;
pub mod profiles;

/// Static description of a GPU platform.
#[derive(Debug, Clone, Copy)]
pub struct Profile {
    pub name: &'static str,
    /// Peak fp32 multiply-add throughput, GFLOP/s (2 flops per FMA).
    pub fp32_gflops: f64,
    /// Peak 32-bit integer/logic op throughput, Gop/s (xor, popcount,
    /// shift each count as one op).
    pub int_gops: f64,
    /// DRAM bandwidth, GB/s.
    pub dram_gbps: f64,
    /// On-chip (shared/local) memory bandwidth, GB/s.
    pub onchip_gbps: f64,
    /// Fraction of ideal on-chip reuse the platform actually delivers
    /// (1.0 = true on-chip local memory; Mali's local memory lives in
    /// DRAM so reuse buys nothing: 0.0).
    pub onchip_effectiveness: f64,
    /// Fixed per-kernel launch overhead, microseconds.
    pub launch_overhead_us: f64,
}

/// Work performed by one kernel invocation.
#[derive(Debug, Clone, Copy)]
pub struct KernelWork {
    /// Floating-point operations (0 for binarized kernels).
    pub flops: f64,
    /// 32-bit integer/logic operations (0 for float kernels).
    pub int_ops: f64,
    /// Bytes that must cross DRAM assuming perfect on-chip reuse.
    pub dram_bytes_min: f64,
    /// Bytes that cross DRAM with *no* reuse (every access goes out).
    pub dram_bytes_no_reuse: f64,
    /// Whether the kernel's reuse depends on shared/local memory tiling.
    /// The paper's binarized kernels do ("we heavily take advantage of
    /// local memory"); the vendor float libraries (cuDNN / ARM CL) reach
    /// their reuse through register blocking and stay near
    /// `dram_bytes_min` even on Mali.
    pub reuse_needs_onchip: bool,
}

impl Profile {
    /// Roofline estimate for one kernel, in microseconds.
    pub fn kernel_time_us(&self, w: &KernelWork) -> f64 {
        let compute_s = w.flops / (self.fp32_gflops * 1e9) + w.int_ops / (self.int_gops * 1e9);
        // effective DRAM traffic: kernels that tile through local memory
        // degrade toward no-reuse on platforms whose local memory is fake
        let bytes = if w.reuse_needs_onchip {
            w.dram_bytes_min * self.onchip_effectiveness
                + w.dram_bytes_no_reuse * (1.0 - self.onchip_effectiveness)
        } else {
            w.dram_bytes_min
        };
        let mem_s = bytes / (self.dram_gbps * 1e9);
        compute_s.max(mem_s) * 1e6 + self.launch_overhead_us
    }

    /// Total estimate for a kernel sequence, microseconds.
    pub fn pipeline_time_us(&self, kernels: &[KernelWork]) -> f64 {
        kernels.iter().map(|k| self.kernel_time_us(k)).sum()
    }
}

/// Work models for every layer of the vehicle network (Table 2 rows),
/// full-precision and binarized variants.
pub mod workloads {
    use super::KernelWork;

    /// Explicit-GEMM conv, full precision: im2col + GEMM as two kernels.
    pub fn im2col_float(h: usize, w: usize, c: usize, k: usize) -> KernelWork {
        let patches = (h * w) as f64;
        let d = (k * k * c) as f64;
        KernelWork {
            flops: 0.0,
            int_ops: patches * d * 0.5, // index arithmetic
            dram_bytes_min: (h * w * c) as f64 * 4.0 + patches * d * 4.0,
            dram_bytes_no_reuse: patches * d * 8.0,
            reuse_needs_onchip: false,
        }
    }

    pub fn gemm_float(m: usize, n: usize, d: usize) -> KernelWork {
        let (m, n, d) = (m as f64, n as f64, d as f64);
        KernelWork {
            flops: 2.0 * m * n * d,
            int_ops: 0.0,
            dram_bytes_min: (m * d + n * d + m * n) * 4.0,
            dram_bytes_no_reuse: m * n * d * 8.0,
            reuse_needs_onchip: false,
        }
    }

    /// Fused binarized im2col+pack (Algorithm 1): D bit inserts per patch.
    pub fn im2col_pack(h: usize, w: usize, c: usize, k: usize, b: usize) -> KernelWork {
        let patches = (h * w) as f64;
        let d = (k * k * c) as f64;
        let words = (k * k * c).div_ceil(b) as f64;
        KernelWork {
            flops: 0.0,
            int_ops: patches * d * 2.0, // compare + shift-or per bit
            dram_bytes_min: (h * w * c) as f64 * 4.0 + patches * words * 4.0,
            dram_bytes_no_reuse: patches * d * 4.0 + patches * words * 4.0,
            reuse_needs_onchip: true,
        }
    }

    /// Packed xnor-popcount GEMM (Eq. 4).
    pub fn bgemm(m: usize, n: usize, kw: usize) -> KernelWork {
        let (m, n, kw) = (m as f64, n as f64, kw as f64);
        KernelWork {
            flops: 0.0,
            int_ops: 3.0 * m * n * kw, // xor + popcount + add per word
            dram_bytes_min: (m * kw + n * kw) * 4.0 + m * n * 4.0,
            dram_bytes_no_reuse: m * n * kw * 8.0,
            reuse_needs_onchip: true,
        }
    }

    pub fn maxpool_float(h: usize, w: usize, c: usize) -> KernelWork {
        let elems = (h * w * c) as f64;
        KernelWork {
            flops: elems, // one compare per input element
            int_ops: 0.0,
            dram_bytes_min: elems * 4.0 * 1.25,
            dram_bytes_no_reuse: elems * 4.0 * 2.0,
            reuse_needs_onchip: false,
        }
    }

    pub fn orpool_packed(h: usize, w: usize, nw: usize) -> KernelWork {
        let words = (h * w * nw) as f64;
        KernelWork {
            flops: 0.0,
            int_ops: words, // one OR per input word
            dram_bytes_min: words * 4.0 * 1.25,
            dram_bytes_no_reuse: words * 4.0 * 2.0,
            reuse_needs_onchip: true,
        }
    }

    pub fn fc_float(l: usize, d: usize) -> KernelWork {
        let (l, d) = (l as f64, d as f64);
        KernelWork {
            flops: 2.0 * l * d,
            int_ops: 0.0,
            // weights dominate and cannot be reused across a single sample
            dram_bytes_min: l * d * 4.0,
            dram_bytes_no_reuse: l * d * 8.0,
            reuse_needs_onchip: false,
        }
    }

    pub fn fc_packed(l: usize, kw: usize) -> KernelWork {
        let (l, kw) = (l as f64, kw as f64);
        KernelWork {
            flops: 0.0,
            int_ops: 3.0 * l * kw,
            dram_bytes_min: l * kw * 4.0,
            dram_bytes_no_reuse: l * kw * 8.0,
            reuse_needs_onchip: true,
        }
    }
}

/// Full-precision network as a kernel sequence (Table 2 rows).
pub fn float_network_workload() -> Vec<KernelWork> {
    use workloads as wl;
    vec![
        wl::im2col_float(96, 96, 3, 5),
        wl::gemm_float(9216, 32, 75),
        wl::maxpool_float(96, 96, 32),
        wl::im2col_float(48, 48, 32, 5),
        wl::gemm_float(2304, 32, 800),
        wl::maxpool_float(48, 48, 32),
        wl::fc_float(100, 18432),
    ]
}

/// Binarized network (packed kernels) as a kernel sequence.
pub fn binarized_network_workload() -> Vec<KernelWork> {
    use workloads as wl;
    vec![
        wl::im2col_pack(96, 96, 3, 5, 32),
        wl::bgemm(9216, 32, 3),
        wl::orpool_packed(96, 96, 1),
        wl::im2col_pack(48, 48, 32, 5, 32),
        wl::bgemm(2304, 32, 25),
        wl::orpool_packed(48, 48, 1),
        wl::fc_packed(100, 576),
    ]
}

/// Print the modelled Table 1 (runtime per platform, float vs binarized).
pub fn print_table1_projection() {
    let float = float_network_workload();
    let bin = binarized_network_workload();
    println!("analytical platform model (paper Table 1 projection)");
    println!(
        "{:<12}{:>18}{:>14}{:>10}",
        "platform", "full-precision", "binarized", "speedup"
    );
    for p in profiles::ALL {
        let f = p.pipeline_time_us(&float);
        let b = p.pipeline_time_us(&bin);
        let (fs, bs) = if f > 2000.0 {
            (format!("{:.2} ms", f / 1000.0), format!("{:.2} ms", b / 1000.0))
        } else {
            (format!("{f:.1} µs"), format!("{b:.1} µs"))
        };
        println!("{:<12}{:>18}{:>14}{:>9.1}x", p.name, fs, bs, f / b);
    }
    println!("\npaper Table 1: GTX1080 401.8µs -> 55.6µs (7.2x), Mali 29.6ms -> 17.6ms (1.7x),");
    println!("               Tegra X2 2.27ms -> 0.41ms (5.5x)");
}

#[cfg(test)]
mod tests {
    use super::profiles::*;
    use super::workloads as wl;
    use super::*;

    #[test]
    fn binarized_beats_float_on_every_platform() {
        for p in [GTX1080, MALI_T860, TEGRA_X2] {
            let float_us = p.pipeline_time_us(&float_network());
            let bin_us = p.pipeline_time_us(&binarized_network());
            assert!(
                bin_us < float_us,
                "{}: binarized {bin_us:.1}us !< float {float_us:.1}us",
                p.name
            );
        }
    }

    #[test]
    fn mali_gains_least_from_binarization() {
        // Table 1's qualitative claim: the Mali speedup (~1.7x) is far
        // below the desktop/Tegra speedups (5-7x) because its local
        // memory is not on-chip.
        let ratio = |p: &Profile| {
            p.pipeline_time_us(&float_network()) / p.pipeline_time_us(&binarized_network())
        };
        let g = ratio(&GTX1080);
        let m = ratio(&MALI_T860);
        let t = ratio(&TEGRA_X2);
        assert!(m < g && m < t, "mali ratio {m:.2} should be smallest (gtx {g:.2}, tegra {t:.2})");
    }

    #[test]
    fn gtx_is_fastest_platform() {
        let f = |p: &Profile| p.pipeline_time_us(&binarized_network());
        assert!(f(&GTX1080) < f(&TEGRA_X2));
        assert!(f(&TEGRA_X2) < f(&MALI_T860));
    }

    #[test]
    fn kernel_time_monotone_in_work() {
        let small = wl::gemm_float(100, 32, 75);
        let big = wl::gemm_float(1000, 32, 75);
        assert!(GTX1080.kernel_time_us(&big) > GTX1080.kernel_time_us(&small));
    }

    fn float_network() -> Vec<KernelWork> {
        super::float_network_workload()
    }

    fn binarized_network() -> Vec<KernelWork> {
        super::binarized_network_workload()
    }
}
