//! PJRT execution: compile one model's HLO text, upload its weights once,
//! serve `infer` calls.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so a
//! `ModelRuntime` lives on one thread; the coordinator wraps it in a
//! dedicated executor thread (`coordinator::backend::RuntimeBackend`).

use std::path::Path;

// In a build with the real PJRT bindings this alias points at the `xla`
// crate; the offline build uses the API-compatible stub (see xla_stub.rs).
use crate::runtime::xla_stub as xla;

use crate::runtime::artifact::{ArgDType, ArgSpec, Artifacts, LayerSpec, ModelSpec};
use crate::util::tensorio::TensorFile;

#[derive(Debug)]
pub enum RuntimeError {
    Xla(String),
    MissingWeight(String),
    InputShape { got: usize, want: usize },
    Tensor(crate::util::tensorio::TensorIoError),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Xla(msg) => write!(f, "xla: {msg}"),
            RuntimeError::MissingWeight(n) => {
                write!(f, "runtime: weight tensor {n:?} missing from container")
            }
            RuntimeError::InputShape { got, want } => {
                write!(f, "runtime: input has {got} elements, model expects {want}")
            }
            RuntimeError::Tensor(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for RuntimeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RuntimeError::Tensor(e) => Some(e),
            _ => None,
        }
    }
}

impl From<crate::util::tensorio::TensorIoError> for RuntimeError {
    fn from(e: crate::util::tensorio::TensorIoError) -> Self {
        RuntimeError::Tensor(e)
    }
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

/// Shared PJRT CPU client (create once, clone per model).
pub fn cpu_client() -> Result<xla::PjRtClient, RuntimeError> {
    Ok(xla::PjRtClient::cpu()?)
}

/// Compile an HLO-text file on a client.
pub fn compile_hlo(
    client: &xla::PjRtClient,
    path: impl AsRef<Path>,
) -> Result<xla::PjRtLoadedExecutable, RuntimeError> {
    let proto = xla::HloModuleProto::from_text_file(path.as_ref().to_str().unwrap())?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

fn upload_tensor(
    client: &xla::PjRtClient,
    tf: &TensorFile,
    spec: &ArgSpec,
) -> Result<xla::PjRtBuffer, RuntimeError> {
    if !tf.contains(&spec.name) {
        return Err(RuntimeError::MissingWeight(spec.name.clone()));
    }
    let buf = match spec.dtype {
        ArgDType::F32 => {
            let v = tf.f32(&spec.name)?;
            client.buffer_from_host_buffer(&v, &spec.shape, None)?
        }
        ArgDType::U32 => {
            let v = tf.u32(&spec.name)?;
            client.buffer_from_host_buffer(&v, &spec.shape, None)?
        }
        ArgDType::I32 => {
            let v = tf.i32(&spec.name)?;
            client.buffer_from_host_buffer(&v, &spec.shape, None)?
        }
    };
    Ok(buf)
}

/// One compiled model + its resident weight buffers.
pub struct ModelRuntime {
    pub spec: ModelSpec,
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    weights: Vec<xla::PjRtBuffer>,
}

impl ModelRuntime {
    /// Compile `model_name` from `artifacts` and upload its weights.
    pub fn load(
        client: &xla::PjRtClient,
        artifacts: &Artifacts,
        model_name: &str,
    ) -> Result<Self, RuntimeError> {
        let spec = artifacts
            .model(model_name)
            .map_err(|e| RuntimeError::Xla(e.to_string()))?
            .clone();
        let exe = compile_hlo(client, artifacts.path_of(&spec.file))?;
        let tf = TensorFile::load(artifacts.path_of(&spec.weights_file))?;
        let weights = spec
            .weight_args
            .iter()
            .map(|a| upload_tensor(client, &tf, a))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Self { spec, client: client.clone(), exe, weights })
    }

    /// Elements expected in one input (batch included).
    pub fn input_elements(&self) -> usize {
        self.spec.input.elements()
    }

    /// Logits per sample (4 for this network).
    pub fn output_elements(&self) -> usize {
        self.spec.output_shape.iter().product()
    }

    /// Run one inference; `image` must match the artifact's input shape
    /// (e.g. 96*96*3 for batch-1 models, batch*96*96*3 otherwise).
    /// Returns the flattened logits.
    pub fn infer(&self, image: &[f32]) -> Result<Vec<f32>, RuntimeError> {
        if image.len() != self.input_elements() {
            return Err(RuntimeError::InputShape {
                got: image.len(),
                want: self.input_elements(),
            });
        }
        let x = self.client.buffer_from_host_buffer(image, &self.spec.input.shape, None)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weights.len());
        args.push(&x);
        args.extend(self.weights.iter());
        let result = self.exe.execute_b(&args)?;
        let lit = result[0][0].to_literal_sync()?;
        let out = lit.to_tuple1()?; // aot.py lowers with return_tuple=True
        Ok(out.to_vec::<f32>()?)
    }
}

/// A compiled per-layer kernel (Table 2 benches): inputs are generated by
/// the bench harness, uploaded once, and executed repeatedly.
pub struct LayerRuntime {
    pub spec: LayerSpec,
    exe: xla::PjRtLoadedExecutable,
    args: Vec<xla::PjRtBuffer>,
}

impl LayerRuntime {
    /// Compile a layer artifact and upload the given argument buffers.
    /// `fill` produces the flat data for argument `i` (as raw f32/u32).
    pub fn load(
        client: &xla::PjRtClient,
        artifacts: &Artifacts,
        layer_name: &str,
        mut fill: impl FnMut(usize, &ArgSpec) -> LayerArg,
    ) -> Result<Self, RuntimeError> {
        let spec = artifacts
            .layer(layer_name)
            .map_err(|e| RuntimeError::Xla(e.to_string()))?
            .clone();
        let exe = compile_hlo(client, artifacts.path_of(&spec.file))?;
        let mut args = Vec::with_capacity(spec.args.len());
        for (i, a) in spec.args.iter().enumerate() {
            let buf = match fill(i, a) {
                LayerArg::F32(v) => client.buffer_from_host_buffer(&v, &a.shape, None)?,
                LayerArg::U32(v) => client.buffer_from_host_buffer(&v, &a.shape, None)?,
                LayerArg::I32(v) => client.buffer_from_host_buffer(&v, &a.shape, None)?,
            };
            args.push(buf);
        }
        Ok(Self { spec, exe, args })
    }

    /// Execute with the resident argument buffers; result is discarded
    /// after materialization (benches measure kernel time).
    pub fn run(&self) -> Result<(), RuntimeError> {
        let args: Vec<&xla::PjRtBuffer> = self.args.iter().collect();
        let result = self.exe.execute_b(&args)?;
        // force completion so the timing is honest
        let _ = result[0][0].to_literal_sync()?;
        Ok(())
    }

    /// Execute and return the first output as a flat vector (tests).
    pub fn run_to_vec<T: xla::ArrayElement>(&self) -> Result<Vec<T>, RuntimeError> {
        let args: Vec<&xla::PjRtBuffer> = self.args.iter().collect();
        let result = self.exe.execute_b(&args)?;
        let lit = result[0][0].to_literal_sync()?;
        let out = lit.to_tuple1()?;
        Ok(out.to_vec::<T>()?)
    }
}

/// Flat argument payload for a layer artifact.
pub enum LayerArg {
    F32(Vec<f32>),
    U32(Vec<u32>),
    I32(Vec<i32>),
}

impl LayerArg {
    /// Random data of the right dtype/size for an arg spec.
    pub fn random(spec: &ArgSpec, rng: &mut crate::util::rng::Xoshiro256) -> Self {
        let n = spec.elements();
        match spec.dtype {
            ArgDType::F32 => LayerArg::F32((0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()),
            ArgDType::U32 => LayerArg::U32((0..n).map(|_| rng.next_u32()).collect()),
            ArgDType::I32 => LayerArg::I32((0..n).map(|_| rng.next_u32() as i32 % 100).collect()),
        }
    }
}
