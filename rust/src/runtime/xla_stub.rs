//! Compile-time stand-in for the `xla` crate (PJRT bindings).
//!
//! The offline build has no XLA shared library, so the runtime path is
//! represented by this API-compatible stub: every entry point that would
//! touch PJRT returns [`Error::Unavailable`].  `runtime/client.rs`
//! aliases this module as `xla`; dropping the real `xla` crate into the
//! dependency set and flipping that alias restores the real runtime with
//! no other code changes.  All callers already treat runtime construction
//! as fallible (artifacts may be absent), so the stub degrades into the
//! same "runtime backend unavailable" error path.

/// Error type mirroring `xla::Error` (Display + Debug only).
#[derive(Debug)]
pub enum Error {
    /// The build carries no PJRT runtime.
    Unavailable,
}

crate::error_enum_impls!(Error {
    Error::Unavailable => (
        "PJRT/XLA runtime not available in this build (stubbed; link the `xla` crate to enable)"
    ),
});

/// Element types the runtime can transfer (mirrors `xla::ArrayElement`).
pub trait ArrayElement: Copy {}
impl ArrayElement for f32 {}
impl ArrayElement for u32 {}
impl ArrayElement for i32 {}

/// Stub of `xla::PjRtClient` — construction always fails.
#[derive(Clone)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        Err(Error::Unavailable)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::Unavailable)
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        Err(Error::Unavailable)
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        Err(Error::Unavailable)
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::Unavailable)
    }
}

/// Stub of `xla::PjRtBuffer`.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::Unavailable)
    }
}

/// Stub of `xla::Literal`.
pub struct Literal(());

impl Literal {
    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(Error::Unavailable)
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>, Error> {
        Err(Error::Unavailable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("not available"));
    }

    #[test]
    fn hlo_load_reports_unavailable() {
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo.txt").is_err());
    }
}
