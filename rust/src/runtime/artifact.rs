//! Manifest parsing: the index of everything `python/compile/aot.py`
//! exported (models, per-layer kernels, weight/test containers).

use std::path::{Path, PathBuf};

use crate::util::json::{Json, JsonError};

#[derive(Debug)]
pub enum ArtifactError {
    Io(std::io::Error),
    Json(JsonError),
    ModelNotFound(String, String),
    LayerNotFound(String),
    BadDType(String),
    /// A structurally-valid JSON manifest with semantically-invalid
    /// contents (bad version number, malformed checksum, bad name).
    BadManifest(String),
}

crate::error_enum_impls!(ArtifactError {
    ArtifactError::Io(e) => ("artifact io: {e}"),
    ArtifactError::Json(e) => ("{e}"),
    ArtifactError::ModelNotFound(name, avail) =>
        ("manifest: model {name:?} not found (available: {avail})"),
    ArtifactError::LayerNotFound(name) => ("manifest: layer {name:?} not found"),
    ArtifactError::BadDType(d) => ("manifest: unsupported dtype {d:?}"),
    ArtifactError::BadManifest(why) => ("manifest: {why}"),
}
source {
    ArtifactError::Io(e) => e,
    ArtifactError::Json(e) => e,
}
from {
    std::io::Error => ArtifactError::Io,
    JsonError => ArtifactError::Json,
});

/// Element dtype of a runtime argument.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArgDType {
    F32,
    I32,
    U32,
}

impl ArgDType {
    fn parse(s: &str) -> Result<Self, ArtifactError> {
        Ok(match s {
            "f32" => ArgDType::F32,
            "i32" => ArgDType::I32,
            "u32" => ArgDType::U32,
            other => return Err(ArtifactError::BadDType(other.to_string())),
        })
    }
}

/// One runtime argument (name + dtype + shape).
#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub dtype: ArgDType,
    pub shape: Vec<usize>,
}

impl ArgSpec {
    fn parse(j: &Json, default_name: &str) -> Result<Self, ArtifactError> {
        let name = match j.get_opt("name")? {
            Some(n) => n.as_str()?.to_string(),
            None => default_name.to_string(),
        };
        let dtype = ArgDType::parse(j.get("dtype")?.as_str()?)?;
        let shape = j
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Self { name, dtype, shape })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// An end-to-end model artifact.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub file: String,
    /// "float" | "bcnn_pallas" | "bcnn_ref"
    pub kind: String,
    pub scheme: String,
    pub batch: usize,
    pub weights_file: String,
    pub input: ArgSpec,
    pub weight_args: Vec<ArgSpec>,
    pub output_shape: Vec<usize>,
}

/// A per-layer kernel artifact (Table 2 benches).
#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub name: String,
    pub file: String,
    pub args: Vec<ArgSpec>,
}

/// Parsed manifest + base directory.
pub struct Artifacts {
    pub dir: PathBuf,
    pub classes: Vec<String>,
    pub models: Vec<ModelSpec>,
    pub layers: Vec<LayerSpec>,
    /// scheme -> whether trained weights were baked (vs random init)
    pub trained: Vec<(String, bool)>,
    pub testset_file: Option<String>,
    pub expected_logits_file: Option<String>,
}

impl Artifacts {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self, ArtifactError> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let j = Json::parse(&text)?;

        let classes = j
            .get("classes")?
            .as_arr()?
            .iter()
            .map(|c| Ok(c.as_str()?.to_string()))
            .collect::<Result<Vec<_>, JsonError>>()?;

        let mut models = Vec::new();
        for m in j.get("models")?.as_arr()? {
            models.push(ModelSpec {
                name: m.get("name")?.as_str()?.to_string(),
                file: m.get("file")?.as_str()?.to_string(),
                kind: m.get("kind")?.as_str()?.to_string(),
                scheme: m.get("scheme")?.as_str()?.to_string(),
                batch: m.get("batch")?.as_usize()?,
                weights_file: m.get("weights_file")?.as_str()?.to_string(),
                input: ArgSpec::parse(m.get("input")?, "x")?,
                weight_args: m
                    .get("weight_args")?
                    .as_arr()?
                    .iter()
                    .map(|a| ArgSpec::parse(a, "?"))
                    .collect::<Result<Vec<_>, _>>()?,
                output_shape: m
                    .get("output")?
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<Vec<_>, _>>()?,
            });
        }

        let mut layers = Vec::new();
        for l in j.get("layers")?.as_arr()? {
            layers.push(LayerSpec {
                name: l.get("name")?.as_str()?.to_string(),
                file: l.get("file")?.as_str()?.to_string(),
                args: l
                    .get("args")?
                    .as_arr()?
                    .iter()
                    .enumerate()
                    .map(|(i, a)| ArgSpec::parse(a, &format!("arg{i}")))
                    .collect::<Result<Vec<_>, _>>()?,
            });
        }

        let mut trained = Vec::new();
        if let Some(t) = j.get_opt("trained")? {
            for (k, v) in t.as_obj()?.iter() {
                trained.push((k.clone(), v.as_bool().unwrap_or(false)));
            }
        }

        let testset_file = match j.get_opt("testset")? {
            Some(t) => Some(t.get("file")?.as_str()?.to_string()),
            None => None,
        };
        let expected_logits_file = match j.get_opt("expected_logits")? {
            Some(t) => Some(t.get("file")?.as_str()?.to_string()),
            None => None,
        };

        Ok(Self { dir, classes, models, layers, trained, testset_file, expected_logits_file })
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec, ArtifactError> {
        self.models.iter().find(|m| m.name == name).ok_or_else(|| {
            ArtifactError::ModelNotFound(
                name.to_string(),
                self.models.iter().map(|m| m.name.as_str()).collect::<Vec<_>>().join(", "),
            )
        })
    }

    pub fn layer(&self, name: &str) -> Result<&LayerSpec, ArtifactError> {
        self.layers
            .iter()
            .find(|l| l.name == name)
            .ok_or_else(|| ArtifactError::LayerNotFound(name.to_string()))
    }

    pub fn path_of(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    pub fn testset_path(&self) -> Option<PathBuf> {
        self.testset_file.as_ref().map(|f| self.dir.join(f))
    }

    pub fn expected_logits_path(&self) -> Option<PathBuf> {
        self.expected_logits_file.as_ref().map(|f| self.dir.join(f))
    }
}

// ---------------------------------------------------------------------------
// registry manifest (`registry.json`)
// ---------------------------------------------------------------------------

/// Per-model batch-policy overrides declared in `registry.json`
/// (`"batch": {"max_images": N, "executors": N}`).  Absent fields fall
/// back to the registry's shared [`crate::coordinator::BatchPolicy`],
/// so one hot entry can run a deeper batcher or a wider executor pool
/// without touching its neighbours.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RegistryBatchSpec {
    /// Lane `max_batch` override (≥ 1).
    pub max_images: Option<usize>,
    /// Lane executor-pool width override (1..=64, the CLI's cap).
    pub executors: Option<usize>,
}

/// One versioned, servable model in a registry directory.
///
/// Unlike [`ModelSpec`] (which indexes AOT HLO artifacts for the PJRT
/// path), a registry entry names a weight container the engine loads
/// directly, plus the identity the serving plane exposes:
/// `name@version`, the binarization scheme, and the checksum the loader
/// verifies before the entry can be published.
#[derive(Debug, Clone)]
pub struct RegistryEntrySpec {
    pub name: String,
    pub version: u32,
    /// `"bcnn"` (packed engine) or `"float"` (full-precision baseline).
    /// With an `arch` present the graph defines execution and `kind`
    /// becomes descriptive metadata (any label is accepted).
    pub kind: String,
    /// Input-binarization scheme for `bcnn` entries
    /// (`none|rgb|gray|lbp`); `"float"` for float entries.  Metadata
    /// only when `arch` is present (the graph carries its own scheme).
    pub scheme: String,
    pub weights_file: String,
    /// `fnv1a64:<16 hex digits>` over the raw bytes of `weights_file`
    /// (see `registry::fnv1a64`).  Verified on every load.
    pub checksum: String,
    /// Optional layer-graph declaration (`"arch": [{"op": ...}, ...]`).
    /// Absent → the loader synthesizes the legacy 2-conv/2-fc spec from
    /// `kind`/`scheme`.  Stored as raw JSON here (structurally checked:
    /// non-empty array of `{"op": ...}` objects); full shape inference
    /// happens in `bnn::graph` at load time, and the compiled plan must
    /// then pass `bnn::graph::verify_plan` (aliasing/dataflow/extent/
    /// weight proofs) before the loader will publish the entry.
    pub arch: Option<Json>,
    /// Optional per-model batch-policy overrides.
    pub batch: Option<RegistryBatchSpec>,
}

impl RegistryEntrySpec {
    /// The serving key, `name@version`.
    pub fn key(&self) -> String {
        format!("{}@{}", self.name, self.version)
    }
}

/// Parsed `<dir>/registry.json`: the catalog of model versions the
/// serving registry may load at startup or via the `load_model` admin
/// op.  Shape:
///
/// ```text
/// {"version": 1,
///  "default": "bcnn",
///  "models": [
///    {"name": "bcnn", "version": 1, "kind": "bcnn", "scheme": "rgb",
///     "weights_file": "weights_bcnn_rgb.bcnt",
///     "checksum": "fnv1a64:89abcdef01234567",
///     "batch": {"max_images": 16, "executors": 2},         // optional
///     "arch": [{"op": "binarize", "scheme": "rgb"}, ...]}, // optional
///    ...]}
/// ```
pub struct RegistryManifest {
    pub dir: PathBuf,
    /// Model *name* to serve when the client names none.
    pub default_model: Option<String>,
    pub entries: Vec<RegistryEntrySpec>,
}

impl RegistryManifest {
    /// Load `<dir>/registry.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self, ArtifactError> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("registry.json"))?;
        let j = Json::parse(&text)?;
        let default_model = match j.get_opt("default")? {
            Some(d) => Some(d.as_str()?.to_string()),
            None => None,
        };
        let mut entries = Vec::new();
        for m in j.get("models")?.as_arr()? {
            let name = m.get("name")?.as_str()?.to_string();
            if name.is_empty() || name.contains('@') || name.contains(char::is_whitespace) {
                return Err(ArtifactError::BadManifest(format!(
                    "model name {name:?} must be non-empty with no '@' or whitespace"
                )));
            }
            let version = m.get("version")?.as_usize()?;
            let version = u32::try_from(version).map_err(|_| {
                ArtifactError::BadManifest(format!("version {version} of {name:?} exceeds u32"))
            })?;
            if version == 0 {
                return Err(ArtifactError::BadManifest(format!(
                    "version of {name:?} must be >= 1"
                )));
            }
            let arch = match m.get_opt("arch")? {
                Some(a) => {
                    let arr = a.as_arr()?;
                    if arr.is_empty() {
                        return Err(ArtifactError::BadManifest(format!(
                            "arch of {name:?} is an empty array"
                        )));
                    }
                    // structural check only; the graph compiler does full
                    // shape inference when the entry actually loads
                    for (oi, op) in arr.iter().enumerate() {
                        op.get("op").and_then(|o| o.as_str()).map_err(|e| {
                            ArtifactError::BadManifest(format!(
                                "arch[{oi}] of {name:?} needs an \"op\" string: {e}"
                            ))
                        })?;
                    }
                    Some(a.clone())
                }
                None => None,
            };
            let batch = match m.get_opt("batch")? {
                Some(b) => {
                    let field = |key: &str| -> Result<Option<usize>, ArtifactError> {
                        Ok(match b.get_opt(key)? {
                            Some(v) => Some(v.as_usize()?),
                            None => None,
                        })
                    };
                    let max_images = field("max_images")?;
                    let executors = field("executors")?;
                    if max_images == Some(0) {
                        return Err(ArtifactError::BadManifest(format!(
                            "batch.max_images of {name:?} must be >= 1"
                        )));
                    }
                    if matches!(executors, Some(e) if e == 0 || e > 64) {
                        return Err(ArtifactError::BadManifest(format!(
                            "batch.executors of {name:?} must be in 1..=64"
                        )));
                    }
                    Some(RegistryBatchSpec { max_images, executors })
                }
                None => None,
            };
            entries.push(RegistryEntrySpec {
                name,
                version,
                kind: m.get("kind")?.as_str()?.to_string(),
                scheme: m.get("scheme")?.as_str()?.to_string(),
                weights_file: m.get("weights_file")?.as_str()?.to_string(),
                checksum: m.get("checksum")?.as_str()?.to_string(),
                arch,
                batch,
            });
        }
        Ok(Self { dir, default_model, entries })
    }

    pub fn entry(&self, name: &str, version: u32) -> Result<&RegistryEntrySpec, ArtifactError> {
        self.entries
            .iter()
            .find(|e| e.name == name && e.version == version)
            .ok_or_else(|| {
                ArtifactError::ModelNotFound(
                    format!("{name}@{version}"),
                    self.entries.iter().map(|e| e.key()).collect::<Vec<_>>().join(", "),
                )
            })
    }

    pub fn path_of(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI_MANIFEST: &str = r#"{
      "version": 1,
      "classes": ["bus", "normal", "truck", "van"],
      "models": [
        {
          "name": "model_bcnn_rgb_b1",
          "file": "model_bcnn_rgb_b1.hlo.txt",
          "kind": "bcnn_pallas",
          "scheme": "rgb",
          "batch": 1,
          "weights_file": "weights_bcnn_rgb.bcnt",
          "input": {"name": "x", "dtype": "f32", "shape": [96, 96, 3]},
          "weight_args": [
            {"name": "w1_packed", "dtype": "u32", "shape": [32, 3]}
          ],
          "output": {"dtype": "f32", "shape": [4]}
        }
      ],
      "layers": [
        {
          "name": "layer_bgemm1",
          "file": "layer_bgemm1.hlo.txt",
          "args": [
            {"dtype": "u32", "shape": [9216, 3]},
            {"dtype": "u32", "shape": [32, 3]}
          ]
        }
      ],
      "trained": {"float": false, "rgb": true},
      "testset": {"file": "testset.bcnt", "count": 656}
    }"#;

    fn write_manifest() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("bcnn-artifact-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), MINI_MANIFEST).unwrap();
        dir
    }

    #[test]
    fn parses_mini_manifest() {
        let dir = write_manifest();
        let a = Artifacts::load(&dir).unwrap();
        assert_eq!(a.classes, vec!["bus", "normal", "truck", "van"]);
        let m = a.model("model_bcnn_rgb_b1").unwrap();
        assert_eq!(m.batch, 1);
        assert_eq!(m.input.shape, vec![96, 96, 3]);
        assert_eq!(m.weight_args.len(), 1);
        assert_eq!(m.weight_args[0].dtype, ArgDType::U32);
        let l = a.layer("layer_bgemm1").unwrap();
        assert_eq!(l.args[0].elements(), 9216 * 3);
        assert_eq!(a.trained, vec![("float".to_string(), false), ("rgb".to_string(), true)]);
        assert!(a.testset_path().unwrap().ends_with("testset.bcnt"));
    }

    #[test]
    fn unknown_model_lists_available() {
        let dir = write_manifest();
        let a = Artifacts::load(&dir).unwrap();
        let err = a.model("nope").unwrap_err();
        assert!(err.to_string().contains("model_bcnn_rgb_b1"));
    }

    const MINI_REGISTRY: &str = r#"{
      "version": 1,
      "default": "bcnn",
      "models": [
        {"name": "bcnn", "version": 1, "kind": "bcnn", "scheme": "rgb",
         "weights_file": "weights_bcnn_rgb.bcnt",
         "checksum": "fnv1a64:0011223344556677"},
        {"name": "float", "version": 1, "kind": "float", "scheme": "float",
         "weights_file": "weights_float.bcnt",
         "checksum": "fnv1a64:8899aabbccddeeff"}
      ]
    }"#;

    fn write_registry(body: &str, tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("bcnn-registry-manifest-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("registry.json"), body).unwrap();
        dir
    }

    #[test]
    fn parses_registry_manifest() {
        let dir = write_registry(MINI_REGISTRY, "ok");
        let r = RegistryManifest::load(&dir).unwrap();
        assert_eq!(r.default_model.as_deref(), Some("bcnn"));
        assert_eq!(r.entries.len(), 2);
        let e = r.entry("bcnn", 1).unwrap();
        assert_eq!(e.key(), "bcnn@1");
        assert_eq!(e.scheme, "rgb");
        assert!(e.checksum.starts_with("fnv1a64:"));
        assert!(r.path_of(&e.weights_file).ends_with("weights_bcnn_rgb.bcnt"));
        let err = r.entry("bcnn", 9).unwrap_err();
        assert!(err.to_string().contains("bcnn@1"), "{err}");
    }

    #[test]
    fn registry_manifest_parses_arch_and_batch_extensions() {
        let body = r#"{"models":[
          {"name": "deep", "version": 1, "kind": "bcnn", "scheme": "gray",
           "weights_file": "deep.bcnt", "checksum": "fnv1a64:0000000000000001",
           "batch": {"max_images": 16, "executors": 2},
           "arch": [{"op": "binarize", "scheme": "gray"},
                    {"op": "conv_bin", "k": 5, "out": 32}]},
          {"name": "plain", "version": 1, "kind": "bcnn", "scheme": "rgb",
           "weights_file": "plain.bcnt", "checksum": "fnv1a64:0000000000000002"}
        ]}"#;
        let dir = write_registry(body, "arch-batch");
        let r = RegistryManifest::load(&dir).unwrap();
        let deep = r.entry("deep", 1).unwrap();
        assert_eq!(
            deep.batch,
            Some(RegistryBatchSpec { max_images: Some(16), executors: Some(2) })
        );
        let arch = deep.arch.as_ref().unwrap().as_arr().unwrap();
        assert_eq!(arch.len(), 2);
        assert_eq!(arch[1].get("op").unwrap().as_str().unwrap(), "conv_bin");
        // absent extensions stay absent (legacy entries untouched)
        let plain = r.entry("plain", 1).unwrap();
        assert!(plain.arch.is_none() && plain.batch.is_none());
    }

    #[test]
    fn registry_manifest_rejects_bad_arch_and_batch() {
        let entry = |extra: &str| {
            format!(
                r#"{{"models":[{{"name": "m", "version": 1, "kind": "bcnn",
                 "scheme": "rgb", "weights_file": "w", "checksum": "c"{extra}}}]}}"#
            )
        };
        for (tag, extra) in [
            ("empty-arch", r#", "arch": []"#),
            ("opless-arch", r#", "arch": [{"k": 5}]"#),
            ("arch-not-array", r#", "arch": {"op": "orpool"}"#),
            ("zero-batch", r#", "batch": {"max_images": 0}"#),
            ("zero-executors", r#", "batch": {"executors": 0}"#),
            ("huge-executors", r#", "batch": {"executors": 65}"#),
        ] {
            let dir = write_registry(&entry(extra), tag);
            let err = RegistryManifest::load(&dir).unwrap_err();
            assert!(
                matches!(err, ArtifactError::BadManifest(_) | ArtifactError::Json(_)),
                "{tag}: {err}"
            );
        }
    }

    #[test]
    fn registry_manifest_rejects_bad_names_and_versions() {
        for (tag, body) in [
            ("atname", r#"{"models":[{"name":"a@b","version":1,"kind":"bcnn","scheme":"rgb","weights_file":"w","checksum":"c"}]}"#),
            ("emptyname", r#"{"models":[{"name":"","version":1,"kind":"bcnn","scheme":"rgb","weights_file":"w","checksum":"c"}]}"#),
            ("zerover", r#"{"models":[{"name":"a","version":0,"kind":"bcnn","scheme":"rgb","weights_file":"w","checksum":"c"}]}"#),
        ] {
            let dir = write_registry(body, tag);
            let err = RegistryManifest::load(&dir).unwrap_err();
            assert!(matches!(err, ArtifactError::BadManifest(_)), "{tag}: {err}");
        }
    }
}
