//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! `Artifacts` reads `artifacts/manifest.json`; `ModelRuntime` compiles
//! one model's HLO on the CPU PJRT client, uploads its weight buffers
//! once, and serves `infer` calls with only the input image crossing the
//! host boundary per request.

pub mod artifact;
pub mod client;
pub mod xla_stub;

pub use artifact::{
    ArtifactError, Artifacts, LayerSpec, ModelSpec, RegistryBatchSpec, RegistryEntrySpec,
    RegistryManifest,
};
pub use client::{ModelRuntime, RuntimeError};
