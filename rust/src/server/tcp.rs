//! TCP listener: one line-JSON session per connection, handled on a
//! fixed thread pool, requests routed through the coordinator.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use super::protocol::{Request, Response};
use crate::coordinator::Router;
use crate::dataset::synth;
use crate::util::threadpool::ThreadPool;

/// The serving front end.
pub struct Server {
    router: Arc<Router>,
    classes: Vec<String>,
    synth_seed: u64,
}

impl Server {
    pub fn new(router: Arc<Router>, classes: Vec<String>) -> Self {
        Self { router, classes, synth_seed: synth::DEFAULT_SEED }
    }

    /// Handle one already-parsed request (also used by unit tests and the
    /// in-process CLI path — no socket required).
    pub fn handle(&self, req: Request) -> Response {
        match req {
            Request::Ping => Response::Pong,
            Request::Variants => Response::Variants(self.router.variants()),
            Request::Stats => Response::Stats(self.router.stats()),
            Request::Classify { model, pixels } => self.classify(&model, pixels),
            Request::ClassifySynth { model, index } => {
                let sample = synth::render_vehicle(index, self.synth_seed);
                self.classify(&model, sample.image)
            }
        }
    }

    fn classify(&self, model: &str, pixels: Vec<f32>) -> Response {
        match self.router.infer_blocking(model, pixels) {
            Ok(resp) => {
                if let Some(err) = resp.error {
                    return Response::Error(err);
                }
                Response::Classified {
                    class: resp.class,
                    label: self
                        .classes
                        .get(resp.class)
                        .cloned()
                        .unwrap_or_else(|| "?".to_string()),
                    logits: resp.logits,
                    queue_us: resp.queue_time.as_nanos() as f64 / 1_000.0,
                    exec_us: resp.exec_time.as_nanos() as f64 / 1_000.0,
                    batch: resp.batch_size,
                }
            }
            Err(e) => Response::Error(e.to_string()),
        }
    }

    fn session(&self, stream: TcpStream) {
        let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
        log::info!("session open: {peer}");
        let mut writer = match stream.try_clone() {
            Ok(w) => w,
            Err(_) => return,
        };
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            let line = match line {
                Ok(l) => l,
                Err(_) => break,
            };
            if line.trim().is_empty() {
                continue;
            }
            let resp = match Request::parse(&line) {
                Ok(req) => self.handle(req),
                Err(e) => Response::Error(e),
            };
            let mut out = resp.to_json_line();
            out.push('\n');
            if writer.write_all(out.as_bytes()).is_err() {
                break;
            }
        }
        log::info!("session closed: {peer}");
    }

    /// Bind and serve until `stop` flips (or forever).  Returns the bound
    /// address once listening.
    pub fn serve(
        self: Arc<Self>,
        addr: &str,
        threads: usize,
        stop: Arc<AtomicBool>,
    ) -> std::io::Result<std::net::SocketAddr> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let pool = ThreadPool::new(threads, "server");
        std::thread::Builder::new().name("acceptor".into()).spawn(move || {
            loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let _ = stream.set_nonblocking(false);
                        let me = Arc::clone(&self);
                        pool.execute(move || me.session(stream));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        })?;
        Ok(local)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::network::tests_support::synth_bcnn_network;
    use crate::coordinator::{EngineBackend, InferBackend, Router};
    use crate::input::binarize::Scheme;

    fn test_server() -> Arc<Server> {
        let be: Arc<dyn InferBackend> =
            Arc::new(EngineBackend::bcnn(synth_bcnn_network(Scheme::Rgb, 5), 2));
        let router = Arc::new(Router::builder().variant("bcnn_rgb", be).build());
        Arc::new(Server::new(
            router,
            vec!["bus".into(), "normal".into(), "truck".into(), "van".into()],
        ))
    }

    #[test]
    fn handle_ping_and_variants() {
        let s = test_server();
        assert!(matches!(s.handle(Request::Ping), Response::Pong));
        match s.handle(Request::Variants) {
            Response::Variants(v) => assert_eq!(v, vec!["bcnn_rgb"]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn handle_classify_synth() {
        let s = test_server();
        match s.handle(Request::ClassifySynth { model: "".into(), index: 3 }) {
            Response::Classified { class, label, logits, batch, .. } => {
                assert!(class < 4);
                assert!(["bus", "normal", "truck", "van"].contains(&label.as_str()));
                assert_eq!(logits.len(), 4);
                assert_eq!(batch, 1);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn handle_bad_model() {
        let s = test_server();
        match s.handle(Request::ClassifySynth { model: "bogus".into(), index: 0 }) {
            Response::Error(e) => assert!(e.contains("bcnn_rgb")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn tcp_end_to_end() {
        let s = test_server();
        let stop = Arc::new(AtomicBool::new(false));
        let addr = Arc::clone(&s).serve("127.0.0.1:0", 2, Arc::clone(&stop)).unwrap();
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(b"{\"op\":\"classify_synth\",\"index\":1}\n{\"op\":\"stats\"}\n")
            .unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\": true") || line.contains("\"ok\":true"), "{line}");
        assert!(line.contains("label"));
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("stats"));
        stop.store(true, Ordering::Relaxed);
    }
}
